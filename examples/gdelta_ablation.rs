//! Ablation of the pre-rounding gain factor G_δ (the Fig. 11 scenario),
//! reporting admissions, utility and rounding-attempt statistics per G_δ.
//!
//! ```bash
//! cargo run --release --example gdelta_ablation
//! ```

use dmlrs::cluster::AllocLedger;
use dmlrs::sched::solver::GdeltaMode;
use dmlrs::sched::{PdOrs, PdOrsConfig, PricingParams};
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

fn main() {
    let horizon = 20;
    // contended: few machines per job, so packing violations at G_δ > 1 bind
    let cluster = paper_cluster(12);
    let mut rng = Rng::new(99);
    let jobs = synthetic_jobs(&SynthConfig::paper(25, horizon, MIX_DEFAULT), &mut rng);
    // pricing depends only on (jobs, cluster, horizon): one estimate
    // serves every G_δ variant below
    let pricing = PricingParams::from_jobs(&jobs, &cluster, horizon);

    println!("== G_delta ablation: 12 machines, 25 jobs, T = 20 ==\n");
    println!(
        "{:>8} {:>9} {:>14} {:>18}",
        "G_delta", "admitted", "total_utility", "avg_round_attempts"
    );
    for g in [0.2, 0.4, 0.6, 0.8, 1.0, 1.2] {
        let cfg = PdOrsConfig {
            gdelta: GdeltaMode::Fixed(g),
            // the paper's 5000-attempt budget before discarding
            attempts: 5000,
            ..Default::default()
        };
        let mut sched = PdOrs::with_pricing(cfg, pricing.clone(), &cluster);
        let mut ledger = AllocLedger::new(&cluster, horizon);
        for job in &jobs {
            sched.on_arrival(job, &mut ledger);
        }
        let admitted = sched.log.iter().filter(|a| a.admitted).count();
        let avg_attempts = sched
            .log
            .iter()
            .map(|a| a.rounding_attempts as f64)
            .sum::<f64>()
            / sched.log.len() as f64;
        println!(
            "{g:>8.1} {admitted:>9} {:>14.2} {avg_attempts:>18.1}",
            sched.total_utility()
        );
    }
    println!(
        "\nexpected shape (paper Fig. 11): utility peaks at G_delta = 1.0;\n\
         small G_delta starves the cover constraint (more failed roundings),\n\
         large G_delta overshoots capacity (packing violations)."
    );
}
