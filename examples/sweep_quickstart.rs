//! Sweep quickstart: declare a 3-scheduler × 2-cluster × 3-seed scenario
//! matrix, run it on all cores through the work-stealing sweep runner,
//! persist per-cell JSONL results, and aggregate them.
//!
//! ```bash
//! cargo run --release --example sweep_quickstart
//! ```
//!
//! Run it twice: the second run finds every cell already in the store and
//! skips straight to the summary (resumable sweeps).

use dmlrs::sweep::{run_matrix, ClusterSpec, ResultStore, ScenarioMatrix, SweepSpec, WorkloadSpec};
use dmlrs::util::Timer;

fn main() {
    // The matrix: schedulers × (workload, cluster) columns × seeds.
    // Each cell is self-contained — its own deterministic RNG stream —
    // so cells run on any thread in any order with identical metrics.
    let matrix = ScenarioMatrix::new()
        .schedulers(&["pd-ors", "fifo", "drf"])
        .workload(WorkloadSpec::synthetic(20, 15, 100))
        .cluster(ClusterSpec::homogeneous(10)) // paper-style homogeneous
        .cluster(ClusterSpec::skewed(10, 2.0)) // quarter big 2x, quarter small 0.5x
        .seeds(3);
    println!(
        "== sweep quickstart: {} cells on {} workers ==",
        matrix.len(),
        SweepSpec::available_parallelism()
    );

    // One JSON line per completed cell; cells already on disk are skipped.
    let mut store =
        ResultStore::open("results/sweep_quickstart.jsonl").expect("open result store");

    let timer = Timer::start();
    let outcomes =
        run_matrix(&matrix, 0 /* auto */, Some(&mut store)).expect("run the matrix");
    let ran = outcomes.iter().filter(|o| !o.cached).count();

    for o in &outcomes {
        println!(
            "{:<8} {:<24} seed {}  utility {:>9.2}  completed {:>2}/{:<2} {:>7.1} ms{}",
            o.record.scheduler,
            o.record.cluster,
            o.record.seed,
            o.record.total_utility,
            o.record.completed,
            o.record.jobs,
            o.record.wall_secs * 1e3,
            if o.cached { "  (cached)" } else { "" }
        );
    }

    println!("\n-- mean over seeds, per scheduler x cluster --");
    for row in store.summary() {
        println!(
            "{:<8} {:<24} seeds {}  mean utility {:>9.2}  mean completed {:>4.1}",
            row.scheduler, row.cluster, row.seeds, row.mean_utility, row.mean_completed
        );
    }
    println!(
        "\n== {} cells ({ran} ran, {} cached) in {:.3}s; results in {} ==",
        outcomes.len(),
        outcomes.len() - ran,
        timer.elapsed_secs(),
        store.path().display()
    );
}
