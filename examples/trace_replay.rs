//! Trace replay: the Google-trace-style workload through the full
//! scheduler zoo (the Fig. 12/13 scenario as a single run), with every
//! policy resolved by name from the registry.
//!
//! ```bash
//! cargo run --release --example trace_replay -- [jobs] [machines] [horizon]
//! ```

use dmlrs::sched::registry::{SchedulerRegistry, ZOO};
use dmlrs::sim::metrics::median_training_time;
use dmlrs::sim::SimEngine;
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{google_trace_jobs, MIX_TRACE};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let jobs_n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(60);
    let machines: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    let horizon: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(60);

    let mut rng = Rng::new(2024);
    let jobs = google_trace_jobs(jobs_n, horizon, MIX_TRACE, &mut rng);
    let cluster = paper_cluster(machines);

    println!(
        "== trace replay: {jobs_n} jobs (mix 30/69/1), {machines} machines, T = {horizon} =="
    );
    println!(
        "\narrivals: {:?} ...",
        jobs.iter().take(16).map(|j| j.arrival).collect::<Vec<_>>()
    );

    println!(
        "\n{:<8} {:>14} {:>9} {:>10} {:>13}",
        "sched", "total_utility", "admitted", "completed", "median_time"
    );
    let registry = SchedulerRegistry::builtin();
    let mut best = (String::new(), f64::NEG_INFINITY);
    for key in ZOO {
        let mut sched = registry
            .build_named(key, 0, &jobs, &cluster, horizon)
            .expect("built-in scheduler");
        let res = SimEngine::builder()
            .jobs(&jobs)
            .cluster(&cluster)
            .horizon(horizon)
            .run(sched.as_mut());
        println!(
            "{:<8} {:>14.2} {:>9} {:>10} {:>13.1}",
            res.scheduler,
            res.total_utility,
            res.admitted,
            res.completed,
            median_training_time(&res)
        );
        if res.total_utility > best.1 {
            best = (res.scheduler.clone(), res.total_utility);
        }
    }
    println!("\nwinner: {} ({:.2})", best.0, best.1);
}
