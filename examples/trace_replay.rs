//! Trace replay: the Google-trace-style workload through the full
//! scheduler zoo (the Fig. 12/13 scenario as a single run).
//!
//! ```bash
//! cargo run --release --example trace_replay -- [jobs] [machines] [horizon]
//! ```

use dmlrs::experiments::SchedulerKind;
use dmlrs::sim::metrics::median_training_time;
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{google_trace_jobs, MIX_TRACE};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let jobs_n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(60);
    let machines: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    let horizon: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(60);

    let mut rng = Rng::new(2024);
    let jobs = google_trace_jobs(jobs_n, horizon, MIX_TRACE, &mut rng);
    let cluster = paper_cluster(machines);

    println!(
        "== trace replay: {jobs_n} jobs (mix 30/69/1), {machines} machines, T = {horizon} =="
    );
    println!(
        "\narrivals: {:?} ...",
        jobs.iter().take(16).map(|j| j.arrival).collect::<Vec<_>>()
    );

    println!(
        "\n{:<8} {:>14} {:>9} {:>10} {:>13}",
        "sched", "total_utility", "admitted", "completed", "median_time"
    );
    let mut best = ("", f64::NEG_INFINITY);
    let mut results = Vec::new();
    for kind in SchedulerKind::ALL {
        let res = kind.run(&jobs, &cluster, horizon, 0);
        println!(
            "{:<8} {:>14.2} {:>9} {:>10} {:>13.1}",
            res.scheduler,
            res.total_utility,
            res.admitted,
            res.completed,
            median_training_time(&res)
        );
        if res.total_utility > best.1 {
            best = (kind.name(), res.total_utility);
        }
        results.push(res);
    }
    println!("\nwinner: {} ({:.2})", best.0, best.1);
}
