//! Quickstart: schedule a small workload with PD-ORS and inspect the
//! decisions.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dmlrs::cluster::AllocLedger;
use dmlrs::jobs::speed::{per_worker_rate, Locality};
use dmlrs::sched::{PdOrs, PdOrsConfig};
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

fn main() {
    // A 24-machine cluster (EC2 C5n-class capacities) and 12 jobs drawn
    // from the paper's synthetic distribution, over a 20-slot horizon.
    let horizon = 20;
    let cluster = paper_cluster(24);
    let mut rng = Rng::new(21);
    let jobs = synthetic_jobs(&SynthConfig::paper(12, horizon, MIX_DEFAULT), &mut rng);

    // PD-ORS estimates its price constants from the job population.
    let mut sched = PdOrs::new(PdOrsConfig::default(), &jobs, &cluster, horizon);
    let mut ledger = AllocLedger::new(&cluster, horizon);

    println!("== PD-ORS quickstart: 24 machines, 12 jobs, T = 20 ==\n");
    println!(
        "pricing: L = {:.3e}, epsilon = {:.2}",
        sched.pricing().l,
        sched.pricing().epsilon()
    );

    for job in &jobs {
        println!(
            "\njob {:2}  arrives t={:2}  E*K = {:.1e} samples  F = {:3}  gamma = {}",
            job.id,
            job.arrival,
            job.total_workload(),
            job.batch,
            job.gamma
        );
        println!(
            "        rate/worker: internal {:.0} vs external {:.0} samples/slot",
            per_worker_rate(job, Locality::Internal),
            per_worker_rate(job, Locality::External)
        );
        match sched.on_arrival(job, &mut ledger) {
            Some(s) => {
                let done = s.completion_time().unwrap();
                println!(
                    "  ADMITTED: {} slots, completes t={done}, utility {:.2}",
                    s.slots.len(),
                    job.utility_at(done)
                );
                for slot in s.slots.iter().take(3) {
                    println!("    t={:2} placements {:?}", slot.t, slot.placements);
                }
                if s.slots.len() > 3 {
                    println!("    ... {} more slots", s.slots.len() - 3);
                }
            }
            None => println!("  rejected (infeasible within horizon or payoff <= 0)"),
        }
    }

    let admitted = sched.log.iter().filter(|a| a.admitted).count();
    println!(
        "\n== total: {}/{} admitted, utility {:.2} ==",
        admitted,
        jobs.len(),
        sched.total_utility()
    );
    assert!(ledger.within_capacity(1e-6), "capacity invariant violated");
}
