//! Quickstart: resolve a scheduler from the registry, run it through the
//! event-driven engine, and inspect the decisions via observers.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dmlrs::jobs::speed::{per_worker_rate, Locality};
use dmlrs::sched::registry::{SchedulerRegistry, SchedulerSpec};
use dmlrs::sim::{SimEngine, StreamingMetrics, TraceObserver};
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

fn main() {
    // A 24-machine cluster (EC2 C5n-class capacities) and 12 jobs drawn
    // from the paper's synthetic distribution, over a 20-slot horizon.
    let horizon = 20;
    let cluster = paper_cluster(24);
    let mut rng = Rng::new(21);
    let jobs = synthetic_jobs(&SynthConfig::paper(12, horizon, MIX_DEFAULT), &mut rng);

    // Schedulers are registry entries resolved by name — swap "pd-ors"
    // for "oasis" / "fifo" / "drf" / "dorm" (or anything you register).
    let registry = SchedulerRegistry::builtin();
    let spec = SchedulerSpec::new("pd-ors").with_seed(0);
    let mut sched = registry
        .build(&spec, &jobs, &cluster, horizon)
        .expect("pd-ors is a built-in scheduler");

    println!("== quickstart: 24 machines, 12 jobs, T = 20 ==");
    println!(
        "scheduler: {} ({})\n",
        sched.name(),
        registry.description("pd-ors").unwrap()
    );
    for job in &jobs {
        println!(
            "job {:2}  arrives t={:2}  E*K = {:.1e} samples  F = {:3}  gamma = {}  \
             rate/worker int {:.0} / ext {:.0}",
            job.id,
            job.arrival,
            job.total_workload(),
            job.batch,
            job.gamma,
            per_worker_rate(job, Locality::Internal),
            per_worker_rate(job, Locality::External)
        );
    }

    // The engine emits typed events (Arrival, Admitted/Rejected, Granted,
    // Completed, ...) to any observer; result aggregation itself is one.
    let mut trace = TraceObserver::new();
    let mut metrics = StreamingMetrics::new();
    let result = SimEngine::builder()
        .jobs(&jobs)
        .cluster(&cluster)
        .horizon(horizon)
        .observer(&mut trace)
        .observer(&mut metrics)
        .run(sched.as_mut());

    println!("\n-- event trace --");
    for line in trace.lines() {
        // slot-start lines are noisy; show the decisions
        if !line.contains("slot start") {
            println!("{line}");
        }
    }

    println!("\n-- outcomes --");
    for o in &result.outcomes {
        println!(
            "job {:2}  admitted={} completed={} completion={:?} utility={:.2}",
            o.job_id, o.admitted as u8, o.completed as u8, o.completion, o.utility
        );
    }
    println!(
        "\n== total: {}/{} admitted, {} completed, utility {:.2} \
         (streamed: {} arrivals, {} grants) ==",
        result.admitted,
        jobs.len(),
        result.completed,
        result.total_utility,
        metrics.arrivals,
        metrics.grants
    );
    assert_eq!(metrics.admitted, result.admitted, "observer/aggregate agreement");
}
