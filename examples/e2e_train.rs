//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! PD-ORS (L3, rust) admits and schedules a training job; the schedule is
//! then *executed* — every BSP iteration runs the AOT-compiled JAX model
//! (L2) whose GEMM/attention/SGD hot-spots are Pallas kernels (L1) —
//! against synthetic Markov token data, and the loss curve is logged.
//!
//! ```bash
//! cargo run --release --example e2e_train -- [size] [steps]
//! # default: small (~470k params), 300 steps
//! ```
//!
//! The run recorded in EXPERIMENTS.md uses the default arguments.

use dmlrs::cluster::{AllocLedger, ResVec};
use dmlrs::exec::{execute_schedule, ExecConfig};
use dmlrs::jobs::Sigmoid;
use dmlrs::runtime::{ModelBundle, XlaRuntime};
use dmlrs::sched::{PdOrs, PdOrsConfig};
use dmlrs::util::{Rng, Timer};
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

fn main() -> dmlrs::util::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size = args.first().map(|s| s.as_str()).unwrap_or("small").to_string();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    let rt = XlaRuntime::cpu()?;
    let t_load = Timer::start();
    let bundle = ModelBundle::load(&rt, "artifacts", &size)?;
    println!(
        "loaded lm_{size}: {} params ({}-layer path), compile {:.1}s",
        bundle.meta.num_params,
        bundle.meta.files.len(),
        t_load.elapsed_secs()
    );

    // L3: schedule the job. Its analytical parameters mirror the model.
    let horizon = 20;
    let cluster = paper_cluster(8);
    let mut rng = Rng::new(7);
    let mut jobs = synthetic_jobs(&SynthConfig::paper(1, horizon, MIX_DEFAULT), &mut rng);
    {
        let job = &mut jobs[0];
        job.arrival = 0;
        job.grad_size_mb = bundle.meta.num_params as f64 * 4.0 / 1e6;
        // F = 4: at most 4 concurrent workers — every scheduled worker
        // runs a *real* gradient computation per BSP iteration on the one
        // CPU PJRT device, so the worker group is kept small.
        job.batch = 4;
        job.gamma = 4.0;
        job.tau = 5e-5;
        job.epochs = 10;
        job.samples = (job.batch as f64 / job.tau) * 5.0 / job.epochs as f64;
        job.worker_demand = ResVec::new([1.0, 2.0, 4.0, 2.0]);
        job.ps_demand = ResVec::new([0.0, 2.0, 4.0, 2.0]);
        job.utility = Sigmoid { theta1: 80.0, theta2: 0.3, theta3: 12.0 };
    }
    let mut pdors = PdOrs::new(PdOrsConfig::default(), &jobs, &cluster, horizon);
    let mut ledger = AllocLedger::new(&cluster, horizon);
    let schedule = pdors
        .on_arrival(&jobs[0], &mut ledger)
        .expect("PD-ORS should admit the sized job");
    println!(
        "PD-ORS schedule: {} slots, completes t={}, payoff {:.2}",
        schedule.slots.len(),
        schedule.completion_time().unwrap(),
        pdors.log.last().unwrap().payoff
    );

    // Execute: spread `steps` BSP iterations over the scheduled slots.
    let per_slot = steps.div_ceil(schedule.slots.len().max(1)).max(1);
    let cfg = ExecConfig { max_iters_per_slot: per_slot, eval_each_slot: true, seed: 7 };
    let report = execute_schedule(&bundle, &jobs[0], &schedule, &cfg)?;

    println!("\nslot  workers ps  locality  iters  mean_loss  wall");
    for s in &report.slots {
        println!(
            "t={:3}  {:6} {:3}  {:>8}  {:5}  {:9.4}  {:.1}s",
            s.t,
            s.workers,
            s.ps,
            format!("{:?}", s.locality),
            s.iterations,
            s.mean_loss,
            s.wall_secs
        );
    }

    // Loss curve (downsampled print; full curve to file).
    let n = report.losses.len();
    println!("\nloss curve ({n} BSP steps):");
    for (i, chunk) in report.losses.chunks((n / 12).max(1)).enumerate() {
        let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  step {:4}: {:.4}", i * (n / 12).max(1), mean);
    }
    let mut curve = String::from("step\tloss\n");
    for (i, l) in report.losses.iter().enumerate() {
        curve.push_str(&format!("{i}\t{l}\n"));
    }
    std::fs::create_dir_all("results").ok();
    let path = format!("results/e2e_loss_{size}.tsv");
    std::fs::write(&path, curve)?;
    println!("\nwrote {path}");
    println!(
        "first {:.4} -> last {:.4} over {} steps, {} samples, wall {:.1}s",
        report.losses.first().unwrap(),
        report.losses.last().unwrap(),
        n,
        report.total_samples,
        report.total_wall_secs
    );
    if let (Some(first), Some(last)) = (report.eval_losses.first(), report.eval_losses.last()) {
        println!("held-out eval: {first:.4} -> {last:.4}");
    }
    assert!(
        report.losses.last().unwrap() < report.losses.first().unwrap(),
        "training must reduce the loss"
    );
    Ok(())
}
