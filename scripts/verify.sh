#!/usr/bin/env bash
# Tier-1 verification: release build + test suite (+ a formatting check).
#
#   scripts/verify.sh
#
# Run from anywhere; operates on the rust/ crate. The fmt check is
# advisory (the offline toolchain image may lack the rustfmt component);
# build + test failures are fatal.

set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check (advisory) =="
if command -v cargo-fmt >/dev/null 2>&1 || cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check || echo "warning: rustfmt differences (non-fatal)"
else
    echo "rustfmt unavailable; skipping"
fi

echo "== cargo clippy (advisory here; CI runs it with -D warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings || echo "warning: clippy findings (fatal in CI)"
else
    echo "clippy unavailable; skipping"
fi

echo "== sweep bench (quick matrix, serial vs parallel) =="
# Wall-time the quick scenario matrix at --jobs 1 vs all cores and emit
# BENCH_sweep.json at the repo root (the bench trajectory data point).
BIN=target/release/dmlrs
PAR=$( (command -v nproc >/dev/null 2>&1 && nproc) || echo 2 )
SERIAL_OUT=target/bench_sweep_serial.jsonl
PAR_OUT=target/bench_sweep_parallel.jsonl
rm -f "$SERIAL_OUT" "$PAR_OUT"
# The sweep command prints "sweep: ... elapsed=<secs>s ..." itself —
# parse that (portable; GNU date's sub-second %N is not).
elapsed_of() { awk '/^sweep: /{sub(/.*elapsed=/,""); sub(/s .*/,""); print}'; }
SERIAL_SECS=$("$BIN" sweep --quick --jobs 1 --out "$SERIAL_OUT" | elapsed_of)
PAR_SECS=$("$BIN" sweep --quick --jobs "$PAR" --out "$PAR_OUT" | elapsed_of)
CELLS=$(wc -l < "$SERIAL_OUT" | tr -d ' ')
awk -v serial="$SERIAL_SECS" -v parallel="$PAR_SECS" -v par="$PAR" -v cells="$CELLS" 'BEGIN {
    speedup = (parallel > 0) ? serial / parallel : 0;
    printf "{\"bench\": \"sweep_quick_matrix\", \"cells\": %d, \"serial_secs\": %.3f, \"parallel_secs\": %.3f, \"parallel_jobs\": %d, \"speedup\": %.2f}\n", cells, serial, parallel, par, speedup;
}' > ../BENCH_sweep.json
cat ../BENCH_sweep.json
rm -f "$SERIAL_OUT" "$PAR_OUT"

echo "== solver bench (Fig. 6 quick, theta-cache vs parity oracle) =="
# Time the quick Fig. 6 run cached vs --no-theta-cache and emit
# BENCH_solver.json (wall time + the θ-solve / memo-hit counters the
# figure prints as its '# solver: ...' note). The experiment command
# prints 'experiment: fig=6 elapsed=<secs>s' itself.
CACHED_LOG=$("$BIN" experiment --fig 6 --quick)
UNCACHED_LOG=$("$BIN" experiment --fig 6 --quick --no-theta-cache)
secs_of() { awk '/^# experiment: /{sub(/.*elapsed=/,""); sub(/s$/,""); print}'; }
field_of() { awk -v f="$1" '/^# solver:/{n=split($0,a," "); for(i=1;i<=n;i++){if(index(a[i],f"=")==1){sub(f"=","",a[i]); print a[i]; exit}}}'; }
CACHED_SECS=$(printf '%s\n' "$CACHED_LOG" | secs_of)
UNCACHED_SECS=$(printf '%s\n' "$UNCACHED_LOG" | secs_of)
THETA_SOLVES=$(printf '%s\n' "$CACHED_LOG" | field_of theta_solves)
MEMO_HITS=$(printf '%s\n' "$CACHED_LOG" | field_of memo_hits)
UNCACHED_HITS=$(printf '%s\n' "$UNCACHED_LOG" | field_of memo_hits)
awk -v cached="$CACHED_SECS" -v uncached="$UNCACHED_SECS" \
    -v theta="$THETA_SOLVES" -v hits="$MEMO_HITS" -v uhits="$UNCACHED_HITS" 'BEGIN {
    speedup = (cached > 0) ? uncached / cached : 0;
    printf "{\"bench\": \"fig6_quick_solver\", \"cached_secs\": %.3f, \"uncached_secs\": %.3f, \"speedup\": %.2f, \"theta_solves\": %d, \"memo_hits\": %d, \"uncached_memo_hits\": %d}\n", cached, uncached, speedup, theta, hits, uhits;
}' > ../BENCH_solver.json
cat ../BENCH_solver.json
if [ "${MEMO_HITS:-0}" -eq 0 ]; then
    echo "error: cached Fig. 6 run recorded zero memo hits" >&2
    exit 1
fi
if [ "${UNCACHED_HITS:-0}" -ne 0 ]; then
    echo "error: --no-theta-cache run recorded memo hits" >&2
    exit 1
fi

echo "== service bench (1024-machine admission daemon + open-loop load + prom scrape) =="
# Boot the daemon at service scale (a 1024-machine ledger, the same
# cluster size as the admission bench below) on an ephemeral port (with
# the Prometheus HTTP exposition on a second ephemeral port), fire a
# quick load burst at it, scrape /metrics into PROM_snapshot.txt, then
# drain over the wire. Fails if the daemon does not come up, the report
# lacks the latency/throughput fields, or the exposition lacks the stage
# histogram / decision counters.
SERVE_LOG=target/serve_bench.log
rm -f ../BENCH_service.json ../PROM_snapshot.txt "$SERVE_LOG"
"$BIN" serve --addr 127.0.0.1:0 --prom-addr 127.0.0.1:0 \
    --machines 1024 --jobs 24 --horizon 12 --seed 1 \
    >"$SERVE_LOG" 2>&1 &
SERVE_PID=$!
ADDR=""
for _ in $(seq 1 100); do
    ADDR=$(awk '/listening on /{print $NF; exit}' "$SERVE_LOG" 2>/dev/null || true)
    [ -n "$ADDR" ] && break
    sleep 0.1
done
if [ -z "$ADDR" ]; then
    echo "error: admission daemon did not come up" >&2
    cat "$SERVE_LOG" >&2
    kill "$SERVE_PID" 2>/dev/null || true
    exit 1
fi
"$BIN" load --addr "$ADDR" --connections 4 --rate 400 \
    --jobs 24 --horizon 12 --seed 1 --bench-out ../BENCH_service.json
# Scrape the Prometheus endpoint (plain HTTP over bash's /dev/tcp) after
# the burst so the stage histograms and decision counters are non-empty.
PROM_URL=$(awk '/prometheus exposition at /{print $NF; exit}' "$SERVE_LOG")
if [ -z "$PROM_URL" ]; then
    echo "error: daemon did not announce the prometheus endpoint" >&2
    cat "$SERVE_LOG" >&2
    exit 1
fi
PROM_HP=${PROM_URL#http://}; PROM_HP=${PROM_HP%/metrics}
exec 3<>"/dev/tcp/${PROM_HP%:*}/${PROM_HP##*:}"
printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3
cat <&3 > ../PROM_snapshot.txt
exec 3<&- 3>&-
for want in 'dmlrs_stage_duration_us_bucket' 'dmlrs_stage_max_us' \
            'stage="admission_commit"' 'dmlrs_submitted_total' \
            'dmlrs_decisions_total{decision=' 'dmlrs_log_warnings_total'; do
    if ! grep -q "$want" ../PROM_snapshot.txt; then
        echo "error: PROM_snapshot.txt lacks $want" >&2
        cat ../PROM_snapshot.txt >&2
        exit 1
    fi
done
if grep -q 'dmlrs_submitted_total 0$' ../PROM_snapshot.txt; then
    echo "error: prom scrape saw zero submissions after the load burst" >&2
    exit 1
fi
echo "prom scrape OK ($(wc -l < ../PROM_snapshot.txt | tr -d ' ') exposition lines)"
# drain the daemon over the wire (the load run no longer does it, so the
# prom scrape above could observe the live counters)
exec 3<>"/dev/tcp/${ADDR%:*}/${ADDR##*:}"
printf '{"op":"shutdown"}\n' >&3
read -r _ <&3 || true
exec 3<&- 3>&-
wait "$SERVE_PID"
cat ../BENCH_service.json
for field in p99_ms p50_ms p95_ms p999_ms achieved_rate; do
    if ! grep -q "\"$field\":" ../BENCH_service.json; then
        echo "error: BENCH_service.json lacks $field" >&2
        exit 1
    fi
done

echo "== shard soak (1/2/4-cell daemon under 10k concurrent connections) =="
# The PR 10 scaling gate: the same open-loop burst — one job per
# connection, ~10k concurrent connections against the readiness-loop
# frontend — at 1, 2, and 4 shards. Sharding must buy admission
# throughput (the cells solve concurrently) without regressing the p99
# admission latency. Emits BENCH_shard.json.
ulimit -n 32768 2>/dev/null || true
NOFILE=$(ulimit -n)
SOAK_CONNS=10000
if [ "$NOFILE" != "unlimited" ] && [ "$NOFILE" -lt 10500 ]; then
    # leave headroom below the fd ceiling the environment actually grants
    SOAK_CONNS=$(( NOFILE > 600 ? NOFILE - 500 : 100 ))
    echo "note: fd limit $NOFILE caps the soak at $SOAK_CONNS connections"
fi
SHARD_LOG=target/serve_shard.log
shard_field() {
    awk -v f="\"$1\":" '{
        n = index($0, f);
        if (n) { s = substr($0, n + length(f)); sub(/[,}].*/, "", s); gsub(/[" ]/, "", s); print s; exit }
    }'
}
run_shard_soak() { # $1 = shards; sets SOAK_THR / SOAK_P99 / SOAK_FAILURES
    rm -f "$SHARD_LOG" target/bench_shard_run.json
    "$BIN" serve --addr 127.0.0.1:0 --machines 64 --jobs 64 --horizon 12 --seed 1 \
        --shards "$1" --batch 16 >"$SHARD_LOG" 2>&1 &
    local pid=$!
    local addr=""
    for _ in $(seq 1 100); do
        addr=$(awk '/listening on /{print $NF; exit}' "$SHARD_LOG" 2>/dev/null || true)
        [ -n "$addr" ] && break
        sleep 0.1
    done
    if [ -z "$addr" ]; then
        echo "error: $1-shard daemon did not come up" >&2
        cat "$SHARD_LOG" >&2
        kill "$pid" 2>/dev/null || true
        exit 1
    fi
    "$BIN" load --addr "$addr" --connections "$SOAK_CONNS" --rate 100000 \
        --jobs "$SOAK_CONNS" --horizon 12 --seed 1 --shutdown \
        --bench-out target/bench_shard_run.json >/dev/null
    wait "$pid"
    SOAK_THR=$(shard_field achieved_rate < target/bench_shard_run.json)
    SOAK_P99=$(shard_field p99_ms < target/bench_shard_run.json)
    SOAK_FAILURES=$(shard_field conn_failures < target/bench_shard_run.json)
}
run_shard_soak 1; THR1=$SOAK_THR; P99_1=$SOAK_P99; FAIL1=$SOAK_FAILURES
run_shard_soak 2; THR2=$SOAK_THR; P99_2=$SOAK_P99
run_shard_soak 4; THR4=$SOAK_THR; P99_4=$SOAK_P99
awk -v conns="$SOAK_CONNS" -v t1="$THR1" -v p1="$P99_1" -v t2="$THR2" -v p2="$P99_2" \
    -v t4="$THR4" -v p4="$P99_4" -v f1="$FAIL1" 'BEGIN {
    speedup = (t1 > 0) ? t4 / t1 : 0;
    printf "{\"bench\": \"shard_soak\", \"connections\": %d, \"machines\": 64, \"batch\": 16, \"conn_failures\": %d, \"thr_1\": %.1f, \"p99_ms_1\": %.3f, \"thr_2\": %.1f, \"p99_ms_2\": %.3f, \"thr_4\": %.1f, \"p99_ms_4\": %.3f, \"shard_speedup\": %.2f}\n", conns, f1, t1, p1, t2, p2, t4, p4, speedup;
}' > ../BENCH_shard.json
cat ../BENCH_shard.json
SHARD_SPEEDUP=$(shard_field shard_speedup < ../BENCH_shard.json)
# the scaling gate needs cores for the cells to run on; on a starved
# runner (< 4 cores) sharding can only interleave, so the bar drops
MIN_SPEEDUP=$(awk -v par="$PAR" 'BEGIN { print (par >= 4) ? 2.0 : 1.2 }')
if awk -v s="$SHARD_SPEEDUP" -v m="$MIN_SPEEDUP" 'BEGIN { exit !(s < m) }'; then
    echo "error: 4-shard throughput speedup $SHARD_SPEEDUP below ${MIN_SPEEDUP}x (thr $THR4 vs $THR1)" >&2
    exit 1
fi
# sharding must not regress the tail: 4-shard p99 within 10% of 1-shard
if awk -v p1="$P99_1" -v p4="$P99_4" 'BEGIN { exit !(p1 > 0 && p4 > 1.10 * p1) }'; then
    echo "error: 4-shard p99 ${P99_4}ms regressed beyond 1-shard ${P99_1}ms" >&2
    exit 1
fi
echo "shard soak OK ($SOAK_CONNS conns: ${THR1}/s -> ${THR4}/s, speedup ${SHARD_SPEEDUP}x, p99 ${P99_1}ms -> ${P99_4}ms)"

echo "== replan bench (diurnal quick sweep, replan on vs off) =="
# Run the quick primal-dual sweep on a churny diurnal workload with and
# without elastic re-planning and emit BENCH_replan.json. The replan run
# must actually move plans: zero replanned jobs across the whole matrix
# means the subsystem is wired off, which is a failure.
REPLAN_OFF=target/bench_replan_off.jsonl
REPLAN_ON=target/bench_replan_on.jsonl
rm -f "$REPLAN_OFF" "$REPLAN_ON"
"$BIN" sweep --quick --arrivals diurnal:4 --schedulers pd-ors,oasis --seeds 3 \
    --jobs "$PAR" --out "$REPLAN_OFF" >/dev/null
"$BIN" sweep --quick --arrivals diurnal:4 --schedulers pd-ors,oasis --seeds 3 \
    --replan every:2 --jobs "$PAR" --out "$REPLAN_ON" >/dev/null
# sum a numeric field over a JSONL file
sum_field() {
    awk -v f="\"$2\":" '{
        n = index($0, f);
        if (n) { s = substr($0, n + length(f)); sub(/[,}].*/, "", s); total += s }
    } END { printf "%.6f", total }' "$1"
}
OFF_UTIL=$(sum_field "$REPLAN_OFF" total_utility)
ON_UTIL=$(sum_field "$REPLAN_ON" total_utility)
ON_REPLANNED=$(sum_field "$REPLAN_ON" replanned | awk '{printf "%.0f", $0}')
CELLS=$(wc -l < "$REPLAN_ON" | tr -d ' ')
awk -v off="$OFF_UTIL" -v on="$ON_UTIL" -v moved="$ON_REPLANNED" -v cells="$CELLS" 'BEGIN {
    gain = (off > 0) ? (on - off) / off : 0;
    printf "{\"bench\": \"replan_diurnal_quick\", \"cells\": %d, \"replan\": \"every:2\", \"replanned_jobs\": %d, \"utility_replan_off\": %.3f, \"utility_replan_on\": %.3f, \"utility_gain\": %.4f}\n", cells, moved, off, on, gain;
}' > ../BENCH_replan.json
cat ../BENCH_replan.json
if [ "${ON_REPLANNED:-0}" -eq 0 ]; then
    echo "error: the replan-enabled sweep reported zero replanned jobs" >&2
    exit 1
fi
# acceptance criterion: re-planning must not lose total utility on the
# diurnal matrix (per-job adoptions are utility-monotone by construction)
if awk -v off="$OFF_UTIL" -v on="$ON_UTIL" 'BEGIN { exit !(on + 1e-9 < off) }'; then
    echo "error: replan-on utility ($ON_UTIL) below replan-off ($OFF_UTIL)" >&2
    exit 1
fi
rm -f "$REPLAN_OFF" "$REPLAN_ON"

echo "== churn bench (churny quick sweep: faults, migrations, FTF) =="
# Run the quick sweep with seeded MTBF/MTTR machine churn plus elastic
# re-planning and emit BENCH_churn.json. The churny run must actually
# interrupt and migrate started jobs (zero migrations means the fault
# path is wired off) and every cell must report finish-time fairness.
CHURN_OFF=target/bench_churn_off.jsonl
CHURN_ON=target/bench_churn_on.jsonl
rm -f "$CHURN_OFF" "$CHURN_ON"
"$BIN" sweep --quick --schedulers pd-ors,oasis --seeds 3 \
    --replan every:2 --jobs "$PAR" --out "$CHURN_OFF" >/dev/null
"$BIN" sweep --quick --schedulers pd-ors,oasis --seeds 3 \
    --replan every:2 --churn mtbf:40,mttr:8 --jobs "$PAR" --out "$CHURN_ON" >/dev/null
OFF_UTIL=$(sum_field "$CHURN_OFF" total_utility)
ON_UTIL=$(sum_field "$CHURN_ON" total_utility)
EVICTED=$(sum_field "$CHURN_ON" evicted | awk '{printf "%.0f", $0}')
MIGRATED=$(sum_field "$CHURN_ON" migrated | awk '{printf "%.0f", $0}')
FTF_SUM=$(sum_field "$CHURN_ON" ftf)
CELLS=$(wc -l < "$CHURN_ON" | tr -d ' ')
FTF_LINES=$(grep -c '"ftf":' "$CHURN_ON" || true)
awk -v off="$OFF_UTIL" -v on="$ON_UTIL" -v ev="$EVICTED" -v mi="$MIGRATED" \
    -v ftf="$FTF_SUM" -v cells="$CELLS" 'BEGIN {
    loss = (off > 0) ? (off - on) / off : 0;
    mean_ftf = (cells > 0) ? ftf / cells : 0;
    printf "{\"bench\": \"churn_quick_sweep\", \"cells\": %d, \"churn\": \"mtbf:40,mttr:8\", \"evicted_jobs\": %d, \"migrated_jobs\": %d, \"mean_ftf\": %.3f, \"utility_churn_off\": %.3f, \"utility_churn_on\": %.3f, \"utility_loss\": %.4f}\n", cells, ev, mi, mean_ftf, off, on, loss;
}' > ../BENCH_churn.json
cat ../BENCH_churn.json
if [ "${MIGRATED:-0}" -eq 0 ]; then
    echo "error: the churny sweep migrated zero started jobs" >&2
    exit 1
fi
if [ "${FTF_LINES:-0}" -ne "$CELLS" ]; then
    echo "error: only $FTF_LINES of $CELLS churny cells report an ftf field" >&2
    exit 1
fi
rm -f "$CHURN_OFF" "$CHURN_ON"

echo "== telemetry trace smoke (schedule --trace-out) =="
# One busy quick run (replan + churn active, so every instrumented
# engine stage fires) exported as Chrome trace-event JSON. The trace
# must contain at least one span per instrumented pipeline stage
# (queue_wait is daemon-only and covered by the prom scrape above).
TRACE_OUT=../trace_quick.json
rm -f "$TRACE_OUT"
"$BIN" schedule --scheduler pd-ors --machines 8 --jobs 16 --horizon 12 --seed 3 \
    --replan every:2 --churn down@2:1,up@5:1 --trace-out "$TRACE_OUT" >/dev/null
for stage in snapshot_build theta_solve memo_lookup lp_solve rounding \
             replan_pass migration_pass admission_commit; do
    if ! grep -q "\"name\":\"$stage\"" "$TRACE_OUT"; then
        echo "error: trace_quick.json lacks a $stage span" >&2
        exit 1
    fi
done
if ! grep -q '"traceEvents"' "$TRACE_OUT" || ! grep -q '"ph":"i"' "$TRACE_OUT"; then
    echo "error: trace_quick.json is not a Chrome trace with engine instants" >&2
    exit 1
fi
echo "trace OK: all instrumented engine stages present in trace_quick.json"

echo "== provenance smoke (schedule --explain / --explain-out / --price-out) =="
# One overloaded quick run (32 jobs on 6 machines, so the dual prices
# actually price jobs out) with full decision provenance exported. The
# gates check the point of the subsystem: at least one admitted AND one
# rejected job carry a machine-readable explanation, the human-readable
# --explain lines show the utility-vs-price margins, and the price
# series is non-empty.
EXPLAIN_OUT=../explain_quick.jsonl
PRICES_OUT=../prices_quick.json
rm -f "$EXPLAIN_OUT" "$PRICES_OUT"
EXPLAIN_LOG=$("$BIN" schedule --scheduler pd-ors --machines 6 --jobs 32 --horizon 12 \
    --seed 3 --replan every:2 --churn down@2:1,up@5:1 \
    --explain --explain-out "$EXPLAIN_OUT" --price-out "$PRICES_OUT")
ADMIT_LINES=$(grep -c '"decision":"admit"' "$EXPLAIN_OUT" || true)
REJECT_LINES=$(grep -c '"decision":"reject"' "$EXPLAIN_OUT" || true)
if [ "${ADMIT_LINES:-0}" -eq 0 ] || [ "${REJECT_LINES:-0}" -eq 0 ]; then
    echo "error: explain_quick.jsonl must explain >=1 admitted and >=1 rejected job (admit=$ADMIT_LINES reject=$REJECT_LINES)" >&2
    cat "$EXPLAIN_OUT" >&2
    exit 1
fi
if grep -v -q '"reason":"' "$EXPLAIN_OUT"; then
    echo "error: explain_quick.jsonl has a decision without a machine-readable reason" >&2
    exit 1
fi
if ! printf '%s\n' "$EXPLAIN_LOG" | grep -q 'margin'; then
    echo "error: schedule --explain printed no margin lines" >&2
    printf '%s\n' "$EXPLAIN_LOG" >&2
    exit 1
fi
for want in '"series":"cluster_prices"' '"samples":' '"utilization"'; do
    if ! grep -q "$want" "$PRICES_OUT"; then
        echo "error: prices_quick.json lacks $want" >&2
        cat "$PRICES_OUT" >&2
        exit 1
    fi
done
echo "provenance OK: $ADMIT_LINES admits + $REJECT_LINES rejects explained, price series exported"

echo "== admission bench (1024-machine cold vs incremental solver) =="
# The incremental-solver acceptance harness: one long-horizon arrival
# stream over a 1024-machine skewed cluster, solved twice — cold (every
# cross-arrival cache disabled, the --cold-solver oracle) and
# incrementally (persistent snapshots + memo carry-over + warm simplex).
# The command itself enforces byte parity between the passes and exits
# nonzero on divergence; the gates below check the point of the
# exercise: strictly less simplex work and a lower p99 admission latency
# on the incremental path.
rm -f ../BENCH_admission.json
"$BIN" admission-bench --machines 1024 --jobs 96 --horizon 48 --seed 1 \
    --out ../BENCH_admission.json
cat ../BENCH_admission.json
ADMISSION_JSON=$(cat ../BENCH_admission.json)
# the artifact nests per-pass objects; slice at the pass key first, then
# reuse the flat json_field extractor on the remainder
COLD_PART=${ADMISSION_JSON#*\"cold\":}
INC_PART=${ADMISSION_JSON#*\"incremental\":}
json_field() {
    awk -v f="\"$1\":" '{
        n = index($0, f);
        if (n) { s = substr($0, n + length(f)); sub(/[,}].*/, "", s); gsub(/[" ]/, "", s); print s; exit }
    }'
}
COLD_PPT=$(printf '%s\n' "$COLD_PART" | json_field pivots_per_theta)
INC_PPT=$(printf '%s\n' "$INC_PART" | json_field pivots_per_theta)
INC_WARM=$(printf '%s\n' "$INC_PART" | json_field warm_hits)
INC_THETA=$(printf '%s\n' "$INC_PART" | json_field theta_solves)
INC_DELTAS=$(printf '%s\n' "$INC_PART" | json_field snapshot_delta_updates)
SPEEDUP_P99=$(printf '%s\n' "$ADMISSION_JSON" | json_field speedup_p99)
ADM_JOBS=$(printf '%s\n' "$ADMISSION_JSON" | json_field jobs)
if awk -v c="$COLD_PPT" -v i="$INC_PPT" 'BEGIN { exit !(i >= c) }'; then
    echo "error: incremental solver did not reduce pivots-per-solve ($INC_PPT vs cold $COLD_PPT)" >&2
    exit 1
fi
if awk -v s="$SPEEDUP_P99" 'BEGIN { exit !(s <= 1.0) }'; then
    echo "error: incremental p99 admission latency did not beat cold (speedup_p99=$SPEEDUP_P99)" >&2
    exit 1
fi
if [ "${INC_WARM:-0}" -eq 0 ]; then
    echo "error: the incremental pass recorded zero warm-simplex hits" >&2
    exit 1
fi
if [ "${INC_DELTAS:-0}" -eq 0 ]; then
    echo "error: the incremental pass never delta-updated a snapshot" >&2
    exit 1
fi
echo "admission bench OK (pivots/solve $INC_PPT vs $COLD_PPT cold, p99 speedup ${SPEEDUP_P99}x)"

echo "== bench baseline gate (BENCH_TREND.json) =="
# Committed per-PR bench baselines: BENCH_TREND.json holds one JSON line
# per bench. Deterministic metrics are compared against the baseline and
# regressions beyond the thresholds are fatal; a bench with no baseline
# entry yet records one (commit the updated file to pin it).
TREND=../BENCH_TREND.json
touch "$TREND"
# extract "<field>": <value> from a single JSON line on stdin
json_field() {
    awk -v f="\"$1\":" '{
        n = index($0, f);
        if (n) { s = substr($0, n + length(f)); sub(/[,}].*/, "", s); gsub(/[" ]/, "", s); print s; exit }
    }'
}
CURRENT=$(cat ../BENCH_churn.json)
BASE=$(grep '"bench": "churn_quick_sweep"' "$TREND" | head -n 1 || true)
if [ -n "$BASE" ]; then
    BASE_UTIL=$(printf '%s\n' "$BASE" | json_field utility_churn_on)
    NEW_UTIL=$(printf '%s\n' "$CURRENT" | json_field utility_churn_on)
    BASE_FTF=$(printf '%s\n' "$BASE" | json_field mean_ftf)
    NEW_FTF=$(printf '%s\n' "$CURRENT" | json_field mean_ftf)
    # utility under churn must not drop >5% below the pinned baseline
    if awk -v b="$BASE_UTIL" -v n="$NEW_UTIL" 'BEGIN { exit !(b > 0 && n < 0.95 * b) }'; then
        echo "error: churny utility regressed beyond 5%: $NEW_UTIL vs baseline $BASE_UTIL" >&2
        exit 1
    fi
    # mean FTF (training time / ideal; higher = worse) must not grow >10%
    if awk -v b="$BASE_FTF" -v n="$NEW_FTF" 'BEGIN { exit !(b > 0 && n > 1.10 * b) }'; then
        echo "error: mean finish-time fairness regressed beyond 10%: $NEW_FTF vs baseline $BASE_FTF" >&2
        exit 1
    fi
    echo "churn bench within baseline thresholds (utility $NEW_UTIL vs $BASE_UTIL, ftf $NEW_FTF vs $BASE_FTF)"
else
    printf '%s\n' "$CURRENT" >> "$TREND"
    echo "recorded new churn baseline in BENCH_TREND.json — commit it to pin"
fi

# Derived machine-normalized trend metrics: counter ratios only, never
# raw wall time, so the gate is stable across runner hardware.
#   memo_hit_rate      — θ-memo hits / probes on the quick Fig. 6 run
#                        (solver caching efficiency)
#   replan_utility_gain — relative utility gained by replan on the
#                        diurnal quick sweep (deterministic given seeds)
#   churn_disruption   — evicted + migrated jobs on the churny quick
#                        sweep (the seeded fault path's footprint)
#   warm_hit_rate      — warm-simplex hits / θ-solves on the 1024-machine
#                        admission bench's incremental pass
#   snapshot_deltas_per_admission — per-machine snapshot entries carried
#                        over (delta-updated instead of rebuilt) per
#                        admission on the same bench
#   spans_per_admission — total instrumented span count across all
#                        pipeline stages over admitted jobs, from the
#                        service bench's prometheus scrape (the PR 7
#                        carried-over instrumentation-drift canary)
#   mean_admit_margin  — mean utility-minus-price margin over admitted
#                        jobs in the provenance smoke run (deterministic
#                        given seeds; drift means the pricing or the
#                        admission rule changed silently)
#   shard_speedup      — 4-shard vs 1-shard admission throughput on the
#                        soak (the one hardware-sensitive entry, so its
#                        gate is deliberately loose: it only catches the
#                        sharding being wired off, not runner noise)
THETA=$(cat ../BENCH_solver.json | json_field theta_solves)
HITS=$(cat ../BENCH_solver.json | json_field memo_hits)
HIT_RATE=$(awk -v t="$THETA" -v h="$HITS" 'BEGIN { printf "%.4f", (t + h > 0) ? h / (t + h) : 0 }')
GAIN=$(cat ../BENCH_replan.json | json_field utility_gain)
EVICTED=$(cat ../BENCH_churn.json | json_field evicted_jobs)
MIGRATED=$(cat ../BENCH_churn.json | json_field migrated_jobs)
DISRUPTION=$((EVICTED + MIGRATED))
WARM_RATE=$(awk -v w="$INC_WARM" -v t="$INC_THETA" 'BEGIN { printf "%.4f", (t > 0) ? w / t : 0 }')
DELTAS_PER_ADM=$(awk -v d="$INC_DELTAS" -v j="$ADM_JOBS" 'BEGIN { printf "%.2f", (j > 0) ? d / j : 0 }')
SPAN_COUNT=$(awk '/^dmlrs_stage_duration_us_count/ { total += $NF } END { printf "%.0f", total }' ../PROM_snapshot.txt)
PROM_ADMITTED=$(awk '/^dmlrs_admitted_total / { printf "%.0f", $2; exit }' ../PROM_snapshot.txt)
SPANS_PER_ADM=$(awk -v s="$SPAN_COUNT" -v a="$PROM_ADMITTED" 'BEGIN { printf "%.2f", (a > 0) ? s / a : 0 }')
MEAN_MARGIN=$(awk '/"decision":"admit"/ {
    n = index($0, "\"margin\":");
    if (n) { s = substr($0, n + 9); sub(/[,}].*/, "", s); total += s; cnt++ }
} END { printf "%.4f", (cnt > 0) ? total / cnt : 0 }' ../explain_quick.jsonl)
CURRENT=$(printf '{"bench": "derived_trend_metrics", "memo_hit_rate": %s, "replan_utility_gain": %s, "churn_disruption": %d, "warm_hit_rate": %s, "snapshot_deltas_per_admission": %s, "spans_per_admission": %s, "mean_admit_margin": %s, "shard_speedup": %s}' \
    "$HIT_RATE" "$GAIN" "$DISRUPTION" "$WARM_RATE" "$DELTAS_PER_ADM" "$SPANS_PER_ADM" "$MEAN_MARGIN" "$SHARD_SPEEDUP")
BASE=$(grep '"bench": "derived_trend_metrics"' "$TREND" | head -n 1 || true)
if [ -n "$BASE" ]; then
    BASE_RATE=$(printf '%s\n' "$BASE" | json_field memo_hit_rate)
    BASE_GAIN=$(printf '%s\n' "$BASE" | json_field replan_utility_gain)
    BASE_DISRUPT=$(printf '%s\n' "$BASE" | json_field churn_disruption)
    BASE_WARM=$(printf '%s\n' "$BASE" | json_field warm_hit_rate)
    BASE_DELTAS=$(printf '%s\n' "$BASE" | json_field snapshot_deltas_per_admission)
    BASE_SPANS=$(printf '%s\n' "$BASE" | json_field spans_per_admission)
    BASE_MARGIN=$(printf '%s\n' "$BASE" | json_field mean_admit_margin)
    # the θ-memo must stay effective: hit rate not >10% (relative) below baseline
    if awk -v b="$BASE_RATE" -v n="$HIT_RATE" 'BEGIN { exit !(b > 0 && n < 0.90 * b) }'; then
        echo "error: memo hit rate regressed beyond 10%: $HIT_RATE vs baseline $BASE_RATE" >&2
        exit 1
    fi
    # replan must keep earning: gain not more than 0.05 (absolute) below baseline
    if awk -v b="$BASE_GAIN" -v n="$GAIN" 'BEGIN { exit !(n < b - 0.05) }'; then
        echo "error: replan utility gain regressed: $GAIN vs baseline $BASE_GAIN" >&2
        exit 1
    fi
    # the seeded fault path is deterministic; large drift means churn or
    # migration behavior changed silently (re-pin the baseline if intended)
    if awk -v b="$BASE_DISRUPT" -v n="$DISRUPTION" 'BEGIN { exit !(b > 0 && (n > 1.25 * b || n < 0.75 * b)) }'; then
        echo "error: churn disruption drifted beyond 25%: $DISRUPTION vs baseline $BASE_DISRUPT" >&2
        exit 1
    fi
    # the warm simplex must stay effective (a baseline that predates the
    # field parses as empty and skips the gate until re-pinned)
    if awk -v b="${BASE_WARM:-0}" -v n="$WARM_RATE" 'BEGIN { exit !(b > 0 && n < 0.90 * b) }'; then
        echo "error: warm-simplex hit rate regressed beyond 10%: $WARM_RATE vs baseline $BASE_WARM" >&2
        exit 1
    fi
    # snapshot carry-over is deterministic on the seeded bench; drift
    # means the delta path silently changed shape
    if awk -v b="${BASE_DELTAS:-0}" -v n="$DELTAS_PER_ADM" 'BEGIN { exit !(b > 0 && (n > 1.25 * b || n < 0.75 * b)) }'; then
        echo "error: snapshot deltas per admission drifted beyond 25%: $DELTAS_PER_ADM vs baseline $BASE_DELTAS" >&2
        exit 1
    fi
    # instrumentation drift: span counts per admission on the service
    # bench are a counter ratio — large movement means a stage gained or
    # lost spans silently (re-pin the baseline if intended)
    if awk -v b="${BASE_SPANS:-0}" -v n="$SPANS_PER_ADM" 'BEGIN { exit !(b > 0 && (n > 1.25 * b || n < 0.75 * b)) }'; then
        echo "error: spans per admission drifted beyond 25%: $SPANS_PER_ADM vs baseline $BASE_SPANS" >&2
        exit 1
    fi
    # the admit margin on the seeded provenance run is deterministic;
    # drift means the dual prices or the admission rule moved silently
    if awk -v b="${BASE_MARGIN:-0}" -v n="$MEAN_MARGIN" 'BEGIN { exit !(b > 0 && (n > 1.25 * b || n < 0.75 * b)) }'; then
        echo "error: mean admit margin drifted beyond 25%: $MEAN_MARGIN vs baseline $BASE_MARGIN" >&2
        exit 1
    fi
    # shard speedup is hardware-sensitive, so only a collapse (< 60% of
    # the pinned baseline) fails — that means the cells stopped solving
    # concurrently, not that the runner was busy
    BASE_SHARD=$(printf '%s\n' "$BASE" | json_field shard_speedup)
    if awk -v b="${BASE_SHARD:-0}" -v n="$SHARD_SPEEDUP" 'BEGIN { exit !(b > 0 && n < 0.60 * b) }'; then
        echo "error: shard speedup collapsed: $SHARD_SPEEDUP vs baseline $BASE_SHARD" >&2
        exit 1
    fi
    echo "derived trend metrics within thresholds (hit_rate $HIT_RATE vs $BASE_RATE, gain $GAIN vs $BASE_GAIN, disruption $DISRUPTION vs $BASE_DISRUPT, warm_rate $WARM_RATE vs ${BASE_WARM:-unpinned}, deltas/adm $DELTAS_PER_ADM vs ${BASE_DELTAS:-unpinned}, spans/adm $SPANS_PER_ADM vs ${BASE_SPANS:-unpinned}, admit_margin $MEAN_MARGIN vs ${BASE_MARGIN:-unpinned})"
else
    printf '%s\n' "$CURRENT" >> "$TREND"
    echo "recorded derived trend baseline in BENCH_TREND.json — commit it to pin"
fi

echo "verify: OK"
