#!/usr/bin/env bash
# Tier-1 verification: release build + test suite (+ a formatting check).
#
#   scripts/verify.sh
#
# Run from anywhere; operates on the rust/ crate. The fmt check is
# advisory (the offline toolchain image may lack the rustfmt component);
# build + test failures are fatal.

set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check (advisory) =="
if command -v cargo-fmt >/dev/null 2>&1 || cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check || echo "warning: rustfmt differences (non-fatal)"
else
    echo "rustfmt unavailable; skipping"
fi

echo "== sweep bench (quick matrix, serial vs parallel) =="
# Wall-time the quick scenario matrix at --jobs 1 vs all cores and emit
# BENCH_sweep.json at the repo root (the bench trajectory data point).
BIN=target/release/dmlrs
PAR=$( (command -v nproc >/dev/null 2>&1 && nproc) || echo 2 )
SERIAL_OUT=target/bench_sweep_serial.jsonl
PAR_OUT=target/bench_sweep_parallel.jsonl
rm -f "$SERIAL_OUT" "$PAR_OUT"
# The sweep command prints "sweep: ... elapsed=<secs>s ..." itself —
# parse that (portable; GNU date's sub-second %N is not).
elapsed_of() { awk '/^sweep: /{sub(/.*elapsed=/,""); sub(/s .*/,""); print}'; }
SERIAL_SECS=$("$BIN" sweep --quick --jobs 1 --out "$SERIAL_OUT" | elapsed_of)
PAR_SECS=$("$BIN" sweep --quick --jobs "$PAR" --out "$PAR_OUT" | elapsed_of)
CELLS=$(wc -l < "$SERIAL_OUT" | tr -d ' ')
awk -v serial="$SERIAL_SECS" -v parallel="$PAR_SECS" -v par="$PAR" -v cells="$CELLS" 'BEGIN {
    speedup = (parallel > 0) ? serial / parallel : 0;
    printf "{\"bench\": \"sweep_quick_matrix\", \"cells\": %d, \"serial_secs\": %.3f, \"parallel_secs\": %.3f, \"parallel_jobs\": %d, \"speedup\": %.2f}\n", cells, serial, parallel, par, speedup;
}' > ../BENCH_sweep.json
cat ../BENCH_sweep.json
rm -f "$SERIAL_OUT" "$PAR_OUT"

echo "verify: OK"
