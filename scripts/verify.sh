#!/usr/bin/env bash
# Tier-1 verification: release build + test suite (+ a formatting check).
#
#   scripts/verify.sh
#
# Run from anywhere; operates on the rust/ crate. The fmt check is
# advisory (the offline toolchain image may lack the rustfmt component);
# build + test failures are fatal.

set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check (advisory) =="
if command -v cargo-fmt >/dev/null 2>&1 || cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check || echo "warning: rustfmt differences (non-fatal)"
else
    echo "rustfmt unavailable; skipping"
fi

echo "verify: OK"
