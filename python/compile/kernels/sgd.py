"""PS-side parameter update kernel.

This is the parameter-server step of the paper's §3.1 workflow
(``w[k] = w[k-1] - α·ĝ[k]`` with ĝ the average of the workers' gradient
pushes): aggregate the gradient sum that the coordinator accumulated from
its workers and apply the SGD step, in one elementwise-tiled pass over the
flat parameter vector (exactly the memory-bound loop a real PS runs per
iteration).

``scale`` is passed as a (1,)-array (= lr / num_workers) so a single
compiled artifact serves any worker count.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET

#: 1-D tile for the update sweep; 64Ki f32 = 256 KiB per operand block,
#: 3 live blocks => ~0.75 MiB VMEM, far under the 16 MiB budget.
_BLOCK = 65536


def _sgd_kernel(p_ref, g_ref, scale_ref, o_ref):
    o_ref[...] = p_ref[...] - scale_ref[0] * g_ref[...]


def sgd_apply(params, grad_sum, scale):
    """params, grad_sum: (N,) f32; scale: (1,) f32 -> updated params (N,).

    The grid is a *ceil* division: a parameter count with no large divisor
    (e.g. 470528 = 2^9 x 919) would otherwise force a tiny exact block and
    a thousands-step grid loop (measured 1.4 s/apply vs 60 ms — §Perf).
    Elementwise OOB in the ragged last block is masked by Pallas (reads
    padded, stores dropped), so ceil-div is safe here, unlike the GEMM
    accumulator kernels which require exact tiling.
    """
    (n,) = params.shape
    bn = min(n, _BLOCK)
    grid = (n + bn - 1) // bn
    return pl.pallas_call(
        _sgd_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), params.dtype),
        interpret=INTERPRET,
    )(params, grad_sum, scale)
