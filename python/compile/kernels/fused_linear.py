"""Fused linear layer: y = act(x @ W + b) as a single Pallas kernel.

Forward fuses the GEMM epilogue (bias add + GELU) into the same VMEM tile
that the MXU accumulation lands in — on a real TPU this saves one full
HBM round-trip of the (M, N) activation compared to unfused matmul+bias+gelu.

Backward (custom_vjp) reuses the tiled :func:`..matmul.matmul` kernel for
the three GEMMs (dx = dy_pre @ W^T, dW = x^T @ dy_pre) and a jnp elementwise
GELU' (which XLA fuses into the surrounding graph).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, gelu, gelu_grad, pick_block
from .matmul import matmul


def _fused_kernel(x_ref, w_ref, b_ref, o_ref, *, k_steps: int, activation: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...]
        if activation == "gelu":
            y = gelu(y)
        o_ref[...] = y


def _fused_linear_raw(x, w, b, activation: str, bm: int, bn: int, bk: int):
    m, k = x.shape
    _, n = w.shape
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    kernel = functools.partial(_fused_kernel, k_steps=k_steps, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, w, b.reshape(1, n))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation: str = "gelu"):
    """act(x @ w + b); x: (M, K), w: (K, N), b: (N,). activation in
    {"gelu", "none"}."""
    return _fused_linear_raw(x, w, b, activation, 128, 128, 128)


def _fused_fwd(x, w, b, activation):
    y = fused_linear(x, w, b, activation)
    return y, (x, w, b)


def _fused_bwd(activation, res, dy):
    x, w, b = res
    if activation == "gelu":
        # Recompute the pre-activation (cheap GEMM via the pallas kernel;
        # the standard memory/compute trade for fused epilogues).
        pre = matmul(x, w) + b.reshape(1, -1)
        dpre = dy * gelu_grad(pre)
    else:
        dpre = dy
    dx = matmul(dpre, w.T)
    dw = matmul(x.T, dpre)
    db = jnp.sum(dpre, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_fwd, _fused_bwd)
