"""Causal flash attention as a Pallas kernel.

Inputs are packed as (BH, L, D) — batch and heads flattened into the leading
grid axis — so the kernel never needs a vmap batching rule. The grid is
(BH, L/bq): each program owns one query block and streams all key/value
blocks through VMEM with the classic running-max / running-denominator
(online softmax) recurrence, i.e. the memory schedule FlashAttention
expresses with CUDA threadblocks is expressed here with BlockSpec + an
in-kernel fori_loop.

Backward (custom_vjp) uses the standard recompute strategy in plain jnp
(XLA-fused), keeping only (q, k, v, o, lse) as residuals.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, bq, bk, kv_len, scale):
    qi = pl.program_id(1)
    q = q_ref[0]  # (bq, d)
    d = q.shape[-1]
    row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    m0 = jnp.full((bq, 1), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(j * bk, bk), :]  # (bk, d)
        v = v_ref[0, pl.ds(j * bk, bk), :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        col = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(col <= row, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = alpha * acc + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    n_kv = kv_len // bk
    # Causality: query block qi only attends to kv blocks j with
    # j*bk <= qi*bq + bq - 1; iterating further is wasted work.
    n_needed = jnp.minimum(n_kv, (qi * bq + bq + bk - 1) // bk)
    m, l, acc = jax.lax.fori_loop(0, n_needed, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)
    lse_ref[0] = (m + jnp.log(l))[:, 0]


def _flash_raw(q, k, v, scale, bq, bk):
    bh, lq, d = q.shape
    _, lk, _ = k.shape
    bq = pick_block(lq, bq)
    bk = pick_block(lk, bk)
    grid = (bh, lq // bq)
    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, kv_len=lk, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, lk, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, lk, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, lq), jnp.float32),
        ],
        interpret=INTERPRET,
    )(q, k, v)


@jax.custom_vjp
def flash_attention(q, k, v):
    """Causal attention; q, k, v: (BH, L, D) -> (BH, L, D)."""
    o, _ = _flash_raw(q, k, v, 1.0 / (q.shape[-1] ** 0.5), 128, 128)
    return o


def _flash_fwd(q, k, v):
    scale = 1.0 / (q.shape[-1] ** 0.5)
    o, lse = _flash_raw(q, k, v, scale, 128, 128)
    return o, (q, k, v, o, lse)


def _flash_bwd(res, do):
    q, k, v, o, lse = res
    scale = 1.0 / (q.shape[-1] ** 0.5)
    lq, lk = q.shape[1], k.shape[1]
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 1)
    )
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    s = jnp.where(mask[None], s, _NEG_INF)
    p = jnp.exp(s - lse[:, :, None])  # softmax via stored logsumexp
    dv = jnp.einsum("bqk,bqd->bkd", p, do)
    dp = jnp.einsum("bqd,bkd->bqk", do, v)
    delta = jnp.sum(do * o, axis=-1, keepdims=True)  # (BH, L, 1)
    ds = p * (dp - delta)
    dq = jnp.einsum("bqk,bkd->bqd", ds, k) * scale
    dk = jnp.einsum("bqk,bqd->bkd", ds, q) * scale
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
