"""L1 Pallas kernels for the PD-ORS training payload.

All kernels are authored TPU-style (BlockSpec tiling sized for VMEM, MXU
128x128 tiles) but executed with ``interpret=True`` on this CPU image —
real-TPU lowering emits Mosaic custom-calls the CPU PJRT plugin cannot run.

Public API (see each module for details):

* :func:`matmul`            — tiled GEMM, the building block of every vjp
* :func:`fused_linear`      — x @ W + b (+ optional GELU), custom_vjp
* :func:`flash_attention`   — causal flash attention, custom_vjp
* :func:`sgd_apply`         — PS-side gradient aggregation + SGD update
"""

from .matmul import matmul
from .fused_linear import fused_linear
from .attention import flash_attention
from .sgd import sgd_apply

__all__ = ["matmul", "fused_linear", "flash_attention", "sgd_apply"]
