"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis
and asserts allclose(kernel, ref). These functions intentionally use the
most direct jnp formulation — no tiling, no tricks.
"""

import jax
import jax.numpy as jnp

from .common import gelu as _gelu


def matmul(x, w):
    return jnp.matmul(x, w)


def fused_linear(x, w, b, activation: str = "gelu"):
    y = jnp.matmul(x, w) + b.reshape(1, -1)
    if activation == "gelu":
        y = _gelu(y)
    return y


def flash_attention(q, k, v):
    """Causal softmax(q k^T / sqrt(d)) v over (BH, L, D)."""
    d = q.shape[-1]
    lq, lk = q.shape[1], k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 1)
    )
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def sgd_apply(params, grad_sum, scale):
    return params - scale[0] * grad_sum


def gelu(x):
    return _gelu(x)
