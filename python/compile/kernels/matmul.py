"""Tiled GEMM Pallas kernel.

The grid is (M/bm, N/bn, K/bk); the output block is revisited along the k
axis and used as the accumulator (its index map ignores k), which avoids a
scratch allocation and matches the classic TPU "HBM->VMEM stream + MXU
accumulate" schedule. ``preferred_element_type=float32`` pins the MXU
accumulation dtype.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _matmul_raw(x, w, bm: int = 128, bn: int = 128, bk: int = 128):
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    kernel = functools.partial(_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, w)


@jax.custom_vjp
def matmul(x, w):
    """``x @ w`` with x: (M, K), w: (K, N) -> (M, N).

    Block sizes are clamped to divisors of the problem shape so the grid
    tiles exactly (no masking). f32 in / f32 out. Differentiable: the vjp
    runs the same tiled kernel on the transposed operands.
    """
    return _matmul_raw(x, w)


def _matmul_fwd(x, w):
    return _matmul_raw(x, w), (x, w)


def _matmul_bwd(res, dy):
    x, w = res
    return _matmul_raw(dy, w.T), _matmul_raw(x.T, dy)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
