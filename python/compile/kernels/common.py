"""Shared helpers for the Pallas kernels."""

import jax.numpy as jnp

# All pallas_call sites go through interpret mode on this CPU-only image.
# Real-TPU builds flip this to False (Mosaic lowering) without touching the
# kernel bodies.
INTERPRET = True

#: MXU-friendly preferred tile edge. 128 matches the TPU systolic array;
#: on shapes that are not multiples we fall back to the largest divisor so
#: that no masking is needed (exactness > padding for the CPU oracle path).
PREFERRED_BLOCK = 128

_CANDIDATES = (256, 128, 64, 32, 16, 8, 4, 2, 1)


def pick_block(dim: int, preferred: int = PREFERRED_BLOCK) -> int:
    """Largest candidate block size <= ``preferred`` that divides ``dim``.

    Guarantees grid * block == dim exactly, so kernels never need bounds
    masks. Falls back to ``dim`` itself for small or prime dimensions.
    """
    if dim <= preferred:
        return dim
    for c in _CANDIDATES:
        if c <= preferred and dim % c == 0:
            return c
    return dim


def gelu(x):
    """tanh-approximated GELU (matches the reference oracle exactly)."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def gelu_grad(x):
    """d/dx of :func:`gelu` — used by the fused_linear backward pass."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    u = c * (x + 0.044715 * x * x * x)
    t = jnp.tanh(u)
    du = c * (1.0 + 3.0 * 0.044715 * x * x)
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du


def vmem_bytes(*block_shapes, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of a kernel instance (sum of live blocks).

    Used by the §Perf notes in DESIGN.md / EXPERIMENTS.md: on a real TPU
    the sum over in/out/scratch blocks must stay well under ~16 MiB.
    """
    total = 0
    for shape in block_shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * dtype_bytes
    return total
