"""L2 — decoder-only transformer LM (fwd/bwd/update) calling the L1 kernels.

The model is written over a **flat f32[n] parameter vector** rather than a
pytree. That choice is deliberate: the Rust coordinator then moves exactly
one parameter literal and one gradient literal per worker push/pull, which
mirrors the paper's PS model (parameters evenly sharded across PSs as flat
ranges) and keeps the PJRT call signatures stable across model sizes.

Exported computations (AOT-lowered by aot.py):

* ``init(seed)                     -> params``            f32[n]
* ``grad(params, tokens)           -> (grads, loss)``     worker-side
* ``apply(params, gradsum, scale)  -> params``            PS-side (Pallas sgd)
* ``train_step(params, tokens)     -> (params, loss)``    single-node fused
* ``eval_loss(params, tokens)      -> loss``
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import kernels


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    batch: int
    lr: float = 0.05

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


#: Size ladder. `tiny` is the pytest size; `small` is the e2e default
#: (CPU-feasible for a few hundred BSP steps under interpret-mode Pallas);
#: `base`/`medium`/`gpt100m` scale up to the paper-style ~100M config.
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=64, d_model=32, n_layers=1, n_heads=2,
                        seq_len=16, batch=2),
    "small": ModelConfig("small", vocab=512, d_model=128, n_layers=2,
                         n_heads=4, seq_len=64, batch=4),
    "base": ModelConfig("base", vocab=2048, d_model=256, n_layers=4,
                        n_heads=8, seq_len=128, batch=8),
    "medium": ModelConfig("medium", vocab=8192, d_model=512, n_layers=6,
                          n_heads=8, seq_len=128, batch=4),
    "gpt100m": ModelConfig("gpt100m", vocab=32768, d_model=768, n_layers=12,
                           n_heads=12, seq_len=256, batch=8),
}


def param_specs(cfg: ModelConfig):
    """Ordered (name, shape, init_std) spec of the flat parameter vector."""
    d, ff, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    std = 0.02
    # residual-branch projections get the GPT-2 1/sqrt(2*n_layers) shrink
    rstd = std / (2.0 * cfg.n_layers) ** 0.5
    specs = [("embed", (v, d), std), ("pos", (s, d), std)]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1.g", (d,), -1.0),   # init_std<0 => constant 1.0
            (p + "ln1.b", (d,), 0.0),    # init_std==0 => constant 0.0
            (p + "qkv.w", (d, 3 * d), std),
            (p + "qkv.b", (3 * d,), 0.0),
            (p + "proj.w", (d, d), rstd),
            (p + "proj.b", (d,), 0.0),
            (p + "ln2.g", (d,), -1.0),
            (p + "ln2.b", (d,), 0.0),
            (p + "mlp1.w", (d, ff), std),
            (p + "mlp1.b", (ff,), 0.0),
            (p + "mlp2.w", (ff, d), rstd),
            (p + "mlp2.b", (d,), 0.0),
        ]
    specs += [("lnf.g", (d,), -1.0), ("lnf.b", (d,), 0.0)]
    return specs


def num_params(cfg: ModelConfig) -> int:
    n = 0
    for _, shape, _ in param_specs(cfg):
        size = 1
        for dim in shape:
            size *= dim
        n += size
    return n


def _views(cfg: ModelConfig, flat):
    """Slice the flat vector into named weight views (static offsets)."""
    out, off = {}, 0
    for name, shape, _ in param_specs(cfg):
        size = 1
        for dim in shape:
            size *= dim
        out[name] = flat[off:off + size].reshape(shape)
        off += size
    return out


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def init(cfg: ModelConfig, seed):
    """Build the flat parameter vector from a scalar uint32 seed."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape, std in param_specs(cfg):
        size = 1
        for dim in shape:
            size *= dim
        if std == 0.0:
            chunks.append(jnp.zeros((size,), jnp.float32))
        elif std < 0.0:
            chunks.append(jnp.ones((size,), jnp.float32))
        else:
            key, sub = jax.random.split(key)
            chunks.append(jax.random.normal(sub, (size,), jnp.float32) * std)
    return jnp.concatenate(chunks)


def forward(cfg: ModelConfig, flat, tokens):
    """Next-token cross-entropy loss of the LM on tokens i32[B, S]."""
    w = _views(cfg, flat)
    b, s = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head

    x = jnp.take(w["embed"], tokens, axis=0) + w["pos"][None, :s, :]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        # --- attention block ---
        xn = _layer_norm(x, w[p + "ln1.g"], w[p + "ln1.b"])
        qkv = kernels.fused_linear(
            xn.reshape(b * s, d), w[p + "qkv.w"], w[p + "qkv.b"], "none"
        ).reshape(b, s, 3, h, dh)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        att = kernels.flash_attention(q, k, v)
        att = att.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b * s, d)
        x = x + kernels.fused_linear(
            att, w[p + "proj.w"], w[p + "proj.b"], "none"
        ).reshape(b, s, d)
        # --- MLP block ---
        xn = _layer_norm(x, w[p + "ln2.g"], w[p + "ln2.b"])
        hdn = kernels.fused_linear(
            xn.reshape(b * s, d), w[p + "mlp1.w"], w[p + "mlp1.b"], "gelu"
        )
        x = x + kernels.fused_linear(
            hdn, w[p + "mlp2.w"], w[p + "mlp2.b"], "none"
        ).reshape(b, s, d)

    x = _layer_norm(x, w["lnf.g"], w["lnf.b"])
    # Weight-tied readout through the Pallas GEMM.
    logits = kernels.matmul(x.reshape(b * s, d), w["embed"].T)
    logits = logits.reshape(b, s, cfg.vocab)

    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def grad(cfg: ModelConfig, flat, tokens):
    """Worker-side computation: (flat grads, loss)."""
    loss, g = jax.value_and_grad(lambda p: forward(cfg, p, tokens))(flat)
    return g, loss


def apply_update(cfg: ModelConfig, flat, grad_sum, scale):
    """PS-side update through the Pallas sgd kernel.

    scale is an f32[1] carrying lr / num_workers so one artifact serves any
    worker count chosen by the scheduler.
    """
    del cfg
    return kernels.sgd_apply(flat, grad_sum, scale)


def train_step(cfg: ModelConfig, flat, tokens):
    """Single-node fused step: grad + sgd at the config learning rate."""
    g, loss = grad(cfg, flat, tokens)
    scale = jnp.asarray([cfg.lr], jnp.float32)
    return kernels.sgd_apply(flat, g, scale), loss


def eval_loss(cfg: ModelConfig, flat, tokens):
    return forward(cfg, flat, tokens)


def jitted(cfg: ModelConfig):
    """Convenience bundle of jitted callables (used by tests)."""
    return {
        "init": jax.jit(functools.partial(init, cfg)),
        "grad": jax.jit(functools.partial(grad, cfg)),
        "apply": jax.jit(functools.partial(apply_update, cfg)),
        "train_step": jax.jit(functools.partial(train_step, cfg)),
        "eval_loss": jax.jit(functools.partial(eval_loss, cfg)),
    }
