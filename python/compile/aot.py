"""AOT lowering: jax -> HLO *text* artifacts for the Rust PJRT runtime.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate binds)
rejects (``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts \
                         [--sizes tiny,small,base]

Per size this writes
  lm_<size>_init.hlo.txt        (seed u32[])                  -> (params,)
  lm_<size>_grad.hlo.txt        (params, tokens)              -> (grads, loss)
  lm_<size>_apply.hlo.txt       (params, gradsum, scale f32[1]) -> (params,)
  lm_<size>_train_step.hlo.txt  (params, tokens)              -> (params, loss)
  lm_<size>_eval.hlo.txt        (params, tokens)              -> (loss,)
and lm_<size>.meta.json describing shapes for the Rust loader.
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _lower(fn, *args):
    return jax.jit(fn).lower(*args)


def build_size(cfg: model.ModelConfig, out_dir: str) -> dict:
    n = model.num_params(cfg)
    params = jax.ShapeDtypeStruct((n,), jnp.float32)
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    seed = jax.ShapeDtypeStruct((), jnp.uint32)
    scale = jax.ShapeDtypeStruct((1,), jnp.float32)

    # Every exported fn returns a tuple (return_tuple=True on the XLA side
    # anyway); keep the python-level outputs tuples too for clarity.
    exports = {
        "init": (lambda s: (model.init(cfg, s),), (seed,)),
        "grad": (lambda p, t: model.grad(cfg, p, t), (params, tokens)),
        "apply": (lambda p, g, sc: (model.apply_update(cfg, p, g, sc),),
                  (params, params, scale)),
        "train_step": (lambda p, t: model.train_step(cfg, p, t),
                       (params, tokens)),
        "eval": (lambda p, t: (model.eval_loss(cfg, p, t),),
                 (params, tokens)),
    }

    files = {}
    for name, (fn, args) in exports.items():
        text = to_hlo_text(_lower(fn, *args))
        fname = f"lm_{cfg.name}_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[name] = fname
        print(f"  {fname}: {len(text)} chars", file=sys.stderr)

    meta = {
        "name": cfg.name,
        "num_params": n,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "lr": cfg.lr,
        "files": files,
    }
    with open(os.path.join(out_dir, f"lm_{cfg.name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="tiny,small,base")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for size in args.sizes.split(","):
        size = size.strip()
        if size not in model.CONFIGS:
            raise SystemExit(f"unknown size {size!r}; have {list(model.CONFIGS)}")
        cfg = model.CONFIGS[size]
        print(f"[aot] lowering {size} ({model.num_params(cfg)} params)",
              file=sys.stderr)
        build_size(cfg, args.out_dir)
    # stamp for make
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
