"""Static performance report for the L1/L2 layers (§Perf).

interpret-mode wallclock is CPU-numpy time, NOT a TPU proxy, so this tool
reports the *structural* quantities that determine real-TPU performance:

* per-kernel VMEM working set vs the ~16 MiB budget;
* MXU tile occupancy (how much of each 128x128 systolic pass is useful);
* arithmetic intensity (FLOPs / HBM byte) vs the TPU roofline knee;
* L2 graph statistics from the lowered HLO (op histogram, fusion count,
  and the estimated fraction of FLOPs inside the Pallas GEMM paths).

Usage: python -m compile.perf_report [--sizes tiny,small,base]
"""

import argparse
import re
import sys

from . import model
from .kernels.common import pick_block, vmem_bytes

VMEM_BUDGET = 16 * 2**20
#: TPUv4-class roofline knee (bf16 MXU ~275 TFLOP/s / 1.2 TB/s HBM);
#: intensities above this are compute-bound.
ROOFLINE_KNEE = 230.0


def gemm_report(name, m, k, n):
    bm, bn, bk = pick_block(m, 128), pick_block(n, 128), pick_block(k, 128)
    vmem = vmem_bytes((bm, bk), (bk, bn), (bm, bn))
    occupancy = (bm / 128) * (bn / 128) * (bk / 128) if min(bm, bn, bk) < 128 else 1.0
    flops = 2.0 * m * k * n
    bytes_moved = 4.0 * (m * k + k * n + m * n)
    intensity = flops / bytes_moved
    bound = "compute" if intensity >= ROOFLINE_KNEE else "memory"
    print(
        f"  {name:<28} {m:>5}x{k:<5}@{k:>5}x{n:<5} tiles ({bm:>3},{bn:>3},{bk:>3})"
        f"  vmem {vmem/2**20:5.2f} MiB  mxu_occ {occupancy:4.2f}"
        f"  intensity {intensity:7.1f} ({bound}-bound)"
    )
    assert vmem <= VMEM_BUDGET, f"{name} exceeds VMEM budget"
    return flops


def attention_report(name, bh, length, d):
    bq = pick_block(length, 128)
    vmem = vmem_bytes((bq, d), (length, d), (length, d), (bq, d))
    flops = 2.0 * bh * length * length * d * 2  # qk^T and pv
    print(
        f"  {name:<28} (BH={bh:<3} L={length:<4} D={d:<3})      "
        f"  vmem {vmem/2**20:5.2f} MiB  (flash: K/V streamed per q-block)"
    )
    assert vmem <= VMEM_BUDGET, f"{name} exceeds VMEM budget"
    return flops


def hlo_stats(cfg):
    import jax
    import jax.numpy as jnp
    from . import aot

    n = model.num_params(cfg)
    params = jax.ShapeDtypeStruct((n,), jnp.float32)
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    lowered = jax.jit(lambda p, t: model.train_step(cfg, p, t)).lower(params, tokens)
    text = aot.to_hlo_text(lowered)
    ops = re.findall(r"= \w+\[?[^\s]* (\w+)\(", text)
    hist = {}
    for op in ops:
        hist[op] = hist.get(op, 0) + 1
    dots = hist.get("dot", 0)
    fusions = hist.get("fusion", 0)
    total = len(ops)
    print(
        f"  train_step HLO: {total} ops, {dots} dot(s), {fusions} fusion(s), "
        f"{hist.get('while', 0)} while loop(s) [pallas grids]"
    )
    return hist


def report_size(size):
    cfg = model.CONFIGS[size]
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    ms = cfg.batch * cfg.seq_len
    print(f"\n== {size}: {model.num_params(cfg):,} params, tokens/step {ms} ==")
    total = 0.0
    total += gemm_report("qkv (fused_linear)", ms, d, 3 * d) * cfg.n_layers
    total += gemm_report("attn proj", ms, d, d) * cfg.n_layers
    total += gemm_report("mlp1 (gelu epilogue)", ms, d, ff) * cfg.n_layers
    total += gemm_report("mlp2", ms, ff, d) * cfg.n_layers
    total += gemm_report("lm head (tied)", ms, d, v)
    total += attention_report(
        "flash attention", cfg.batch * cfg.n_heads, cfg.seq_len, cfg.d_head
    ) * cfg.n_layers
    print(f"  forward GEMM+attn FLOPs/step: {total:.3e} (bwd ~2x)")
    hlo_stats(cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="tiny,small,base")
    args = ap.parse_args()
    print("L1/L2 static perf report (TPU-structural; see DESIGN.md §Perf)")
    for s in args.sizes.split(","):
        report_size(s.strip())


if __name__ == "__main__":
    main()
