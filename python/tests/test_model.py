"""L2 model tests: shapes, determinism, training dynamics, PS semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model

CFG = model.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def fns():
    return model.jitted(CFG)


@pytest.fixture(scope="module")
def params(fns):
    return fns["init"](jnp.uint32(0))


def toks(seed=0, cfg=CFG):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq_len)).astype(np.int32)
    )


def test_param_count_matches_specs(params):
    assert params.shape == (model.num_params(CFG),)
    total = sum(int(np.prod(s)) for _, s, _ in model.param_specs(CFG))
    assert total == model.num_params(CFG)


def test_init_deterministic(fns):
    a = fns["init"](jnp.uint32(5))
    b = fns["init"](jnp.uint32(5))
    np.testing.assert_array_equal(a, b)
    c = fns["init"](jnp.uint32(6))
    assert not np.allclose(a, c)


def test_layernorm_params_initialized(params):
    views = model._views(CFG, params)
    np.testing.assert_array_equal(views["lnf.g"], jnp.ones(CFG.d_model))
    np.testing.assert_array_equal(views["lnf.b"], jnp.zeros(CFG.d_model))


def test_initial_loss_near_uniform(fns, params):
    loss = fns["eval_loss"](params, toks())
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.3


def test_grad_shapes_and_finite(fns, params):
    g, loss = fns["grad"](params, toks())
    assert g.shape == params.shape
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.linalg.norm(g)) > 0.0


def test_train_step_composes_grad_and_apply(fns, params):
    """train_step must equal grad + apply at lr (the PS decomposition)."""
    t = toks(3)
    g, loss_g = fns["grad"](params, t)
    scale = jnp.asarray([CFG.lr], jnp.float32)
    manual = fns["apply"](params, g, scale)
    fused, loss_f = fns["train_step"](params, t)
    np.testing.assert_allclose(manual, fused, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(loss_g), float(loss_f), rtol=1e-6)


def test_loss_decreases_over_steps(fns, params):
    p = params
    t = toks(1)
    first = None
    for _ in range(25):
        p, loss = fns["train_step"](p, t)
        if first is None:
            first = float(loss)
    assert float(loss) < first - 0.2, f"{first} -> {float(loss)}"


def test_multi_worker_aggregation_matches_large_batch(fns, params):
    """Summing two workers' grads and applying lr/2 equals averaging."""
    t1, t2 = toks(10), toks(11)
    g1, _ = fns["grad"](params, t1)
    g2, _ = fns["grad"](params, t2)
    agg = fns["apply"](params, g1 + g2, jnp.asarray([CFG.lr / 2], jnp.float32))
    mean_g = (g1 + g2) / 2
    direct = fns["apply"](params, mean_g, jnp.asarray([CFG.lr], jnp.float32))
    np.testing.assert_allclose(agg, direct, rtol=1e-5, atol=1e-7)


def test_all_config_sizes_are_consistent():
    for name, cfg in model.CONFIGS.items():
        assert cfg.name == name
        assert cfg.d_model % cfg.n_heads == 0
        n = model.num_params(cfg)
        assert n > 0
    # the ~100M config really is ~100M
    assert model.num_params(model.CONFIGS["gpt100m"]) > 80_000_000
