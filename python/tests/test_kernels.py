"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (and the couple of dtypes the artifacts use);
assert_allclose against ref.py is the core correctness signal of the
compile path.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.common import pick_block, vmem_bytes

RTOL = 2e-4
ATOL = 2e-4


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------- matmul


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    w = rand(rng, k, n)
    np.testing.assert_allclose(
        kernels.matmul(x, w), ref.matmul(x, w), rtol=RTOL, atol=ATOL
    )


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([32, 64]),
    k=st.sampled_from([16, 48]),
    n=st.sampled_from([32, 80]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_grad_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    w = rand(rng, k, n)

    g1 = jax.grad(lambda a, b: jnp.sum(kernels.matmul(a, b) ** 2), (0, 1))(x, w)
    g2 = jax.grad(lambda a, b: jnp.sum(ref.matmul(a, b) ** 2), (0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_matmul_large_tiled_exact_grid():
    # shapes that exercise real multi-step K accumulation (grid k > 1)
    rng = np.random.default_rng(0)
    x = rand(rng, 256, 384)
    w = rand(rng, 384, 256)
    np.testing.assert_allclose(
        kernels.matmul(x, w), ref.matmul(x, w), rtol=5e-4, atol=5e-4
    )


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 3))
    with pytest.raises(ValueError):
        kernels.matmul(x, w)


# ----------------------------------------------------------- fused linear


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 64),
    n=st.integers(1, 80),
    act=st.sampled_from(["gelu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    w = rand(rng, k, n)
    b = rand(rng, n)
    np.testing.assert_allclose(
        kernels.fused_linear(x, w, b, act),
        ref.fused_linear(x, w, b, act),
        rtol=RTOL,
        atol=ATOL,
    )


@settings(max_examples=6, deadline=None)
@given(act=st.sampled_from(["gelu", "none"]), seed=st.integers(0, 2**31 - 1))
def test_fused_linear_grads(act, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, 48, 32)
    w = rand(rng, 32, 64)
    b = rand(rng, 64)

    def f(fn):
        return lambda *args: jnp.sum(jnp.tanh(fn(*args, act)))

    g1 = jax.grad(f(kernels.fused_linear), (0, 1, 2))(x, w, b)
    g2 = jax.grad(f(ref.fused_linear), (0, 1, 2))(x, w, b)
    for a, b2 in zip(g1, g2):
        np.testing.assert_allclose(a, b2, rtol=2e-3, atol=2e-3)


# -------------------------------------------------------------- attention


@settings(max_examples=12, deadline=None)
@given(
    bh=st.integers(1, 6),
    length=st.sampled_from([8, 16, 32, 48, 64]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(bh, length, d, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, bh, length, d)
    k = rand(rng, bh, length, d)
    v = rand(rng, bh, length, d)
    np.testing.assert_allclose(
        kernels.flash_attention(q, k, v),
        ref.flash_attention(q, k, v),
        rtol=5e-4,
        atol=5e-4,
    )


def test_attention_is_causal():
    # output at position i must not depend on inputs at positions > i
    rng = np.random.default_rng(1)
    q = rand(rng, 1, 16, 8)
    k = rand(rng, 1, 16, 8)
    v = rand(rng, 1, 16, 8)
    base = kernels.flash_attention(q, k, v)
    k2 = k.at[0, 10:].set(99.0)
    v2 = v.at[0, 10:].set(-99.0)
    pert = kernels.flash_attention(q, k2, v2)
    np.testing.assert_allclose(base[0, :10], pert[0, :10], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[0, 10:], pert[0, 10:])


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_attention_grads(seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, 2, 24, 16)
    k = rand(rng, 2, 24, 16)
    v = rand(rng, 2, 24, 16)

    def loss(fn):
        return lambda a, b, c: jnp.sum(fn(a, b, c) ** 2)

    g1 = jax.grad(loss(kernels.flash_attention), (0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(ref.flash_attention), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=3e-3, atol=3e-3)


# ------------------------------------------------------------------- sgd


@settings(max_examples=10, deadline=None)
@given(
    # fixed shape ladder: each distinct n triggers a fresh interpret-mode
    # pallas trace (~20s for 300k elements), so sweep values, not sizes
    n=st.sampled_from([1, 17, 1024, 65_536, 131_073, 470_528]),
    scale=st.floats(1e-4, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_apply_matches_ref(n, scale, seed):
    rng = np.random.default_rng(seed)
    p = rand(rng, n)
    g = rand(rng, n)
    s = jnp.asarray([scale], dtype=jnp.float32)
    np.testing.assert_allclose(
        kernels.sgd_apply(p, g, s), ref.sgd_apply(p, g, s), rtol=1e-5, atol=1e-5
    )


def test_sgd_zero_scale_is_identity():
    rng = np.random.default_rng(0)
    p = rand(rng, 1024)
    g = rand(rng, 1024)
    s = jnp.asarray([0.0], dtype=jnp.float32)
    np.testing.assert_allclose(kernels.sgd_apply(p, g, s), p)


# ------------------------------------------------------------- tiling api


@settings(max_examples=30, deadline=None)
@given(dim=st.integers(1, 4096), pref=st.sampled_from([32, 128, 256]))
def test_pick_block_divides(dim, pref):
    b = pick_block(dim, pref)
    assert 1 <= b
    assert dim % b == 0
    if dim <= pref:
        assert b == dim


def test_vmem_budget_of_default_tiles():
    # the default 128x128 f32 GEMM working set must sit well under 16 MiB
    used = vmem_bytes((128, 128), (128, 128), (128, 128))
    assert used <= 16 * 2**20 * 0.25
