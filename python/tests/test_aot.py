"""AOT pipeline tests: HLO text lowering round-trips and is well-formed."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_smoke():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # outputs are tupled for the rust loader
    assert "tuple" in text.lower()


def test_hlo_text_executes_same_numbers():
    """Round-trip: text -> XlaComputation -> execute == direct jit."""
    from jax._src.lib import xla_client as xc

    def fn(x, y):
        return (jnp.dot(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)

    backend = jax.devices()[0].client
    comp = xc._xla.hlo_module_from_text(text) if hasattr(xc._xla, "hlo_module_from_text") else None
    if comp is None:
        pytest.skip("no hlo_module_from_text in this jaxlib; rust covers it")
    # executed by the rust integration test; here we just sanity-parse
    assert text.count("ENTRY") == 1


def test_build_size_writes_all_artifacts(tmp_path):
    cfg = model.CONFIGS["tiny"]
    meta = aot.build_size(cfg, str(tmp_path))
    assert meta["num_params"] == model.num_params(cfg)
    for key in ["init", "grad", "apply", "train_step", "eval"]:
        path = tmp_path / meta["files"][key]
        assert path.exists(), f"missing {key}"
        head = path.read_text()[:2000]
        assert "HloModule" in head
    meta_file = tmp_path / "lm_tiny.meta.json"
    assert meta_file.exists()


def test_checked_in_artifacts_match_model(artifacts_dir="../artifacts"):
    """If `make artifacts` has run, the metadata must match the code."""
    path = os.path.join(artifacts_dir, "lm_tiny.meta.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    import json

    with open(path) as f:
        meta = json.load(f)
    assert meta["num_params"] == model.num_params(model.CONFIGS["tiny"])
    for fname in meta["files"].values():
        assert os.path.exists(os.path.join(artifacts_dir, fname))
