//! Property test for the persistent snapshot cache (PR 8): across
//! seeded random sequences of ledger mutations — admission commits,
//! releases, and churn-style availability flips — the delta-updated
//! snapshots held by a long-lived [`PlannerScratch`] must be
//! *structurally identical* to snapshots rebuilt from the ledger from
//! scratch. This is the invariant the `--cold-solver` byte-parity
//! contract rests on: if every cached snapshot equals its rebuild, the
//! θ-solver sees bit-identical inputs on both paths.
//!
//! 256 trials vary the cluster shape (homogeneous / skewed), the
//! eligibility masks (PD-ORS all-true / OASiS separated), machine
//! grouping on/off, and the mutation mix; every trial verifies every
//! slot after every mutation batch.

use dmlrs::cluster::AllocLedger;
use dmlrs::jobs::{Job, Schedule};
use dmlrs::sched::dp::{plan_job_with, slot_snapshot, DpConfig, Masks};
use dmlrs::sched::solver::PlannerScratch;
use dmlrs::sched::PricingParams;
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::{paper_cluster, paper_cluster_skewed};
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

const TRIALS: u64 = 256;
const HORIZON: usize = 10;
const JOBS: usize = 10;

/// Bring every slot up to date through the scratch's incremental path
/// and compare each against a from-scratch rebuild.
fn assert_slots_match_rebuild(
    scratch: &mut PlannerScratch,
    ledger: &AllocLedger,
    pricing: &PricingParams,
    masks: &Masks,
    group: bool,
    ctx: &str,
) {
    scratch.begin_episode(false, ledger, masks, group);
    for t in 0..HORIZON {
        scratch.refresh_slot(ledger, pricing, masks, t, group);
        let (cached, _sig) = scratch.snapshots.get(t);
        let fresh = slot_snapshot(ledger, pricing, masks, t, group);
        assert_eq!(
            *cached, fresh,
            "{ctx}: slot {t} cached snapshot diverged from rebuild"
        );
    }
}

#[test]
fn delta_updated_snapshots_match_rebuilds_over_random_mutation_sequences() {
    let mut total_delta_updates = 0u64;
    for trial in 0..TRIALS {
        let mut rng = Rng::new(0x5eed_0000 + trial);
        let machines = 4 + (trial % 5) as usize;
        let cluster = if trial % 2 == 0 {
            paper_cluster(machines)
        } else {
            paper_cluster_skewed(machines, 2.0)
        };
        let masks = if trial % 3 == 0 {
            Masks::separated(machines)
        } else {
            Masks::all(machines)
        };
        let group = trial % 4 < 2;
        let jobs = synthetic_jobs(
            &SynthConfig::paper(JOBS, HORIZON, MIX_DEFAULT),
            &mut rng.fork(1),
        );
        let pricing = PricingParams::from_jobs(&jobs, &cluster, HORIZON);
        let cfg = DpConfig::default();
        let mut ledger = AllocLedger::new(&cluster, HORIZON);
        let mut scratch = PlannerScratch::new();
        let mut committed: Vec<(Job, Schedule)> = Vec::new();
        let mut next_job = 0usize;

        let ops = 8 + (trial % 7) as usize;
        for op in 0..ops {
            // range_usize is inclusive: 0 = commit, 1 = release, 2 = churn
            match rng.range_usize(0, 2) {
                // plan + commit the next arrival (through the same
                // scratch, so planning itself runs the incremental path)
                0 if next_job < jobs.len() => {
                    let job = jobs[next_job].clone();
                    next_job += 1;
                    let plan = plan_job_with(
                        &job,
                        &ledger,
                        &pricing,
                        &masks,
                        &cfg,
                        &mut rng.fork(2 + op as u64),
                        &mut scratch,
                    );
                    if let Some(p) = plan {
                        if p.payoff > 0.0 {
                            ledger.commit(&job, &p.schedule);
                            committed.push((job, p.schedule));
                        }
                    }
                }
                // release a random committed schedule (replan/migration)
                1 if !committed.is_empty() => {
                    let i = rng.range_usize(0, committed.len() - 1);
                    let (job, sched) = committed.swap_remove(i);
                    ledger.release(&job, &sched);
                }
                // churn: flip one machine's availability from a slot on
                _ => {
                    let h = rng.range_usize(0, machines - 1);
                    let from = rng.range_usize(0, HORIZON - 1);
                    let up = rng.chance(0.5);
                    ledger.set_available_from(h, from, up);
                }
            }
            assert_slots_match_rebuild(
                &mut scratch,
                &ledger,
                &pricing,
                &masks,
                group,
                &format!("trial {trial} op {op}"),
            );
        }
        total_delta_updates += scratch.stats.snapshot_delta_updates;
    }
    // the point of the exercise: the cheap path must actually run —
    // a suite where every refresh fell back to a full rebuild would
    // vacuously pass the equality checks
    assert!(
        total_delta_updates > 0,
        "no snapshot was ever delta-updated across {TRIALS} trials"
    );
}

#[test]
fn snapshot_cache_survives_interleaved_planning_and_churn_exactly() {
    // A tighter end-to-end variant: two scratches plan the same arrival
    // stream over the same mutating ledger — one long-lived (incremental),
    // one rebuilt cold before every plan — and must produce identical
    // plans throughout.
    for trial in 0..16u64 {
        let mut rng = Rng::new(0xabcd + trial);
        let machines = 6;
        let cluster = paper_cluster_skewed(machines, 2.0);
        let masks = Masks::all(machines);
        let jobs = synthetic_jobs(
            &SynthConfig::paper(JOBS, HORIZON, MIX_DEFAULT),
            &mut rng.fork(1),
        );
        let pricing = PricingParams::from_jobs(&jobs, &cluster, HORIZON);
        let warm_cfg = DpConfig::default();
        let cold_cfg = DpConfig { cold_solver: true, ..Default::default() };
        let mut ledger = AllocLedger::new(&cluster, HORIZON);
        let mut warm_scratch = PlannerScratch::new();
        let mut cold_scratch = PlannerScratch::new();

        for (i, job) in jobs.iter().enumerate() {
            // identical RNG streams for both planners (rounding replays)
            let mut rng_a = rng.fork(100 + i as u64);
            let mut rng_b = rng.fork(100 + i as u64);
            let warm = plan_job_with(
                job, &ledger, &pricing, &masks, &warm_cfg, &mut rng_a,
                &mut warm_scratch,
            );
            let cold = plan_job_with(
                job, &ledger, &pricing, &masks, &cold_cfg, &mut rng_b,
                &mut cold_scratch,
            );
            match (&warm, &cold) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.schedule, b.schedule, "trial {trial} job {i}");
                    assert_eq!(
                        a.payoff.to_bits(),
                        b.payoff.to_bits(),
                        "trial {trial} job {i}: payoff bits diverged"
                    );
                }
                (None, None) => {}
                _ => panic!("trial {trial} job {i}: admit/reject diverged"),
            }
            if let Some(p) = warm {
                if p.payoff > 0.0 {
                    ledger.commit(job, &p.schedule);
                }
            }
            if i == JOBS / 2 {
                // mid-stream churn: down a machine, then bring it back
                ledger.set_available_from(1, i % HORIZON, false);
                ledger.set_available_from(1, (i + 2) % HORIZON, true);
            }
        }
        assert!(
            warm_scratch.stats.snapshot_delta_updates > 0
                || warm_scratch.stats.warm_hits > 0,
            "trial {trial}: incremental planner never reused anything"
        );
    }
}
