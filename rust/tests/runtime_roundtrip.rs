//! Integration: the rust PJRT runtime against the AOT artifacts.
//!
//! Requires `make artifacts` (tiny size). Tests skip gracefully when the
//! artifacts directory is absent so `cargo test` works pre-build.

use dmlrs::exec::{execute_schedule, ExecConfig, TokenGen};
use dmlrs::jobs::{Schedule, SlotPlacement};
use dmlrs::runtime::{ModelBundle, XlaRuntime};

fn bundle() -> Option<(XlaRuntime, ModelBundle)> {
    if !std::path::Path::new("artifacts/lm_tiny.meta.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    let b = ModelBundle::load(&rt, "artifacts", "tiny").expect("load tiny bundle");
    Some((rt, b))
}

#[test]
fn init_params_shape_and_determinism() {
    let Some((_rt, b)) = bundle() else { return };
    let p1 = b.init_params(0).unwrap();
    let p2 = b.init_params(0).unwrap();
    let v1 = p1.to_vec::<f32>().unwrap();
    let v2 = p2.to_vec::<f32>().unwrap();
    assert_eq!(v1.len(), b.meta.num_params);
    assert_eq!(v1, v2, "same seed, same params");
    let p3 = b.init_params(1).unwrap();
    assert_ne!(v1, p3.to_vec::<f32>().unwrap(), "different seed differs");
}

#[test]
fn initial_loss_is_near_uniform() {
    let Some((_rt, b)) = bundle() else { return };
    let params = b.init_params(0).unwrap();
    let mut gen = TokenGen::new(0, b.meta.vocab);
    let tokens = gen.batch(b.meta.batch, b.meta.seq_len);
    let loss = b.eval_loss(&params, &tokens).unwrap();
    let uniform = (b.meta.vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 0.5,
        "init loss {loss} should be near ln(V) = {uniform}"
    );
}

#[test]
fn grad_plus_apply_equals_train_step() {
    // The PS decomposition (grad artifact + apply artifact) must reproduce
    // the fused train_step artifact bit-for-bit-ish.
    let Some((_rt, b)) = bundle() else { return };
    let params = b.init_params(7).unwrap();
    let mut gen = TokenGen::new(1, b.meta.vocab);
    let tokens = gen.batch(b.meta.batch, b.meta.seq_len);

    let (g, loss_g) = b.grad(&params, &tokens).unwrap();
    let manual = b
        .apply(params.clone(), &g, b.meta.lr as f32)
        .unwrap()
        .to_vec::<f32>()
        .unwrap();
    let (fused, loss_f) = b.train_step(params, &tokens).unwrap();
    let fused = fused.to_vec::<f32>().unwrap();

    assert!((loss_g - loss_f).abs() < 1e-6);
    let max_diff = manual
        .iter()
        .zip(&fused)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "PS decomposition diverges: {max_diff}");
}

#[test]
fn fused_steps_reduce_loss() {
    let Some((_rt, b)) = bundle() else { return };
    let mut params = b.init_params(0).unwrap();
    let mut gen = TokenGen::new(2, b.meta.vocab);
    let tokens = gen.batch(b.meta.batch, b.meta.seq_len);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..20 {
        let (p, loss) = b.train_step(params, &tokens).unwrap();
        params = p;
        first.get_or_insert(loss);
        last = loss;
    }
    assert!(
        last < first.unwrap() - 0.1,
        "loss did not fall: {} -> {last}",
        first.unwrap()
    );
}

#[test]
fn executor_runs_a_multi_slot_schedule() {
    let Some((_rt, b)) = bundle() else { return };
    let mut job = dmlrs::jobs::test_support::test_job(0);
    job.grad_size_mb = b.meta.num_params as f64 * 4.0 / 1e6;
    let schedule = Schedule {
        job_id: 0,
        slots: vec![
            // slot 0: co-located on machine 0 (internal locality)
            SlotPlacement { t: 0, placements: vec![(0, 3, 2)] },
            // slot 1: spread (external locality)
            SlotPlacement { t: 1, placements: vec![(0, 2, 0), (1, 0, 1), (2, 1, 1)] },
        ],
    };
    let cfg = ExecConfig { max_iters_per_slot: 3, eval_each_slot: true, seed: 5 };
    let report = execute_schedule(&b, &job, &schedule, &cfg).unwrap();
    assert_eq!(report.slots.len(), 2);
    assert_eq!(report.slots[0].locality, dmlrs::jobs::Locality::Internal);
    assert_eq!(report.slots[1].locality, dmlrs::jobs::Locality::External);
    assert_eq!(report.losses.len(), 6);
    assert_eq!(report.eval_losses.len(), 2);
    // BSP with more workers trains more samples per iteration
    assert!(report.total_samples > 0.0);
    // internal slot should simulate faster per-iteration time than external
    let t_int = report.slots[0].sim_time / report.slots[0].iterations as f64;
    let t_ext = report.slots[1].sim_time / report.slots[1].iterations as f64;
    assert!(t_int < t_ext, "internal {t_int} !< external {t_ext}");
}
