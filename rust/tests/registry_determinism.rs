//! Table-driven coverage of the scheduler registry: every registered
//! policy runs on a tiny synthetic workload through the unified engine,
//! and a fixed seed must reproduce the exact same `SimResult` across
//! independent runs (construction included).

use dmlrs::sched::registry::{SchedulerRegistry, SchedulerSpec, ZOO};
use dmlrs::sim::{simulate, SimEngine, SimResult, StreamingMetrics, TraceObserver};
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

const JOBS: usize = 10;
const MACHINES: usize = 6;
const HORIZON: usize = 12;
const WORKLOAD_SEED: u64 = 42;
const SCHED_SEED: u64 = 7;

fn tiny_workload() -> Vec<dmlrs::jobs::Job> {
    let mut rng = Rng::new(WORKLOAD_SEED);
    synthetic_jobs(&SynthConfig::paper(JOBS, HORIZON, MIX_DEFAULT), &mut rng)
}

fn run_once(key: &str) -> SimResult {
    let reg = SchedulerRegistry::builtin();
    let jobs = tiny_workload();
    let cluster = paper_cluster(MACHINES);
    let spec = SchedulerSpec::new(key).with_seed(SCHED_SEED);
    let mut sched = reg.build(&spec, &jobs, &cluster, HORIZON).unwrap();
    simulate(&jobs, &cluster, HORIZON, sched.as_mut())
}

#[test]
fn every_registered_scheduler_is_deterministic() {
    let reg = SchedulerRegistry::builtin();
    for key in reg.names() {
        let a = run_once(key);
        let b = run_once(key);
        assert_eq!(a.scheduler, reg.display(key).unwrap(), "{key}");
        assert_eq!(a.outcomes.len(), JOBS, "{key}");
        assert_eq!(
            a, b,
            "{key}: two runs with the same seed must produce identical SimResults"
        );
    }
}

#[test]
fn zoo_constant_matches_the_builtin_registry() {
    let reg = SchedulerRegistry::builtin();
    assert_eq!(reg.names(), ZOO.to_vec());
}

#[test]
fn observers_do_not_perturb_results() {
    // Attaching observers must not change the outcome (they only watch).
    for key in ZOO {
        let bare = run_once(key);

        let reg = SchedulerRegistry::builtin();
        let jobs = tiny_workload();
        let cluster = paper_cluster(MACHINES);
        let spec = SchedulerSpec::new(key).with_seed(SCHED_SEED);
        let mut sched = reg.build(&spec, &jobs, &cluster, HORIZON).unwrap();
        let mut trace = TraceObserver::new();
        let mut metrics = StreamingMetrics::new();
        let observed = SimEngine::builder()
            .jobs(&jobs)
            .cluster(&cluster)
            .horizon(HORIZON)
            .observer(&mut trace)
            .observer(&mut metrics)
            .run(sched.as_mut());

        assert_eq!(bare, observed, "{key}");
        // streaming counters agree with the aggregate
        assert_eq!(metrics.admitted, observed.admitted, "{key}");
        assert_eq!(metrics.completed, observed.completed, "{key}");
        assert!(
            (metrics.total_utility - observed.total_utility).abs() < 1e-9,
            "{key}"
        );
        assert!(!trace.lines().is_empty(), "{key}");
    }
}
