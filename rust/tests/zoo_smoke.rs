//! Integration: the full scheduler zoo on shared workloads — the
//! comparative claims behind Figs. 6–9 at smoke scale, resolved through
//! the scheduler registry.

use dmlrs::baselines::offline_optimum;
use dmlrs::cluster::AllocLedger;
use dmlrs::jobs::Schedule;
use dmlrs::sched::registry::{run_named, SchedulerRegistry, ZOO};
use dmlrs::sched::{PdOrs, PdOrsConfig};
use dmlrs::sim::metrics::median_training_time;
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{google_trace_jobs, synthetic_jobs, SynthConfig, MIX_DEFAULT, MIX_TRACE};

#[test]
fn all_schedulers_produce_valid_results() {
    let cluster = paper_cluster(20);
    let mut rng = Rng::new(1);
    let jobs = synthetic_jobs(&SynthConfig::paper(25, 20, MIX_DEFAULT), &mut rng);
    for key in ZOO {
        let res = run_named(key, &jobs, &cluster, 20, 7).unwrap();
        assert_eq!(res.outcomes.len(), jobs.len(), "{}", res.scheduler);
        assert!(res.total_utility >= 0.0, "{}", res.scheduler);
        assert!(res.completed <= res.admitted, "{}", res.scheduler);
        for o in &res.outcomes {
            assert!(o.training_time <= 20.0);
            if o.completed {
                assert!(o.admitted);
                assert!(o.completion.is_some());
            } else {
                assert_eq!(o.utility, 0.0, "{} uncompleted job got utility", res.scheduler);
            }
        }
    }
}

#[test]
fn pdors_wins_on_average() {
    // Fig. 6/7 headline: PD-ORS beats every baseline in total utility,
    // averaged over a few seeds.
    let reg = SchedulerRegistry::builtin();
    let mut totals = std::collections::HashMap::new();
    for seed in 0..3u64 {
        let cluster = paper_cluster(30);
        let mut rng = Rng::new(100 + seed);
        let jobs = synthetic_jobs(&SynthConfig::paper(30, 20, MIX_DEFAULT), &mut rng);
        for key in ZOO {
            let res = run_named(key, &jobs, &cluster, 20, seed).unwrap();
            *totals.entry(reg.display(key).unwrap()).or_insert(0.0) += res.total_utility;
        }
    }
    let pdors = totals["PD-ORS"];
    for (name, total) in &totals {
        if *name != "PD-ORS" {
            assert!(
                pdors >= *total,
                "PD-ORS ({pdors:.1}) lost to {name} ({total:.1}): {totals:?}"
            );
        }
    }
}

#[test]
fn pdors_median_training_time_not_worst() {
    // Fig. 9: PD-ORS should have the (near-)smallest median training time.
    let reg = SchedulerRegistry::builtin();
    let cluster = paper_cluster(20);
    let mut rng = Rng::new(9);
    let jobs = google_trace_jobs(40, 40, MIX_TRACE, &mut rng);
    let mut medians = Vec::new();
    for key in ZOO {
        let res = run_named(key, &jobs, &cluster, 40, 3).unwrap();
        medians.push((reg.display(key).unwrap().to_string(), median_training_time(&res)));
    }
    let pdors = medians.iter().find(|(n, _)| n == "PD-ORS").unwrap().1;
    let worst = medians.iter().map(|(_, m)| *m).fold(0.0, f64::max);
    assert!(
        pdors <= worst,
        "PD-ORS median {pdors} is the worst: {medians:?}"
    );
}

#[test]
fn offline_optimum_dominates_online() {
    let t = 10;
    let cluster = paper_cluster(4);
    // small instances (the Fig. 10 regime)
    let mut rng = Rng::new(77);
    let mut cfg = SynthConfig::paper(6, t, MIX_DEFAULT);
    cfg.samples = (2_000.0, 30_000.0);
    cfg.epochs = (10, 40);
    cfg.batch = (10, 60);
    let jobs = synthetic_jobs(&cfg, &mut rng);
    let mut pdors = PdOrs::new(PdOrsConfig::default(), &jobs, &cluster, t);
    let mut ledger = AllocLedger::new(&cluster, t);
    let mut choices: Vec<(usize, f64, Schedule)> = Vec::new();
    let mut total = 0.0;
    for (i, job) in jobs.iter().enumerate() {
        if let Some(s) = pdors.on_arrival(job, &mut ledger) {
            let u = job.utility_at(s.completion_time().unwrap());
            total += u;
            choices.push((i, u, s));
        }
    }
    let opt = offline_optimum(&jobs, &cluster, t, &choices, 0);
    assert!(opt + 1e-6 >= total, "OPT {opt} < PD-ORS {total}");
}

#[test]
fn trace_workload_runs_all_schedulers() {
    let cluster = paper_cluster(15);
    let mut rng = Rng::new(4);
    let jobs = google_trace_jobs(30, 40, MIX_TRACE, &mut rng);
    for key in ZOO {
        let res = run_named(key, &jobs, &cluster, 40, 0).unwrap();
        assert_eq!(res.outcomes.len(), 30, "{}", res.scheduler);
    }
}
