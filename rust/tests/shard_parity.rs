//! Sharding parity suite (PR 10): the cell/router architecture must be
//! invisible at the protocol level.
//!
//! * A **1-shard** router is a pure passthrough: every deterministic
//!   response (submit / tick / status / cells / cluster) is
//!   byte-identical to the plain [`ServiceCore`] fed the same request
//!   sequence, for every scheduler in the zoo, and the final reports are
//!   equal — decisions, completions, utility, and solver counters
//!   included.
//! * A **k-shard** service conserves the ledger: the per-cell loads
//!   reported by the `cells` op sum to the merged `status.ledger_sum`,
//!   and both equal the whole-cluster usage recomputed independently
//!   from the admitted schedules' placements. No placement ever lands
//!   outside its owner cell's machine range.
//! * **Batch drain** is unobservable: `--batch 16` produces the same
//!   response bytes, the same final report (RNG stream and solver
//!   counters included), and the same op-log journal bytes as the
//!   `--batch 1` oracle.
//! * **Per-cell op-logs** recover independently: replaying every
//!   `<path>.cell<i>` journal reproduces the merged pre-shutdown report
//!   exactly.

use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use dmlrs::chaos::ChurnSpec;
use dmlrs::cluster::NUM_RESOURCES;
use dmlrs::sched::registry::{SchedulerSpec, ZOO};
use dmlrs::service::{
    Request, RouterMsg, ServiceConfig, ServiceCore, ShardConfig,
};
use dmlrs::service::shard::{cell_log_path, spawn};
use dmlrs::sweep::{ClusterSpec, WorkloadSpec};
use dmlrs::util::json::Json;

fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("dmlrs_shard_parity_{tag}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// A live router: send requests, read raw response strings (the bytes a
/// wire client would see).
struct Router {
    tx: Sender<RouterMsg>,
    handle: std::thread::JoinHandle<Option<dmlrs::service::ServiceReport>>,
}

impl Router {
    fn start(cfg: ShardConfig) -> Router {
        let (tx, rx) = channel::<RouterMsg>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let handle = spawn(cfg, rx, shutdown).expect("router starts");
        Router { tx, handle }
    }

    /// One blocking round-trip, returning the raw response line.
    fn ask(&self, req: Request) -> String {
        let (rtx, rrx) = channel();
        self.tx.send(RouterMsg::new(req, Some(rtx))).expect("router alive");
        rrx.recv().expect("router answers")
    }

    /// Enqueue without waiting (what a pipelining client does); the
    /// response arrives on the returned channel.
    fn send(&self, req: Request) -> Receiver<String> {
        let (rtx, rrx) = channel();
        self.tx.send(RouterMsg::new(req, Some(rtx))).expect("router alive");
        rrx
    }

    fn finish(self) -> dmlrs::service::ServiceReport {
        drop(self.tx);
        self.handle.join().expect("router thread").expect("merged report")
    }
}

fn service(key: &str, seed: u64, machines: usize, jobs: usize) -> ServiceConfig {
    ServiceConfig {
        scheduler: SchedulerSpec::new(key).with_seed(seed),
        cluster: ClusterSpec::homogeneous(machines),
        workload: WorkloadSpec::synthetic(jobs, 12, 0),
        churn: ChurnSpec::None,
    }
}

/// The deterministic request sequence both sides replay: every arrival
/// in submission order, a tick per slot, and periodic status probes.
/// (`metrics` is excluded on purpose — its latency percentiles are
/// wall-clock and legitimately differ between runs.)
fn parity_sequence(svc: &ServiceConfig) -> Vec<Request> {
    let jobs = svc.workload.jobs(svc.scheduler.seed);
    let horizon = svc.horizon();
    let mut seq = Vec::new();
    let mut next = 0usize;
    for t in 0..horizon {
        while next < jobs.len() && jobs[next].arrival <= t {
            seq.push(Request::Submit { job: jobs[next].clone() });
            next += 1;
        }
        seq.push(Request::Tick);
        if t % 4 == 0 {
            seq.push(Request::Status);
        }
    }
    seq.push(Request::Status);
    seq.push(Request::Cells);
    seq.push(Request::Cluster);
    seq
}

#[test]
fn one_shard_router_is_byte_identical_to_the_plain_core() {
    for key in ZOO {
        let svc = service(key, 3, 8, 20);
        let seq = parity_sequence(&svc);

        // plain, unsharded core
        let mut core = ServiceCore::new(svc.clone()).expect("core builds");
        let plain: Vec<String> =
            seq.iter().map(|req| core.apply(req).to_string()).collect();
        let plain_report = core.report();

        // the same sequence through a 1-shard router
        let router = Router::start(ShardConfig {
            service: svc,
            shards: 1,
            batch: 8,
            oplog: None,
            recover: None,
        });
        let routed: Vec<String> =
            seq.iter().map(|req| router.ask(req.clone())).collect();
        let report = router.finish();

        for (i, (a, b)) in plain.iter().zip(&routed).enumerate() {
            assert_eq!(a, b, "{key}: response {i} diverged for {:?}", seq[i]);
        }
        assert_eq!(report, plain_report, "{key}: final reports diverged");
    }
}

#[test]
fn four_shards_conserve_the_ledger_and_respect_cell_ranges() {
    let shards = 4usize;
    let svc = service("pd-ors", 1, 8, 24);
    let jobs = svc.workload.jobs(1);
    let router = Router::start(ShardConfig {
        service: svc,
        shards,
        batch: 8,
        oplog: None,
        recover: None,
    });

    // submit everything up front (slot 0 — the ledger then holds each
    // admitted schedule's full future allocation, which is what the
    // conservation check recomputes below)
    let mut responses = Vec::new();
    for job in &jobs {
        let resp = Json::parse(&router.ask(Request::Submit { job: job.clone() }))
            .expect("submit answers JSON");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string());
        responses.push(resp);
    }

    // global job ids are distinct across cells
    let mut ids: Vec<usize> = responses
        .iter()
        .map(|r| r.get("job_id").unwrap().as_usize().unwrap())
        .collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), jobs.len(), "duplicate global job ids");

    // cell layout: owner of global id g is cell g % k, owning a
    // contiguous machine range
    let cells = Json::parse(&router.ask(Request::Cells)).unwrap();
    assert_eq!(cells.get("shards").unwrap().as_usize(), Some(shards));
    let entries = cells.get("cells").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(entries.len(), shards);
    let range_of = |cell: usize| -> (usize, usize) {
        let e = &entries[cell];
        assert_eq!(e.get("cell").unwrap().as_usize(), Some(cell));
        (
            e.get("machines_start").unwrap().as_usize().unwrap(),
            e.get("machines_end").unwrap().as_usize().unwrap(),
        )
    };

    // every admitted placement stays inside its owner cell's range, and
    // the whole-cluster usage recomputes from the wire artifacts
    let mut expected_usage = 0.0f64;
    let mut admitted = 0usize;
    for (resp, job) in responses.iter().zip(&jobs) {
        if resp.get("decision").and_then(Json::as_str) != Some("admitted") {
            continue;
        }
        admitted += 1;
        let gid = resp.get("job_id").unwrap().as_usize().unwrap();
        let (start, end) = range_of(gid % shards);
        let sched = resp.get("schedule").unwrap();
        for slot in sched.get("slots").unwrap().as_arr().unwrap() {
            for p in slot.get("placements").unwrap().as_arr().unwrap() {
                let p = p.as_arr().unwrap();
                let h = p[0].as_usize().unwrap();
                let w = p[1].as_f64().unwrap();
                let ps = p[2].as_f64().unwrap();
                assert!(
                    (start..end).contains(&h),
                    "job {gid} (cell {}) placed on machine {h} outside {start}..{end}",
                    gid % shards
                );
                for r in 0..NUM_RESOURCES {
                    expected_usage +=
                        w * job.worker_demand.0[r] + ps * job.ps_demand.0[r];
                }
            }
        }
    }
    assert!(admitted > 0, "pd-ors should admit something at slot 0");

    // conservation: per-cell loads sum to the merged ledger_sum, and
    // both equal the independently recomputed usage
    let cell_load_sum: f64 =
        entries.iter().map(|e| e.get("load").unwrap().as_f64().unwrap()).sum();
    let status = Json::parse(&router.ask(Request::Status)).unwrap();
    let ledger_sum = status.get("ledger_sum").unwrap().as_f64().unwrap();
    assert!(
        (cell_load_sum - ledger_sum).abs() < 1e-9,
        "cell loads {cell_load_sum} vs merged ledger {ledger_sum}"
    );
    assert!(
        (ledger_sum - expected_usage).abs() < 1e-6,
        "ledger {ledger_sum} vs usage recomputed from schedules {expected_usage}"
    );

    // merged counters account for every submission
    let submitted = status.get("submitted").unwrap().as_usize().unwrap();
    let decided = status.get("admitted").unwrap().as_usize().unwrap()
        + status.get("rejected").unwrap().as_usize().unwrap()
        + status.get("deferred").unwrap().as_usize().unwrap();
    assert_eq!(submitted, jobs.len());
    assert_eq!(decided, jobs.len());

    // run the horizon out and check the merged final report
    for _ in 0..12 {
        router.ask(Request::Tick);
    }
    let report = router.finish();
    assert_eq!(report.submitted, jobs.len());
    assert_eq!(report.admitted, admitted);
    assert_eq!(report.alloc[0].len(), 8, "merged alloc spans the whole cluster");
}

#[test]
fn batch_16_matches_batch_1_byte_for_byte_including_the_journal() {
    let run = |batch: usize, path: &str| {
        let _ = std::fs::remove_file(path);
        let svc = service("pd-ors", 2, 6, 16);
        let jobs = svc.workload.jobs(2);
        let router = Router::start(ShardConfig {
            service: svc,
            shards: 1,
            batch,
            oplog: Some(path.to_string()),
            recover: None,
        });
        // pipeline all submits without waiting, so a batch > 1 cell
        // actually drains them in bursts; then a tick and a status probe
        let waits: Vec<_> = jobs
            .iter()
            .map(|job| router.send(Request::Submit { job: job.clone() }))
            .collect();
        let mut out: Vec<String> =
            waits.into_iter().map(|w| w.recv().unwrap()).collect();
        out.push(router.ask(Request::Tick));
        out.push(router.ask(Request::Status));
        let report = router.finish();
        let journal = std::fs::read(path).expect("journal written");
        (out, report, journal)
    };

    let path1 = tmp_path("batch1");
    let path16 = tmp_path("batch16");
    let (out1, report1, journal1) = run(1, &path1);
    let (out16, report16, journal16) = run(16, &path16);

    assert_eq!(out1, out16, "responses must not depend on the drain batch");
    assert_eq!(report1, report16, "reports (RNG + solver counters) diverged");
    assert_eq!(journal1, journal16, "op-log bytes diverged");
    let _ = std::fs::remove_file(&path1);
    let _ = std::fs::remove_file(&path16);
}

#[test]
fn per_cell_oplogs_recover_each_cell_independently() {
    let shards = 3usize;
    let base = tmp_path("cells");
    for i in 0..shards {
        let _ = std::fs::remove_file(cell_log_path(&base, i, shards));
    }
    let svc = service("pd-ors", 5, 6, 18);
    let jobs = svc.workload.jobs(5);

    let router = Router::start(ShardConfig {
        service: svc.clone(),
        shards,
        batch: 4,
        oplog: Some(base.clone()),
        recover: None,
    });
    let mut next = 0usize;
    for t in 0..svc.horizon() {
        while next < jobs.len() && jobs[next].arrival <= t {
            router.ask(Request::Submit { job: jobs[next].clone() });
            next += 1;
        }
        router.ask(Request::Tick);
    }
    let report = router.finish();

    // every cell wrote its own journal ...
    for i in 0..shards {
        let path = cell_log_path(&base, i, shards);
        assert!(
            std::fs::metadata(&path).map(|m| m.len() > 0).unwrap_or(false),
            "cell {i} journal missing at {path}"
        );
    }

    // ... and replaying them reproduces the merged state exactly
    let recovered = Router::start(ShardConfig {
        service: svc,
        shards,
        batch: 4,
        oplog: None,
        recover: Some(base.clone()),
    });
    let status = Json::parse(&recovered.ask(Request::Status)).unwrap();
    assert_eq!(
        status.get("submitted").unwrap().as_usize(),
        Some(jobs.len()),
        "{}",
        status.to_string()
    );
    let replayed = recovered.finish();
    assert_eq!(replayed, report, "per-cell replay must be byte-identical");
    for i in 0..shards {
        let _ = std::fs::remove_file(cell_log_path(&base, i, shards));
    }
}
