//! Property tests over the scheduler stack (testkit substrate; proptest is
//! unavailable offline). Each property runs dozens of seeded random cases
//! and reports the failing seed on violation.

use dmlrs::cluster::{AllocLedger, NUM_RESOURCES};
use dmlrs::lp::{solve, Cmp, LpProblem};
use dmlrs::prop_assert;
use dmlrs::sched::pricing::PricingParams;
use dmlrs::sched::rounding::round_coord;
use dmlrs::sched::{PdOrs, PdOrsConfig, Placement};
use dmlrs::testkit::check;
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT, MIX_TRACE};

/// (i) No admitted schedule ever exceeds any (t, h, r) capacity.
#[test]
fn prop_capacity_never_exceeded() {
    check("capacity", 0xC0FFEE, 12, |rng| {
        let h = rng.range_usize(2, 20);
        let i = rng.range_usize(2, 20);
        let t = rng.range_usize(6, 24);
        let cluster = paper_cluster(h);
        let jobs = synthetic_jobs(&SynthConfig::paper(i, t, MIX_DEFAULT), rng);
        let placement =
            if rng.chance(0.5) { Placement::Colocated } else { Placement::Separated };
        let cfg = PdOrsConfig { placement, seed: rng.next_u64(), ..Default::default() };
        let mut sched = PdOrs::new(cfg, &jobs, &cluster, t);
        let mut ledger = AllocLedger::new(&cluster, t);
        for job in &jobs {
            sched.on_arrival(job, &mut ledger);
        }
        prop_assert!(ledger.within_capacity(1e-6), "capacity exceeded (H={h} I={i} T={t})");
        Ok(())
    });
}

/// (ii) Admitted schedules cover E_i K_i and satisfy Eqs. (2), (4), (7).
#[test]
fn prop_admitted_schedules_valid() {
    check("valid-schedules", 0xBEEF, 10, |rng| {
        let h = rng.range_usize(3, 16);
        let t = rng.range_usize(8, 20);
        let cluster = paper_cluster(h);
        let jobs = synthetic_jobs(&SynthConfig::paper(12, t, MIX_DEFAULT), rng);
        let cfg = PdOrsConfig { seed: rng.next_u64(), ..Default::default() };
        let mut sched = PdOrs::new(cfg, &jobs, &cluster, t);
        let mut ledger = AllocLedger::new(&cluster, t);
        for job in &jobs {
            if let Some(s) = sched.on_arrival(job, &mut ledger) {
                prop_assert!(s.covers_workload(job, 1.0), "job {} under-covered", job.id);
                prop_assert!(s.respects_worker_cap(job), "job {} Eq.(4)", job.id);
                prop_assert!(s.respects_gamma(job), "job {} Eq.(2)", job.id);
                prop_assert!(s.respects_arrival(job), "job {} Eq.(7)", job.id);
            }
        }
        Ok(())
    });
}

/// (iii) Prices stay within [L, U^r] and are monotone in ρ.
#[test]
fn prop_prices_bounded_monotone() {
    check("prices", 0xFEED, 20, |rng| {
        let h = rng.range_usize(2, 30);
        let t = rng.range_usize(5, 40);
        let cluster = paper_cluster(h);
        let jobs = synthetic_jobs(&SynthConfig::paper(10, t, MIX_TRACE), rng);
        let p = PricingParams::from_jobs(&jobs, &cluster, t);
        for r in 0..NUM_RESOURCES {
            let cap = 32.0;
            let mut prev = 0.0;
            for k in 0..=16 {
                let rho = cap * k as f64 / 16.0;
                let price = p.price(r, rho, cap);
                prop_assert!(price >= p.l * (1.0 - 1e-12), "price below L");
                prop_assert!(price <= p.u[r] * (1.0 + 1e-12), "price above U^r");
                prop_assert!(price >= prev, "price not monotone");
                prev = price;
            }
        }
        prop_assert!(p.epsilon() >= 1.0, "epsilon < 1");
        Ok(())
    });
}

/// (iv) Randomized rounding preserves expectation to within CLT noise.
#[test]
fn prop_rounding_unbiased() {
    check("rounding", 0xABCD, 10, |rng| {
        let x = rng.range_f64(0.0, 20.0);
        let n = 40_000;
        let mut sum = 0u64;
        for _ in 0..n {
            sum += round_coord(rng, x);
        }
        let mean = sum as f64 / n as f64;
        // sd of the fractional Bernoulli is <= 0.5 => 5 sigma ~ 0.0125
        prop_assert!((mean - x).abs() < 0.02, "E[round {x}] = {mean}");
        Ok(())
    });
}

/// (v) Simplex optimality: on random 2-var LPs the simplex matches a fine
/// grid search over the feasible region.
#[test]
fn prop_simplex_matches_grid() {
    check("simplex-grid", 0x5EED, 25, |rng| {
        let c = [rng.range_f64(0.1, 3.0), rng.range_f64(0.1, 3.0)];
        let a = [rng.range_f64(0.2, 2.0), rng.range_f64(0.2, 2.0)];
        let bnd = rng.range_f64(2.0, 12.0);
        let cap0 = rng.range_f64(4.0, 20.0);
        let cap1 = rng.range_f64(4.0, 20.0);
        let mut p = LpProblem::new(2);
        p.set_objective(c.to_vec());
        p.add_row(a.to_vec(), Cmp::Ge, bnd); // cover
        p.add_row(vec![1.0, 0.0], Cmp::Le, cap0);
        p.add_row(vec![0.0, 1.0], Cmp::Le, cap1);
        let outcome = solve(&p);
        // grid search
        let mut best = f64::INFINITY;
        let steps = 400;
        for i in 0..=steps {
            for j in 0..=steps {
                let x = cap0 * i as f64 / steps as f64;
                let y = cap1 * j as f64 / steps as f64;
                if a[0] * x + a[1] * y >= bnd - 1e-9 {
                    best = best.min(c[0] * x + c[1] * y);
                }
            }
        }
        match outcome.optimal() {
            Some(s) => {
                prop_assert!(
                    s.objective <= best + 1e-6,
                    "simplex {} worse than grid {best}",
                    s.objective
                );
                prop_assert!(p.is_feasible(&s.x, 1e-7), "simplex solution infeasible");
            }
            None => {
                prop_assert!(best.is_infinite(), "simplex said infeasible, grid found {best}");
            }
        }
        Ok(())
    });
}

/// (vi) OASiS (separated) does not outperform PD-ORS *in aggregate* over
/// many workloads (the co-location advantage, Figs. 8/12–17). Individual
/// instances can go either way — admission is online and randomized — so
/// the property sums utilities across all cases.
#[test]
fn prop_colocated_at_least_separated_aggregate() {
    let mut co_total = 0.0;
    let mut sep_total = 0.0;
    check("coloc-dominates", 0xDADA, 8, |rng| {
        let h = rng.range_usize(6, 20) & !1; // even
        let t = 20;
        let cluster = paper_cluster(h);
        let jobs = synthetic_jobs(&SynthConfig::paper(15, t, MIX_DEFAULT), rng);
        let seed = rng.next_u64();
        let mut co = PdOrs::new(PdOrsConfig { seed, ..Default::default() }, &jobs, &cluster, t);
        let mut sep = PdOrs::new(
            PdOrsConfig { placement: Placement::Separated, seed, ..Default::default() },
            &jobs,
            &cluster,
            t,
        );
        let mut l1 = AllocLedger::new(&cluster, t);
        let mut l2 = AllocLedger::new(&cluster, t);
        for job in &jobs {
            co.on_arrival(job, &mut l1);
            sep.on_arrival(job, &mut l2);
        }
        co_total += co.total_utility();
        sep_total += sep.total_utility();
        Ok(())
    });
    assert!(
        co_total >= sep_total * 0.9,
        "co-location lost in aggregate: {co_total:.2} vs {sep_total:.2}"
    );
}

/// (vii) The allocation ledger's commit/release are exact inverses.
#[test]
fn prop_ledger_commit_release_inverse() {
    check("ledger-inverse", 0xF00D, 15, |rng| {
        let h = rng.range_usize(2, 10);
        let t = rng.range_usize(5, 15);
        let cluster = paper_cluster(h);
        let jobs = synthetic_jobs(&SynthConfig::paper(5, t, MIX_DEFAULT), rng);
        let cfg = PdOrsConfig { seed: rng.next_u64(), ..Default::default() };
        let mut sched = PdOrs::new(cfg, &jobs, &cluster, t);
        let mut ledger = AllocLedger::new(&cluster, t);
        let baseline: Vec<Vec<f64>> = (0..t)
            .map(|tt| (0..h).map(|hh| ledger.used(tt, hh).sum()).collect())
            .collect();
        for job in &jobs {
            if let Some(s) = sched.plan(job, &ledger) {
                ledger.commit(job, &s.schedule);
                ledger.release(job, &s.schedule);
            }
        }
        for tt in 0..t {
            for hh in 0..h {
                let now = ledger.used(tt, hh).sum();
                prop_assert!(
                    (now - baseline[tt][hh]).abs() < 1e-9,
                    "ledger drift at t={tt} h={hh}: {now}"
                );
            }
        }
        Ok(())
    });
}
