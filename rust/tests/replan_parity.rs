//! The replan-off parity contract (PR 5): with `replan = none` the whole
//! stack is byte-identical to the pre-replan system — same schedules,
//! metrics, RNG stream, and solver counters — across the registry ZOO on
//! homogeneous and skewed clusters. Replan rounds around a
//! replan-incapable scheduler are likewise a strict no-op. And with
//! replan *enabled*, the engine and the service core stay in lockstep
//! (the shared-`AdmissionCore` contract extends to the replan pass).

use dmlrs::cluster::Cluster;
use dmlrs::jobs::{Job, Schedule, SlotPlacement};
use dmlrs::sched::registry::{SchedulerRegistry, SchedulerSpec, ZOO};
use dmlrs::sched::replan::ReplanPolicy;
use dmlrs::service::{ServiceConfig, ServiceCore};
use dmlrs::sim::{
    ArrivalDecision, Scheduler, SimEngine, SimResult, TraceObserver,
};
use dmlrs::sweep::{ClusterSpec, WorkloadSpec};
use dmlrs::util::json::Json;
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::{paper_cluster, paper_cluster_skewed};
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

const JOBS: usize = 12;
const HORIZON: usize = 14;
const WORKLOAD_SEED: u64 = 21;
const SCHED_SEED: u64 = 4;

fn workload() -> Vec<Job> {
    let mut rng = Rng::new(WORKLOAD_SEED);
    synthetic_jobs(&SynthConfig::paper(JOBS, HORIZON, MIX_DEFAULT), &mut rng)
}

fn clusters() -> Vec<(&'static str, Cluster)> {
    vec![
        ("homogeneous", paper_cluster(8)),
        ("skewed", paper_cluster_skewed(8, 2.0)),
    ]
}

/// Run `key` through the engine. `replan: None` leaves the builder knob
/// untouched (the pre-replan call path); `Some(policy)` sets it
/// explicitly.
fn run(key: &str, cluster: &Cluster, replan: Option<ReplanPolicy>) -> SimResult {
    let reg = SchedulerRegistry::builtin();
    let jobs = workload();
    let spec = SchedulerSpec::new(key).with_seed(SCHED_SEED);
    let mut sched = reg.build(&spec, &jobs, cluster, HORIZON).unwrap();
    let mut builder =
        SimEngine::builder().jobs(&jobs).cluster(cluster).horizon(HORIZON);
    if let Some(p) = replan {
        builder = builder.replan(p);
    }
    builder.run(sched.as_mut())
}

#[test]
fn replan_none_is_byte_identical_across_the_zoo() {
    for (shape, cluster) in clusters() {
        for key in ZOO {
            let default = run(key, &cluster, None);
            let explicit_off = run(key, &cluster, Some(ReplanPolicy::None));
            // full equality — outcomes, utilities, training times, AND the
            // diagnostic solver counters (an untouched RNG/solve stream)
            assert_eq!(default, explicit_off, "{key} on {shape}");
            assert_eq!(explicit_off.replanned, 0, "{key} on {shape}");
        }
    }
}

#[test]
fn churn_none_is_byte_identical_across_the_zoo() {
    // The PR 6 half of the no-op contract: `churn = none` must leave the
    // whole stack untouched — explicit-off and knob-untouched runs are
    // fully equal (outcomes, ftf, solver counters, zero churn activity).
    use dmlrs::chaos::ChurnSpec;
    for (shape, cluster) in clusters() {
        for key in ZOO {
            let default = run(key, &cluster, None);
            let reg = SchedulerRegistry::builtin();
            let jobs = workload();
            let spec = SchedulerSpec::new(key).with_seed(SCHED_SEED);
            let mut sched = reg.build(&spec, &jobs, &cluster, HORIZON).unwrap();
            let explicit_off = SimEngine::builder()
                .jobs(&jobs)
                .cluster(&cluster)
                .horizon(HORIZON)
                .churn(ChurnSpec::None, SCHED_SEED)
                .run(sched.as_mut());
            assert_eq!(default, explicit_off, "{key} on {shape}");
            assert_eq!(explicit_off.evicted, 0, "{key} on {shape}");
            assert_eq!(explicit_off.migrated, 0, "{key} on {shape}");
        }
    }
}

#[test]
fn replan_rounds_are_noops_for_incapable_schedulers() {
    for (shape, cluster) in clusters() {
        for key in ["fifo", "drf", "dorm"] {
            let off = run(key, &cluster, None);
            let on = run(key, &cluster, Some(ReplanPolicy::Every(2)));
            assert_eq!(off, on, "{key} on {shape}: replan must be a strict no-op");
            assert_eq!(on.replanned, 0, "{key} on {shape}");
        }
    }
}

#[test]
fn replan_enabled_service_matches_engine() {
    // With an active cadence, driving the same arrival sequence through
    // the ServiceCore (submit + tick) and through SimEngine must agree on
    // every decision, the replanned count, utility, and solver counters.
    let horizon = 12usize;
    let policy = ReplanPolicy::Every(3);
    let workload = WorkloadSpec::synthetic(16, horizon, 0);
    let cluster_spec = ClusterSpec::homogeneous(6);
    for key in ["pd-ors", "oasis", "dorm"] {
        let seed = 5u64;
        let jobs = workload.jobs(seed);
        let cluster = cluster_spec.build();
        let reg = SchedulerRegistry::builtin();
        let spec = SchedulerSpec::new(key).with_seed(seed).with_replan(policy);
        let mut sched = reg.build(&spec, &jobs, &cluster, horizon).unwrap();
        let sim = SimEngine::builder()
            .jobs(&jobs)
            .cluster(&cluster)
            .horizon(horizon)
            .replan(policy)
            .run(sched.as_mut());

        let mut core = ServiceCore::new(ServiceConfig {
            scheduler: SchedulerSpec::new(key).with_seed(seed).with_replan(policy),
            cluster: cluster_spec.clone(),
            workload,
            churn: dmlrs::chaos::ChurnSpec::None,
        })
        .unwrap();
        let mut next = 0usize;
        for t in 0..horizon {
            while next < jobs.len() && jobs[next].arrival <= t {
                let resp = core.submit(jobs[next].clone());
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{key}");
                let id = resp.get("job_id").unwrap().as_usize().unwrap();
                let decision =
                    resp.get("decision").and_then(Json::as_str).unwrap().to_string();
                let outcome = &sim.outcomes[id];
                match decision.as_str() {
                    "admitted" => assert!(outcome.admitted, "{key}: job {id}"),
                    "rejected" => assert!(!outcome.admitted, "{key}: job {id}"),
                    "deferred" => {}
                    other => panic!("unknown decision {other}"),
                }
                next += 1;
            }
            core.tick();
        }
        let report = core.report();
        assert_eq!(report.submitted, jobs.len(), "{key}");
        assert_eq!(report.replanned, sim.replanned, "{key}: replan lockstep");
        assert_eq!(report.completed, sim.completed, "{key}");
        assert!(
            (report.total_utility - sim.total_utility).abs() < 1e-9,
            "{key}: utility diverged: service {} vs engine {}",
            report.total_utility,
            sim.total_utility
        );
        assert_eq!(report.solver, sim.solver, "{key}: same solver work");
    }
}

/// Deterministic end-to-end check of the replan mechanics through the
/// engine: a toy arrival-driven scheduler parks every job far in the
/// future; each replan round pulls not-yet-started plans to the current
/// slot, so completions move earlier and utility can only grow.
struct Procrastinator;

impl Procrastinator {
    fn plan(job: &Job, t: usize) -> Schedule {
        Schedule {
            job_id: job.id,
            slots: vec![SlotPlacement { t, placements: vec![(0, 2, 1)] }],
        }
    }
}

impl Scheduler for Procrastinator {
    fn name(&self) -> String {
        "procrastinator".into()
    }

    fn on_arrival(
        &mut self,
        job: &Job,
        ledger: &mut dmlrs::cluster::AllocLedger,
    ) -> ArrivalDecision {
        let s = Procrastinator::plan(job, ledger.horizon() - 1);
        ledger.commit(job, &s);
        ArrivalDecision::Admit(s)
    }

    fn replan_capable(&self) -> bool {
        true
    }

    fn replan_job(
        &mut self,
        job: &Job,
        old: Option<&Schedule>,
        t: usize,
        ledger: &mut dmlrs::cluster::AllocLedger,
    ) -> Option<Schedule> {
        // only move plans that are not already at the current boundary
        if old.is_some_and(|o| o.slots.first().is_some_and(|s| s.t == t)) {
            return None;
        }
        let s = Procrastinator::plan(job, t);
        ledger.commit(job, &s);
        Some(s)
    }
}

#[test]
fn engine_replan_moves_completions_and_recredits_utility() {
    let cluster =
        Cluster::homogeneous(1, dmlrs::cluster::ResVec::new([16.0, 32.0, 64.0, 32.0]));
    let horizon = 10usize;
    let mut jobs = Vec::new();
    for (i, arrival) in [0usize, 1, 2].into_iter().enumerate() {
        let mut j = dmlrs::jobs::test_support::test_job(i);
        j.arrival = arrival;
        j.epochs = 1;
        j.samples = 100.0; // one 2-worker slot covers it
        jobs.push(j);
    }

    // replan off: everything completes at the last slot
    let off = SimEngine::builder()
        .jobs(&jobs)
        .cluster(&cluster)
        .horizon(horizon)
        .run(&mut Procrastinator);
    assert_eq!(off.replanned, 0);
    assert_eq!(off.completed, 3);
    assert!(off.outcomes.iter().all(|o| o.completion == Some(horizon - 1)));

    // replan every 4: the t=4 round pulls all three plans to slot 4
    let mut trace = TraceObserver::new();
    let on = SimEngine::builder()
        .jobs(&jobs)
        .cluster(&cluster)
        .horizon(horizon)
        .replan(ReplanPolicy::Every(4))
        .observer(&mut trace)
        .run(&mut Procrastinator);
    assert_eq!(on.replanned, 3, "all three parked plans must move");
    assert_eq!(on.completed, 3);
    assert!(
        on.outcomes.iter().all(|o| o.completion == Some(4)),
        "completions must move to the replan boundary: {:?}",
        on.outcomes
    );
    assert!(
        on.total_utility >= off.total_utility,
        "earlier completions cannot earn less (sigmoid is non-increasing): \
         on={} off={}",
        on.total_utility,
        off.total_utility
    );
    assert!(
        trace.lines().iter().any(|l| l.contains("replanned")),
        "trace must narrate the replan round: {:?}",
        trace.lines()
    );
}
