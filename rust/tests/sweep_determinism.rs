//! Sweep determinism contract: a scenario matrix must produce
//! byte-identical per-cell metrics whether it runs on 1 worker or 8, and
//! the JSONL store's aggregation must not depend on record order.

use dmlrs::sweep::{
    run_matrix, CellRecord, ClusterSpec, ResultStore, ScenarioMatrix, WorkloadSpec,
};

fn quick_matrix() -> ScenarioMatrix {
    ScenarioMatrix::new()
        .schedulers(&["pd-ors", "fifo", "drf"])
        .workload(WorkloadSpec::synthetic(8, 10, 100))
        .cluster(ClusterSpec::homogeneous(4))
        .cluster(ClusterSpec::skewed(4, 2.0))
        .seeds(2)
}

fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("dmlrs_sweep_det_{tag}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn jobs_1_and_jobs_8_produce_byte_identical_metrics() {
    let m = quick_matrix();
    let serial = run_matrix(&m, 1, None).expect("serial sweep");
    let parallel = run_matrix(&m, 8, None).expect("parallel sweep");
    assert_eq!(serial.len(), m.len());
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.scenario, b.scenario, "matrix order must be stable");
        // byte-identical metrics (wall time is the only field allowed to
        // differ between runs)
        assert_eq!(a.record.metrics_line(), b.record.metrics_line());
        // and the full simulation outcomes agree job by job
        assert_eq!(a.result, b.result);
    }
}

#[test]
fn persisted_jsonl_metrics_are_identical_across_thread_counts() {
    let path_a = tmp_path("serial");
    let path_b = tmp_path("parallel");
    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);
    let m = quick_matrix();
    {
        let mut st = ResultStore::open(&path_a).expect("open serial store");
        run_matrix(&m, 1, Some(&mut st)).expect("serial sweep");
    }
    {
        let mut st = ResultStore::open(&path_b).expect("open parallel store");
        run_matrix(&m, 8, Some(&mut st)).expect("parallel sweep");
    }
    let lines = |p: &str| -> Vec<String> {
        std::fs::read_to_string(p)
            .unwrap()
            .lines()
            .map(|l| CellRecord::from_line(l).unwrap().metrics_line())
            .collect()
    };
    assert_eq!(lines(&path_a), lines(&path_b));
    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);
}

#[test]
fn store_aggregation_is_order_insensitive() {
    let m = quick_matrix();
    let outcomes = run_matrix(&m, 4, None).expect("sweep");
    let path_f = tmp_path("fwd");
    let path_r = tmp_path("rev");
    let _ = std::fs::remove_file(&path_f);
    let _ = std::fs::remove_file(&path_r);
    let mut fwd = ResultStore::open(&path_f).expect("open");
    let mut rev = ResultStore::open(&path_r).expect("open");
    for o in &outcomes {
        fwd.append(o.record.clone()).expect("append");
    }
    for o in outcomes.iter().rev() {
        rev.append(o.record.clone()).expect("append");
    }
    assert_eq!(fwd.summary(), rev.summary());
    assert!(!fwd.summary().is_empty());
    let _ = std::fs::remove_file(&path_f);
    let _ = std::fs::remove_file(&path_r);
}

#[test]
fn resume_skips_only_cells_already_on_disk() {
    let path = tmp_path("resume");
    let _ = std::fs::remove_file(&path);
    // first run: only a sub-matrix (one cluster)
    let small = ScenarioMatrix::new()
        .schedulers(&["fifo", "drf"])
        .workload(WorkloadSpec::synthetic(8, 10, 100))
        .cluster(ClusterSpec::homogeneous(4))
        .seeds(2);
    {
        let mut st = ResultStore::open(&path).expect("open");
        let first = run_matrix(&small, 2, Some(&mut st)).expect("sweep");
        assert!(first.iter().all(|o| !o.cached));
    }
    // second run: a superset matrix — old cells cached, new cells run,
    // and cached metrics equal what a fresh run would produce
    let bigger = ScenarioMatrix::new()
        .schedulers(&["fifo", "drf"])
        .workload(WorkloadSpec::synthetic(8, 10, 100))
        .cluster(ClusterSpec::homogeneous(4))
        .cluster(ClusterSpec::skewed(4, 2.0))
        .seeds(2);
    let mut st = ResultStore::open(&path).expect("open");
    let second = run_matrix(&bigger, 2, Some(&mut st)).expect("sweep");
    let cached = second.iter().filter(|o| o.cached).count();
    assert_eq!(cached, small.len());
    assert_eq!(second.len(), bigger.len());
    let fresh = run_matrix(&bigger, 2, None).expect("sweep");
    for (a, b) in second.iter().zip(&fresh) {
        assert_eq!(a.record.metrics_line(), b.record.metrics_line());
    }
    let _ = std::fs::remove_file(&path);
}
