//! Churn determinism contract (PR 6): a churny scenario matrix must
//! produce byte-identical per-cell metrics — including the evicted /
//! migrated counters and finish-time fairness — whether it runs on 1
//! worker or 8; churny cells get their own store keys (coexisting with
//! churn-less cells in one JSONL); and a seeded churny engine run is
//! exactly reproducible.

use dmlrs::chaos::ChurnSpec;
use dmlrs::sched::registry::{SchedulerRegistry, SchedulerSpec};
use dmlrs::sched::replan::ReplanPolicy;
use dmlrs::sim::SimEngine;
use dmlrs::sweep::{run_matrix, ClusterSpec, ScenarioMatrix, WorkloadSpec};
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::paper_cluster;
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

/// Half the cluster goes down at t=2; one machine rejoins at t=8. On a
/// 4-machine cluster with arrival-driven schedulers this reliably
/// strands committed work, so the matrix exercises the migration pass.
fn churn_events() -> ChurnSpec {
    ChurnSpec::parse("down@2:0,down@2:1,up@8:0").expect("valid churn spec")
}

fn churny_matrix() -> ScenarioMatrix {
    ScenarioMatrix::new()
        .schedulers(&["pd-ors", "oasis", "fifo"])
        .workload(WorkloadSpec::synthetic(14, 12, 100))
        .cluster(ClusterSpec::homogeneous(4))
        .seeds(2)
        .replan(ReplanPolicy::Every(2))
        .churn(churn_events())
        .churn(ChurnSpec::Mtbf { mtbf: 5.0, mttr: 2.0 })
}

#[test]
fn churny_matrix_is_byte_identical_across_thread_counts() {
    let m = churny_matrix();
    let serial = run_matrix(&m, 1, None).expect("serial churny sweep");
    let parallel = run_matrix(&m, 8, None).expect("parallel churny sweep");
    assert_eq!(serial.len(), m.len());
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.scenario, b.scenario, "matrix order must be stable");
        // byte-identical metrics — the churn counters and ftf ride in the
        // metrics line, so nondeterministic migration would show up here
        assert_eq!(a.record.metrics_line(), b.record.metrics_line());
        assert_eq!(a.result, b.result);
    }
    // the matrix must actually exercise churn: across the arrival-driven
    // cells at least one committed schedule was stranded and handled
    let activity: usize =
        serial.iter().map(|o| o.record.evicted + o.record.migrated).sum();
    assert!(activity >= 1, "no cell evicted or migrated anything");
    // and every cell that completed work reports a finish-time fairness
    for o in &serial {
        if o.record.completed > 0 {
            assert!(
                o.record.ftf > 0.0,
                "{}: completed {} jobs but ftf = {}",
                o.record.key,
                o.record.completed,
                o.record.ftf
            );
        }
    }
}

#[test]
fn churny_cells_get_their_own_store_keys() {
    let churny = churny_matrix();
    let plain = ScenarioMatrix::new()
        .schedulers(&["pd-ors", "oasis", "fifo"])
        .workload(WorkloadSpec::synthetic(14, 12, 100))
        .cluster(ClusterSpec::homogeneous(4))
        .seeds(2)
        .replan(ReplanPolicy::Every(2));
    let churny_keys: Vec<String> =
        churny.cells().iter().map(|c| c.key()).collect();
    let plain_keys: Vec<String> = plain.cells().iter().map(|c| c.key()).collect();
    for k in &churny_keys {
        assert!(k.contains("|ch"), "churny key {k:?} lacks the churn token");
        assert!(!plain_keys.contains(k), "churny key {k:?} collides");
    }
    for k in &plain_keys {
        assert!(!k.contains("|ch"), "churn-less key {k:?} grew a churn token");
    }
}

#[test]
fn seeded_churny_engine_run_is_reproducible() {
    let horizon = 12usize;
    let cluster = paper_cluster(4);
    let jobs = synthetic_jobs(
        &SynthConfig::paper(14, horizon, MIX_DEFAULT),
        &mut Rng::new(9),
    );
    let reg = SchedulerRegistry::builtin();
    let run = || {
        let spec = SchedulerSpec::new("pd-ors").with_seed(3);
        let mut sched = reg.build(&spec, &jobs, &cluster, horizon).unwrap();
        SimEngine::builder()
            .jobs(&jobs)
            .cluster(&cluster)
            .horizon(horizon)
            .replan(ReplanPolicy::Every(2))
            .churn(churn_events(), 3)
            .run(sched.as_mut())
    };
    let first = run();
    let second = run();
    assert_eq!(first, second, "same churny run must be byte-identical");
    assert!(
        first.evicted + first.migrated >= 1,
        "half the cluster went down mid-run yet nothing was interrupted \
         (evicted {}, migrated {})",
        first.evicted,
        first.migrated
    );
    assert!(first.completed > 0, "the run must still complete some jobs");
    assert!(first.ftf > 0.0, "completed jobs must report finish-time fairness");
}
