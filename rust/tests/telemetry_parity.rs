//! The telemetry inertness contract (PR 7): turning the full telemetry
//! stack on (span histograms + flight recorder + trace buffer + the
//! engine-event observer) is byte-invisible to every deterministic
//! artifact — `SimResult` across the scheduler zoo with replan and churn
//! active, and the `ServiceReport` snapshot of a driven service core.
//! Separately, the per-thread histogram merge is order-insensitive: a
//! sweep run on 1 worker and on 4 workers aggregates identical per-stage
//! span counts.
//!
//! The obs flag word and aggregates are process-global; every test here
//! takes `LOCK` (poison-tolerant, so one failing test doesn't cascade)
//! and restores flags-off + reset state before releasing it.

use std::sync::Mutex;

use dmlrs::chaos::ChurnSpec;
use dmlrs::cluster::Cluster;
use dmlrs::jobs::Job;
use dmlrs::obs::{self, export::TelemetryObserver, Stage};
use dmlrs::sched::registry::{SchedulerRegistry, SchedulerSpec, ZOO};
use dmlrs::sched::replan::ReplanPolicy;
use dmlrs::service::{ServiceConfig, ServiceCore, ServiceReport};
use dmlrs::sim::{SimEngine, SimResult};
use dmlrs::sweep::{run_matrix, ClusterSpec, ScenarioMatrix, WorkloadSpec};
use dmlrs::util::json::Json;
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::{paper_cluster, paper_cluster_skewed};
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

static LOCK: Mutex<()> = Mutex::new(());

const JOBS: usize = 12;
const HORIZON: usize = 14;
const WORKLOAD_SEED: u64 = 21;
const SCHED_SEED: u64 = 4;

fn workload() -> Vec<Job> {
    let mut rng = Rng::new(WORKLOAD_SEED);
    synthetic_jobs(&SynthConfig::paper(JOBS, HORIZON, MIX_DEFAULT), &mut rng)
}

fn clusters() -> Vec<(&'static str, Cluster)> {
    vec![
        ("homogeneous", paper_cluster(8)),
        ("skewed", paper_cluster_skewed(8, 2.0)),
    ]
}

/// Run `key` through the engine with replan + churn active (the busiest
/// code path: every instrumented engine stage fires), optionally with
/// the telemetry observer attached.
fn run(key: &str, cluster: &Cluster, telemetry: Option<&mut TelemetryObserver>) -> SimResult {
    let reg = SchedulerRegistry::builtin();
    let jobs = workload();
    let spec = SchedulerSpec::new(key).with_seed(SCHED_SEED);
    let mut sched = reg.build(&spec, &jobs, cluster, HORIZON).unwrap();
    let mut builder = SimEngine::builder()
        .jobs(&jobs)
        .cluster(cluster)
        .horizon(HORIZON)
        .replan(ReplanPolicy::Every(3))
        .churn(ChurnSpec::parse("down@3:1,up@7:1").unwrap(), SCHED_SEED);
    if let Some(t) = telemetry {
        builder = builder.observer(t);
    }
    builder.run(sched.as_mut())
}

#[test]
fn full_telemetry_is_byte_inert_across_the_zoo() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for (shape, cluster) in clusters() {
        for key in ZOO {
            obs::set_flags(0);
            let off = run(key, &cluster, None);

            obs::set_flags(obs::ALL);
            obs::reset();
            let mut telemetry = TelemetryObserver::new();
            let on = run(key, &cluster, Some(&mut telemetry));
            obs::flush_local();
            let totals = obs::global_totals();
            let trace = telemetry.chrome_trace_json();
            obs::set_flags(0);
            obs::reset();

            // byte-identity: outcomes, utilities, ftf, churn/replan
            // counters, AND the solver diagnostic counters (an untouched
            // RNG/solve stream)
            assert_eq!(off, on, "{key} on {shape}: telemetry must be inert");

            // ... and the instrumentation actually observed the run
            assert!(
                totals[Stage::AdmissionCommit as usize].0 >= JOBS as u64,
                "{key} on {shape}: every submit opens an admission span: {totals:?}"
            );
            assert!(
                totals[Stage::MigrationPass as usize].0 >= 1,
                "{key} on {shape}: the churn trace forces migration passes"
            );
            let doc = Json::parse(&trace).unwrap_or_else(|e| {
                panic!("{key} on {shape}: trace must be valid JSON: {e}")
            });
            assert!(
                !doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty(),
                "{key} on {shape}: trace must carry events"
            );
            assert!(trace.contains("\"admission_commit\""), "{key} on {shape}");
            assert!(
                trace.contains("\"ph\":\"i\""),
                "{key} on {shape}: engine events must land as instants"
            );
            if key == "pd-ors" {
                assert!(
                    totals[Stage::ThetaSolve as usize].0 > 0
                        && totals[Stage::LpSolve as usize].0 > 0
                        && totals[Stage::Rounding as usize].0 > 0,
                    "pd-ors on {shape}: solver stages must record: {totals:?}"
                );
                assert!(trace.contains("\"theta_solve\""), "{shape}");
            }
        }
    }
}

#[test]
fn sweep_span_counts_are_worker_count_invariant() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let matrix = ScenarioMatrix::new()
        .schedulers(&["pd-ors", "fifo"])
        .workload(WorkloadSpec::synthetic(10, 10, 0))
        .cluster(ClusterSpec::homogeneous(5))
        .seeds(2);

    obs::set_flags(obs::SPANS);
    obs::reset();
    let serial = run_matrix(&matrix, 1, None).unwrap();
    let counts_1: Vec<u64> = obs::global_totals().iter().map(|t| t.0).collect();

    obs::reset();
    let parallel = run_matrix(&matrix, 4, None).unwrap();
    let counts_4: Vec<u64> = obs::global_totals().iter().map(|t| t.0).collect();
    obs::set_flags(0);
    obs::reset();

    assert_eq!(serial.len(), parallel.len());
    // span *counts* are deterministic per cell (durations are not), and
    // the per-worker flush_local merge is order-insensitive — so the
    // aggregate must not depend on how cells were dealt to workers
    assert_eq!(counts_1, counts_4, "histogram merge must be order-insensitive");
    assert!(
        counts_1[Stage::ThetaSolve as usize] > 0
            && counts_1[Stage::SnapshotBuild as usize] > 0,
        "the pd-ors cells must have recorded solver spans: {counts_1:?}"
    );
}

#[test]
fn service_report_is_telemetry_inert() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let drive = || -> ServiceReport {
        let horizon = 12usize;
        let workload = WorkloadSpec::synthetic(16, horizon, 0);
        let jobs = workload.jobs(5);
        let mut core = ServiceCore::new(ServiceConfig {
            scheduler: SchedulerSpec::new("pd-ors")
                .with_seed(5)
                .with_replan(ReplanPolicy::Every(3)),
            cluster: ClusterSpec::homogeneous(6),
            workload,
            churn: ChurnSpec::None,
        })
        .unwrap();
        let mut next = 0usize;
        for t in 0..horizon {
            while next < jobs.len() && jobs[next].arrival <= t {
                let resp = core.submit(jobs[next].clone());
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
                next += 1;
            }
            core.tick();
        }
        core.report()
    };

    obs::set_flags(0);
    let off = drive();

    obs::set_flags(obs::ALL);
    obs::reset();
    let on = drive();
    let flight = dmlrs::obs::flight::dump_json();
    obs::set_flags(0);
    obs::reset();

    // the report snapshot excludes wall-clock latencies by design, so
    // full equality is the right oracle
    assert_eq!(off, on, "telemetry must not perturb the service core");
    assert!(
        flight.get("entries").and_then(Json::as_arr).is_some_and(|a| !a.is_empty()),
        "the flight recorder must have captured spans"
    );
}
