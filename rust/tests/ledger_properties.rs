//! Property tests over the admission stack (PR 5 satellite, extended
//! with machine churn in PR 6): across random jobs × every ZOO scheduler
//! × homogeneous/skewed clusters — with and without elastic re-planning
//! and seeded MTBF/MTTR churn — the `AllocLedger` never exceeds per-slot
//! per-machine capacity, no committed schedule leaves `[arrival,
//! horizon)`, no tracked admission keeps work on a hard-down machine
//! after the migration pass, and the credited total utility equals the
//! sum of the per-job completion credits (as rewritten by replans,
//! migrations, and evictions). 256 seeded cases per scheduler
//! (`testkit::check` reports the failing case seed for reproduction).

use std::collections::BTreeMap;

use dmlrs::chaos::{ChurnEvent, ChurnSpec, ChurnTrace};
use dmlrs::prop_assert;
use dmlrs::sched::registry::{SchedulerRegistry, SchedulerSpec};
use dmlrs::sched::replan::{run_migration_pass, run_replan_pass, ReplanPolicy};
use dmlrs::sim::{AdmissionCore, AdmissionOutcome};
use dmlrs::testkit;
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::{paper_cluster, paper_cluster_skewed};
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

const CASES: usize = 256;

/// Drive one randomized scenario through the real admission stack
/// (AdmissionCore + optional replan rounds, exactly the engine's per-slot
/// order) and check the invariants after every mutation.
fn drive_case(rng: &mut Rng, key: &str) -> Result<(), String> {
    // small random shapes keep 256 cases per scheduler fast while still
    // spanning machine counts, skew, horizons, and workload sizes
    let machines = rng.range_usize(2, 6);
    let horizon = rng.range_usize(6, 12);
    let num_jobs = rng.range_usize(3, 8);
    let skewed = rng.chance(0.5);
    let replan = if rng.chance(0.5) {
        ReplanPolicy::Every(rng.range_usize(2, 5))
    } else {
        ReplanPolicy::None
    };
    let cluster = if skewed {
        paper_cluster_skewed(machines, 2.0)
    } else {
        paper_cluster(machines)
    };
    let churn = if rng.chance(0.4) {
        ChurnSpec::Mtbf {
            mtbf: rng.range_usize(3, 8) as f64,
            mttr: rng.range_usize(2, 4) as f64,
        }
    } else {
        ChurnSpec::None
    };
    let churn_seed = rng.next_u64();
    let workload_seed = rng.next_u64();
    let jobs = synthetic_jobs(
        &SynthConfig::paper(num_jobs, horizon, MIX_DEFAULT),
        &mut Rng::new(workload_seed),
    );

    let mut spec = SchedulerSpec::new(key).with_seed(rng.next_u64() & 0xffff);
    // trimmed solver knobs: the invariants do not depend on resolution
    spec.pdors.dp_units = 12;
    spec.pdors.attempts = 8;
    let reg = SchedulerRegistry::builtin();
    let mut sched =
        reg.build(&spec, &jobs, &cluster, horizon).map_err(|e| e.to_string())?;

    let mut core = AdmissionCore::new(&cluster, horizon);
    if replan.is_enabled() && sched.replan_capable() {
        core.set_replan_tracking(true);
    }
    let trace = ChurnTrace::generate(&churn, machines, horizon, churn_seed);
    if trace.is_some() {
        core.set_churn_tracking(true);
    }
    // machines currently hard-down (MTBF traces never drain, so a masked
    // machine must hold no tracked work from its failure slot on)
    let mut down_set: Vec<bool> = vec![false; machines];

    // planned[job] = utility the pending table should eventually credit
    let mut planned: BTreeMap<usize, f64> = BTreeMap::new();
    let mut pending: Vec<Vec<(usize, f64)>> = vec![Vec::new(); horizon];
    let mut slot_credit = 0.0; // utilities of slot-driven completions
    let mut credited = 0.0; // everything actually credited, engine order
    let mut next = 0usize;

    let check_capacity = |core: &AdmissionCore, when: &str| -> Result<(), String> {
        let ledger = core.ledger();
        for t in 0..horizon {
            for h in 0..ledger.num_machines() {
                if !ledger.used(t, h).fits_within(ledger.capacity(h), 1e-6) {
                    return Err(format!(
                        "{when}: slot {t} machine {h} over capacity \
                         (used {:?}, cap {:?})",
                        ledger.used(t, h),
                        ledger.capacity(h)
                    ));
                }
            }
        }
        Ok(())
    };

    for t in 0..horizon {
        // the engine's SlotStart order: churn events + migration pass
        // land before any replan round at the same boundary
        if let Some(tr) = &trace {
            let mut down_now = Vec::new();
            for &(h, e) in tr.events_at(t) {
                match e {
                    ChurnEvent::Down => {
                        core.ledger_mut().set_available_from(h, t, false);
                        down_set[h] = true;
                        down_now.push(h);
                    }
                    ChurnEvent::Drain => {
                        core.ledger_mut().set_available_from(h, t, false);
                        down_set[h] = true;
                    }
                    ChurnEvent::Rejoin => {
                        core.ledger_mut().set_available_from(h, t, true);
                        down_set[h] = false;
                    }
                }
            }
            let report = run_migration_pass(&mut core, sched.as_mut(), t, &down_now);
            for r in &report.records {
                if let Some(of) = r.old_finish {
                    prop_assert!(of.slot < horizon, "stale finish beyond horizon");
                    pending[of.slot].retain(|&(id, _)| id != r.job_id);
                }
                planned.remove(&r.job_id);
                if !r.evicted {
                    if let Some(nf) = r.new_finish {
                        prop_assert!(
                            nf.slot < horizon && nf.slot >= t,
                            "migrated completion {} outside [{t}, {horizon})",
                            nf.slot
                        );
                        pending[nf.slot].push((r.job_id, nf.utility));
                        planned.insert(r.job_id, nf.utility);
                    }
                }
            }
            let down_list: Vec<usize> = down_set
                .iter()
                .enumerate()
                .filter(|&(_, d)| *d)
                .map(|(h, _)| h)
                .collect();
            for ta in core.tracked_admissions() {
                prop_assert!(
                    !ta.strands_on(&down_list, t),
                    "tracked admission for job {} still holds work on a down \
                     machine after the migration pass at t={t}",
                    ta.job.id
                );
            }
            if !tr.events_at(t).is_empty() {
                check_capacity(&core, &format!("after churn events at t={t}"))?;
            }
        }

        if replan.fires_at(t) {
            let report = run_replan_pass(&mut core, sched.as_mut(), t);
            for r in &report.records {
                if let Some(of) = r.old_finish {
                    prop_assert!(of.slot < horizon, "stale finish beyond horizon");
                    pending[of.slot].retain(|&(id, _)| id != r.job_id);
                }
                planned.remove(&r.job_id);
                if let Some(nf) = r.new_finish {
                    prop_assert!(
                        nf.slot < horizon,
                        "replanned completion {} beyond horizon {horizon}",
                        nf.slot
                    );
                    prop_assert!(
                        nf.slot >= t,
                        "replanned completion {} before the boundary {t}",
                        nf.slot
                    );
                    pending[nf.slot].push((r.job_id, nf.utility));
                    planned.insert(r.job_id, nf.utility);
                }
            }
            check_capacity(&core, &format!("after replan round at t={t}"))?;
        }

        while next < jobs.len() && jobs[next].arrival <= t {
            let job = &jobs[next];
            next += 1;
            if let AdmissionOutcome::Admitted { schedule, finish, .. } =
                core.submit(sched.as_mut(), job)
            {
                prop_assert!(
                    schedule.respects_arrival(job),
                    "job {} placed before its arrival {}",
                    job.id,
                    job.arrival
                );
                prop_assert!(
                    schedule.respects_worker_cap(job),
                    "job {} exceeds its worker cap",
                    job.id
                );
                prop_assert!(
                    schedule.slots.iter().all(|s| s.t < horizon),
                    "job {} scheduled beyond the horizon",
                    job.id
                );
                if let Some(f) = finish {
                    prop_assert!(f.slot < horizon, "finish beyond horizon");
                    pending[f.slot].push((job.id, f.utility));
                    planned.insert(job.id, f.utility);
                }
            }
            check_capacity(&core, &format!("after admitting job {}", job.id))?;
        }

        for g in core.run_slot(sched.as_mut(), t) {
            if let Some(f) = g.finish {
                slot_credit += f.utility;
                credited += f.utility;
            }
        }
        check_capacity(&core, &format!("after slot {t} grants"))?;

        for (_, u) in std::mem::take(&mut pending[t]) {
            credited += u;
        }
    }

    // total utility == Σ admitted-job credits: every planned completion
    // (as updated by the replan rounds) plus every slot-driven finish
    let expected: f64 = planned.values().sum::<f64>() + slot_credit;
    prop_assert!(
        (credited - expected).abs() <= 1e-6 * (1.0 + expected.abs()),
        "utility accounting drift: credited {credited}, expected {expected} \
         (replan {replan:?}, churn {churn:?})"
    );
    prop_assert!(
        core.ledger().within_capacity(1e-6),
        "final ledger exceeds capacity"
    );
    Ok(())
}

fn check_scheduler(key: &'static str, base_seed: u64) {
    testkit::check(key, base_seed, CASES, |rng| drive_case(rng, key));
}

#[test]
fn ledger_invariants_pd_ors() {
    check_scheduler("pd-ors", 0xA1);
}

#[test]
fn ledger_invariants_oasis() {
    check_scheduler("oasis", 0xA2);
}

#[test]
fn ledger_invariants_fifo() {
    check_scheduler("fifo", 0xA3);
}

#[test]
fn ledger_invariants_drf() {
    check_scheduler("drf", 0xA4);
}

#[test]
fn ledger_invariants_dorm() {
    check_scheduler("dorm", 0xA5);
}
