//! The tentpole parity contract of the layered solver core: θ-memoization
//! must be semantically invisible. For every scheduler in the registry
//! ZOO, on both homogeneous and skewed (heterogeneous) clusters, a cached
//! run and a `--no-theta-cache` (parity oracle) run must produce
//! byte-identical schedules and metrics — only the diagnostic solver
//! counters may differ, and for the primal-dual schedulers they must
//! differ in the expected direction (memo hits > 0, fewer LP solves).
//!
//! The incremental solver (PR 8) widens the contract: the default path
//! additionally reuses warm-simplex results, θ-memo entries, and slot
//! snapshots *across* arrivals, and `--cold-solver` is its oracle —
//! byte-identical schedules, metrics, and RNG streams even under elastic
//! replanning and machine churn (the ledger mutations that exercise the
//! change journal and delta snapshot updates).

use dmlrs::chaos::ChurnSpec;
use dmlrs::cluster::Cluster;
use dmlrs::sched::registry::{SchedulerRegistry, SchedulerSpec, ZOO};
use dmlrs::sched::replan::ReplanPolicy;
use dmlrs::sim::{simulate, SimEngine, SimResult};
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::{paper_cluster, paper_cluster_skewed};
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

const JOBS: usize = 12;
const HORIZON: usize = 14;
const WORKLOAD_SEED: u64 = 21;
const SCHED_SEED: u64 = 4;

fn workload() -> Vec<dmlrs::jobs::Job> {
    let mut rng = Rng::new(WORKLOAD_SEED);
    synthetic_jobs(&SynthConfig::paper(JOBS, HORIZON, MIX_DEFAULT), &mut rng)
}

fn clusters() -> Vec<(&'static str, Cluster)> {
    vec![
        ("homogeneous", paper_cluster(8)),
        ("skewed", paper_cluster_skewed(8, 2.0)),
    ]
}

fn run(key: &str, cluster: &Cluster, theta_cache: bool) -> SimResult {
    let reg = SchedulerRegistry::builtin();
    let jobs = workload();
    let mut spec = SchedulerSpec::new(key).with_seed(SCHED_SEED);
    spec.pdors.theta_cache = theta_cache;
    let mut sched = reg.build(&spec, &jobs, cluster, HORIZON).unwrap();
    simulate(&jobs, cluster, HORIZON, sched.as_mut())
}

#[test]
fn cached_and_oracle_runs_are_byte_identical_across_the_zoo() {
    for (shape, cluster) in clusters() {
        for key in ZOO {
            let cached = run(key, &cluster, true);
            let oracle = run(key, &cluster, false);
            assert!(
                cached.parity_eq(&oracle),
                "{key} on {shape}: cached vs --no-theta-cache diverged\n\
                 cached:  u={} admitted={} completed={}\n\
                 oracle:  u={} admitted={} completed={}",
                cached.total_utility,
                cached.admitted,
                cached.completed,
                oracle.total_utility,
                oracle.admitted,
                oracle.completed,
            );
            // per-job outcomes (completions, utilities, training times)
            // are part of parity_eq, but spell the intent out:
            assert_eq!(cached.outcomes, oracle.outcomes, "{key} on {shape}");
        }
    }
}

#[test]
fn primal_dual_schedulers_actually_use_the_memo() {
    for (shape, cluster) in clusters() {
        for key in ["pd-ors", "oasis"] {
            let cached = run(key, &cluster, true);
            let oracle = run(key, &cluster, false);
            assert!(
                cached.solver.theta_solves > 0,
                "{key} on {shape}: no θ-solves recorded"
            );
            assert_eq!(
                cached.solver.theta_solves, oracle.solver.theta_solves,
                "{key} on {shape}: solve counts must match"
            );
            assert!(
                cached.solver.memo_hits > 0,
                "{key} on {shape}: cached run never hit the memo"
            );
            assert_eq!(
                oracle.solver.memo_hits, 0,
                "{key} on {shape}: the oracle must not consult a memo"
            );
            assert!(
                cached.solver.lp_solves < oracle.solver.lp_solves,
                "{key} on {shape}: memo should absorb LP solves ({} vs {})",
                cached.solver.lp_solves,
                oracle.solver.lp_solves
            );
        }
    }
}

#[test]
fn baselines_report_zero_solver_work() {
    let cluster = paper_cluster(8);
    for key in ["fifo", "drf", "dorm"] {
        let res = run(key, &cluster, true);
        assert_eq!(res.solver, Default::default(), "{key}");
    }
}

/// A run with every ledger-mutation source active: arrivals commit,
/// elastic replan rounds release + re-commit, and scripted churn takes a
/// machine down mid-run and brings it back — the journal traffic the
/// persistent snapshot cache has to digest correctly.
fn run_full(key: &str, cluster: &Cluster, cold_solver: bool) -> SimResult {
    let reg = SchedulerRegistry::builtin();
    let jobs = workload();
    let mut spec = SchedulerSpec::new(key).with_seed(SCHED_SEED);
    spec.pdors.cold_solver = cold_solver;
    let replan = ReplanPolicy::parse("every:3").unwrap();
    let churn = ChurnSpec::parse("down@4:1,up@9:1").unwrap();
    let mut sched = reg.build(&spec, &jobs, cluster, HORIZON).unwrap();
    let mut engine = SimEngine::builder()
        .jobs(&jobs)
        .cluster(cluster)
        .horizon(HORIZON)
        .replan(replan)
        .churn(churn, SCHED_SEED)
        .build();
    engine.run(sched.as_mut())
}

#[test]
fn cold_solver_oracle_is_byte_identical_across_the_zoo() {
    for (shape, cluster) in clusters() {
        for key in ZOO {
            let incremental = run_full(key, &cluster, false);
            let cold = run_full(key, &cluster, true);
            assert!(
                incremental.parity_eq(&cold),
                "{key} on {shape}: incremental vs --cold-solver diverged\n\
                 incremental: u={} admitted={} completed={} replanned={} \
                 evicted={} migrated={}\n\
                 cold:        u={} admitted={} completed={} replanned={} \
                 evicted={} migrated={}",
                incremental.total_utility,
                incremental.admitted,
                incremental.completed,
                incremental.replanned,
                incremental.evicted,
                incremental.migrated,
                cold.total_utility,
                cold.admitted,
                cold.completed,
                cold.replanned,
                cold.evicted,
                cold.migrated,
            );
            assert_eq!(incremental.outcomes, cold.outcomes, "{key} on {shape}");
        }
    }
}

#[test]
fn incremental_path_actually_reuses_state() {
    for (shape, cluster) in clusters() {
        for key in ["pd-ors", "oasis"] {
            let incremental = run_full(key, &cluster, false);
            let cold = run_full(key, &cluster, true);
            assert_eq!(
                incremental.solver.theta_solves, cold.solver.theta_solves,
                "{key} on {shape}: θ-solve counts must match"
            );
            assert!(
                incremental.solver.warm_hits > 0,
                "{key} on {shape}: warm simplex never hit"
            );
            assert!(
                incremental.solver.snapshot_delta_updates > 0,
                "{key} on {shape}: snapshots were never delta-updated"
            );
            // the cold oracle must not touch any cross-arrival structure
            assert_eq!(cold.solver.warm_hits, 0, "{key} on {shape}");
            assert_eq!(cold.solver.warm_fallbacks, 0, "{key} on {shape}");
            assert_eq!(cold.solver.memo_invalidated, 0, "{key} on {shape}");
            assert_eq!(cold.solver.snapshot_delta_updates, 0, "{key} on {shape}");
            assert!(
                incremental.solver.lp_solves < cold.solver.lp_solves,
                "{key} on {shape}: reuse should absorb LP solves ({} vs {})",
                incremental.solver.lp_solves,
                cold.solver.lp_solves
            );
        }
    }
}

#[test]
fn registry_theta_cache_override_forces_the_oracle() {
    // builtin_with_theta_cache(false) must behave exactly like a spec
    // with theta_cache = false — same schedules, no memo hits.
    let jobs = workload();
    let cluster = paper_cluster(8);
    let reg = SchedulerRegistry::builtin_with_theta_cache(false);
    let mut sched = reg
        .build(&SchedulerSpec::new("pd-ors").with_seed(SCHED_SEED), &jobs, &cluster, HORIZON)
        .unwrap();
    let forced = simulate(&jobs, &cluster, HORIZON, sched.as_mut());
    let oracle = run("pd-ors", &cluster, false);
    assert_eq!(forced, oracle);
    assert_eq!(forced.solver.memo_hits, 0);
}
