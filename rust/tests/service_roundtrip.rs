//! End-to-end tests of the online admission service:
//!
//! * an in-process daemon on an ephemeral port, hit by concurrent client
//!   threads, whose op-log replay (`--recover`) reproduces byte-identical
//!   ledger state and metrics;
//! * the shared-`AdmissionCore` parity contract: the same arrival
//!   sequence fed through the daemon (virtual-clock `tick` mode) and
//!   through `SimEngine` yields identical admit/reject decisions,
//!   completions, and utility, for every scheduler in the zoo.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

use dmlrs::chaos::ChurnSpec;
use dmlrs::jobs::Job;
use dmlrs::sched::registry::{SchedulerSpec, ZOO};
use dmlrs::sched::replan::ReplanPolicy;
use dmlrs::service::{
    start_daemon, DaemonConfig, Request, ServiceConfig, ServiceCore,
};
use dmlrs::sim::{simulate, SimEngine};
use dmlrs::sweep::{ClusterSpec, WorkloadSpec};
use dmlrs::util::json::Json;

struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { reader, stream }
    }

    fn roundtrip(&mut self, req: &Request) -> Json {
        let mut line = req.to_line();
        line.push('\n');
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        let v = Json::parse(resp.trim()).expect("daemon speaks JSON");
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "error response: {resp}");
        v
    }
}

fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("dmlrs_roundtrip_{tag}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn concurrent_submits_recover_to_identical_state() {
    let path = tmp_path("recover");
    let _ = std::fs::remove_file(&path);
    let service = ServiceConfig {
        scheduler: SchedulerSpec::new("pd-ors").with_seed(2),
        cluster: ClusterSpec::homogeneous(6),
        workload: WorkloadSpec::synthetic(16, 10, 0),
        churn: ChurnSpec::None,
    };
    let mut dcfg = DaemonConfig::new(service.clone());
    dcfg.oplog = Some(path.clone());
    let handle = start_daemon(dcfg).expect("daemon starts");
    let addr = handle.addr;
    let jobs = service.workload.jobs(2);

    // four concurrent client threads, each submitting its share
    std::thread::scope(|scope| {
        for c in 0..4usize {
            let share: Vec<Job> = jobs.iter().skip(c).step_by(4).cloned().collect();
            scope.spawn(move || {
                let mut client = Client::connect(addr);
                for job in share {
                    let resp = client.roundtrip(&Request::Submit { job });
                    let decision =
                        resp.get("decision").and_then(Json::as_str).unwrap().to_string();
                    assert!(
                        matches!(decision.as_str(), "admitted" | "rejected"),
                        "PD-ORS never defers: {decision}"
                    );
                }
            });
        }
    });

    // advance the clock a little and read the counters
    let mut client = Client::connect(addr);
    client.roundtrip(&Request::Tick);
    client.roundtrip(&Request::Tick);
    let status = client.roundtrip(&Request::Status);
    assert_eq!(status.get("submitted").unwrap().as_usize(), Some(16));
    assert_eq!(status.get("slot").unwrap().as_usize(), Some(2));
    let metrics = client.roundtrip(&Request::Metrics);
    assert_eq!(metrics.get("decisions").unwrap().as_usize(), Some(16));
    assert!(
        metrics.get("solve_us").unwrap().get("p99").unwrap().as_f64().unwrap() > 0.0,
        "16 PD-ORS decisions take measurable time"
    );

    handle.shutdown();
    let report = handle.join().expect("clean drain");
    assert_eq!(report.submitted, 16);
    assert_eq!(report.admitted + report.rejected, 16);
    assert!(report.admitted > 0, "PD-ORS should admit something");

    // op-log replay reproduces the exact ledger state and metrics, even
    // though the submission order was decided by thread interleaving
    let recovered = ServiceCore::recover(service, &path).expect("replay");
    assert_eq!(recovered.report(), report, "recovery must be byte-identical");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn daemon_matches_sim_engine_across_the_zoo() {
    let horizon = 12usize;
    let workload = WorkloadSpec::synthetic(20, horizon, 0);
    let cluster_spec = ClusterSpec::homogeneous(8);
    for key in ZOO {
        let seed = 3u64;
        // --- simulator side ---
        let jobs = workload.jobs(seed);
        let cluster = cluster_spec.build();
        let reg = dmlrs::sched::SchedulerRegistry::builtin();
        let mut sched = reg.build_named(key, seed, &jobs, &cluster, horizon).unwrap();
        let sim = simulate(&jobs, &cluster, horizon, sched.as_mut());

        // --- daemon side: same arrival sequence in virtual-clock mode ---
        let service = ServiceConfig {
            scheduler: SchedulerSpec::new(key).with_seed(seed),
            cluster: cluster_spec.clone(),
            workload,
            churn: ChurnSpec::None,
        };
        let handle = start_daemon(DaemonConfig::new(service)).expect("daemon starts");
        let mut client = Client::connect(handle.addr);
        let mut next = 0usize;
        let mut decisions: Vec<(usize, String, Option<usize>)> = Vec::new();
        for t in 0..horizon {
            while next < jobs.len() && jobs[next].arrival <= t {
                let resp = client.roundtrip(&Request::Submit { job: jobs[next].clone() });
                let id = resp.get("job_id").unwrap().as_usize().unwrap();
                let decision =
                    resp.get("decision").and_then(Json::as_str).unwrap().to_string();
                let completion = resp.get("completion").and_then(Json::as_usize);
                decisions.push((id, decision, completion));
                next += 1;
            }
            client.roundtrip(&Request::Tick);
        }
        client.roundtrip(&Request::Shutdown);
        let report = handle.join().expect("clean drain");

        // identical decisions, job by job
        assert_eq!(decisions.len(), jobs.len(), "{key}");
        for (id, decision, completion) in &decisions {
            let outcome = &sim.outcomes[*id];
            assert_eq!(outcome.job_id, *id, "{key}");
            match decision.as_str() {
                "admitted" => {
                    assert!(outcome.admitted, "{key}: job {id} diverged");
                    assert_eq!(outcome.completion, *completion, "{key}: job {id}");
                }
                "rejected" => {
                    assert!(!outcome.admitted, "{key}: job {id} diverged");
                }
                "deferred" => {} // admission decided slot by slot below
                other => panic!("unknown decision {other}"),
            }
        }
        // identical aggregate metrics (covers the slot-driven policies).
        // Per-job utilities are bit-identical; the totals are summed in
        // different orders (job id vs completion order), so compare the
        // sums with float tolerance.
        assert_eq!(report.submitted, jobs.len(), "{key}");
        assert_eq!(report.completed, sim.completed, "{key}");
        assert!(
            (report.total_utility - sim.total_utility).abs() < 1e-9,
            "{key}: utility diverged: daemon {} vs engine {}",
            report.total_utility,
            sim.total_utility
        );
        assert_eq!(report.solver, sim.solver, "{key}: same solver work");
    }
}

/// PR 5 crash injection: a daemon dies mid-write of a `replan` op-log
/// record. `--recover` must repair the journal via the tolerant JSONL
/// loader (dropping only the in-flight record), replay the surviving
/// prefix — including the journaled replan rounds — to a byte-identical
/// ledger, and resume appending cleanly.
#[test]
fn recover_repairs_oplog_truncated_mid_replan_record() {
    let path = tmp_path("replan_crash");
    let _ = std::fs::remove_file(&path);
    let service = ServiceConfig {
        scheduler: SchedulerSpec::new("pd-ors")
            .with_seed(7)
            .with_replan(ReplanPolicy::Every(2)),
        cluster: ClusterSpec::homogeneous(6),
        workload: WorkloadSpec::synthetic(10, 10, 0),
        churn: ChurnSpec::None,
    };
    let jobs = service.workload.jobs(7);
    let expected = {
        let mut core = ServiceCore::new(service.clone()).unwrap();
        core.attach_log(&path).unwrap();
        let mut next = 0usize;
        for t in 0..6usize {
            while next < jobs.len() && jobs[next].arrival <= t {
                core.submit(jobs[next].clone());
                next += 1;
            }
            if t == 3 {
                // a wire-triggered round on top of the every:2 cadence —
                // both kinds must survive the crash
                let resp = core.replan();
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            }
            core.tick();
        }
        core.report()
    };

    // crash mid-replan-record: a truncated line with no newline
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"op\":\"replan\",\"slot\":6,\"repla").unwrap();
    }

    let mut recovered = ServiceCore::recover(service.clone(), &path).unwrap();
    assert_eq!(
        recovered.report(),
        expected,
        "replay after repair must reproduce the pre-crash state exactly"
    );

    // the repaired log accepts new ops (including another replan) and
    // replays again cleanly
    recovered.replan();
    recovered.tick();
    let after = recovered.report();
    drop(recovered);
    let again = ServiceCore::recover(service, &path).unwrap();
    assert_eq!(again.report(), after);
    let _ = std::fs::remove_file(&path);
}

/// PR 6 lockstep parity: the same arrival sequence plus the same machine
/// failures/rejoins — injected into the daemon as `machine_down` /
/// `machine_up` wire ops, and into the engine as an explicit churn event
/// list — must produce identical completions, migrations, evictions,
/// finish-time fairness, utility, and solver work. The daemon serves with
/// an out-of-horizon event list (the manual-injection idiom: tracking on,
/// nothing fires automatically) so the wire ops are the only churn.
#[test]
fn daemon_matches_sim_engine_under_wire_churn() {
    let horizon = 12usize;
    let seed = 3u64;
    let workload = WorkloadSpec::synthetic(20, horizon, 0);
    let cluster_spec = ClusterSpec::homogeneous(8);
    let churn = ChurnSpec::parse("down@3:1,down@5:2,up@8:1").unwrap();

    // --- engine side: the trace fires the events at SlotStart ---
    let jobs = workload.jobs(seed);
    let cluster = cluster_spec.build();
    let reg = dmlrs::sched::SchedulerRegistry::builtin();
    let mut sched = reg.build_named("pd-ors", seed, &jobs, &cluster, horizon).unwrap();
    let sim = SimEngine::builder()
        .jobs(&jobs)
        .cluster(&cluster)
        .horizon(horizon)
        .churn(churn, seed)
        .run(sched.as_mut());

    // --- daemon side: the same events as wire ops at the same slots ---
    let service = ServiceConfig {
        scheduler: SchedulerSpec::new("pd-ors").with_seed(seed),
        cluster: cluster_spec,
        workload,
        churn: ChurnSpec::parse("down@900:1").unwrap(),
    };
    let handle = start_daemon(DaemonConfig::new(service)).expect("daemon starts");
    let mut client = Client::connect(handle.addr);
    let mut next = 0usize;
    for t in 0..horizon {
        // SlotStart ordering: churn ops land before this slot's arrivals,
        // exactly where the engine applies its trace events
        if t == 3 {
            client.roundtrip(&Request::MachineDown { machine: 1 });
        }
        if t == 5 {
            client.roundtrip(&Request::MachineDown { machine: 2 });
        }
        if t == 8 {
            client.roundtrip(&Request::MachineUp { machine: 1 });
        }
        while next < jobs.len() && jobs[next].arrival <= t {
            client.roundtrip(&Request::Submit { job: jobs[next].clone() });
            next += 1;
        }
        client.roundtrip(&Request::Tick);
    }
    client.roundtrip(&Request::Shutdown);
    let report = handle.join().expect("clean drain");

    assert_eq!(report.submitted, jobs.len());
    assert_eq!(report.completed, sim.completed, "completions diverged");
    assert_eq!(report.evicted, sim.evicted, "evictions diverged");
    assert_eq!(report.migrated, sim.migrated, "migrations diverged");
    assert!(
        (report.total_utility - sim.total_utility).abs() < 1e-9,
        "utility diverged: daemon {} vs engine {}",
        report.total_utility,
        sim.total_utility
    );
    assert!(
        (report.ftf - sim.ftf).abs() < 1e-9,
        "ftf diverged: daemon {} vs engine {}",
        report.ftf,
        sim.ftf
    );
    assert_eq!(report.solver, sim.solver, "same solver work");
}

/// PR 6 crash injection: a daemon dies mid-write of a `machine_down`
/// op-log record. `--recover` must repair the journal, replay the
/// surviving prefix — including the journaled wire churn ops — to a
/// byte-identical ledger, and resume appending cleanly.
#[test]
fn recover_repairs_oplog_truncated_mid_machine_down_record() {
    let path = tmp_path("churn_crash");
    let _ = std::fs::remove_file(&path);
    let service = ServiceConfig {
        scheduler: SchedulerSpec::new("pd-ors").with_seed(7),
        cluster: ClusterSpec::homogeneous(6),
        workload: WorkloadSpec::synthetic(10, 10, 0),
        churn: ChurnSpec::parse("down@900:1").unwrap(),
    };
    let jobs = service.workload.jobs(7);
    let expected = {
        let mut core = ServiceCore::new(service.clone()).unwrap();
        core.attach_log(&path).unwrap();
        let mut next = 0usize;
        for t in 0..6usize {
            if t == 3 {
                let resp = core.machine_down(1);
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string());
            }
            if t == 5 {
                let resp = core.machine_up(1);
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string());
            }
            while next < jobs.len() && jobs[next].arrival <= t {
                core.submit(jobs[next].clone());
                next += 1;
            }
            core.tick();
        }
        core.report()
    };

    // crash mid-machine_down-record: a truncated line with no newline
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"op\":\"machine_down\",\"slot\":6,\"mach").unwrap();
    }

    let mut recovered = ServiceCore::recover(service.clone(), &path).unwrap();
    assert_eq!(
        recovered.report(),
        expected,
        "replay after repair must reproduce the pre-crash state exactly"
    );

    // the repaired log accepts new churn ops and replays again cleanly
    recovered.machine_down(2);
    recovered.tick();
    let after = recovered.report();
    drop(recovered);
    let again = ServiceCore::recover(service, &path).unwrap();
    assert_eq!(again.report(), after);
    let _ = std::fs::remove_file(&path);
}

/// The op-log config header records an enabled churn spec; replaying it
/// into a daemon configured without one (or with a different one) is
/// config drift and must be refused.
#[test]
fn recover_rejects_churn_config_drift() {
    let path = tmp_path("churn_drift");
    let _ = std::fs::remove_file(&path);
    let with_churn = ServiceConfig {
        scheduler: SchedulerSpec::new("pd-ors").with_seed(3),
        cluster: ClusterSpec::homogeneous(4),
        workload: WorkloadSpec::synthetic(6, 8, 0),
        churn: ChurnSpec::parse("down@3:1,up@5:1").unwrap(),
    };
    {
        let mut core = ServiceCore::new(with_churn.clone()).unwrap();
        core.attach_log(&path).unwrap();
        core.tick();
    }
    // churn-less daemon refuses the churny log
    let mut without = with_churn.clone();
    without.churn = ChurnSpec::None;
    let e = ServiceCore::recover(without, &path).unwrap_err();
    assert!(e.to_string().contains("churn"), "{e}");
    // ...and so does a daemon with a *different* churn spec
    let mut other = with_churn;
    other.churn = ChurnSpec::parse("mtbf:40,mttr:8").unwrap();
    let e = ServiceCore::recover(other, &path).unwrap_err();
    assert!(e.to_string().contains("churn"), "{e}");
    let _ = std::fs::remove_file(&path);
}

/// The op-log config header records an enabled replan cadence; replaying
/// it into a daemon configured without one is config drift and must be
/// refused.
#[test]
fn recover_rejects_replan_config_drift() {
    let path = tmp_path("replan_drift");
    let _ = std::fs::remove_file(&path);
    let with_replan = ServiceConfig {
        scheduler: SchedulerSpec::new("pd-ors")
            .with_seed(3)
            .with_replan(ReplanPolicy::Every(4)),
        cluster: ClusterSpec::homogeneous(4),
        workload: WorkloadSpec::synthetic(6, 8, 0),
        churn: ChurnSpec::None,
    };
    {
        let mut core = ServiceCore::new(with_replan.clone()).unwrap();
        core.attach_log(&path).unwrap();
        core.tick();
    }
    let mut without = with_replan;
    without.scheduler.replan = ReplanPolicy::None;
    let e = ServiceCore::recover(without, &path).unwrap_err();
    assert!(e.to_string().contains("replan"), "{e}");
    let _ = std::fs::remove_file(&path);
}
