//! Protocol fuzz hardening (PR 5 satellite): the admission daemon must
//! answer every malformed NDJSON request with `"ok":false` and an error —
//! and never panic, kill the connection's request/response pairing, or
//! desync the scheduler-core thread. Cases are seeded `testkit`
//! mutations of a valid `submit` line (truncations, byte flips, interior
//! NULs, wrong types, unknown ops, oversized numbers) plus a fixed corpus
//! of known-nasty lines.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

use dmlrs::chaos::ChurnSpec;
use dmlrs::jobs::test_support::test_job;
use dmlrs::sched::registry::SchedulerSpec;
use dmlrs::service::{
    start_daemon, synthetic_service_config, DaemonConfig, Request, ServiceConfig,
};
use dmlrs::sweep::{ClusterSpec, WorkloadSpec};
use dmlrs::testkit;
use dmlrs::util::json::Json;
use dmlrs::util::Rng;

/// A valid submit line to mutate.
fn valid_submit_line() -> String {
    Request::Submit { job: test_job(1) }.to_line()
}

/// Seeded mutation of a valid request line. Never returns bytes that
/// would split into multiple protocol lines (no interior `\n`/`\r`), and
/// never an all-whitespace line (the daemon ignores those by design).
fn mutate(rng: &mut Rng) -> Vec<u8> {
    let base = valid_submit_line().into_bytes();
    let mut out = base.clone();
    match rng.range_usize(0, 5) {
        // truncate mid-JSON
        0 => {
            let cut = rng.range_usize(1, out.len() - 1);
            out.truncate(cut);
        }
        // flip a random byte to a random value
        1 => {
            let pos = rng.range_usize(0, out.len() - 1);
            out[pos] = (rng.range_u64(0, 255)) as u8;
        }
        // interior NUL
        2 => {
            let pos = rng.range_usize(0, out.len() - 1);
            out.insert(pos, 0u8);
        }
        // unknown / mistyped op
        3 => {
            out = format!("{{\"op\":\"x{}\"}}", rng.next_u64()).into_bytes();
        }
        // oversized numbers inside the job payload
        4 => {
            let line = String::from_utf8_lossy(&base)
                .replace("\"samples\":", "\"samples\":1e999,\"x\":");
            out = line.into_bytes();
        }
        // valid JSON, wrong shapes
        _ => {
            let shapes = ["{\"op\":5}", "[1,2,3]", "\"tick\"", "{}", "17"];
            out = shapes[rng.range_usize(0, shapes.len() - 1)].as_bytes().to_vec();
        }
    }
    // keep it one protocol line
    for b in out.iter_mut() {
        if *b == b'\n' || *b == b'\r' {
            *b = b'X';
        }
    }
    if out.iter().all(|b| b.is_ascii_whitespace()) {
        out = b"x".to_vec();
    }
    out
}

/// Parser-level fuzz: `Request::parse` must return Ok or Err — never
/// panic — on arbitrary mutations.
#[test]
fn request_parse_never_panics() {
    testkit::check("request-parse-fuzz", 0xF0, 512, |rng| {
        let bytes = mutate(rng);
        let line = String::from_utf8_lossy(&bytes).into_owned();
        let _ = Request::parse(&line); // Ok or Err both fine; no panic
        Ok(())
    });
}

/// Oversized and non-finite numbers must be rejected at the codec
/// boundary, not saturated into the scheduler core.
#[test]
fn codec_rejects_hostile_numbers() {
    let cases = [
        ("{\"op\":\"submit\",\"job\":{\"id\":1e999}}", "finite"),
        ("{\"op\":\"submit\",\"job\":{\"id\":-1}}", "≥ 0"),
    ];
    for (line, needle) in cases {
        let e = Request::parse(line).unwrap_err();
        assert!(e.contains(needle), "{line}: {e}");
    }
    // a full job with one poisoned field
    for (field, bad) in [
        ("\"samples\":", "\"samples\":-5,\"x\":"),
        ("\"gamma\":", "\"gamma\":0,\"x\":"),
        ("\"b_int\":", "\"b_int\":1e999,\"x\":"),
        ("\"batch\":", "\"batch\":0,\"x\":"),
    ] {
        let line = valid_submit_line().replace(field, bad);
        assert!(
            Request::parse(&line).is_err(),
            "poisoned {field} accepted: {line}"
        );
    }
    // tau and grad_size_mb are each allowed to be 0 — but not both, or
    // the per-sample time hits 0 and the speed model divides by it
    let line = valid_submit_line()
        .replace("\"tau\":", "\"tau\":0,\"x\":")
        .replace("\"grad_size_mb\":", "\"grad_size_mb\":0,\"y\":");
    let e = Request::parse(&line).unwrap_err();
    assert!(e.contains("per-sample"), "{e}");
    // the untouched valid line still parses
    assert!(Request::parse(&valid_submit_line()).is_ok());
}

struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { reader, stream }
    }

    fn send_bytes(&mut self, bytes: &[u8]) -> String {
        self.stream.write_all(bytes).unwrap();
        self.stream.write_all(b"\n").unwrap();
        self.stream.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        assert!(resp.ends_with('\n'), "daemon closed mid-response: {resp:?}");
        resp
    }
}

/// End-to-end fuzz: one live daemon, one connection. Every malformed
/// line gets exactly one `"ok":false` response, and an immediately
/// following `status` round-trip proves the connection and the core are
/// still in sync.
#[test]
fn daemon_survives_malformed_lines_without_desync() {
    let cfg = DaemonConfig::new(synthetic_service_config("pd-ors", 1, 4, 8, 8));
    let handle = start_daemon(cfg).expect("daemon starts");
    let mut client = Client::connect(handle.addr);

    let fixed: Vec<Vec<u8>> = [
        "not json at all",
        "{\"op\":\"fly\"}",
        "{\"op\":5}",
        "{}",
        "[1,2,3]",
        "\"status\"",
        "{\"op\":\"submit\"}",
        "{\"op\":\"submit\",\"job\":{}}",
        "{\"op\":\"submit\",\"job\":{\"id\":1e999}}",
        "{\"op\":\"submit\",\"job\":17}",
        "{\"op\"",
        "\u{7f}\u{1}garbage\u{2}",
    ]
    .into_iter()
    .map(|s| s.as_bytes().to_vec())
    .collect();
    // interior NUL (not expressible via &str literals above cleanly)
    let mut with_nul = b"{\"op\":\"st".to_vec();
    with_nul.push(0);
    with_nul.extend_from_slice(b"atus\"}");

    let mut seeded = Vec::new();
    let mut meta = Rng::new(0xFACE);
    for _ in 0..64 {
        let mut rng = Rng::new(meta.next_u64());
        seeded.push(mutate(&mut rng));
    }

    for (i, bytes) in
        fixed.iter().chain(std::iter::once(&with_nul)).chain(seeded.iter()).enumerate()
    {
        let resp = client.send_bytes(bytes);
        let v = Json::parse(resp.trim()).unwrap_or_else(|e| {
            panic!("case {i}: daemon answered non-JSON {resp:?}: {e}")
        });
        // a mutation may accidentally stay valid; what matters is a
        // well-formed tagged response either way
        let ok = v.get("ok").expect("response carries ok");
        if ok == &Json::Bool(false) {
            assert!(v.get("error").is_some(), "case {i}: ok:false without error");
        }
        // desync probe: the very next request must answer correctly
        let status = client.send_bytes(b"{\"op\":\"status\"}");
        let sv = Json::parse(status.trim()).expect("status is JSON");
        assert_eq!(sv.get("ok"), Some(&Json::Bool(true)), "case {i}: desynced");
        assert!(sv.get("slot").is_some(), "case {i}: status lost its fields");
    }

    // a half-written line followed by connection close must not take the
    // daemon down ...
    {
        let mut half = Client::connect(handle.addr);
        half.stream.write_all(b"{\"op\":\"submit\",\"job\":{\"id\"").unwrap();
        half.stream.flush().unwrap();
        drop(half);
    }
    // ... a fresh connection still gets served
    let mut again = Client::connect(handle.addr);
    let resp = again.send_bytes(b"{\"op\":\"tick\"}");
    assert!(resp.contains("\"ok\":true"), "{resp}");
    let resp = again.send_bytes(b"{\"op\":\"shutdown\"}");
    assert!(resp.contains("\"draining\":true"), "{resp}");
    // (a mutation can accidentally stay a *valid* submit, so the core may
    // have seen a few jobs — what matters is that it drained cleanly and
    // the one explicit tick is accounted for)
    let report = handle.join().expect("clean drain");
    assert_eq!(report.slot, 1, "exactly one tick reached the core");
}

/// PR 10: the same hardening contract holds for the **sharded** router
/// surface. A 2-cell daemon faces hostile ids (explains for jobs that
/// were never submitted, machine ops outside every cell), malformed
/// lines, and submits racing a `machine_down` on the other cell — no
/// panic, no desync, every response tagged. Then both per-cell op-logs
/// are truncated mid-record (a crash while two cells were appending
/// concurrently) and `--recover` must repair and replay every cell to
/// the exact pre-crash state.
#[test]
fn sharded_daemon_survives_hostile_ops_and_recovers_every_cell() {
    let base = std::env::temp_dir()
        .join(format!("dmlrs_fuzz_cells_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned();
    for i in 0..2 {
        let _ = std::fs::remove_file(format!("{base}.cell{i}"));
    }
    // out-of-horizon churn spec: the manual-injection idiom — tracking
    // on, nothing fires automatically, the wire ops are the only churn
    let service = ServiceConfig {
        scheduler: SchedulerSpec::new("pd-ors").with_seed(1),
        cluster: ClusterSpec::homogeneous(8),
        workload: WorkloadSpec::synthetic(16, 10, 0),
        churn: ChurnSpec::parse("down@900:1").unwrap(),
    };
    let mut cfg = DaemonConfig::new(service.clone());
    cfg.shards = 2;
    cfg.batch = 4;
    cfg.oplog = Some(base.clone());
    let handle = start_daemon(cfg).expect("daemon starts");
    let addr = handle.addr;

    // submits racing churn on the other cell: machine 7 lives on cell 1
    // (machines 4..8), while cell 0 keeps admitting — both interleavings
    // are valid, but every response must be ok and every op journaled in
    // the order its cell served it
    let jobs = service.workload.jobs(1);
    std::thread::scope(|scope| {
        scope.spawn(|| {
            let mut c = Client::connect(addr);
            for job in &jobs {
                let line = Request::Submit { job: job.clone() }.to_line();
                let resp = c.send_bytes(line.as_bytes());
                assert!(resp.contains("\"ok\":true"), "{resp}");
            }
        });
        scope.spawn(|| {
            let mut c = Client::connect(addr);
            let resp = c.send_bytes(b"{\"op\":\"machine_down\",\"machine\":7}");
            assert!(resp.contains("\"ok\":true"), "{resp}");
            let resp = c.send_bytes(b"{\"op\":\"machine_up\",\"machine\":7}");
            assert!(resp.contains("\"ok\":true"), "{resp}");
        });
    });

    let mut client = Client::connect(addr);
    // hostile ids against the router: an explain homed on a cell that
    // never saw the job, and machine ops outside every cell's range
    for (bytes, needle) in [
        (b"{\"op\":\"explain\",\"job_id\":999}".as_slice(), "\"ok\":false"),
        (b"{\"op\":\"machine_down\",\"machine\":99}".as_slice(), "out of range"),
        (b"{\"op\":\"machine_up\",\"machine\":12345}".as_slice(), "out of range"),
        (b"{\"op\":\"fly\"}".as_slice(), "\"ok\":false"),
        (b"not json at all".as_slice(), "\"ok\":false"),
    ] {
        let resp = client.send_bytes(bytes);
        assert!(resp.contains(needle), "{resp}");
        // desync probe after every hostile line
        let status = client.send_bytes(b"{\"op\":\"status\"}");
        let sv = Json::parse(status.trim()).expect("status is JSON");
        assert_eq!(sv.get("ok"), Some(&Json::Bool(true)), "desynced: {status}");
        assert_eq!(sv.get("submitted").unwrap().as_usize(), Some(16), "{status}");
    }
    // the merged surface still reports the cell layout
    let cells = client.send_bytes(b"{\"op\":\"cells\"}");
    let cv = Json::parse(cells.trim()).unwrap();
    assert_eq!(cv.get("shards").unwrap().as_usize(), Some(2), "{cells}");

    client.send_bytes(b"{\"op\":\"tick\"}");
    let resp = client.send_bytes(b"{\"op\":\"shutdown\"}");
    assert!(resp.contains("\"draining\":true"), "{resp}");
    let report = handle.join().expect("clean drain");
    assert_eq!(report.submitted, 16);
    assert_eq!(report.slot, 1);

    // crash injection: both cells die mid-append
    for i in 0..2 {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(format!("{base}.cell{i}"))
            .unwrap();
        f.write_all(b"{\"op\":\"submit\",\"job\":{\"id").unwrap();
    }

    // --recover repairs and replays every cell independently
    let mut rcfg = DaemonConfig::new(service);
    rcfg.shards = 2;
    rcfg.batch = 4;
    rcfg.recover = Some(base.clone());
    let handle = start_daemon(rcfg).expect("recovery starts");
    let mut client = Client::connect(handle.addr);
    let status = client.send_bytes(b"{\"op\":\"status\"}");
    let sv = Json::parse(status.trim()).unwrap();
    assert_eq!(sv.get("ok"), Some(&Json::Bool(true)), "{status}");
    assert_eq!(sv.get("submitted").unwrap().as_usize(), Some(16), "{status}");
    assert_eq!(sv.get("slot").unwrap().as_usize(), Some(1), "{status}");
    client.send_bytes(b"{\"op\":\"shutdown\"}");
    let replayed = handle.join().expect("clean drain after recovery");
    assert_eq!(replayed, report, "per-cell replay must reproduce the crash state");
    for i in 0..2 {
        let _ = std::fs::remove_file(format!("{base}.cell{i}"));
    }
}
