//! The decision-provenance inertness contract (PR 9): capturing per-job
//! `DecisionTrace`s and the per-slot cluster price series is
//! byte-invisible to every deterministic artifact — `SimResult` across
//! the scheduler zoo with replan and churn active, the sweep runner's
//! per-cell rejection-reason fields across worker counts, and the
//! daemon's `explain` answers across an op-log recovery.
//!
//! The obs flag word is process-global; every test here takes `LOCK`
//! (poison-tolerant, so one failing test doesn't cascade) and restores
//! flags-off state before releasing it.

use std::sync::Mutex;

use dmlrs::chaos::ChurnSpec;
use dmlrs::cluster::Cluster;
use dmlrs::jobs::Job;
use dmlrs::obs;
use dmlrs::sched::registry::{SchedulerRegistry, SchedulerSpec, ZOO};
use dmlrs::sched::replan::ReplanPolicy;
use dmlrs::service::{ServiceConfig, ServiceCore};
use dmlrs::sim::{SimEngine, SimResult};
use dmlrs::sweep::{run_matrix, ClusterSpec, ScenarioMatrix, WorkloadSpec};
use dmlrs::util::Rng;
use dmlrs::workload::synthetic::{paper_cluster, paper_cluster_skewed};
use dmlrs::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

static LOCK: Mutex<()> = Mutex::new(());

const JOBS: usize = 12;
const HORIZON: usize = 14;
const WORKLOAD_SEED: u64 = 21;
const SCHED_SEED: u64 = 4;

fn workload() -> Vec<Job> {
    let mut rng = Rng::new(WORKLOAD_SEED);
    synthetic_jobs(&SynthConfig::paper(JOBS, HORIZON, MIX_DEFAULT), &mut rng)
}

fn clusters() -> Vec<(&'static str, Cluster)> {
    vec![
        ("homogeneous", paper_cluster(8)),
        ("skewed", paper_cluster_skewed(8, 2.0)),
    ]
}

/// Run `key` through the engine with replan + churn active (the busiest
/// code path — evictions, migrations, and re-solves all interleave with
/// the admission decisions being traced).
fn run(key: &str, cluster: &Cluster) -> SimResult {
    let reg = SchedulerRegistry::builtin();
    let jobs = workload();
    let spec = SchedulerSpec::new(key).with_seed(SCHED_SEED);
    let mut sched = reg.build(&spec, &jobs, cluster, HORIZON).unwrap();
    SimEngine::builder()
        .jobs(&jobs)
        .cluster(cluster)
        .horizon(HORIZON)
        .replan(ReplanPolicy::Every(3))
        .churn(ChurnSpec::parse("down@3:1,up@7:1").unwrap(), SCHED_SEED)
        .run(sched.as_mut())
}

#[test]
fn provenance_is_byte_inert_across_the_zoo() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for (shape, cluster) in clusters() {
        for key in ZOO {
            obs::set_flags(0);
            let off = run(key, &cluster);

            obs::set_flags(obs::PROV);
            let mut on = run(key, &cluster);
            obs::set_flags(0);

            // off: the provenance channel stays completely silent
            assert!(off.decisions.is_empty(), "{key} on {shape}");
            assert!(off.prices.is_empty(), "{key} on {shape}");

            // on: every decision is explained, and the explanation is
            // internally consistent with Algorithm 1
            assert!(
                !on.decisions.is_empty(),
                "{key} on {shape}: every arrival must leave a trace"
            );
            for d in &on.decisions {
                match d.decision {
                    "admit" => assert!(
                        d.reason != "price" && d.reason != "infeasible",
                        "{key} on {shape}: job {} admitted with a rejection \
                         reason {:?}",
                        d.job_id,
                        d.reason
                    ),
                    "reject" => assert!(
                        d.reason == "price"
                            || d.reason == "infeasible"
                            || d.reason == "policy",
                        "{key} on {shape}: job {} rejected without a \
                         machine-readable reason: {:?}",
                        d.job_id,
                        d.reason
                    ),
                    "defer" => {}
                    other => panic!("{key} on {shape}: unknown decision {other:?}"),
                }
                assert!(d.margin.is_finite(), "{key} on {shape}: job {}", d.job_id);
            }
            if key == "pd-ors" {
                assert_eq!(
                    on.decisions.len(),
                    JOBS,
                    "pd-ors on {shape}: one trace per arrival"
                );
                for d in &on.decisions {
                    match d.decision {
                        "admit" => {
                            assert_eq!(d.reason, "margin", "job {}", d.job_id);
                            assert!(
                                d.margin > 0.0,
                                "job {} admitted at non-positive margin {}",
                                d.job_id,
                                d.margin
                            );
                        }
                        "reject" => assert!(
                            d.reason == "price" || d.reason == "infeasible",
                            "job {}: {:?}",
                            d.job_id,
                            d.reason
                        ),
                        other => panic!("pd-ors never defers, got {other:?}"),
                    }
                }
                assert!(
                    on.decisions.iter().any(|d| d.decision == "admit"),
                    "pd-ors on {shape}: the workload admits something"
                );
                // the dual-price series: one sample per slot, all finite
                assert_eq!(on.prices.len(), HORIZON, "pd-ors on {shape}");
                for p in &on.prices {
                    assert!(
                        p.max_price.is_finite() && p.max_price >= 0.0,
                        "pd-ors on {shape}: t={}",
                        p.t
                    );
                    assert!(p.mean_price().is_finite(), "pd-ors on {shape}: t={}", p.t);
                }
            } else {
                // only pricing schedulers expose a dual-price sample
                assert!(on.prices.is_empty(), "{key} on {shape}");
            }

            // byte-identity: with the provenance channel cleared, the two
            // results — outcomes, utilities, ftf, churn/replan counters,
            // AND the solver diagnostic counters — are fully equal
            on.decisions.clear();
            on.prices.clear();
            assert_eq!(off, on, "{key} on {shape}: provenance must be inert");
        }
    }
}

#[test]
fn sweep_reason_fields_are_worker_count_invariant() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    obs::set_flags(0);
    let cell_jobs = 10usize;
    let matrix = ScenarioMatrix::new()
        .schedulers(&["pd-ors", "fifo"])
        .workload(WorkloadSpec::synthetic(cell_jobs, 10, 0))
        .cluster(ClusterSpec::homogeneous(5))
        .seeds(2);

    let fields = |outcomes: &[dmlrs::sweep::CellOutcome]| -> Vec<(String, usize, usize, u64, u64)> {
        let mut rows: Vec<_> = outcomes
            .iter()
            .map(|o| {
                (
                    format!(
                        "{}/{}/{}/{}",
                        o.record.scheduler, o.record.workload, o.record.cluster, o.record.seed
                    ),
                    o.record.rej_price,
                    o.record.rej_infeasible,
                    o.record.mean_admit_margin.to_bits(),
                    o.record.mean_price_level.to_bits(),
                )
            })
            .collect();
        rows.sort();
        rows
    };

    let serial = run_matrix(&matrix, 1, None).unwrap();
    let parallel = run_matrix(&matrix, 4, None).unwrap();
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(
        fields(&serial),
        fields(&parallel),
        "rejection-reason fields are deterministic per cell, not per worker count"
    );

    // every rejection in a pd-ors cell carries a machine-readable reason
    for o in &serial {
        if o.record.scheduler == "pd-ors" {
            assert_eq!(
                o.record.admitted + o.record.rej_price + o.record.rej_infeasible,
                cell_jobs,
                "cell {}/{}/{}: unexplained rejections",
                o.record.workload,
                o.record.cluster,
                o.record.seed
            );
            if o.record.admitted > 0 {
                assert!(
                    o.record.mean_admit_margin > 0.0,
                    "admissions happen at positive margin"
                );
            }
            assert!(o.record.mean_price_level >= 0.0);
        } else {
            assert_eq!(o.record.rej_price, 0, "fifo has no pricing rejections");
        }
    }
}

#[test]
fn daemon_explain_survives_oplog_recovery() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    obs::set_flags(0);
    let path = std::env::temp_dir()
        .join(format!("dmlrs_prov_parity_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let _ = std::fs::remove_file(&path);

    let horizon = 12usize;
    let cfg = || ServiceConfig {
        scheduler: SchedulerSpec::new("pd-ors")
            .with_seed(5)
            .with_replan(ReplanPolicy::Every(3)),
        cluster: ClusterSpec::homogeneous(6),
        workload: WorkloadSpec::synthetic(16, 12, 0),
        churn: ChurnSpec::None,
    };
    let (report, explains) = {
        let mut core = ServiceCore::new(cfg()).unwrap();
        core.attach_log(&path).unwrap();
        let jobs = core.config().workload.jobs(5);
        let mut next = 0usize;
        for t in 0..horizon {
            while next < jobs.len() && jobs[next].arrival <= t {
                core.submit(jobs[next].clone());
                next += 1;
            }
            core.tick();
        }
        let explains: Vec<String> =
            (0..next).map(|id| core.explain(id).to_string()).collect();
        (core.report(), explains)
    };
    assert!(!explains.is_empty());
    assert!(
        explains.iter().any(|e| e.contains("\"decision\":\"admit\"")),
        "at least one admission is explained"
    );
    if report.rejected > 0 {
        assert!(
            explains.iter().any(|e| e.contains("\"decision\":\"reject\"")),
            "every rejection is explained"
        );
    }

    // replay rebuilds the provenance store: identical report, identical
    // answers, and the journaled explain ops themselves replay cleanly
    let mut rec = ServiceCore::recover(cfg(), &path).unwrap();
    assert_eq!(rec.report(), report, "replay must rebuild identical state");
    for (id, want) in explains.iter().enumerate() {
        let got = rec.explain(id).to_string();
        assert_eq!(&got, want, "job {id}: explain must survive recovery");
    }
    let _ = std::fs::remove_file(&path);
}
