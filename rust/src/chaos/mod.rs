//! Deterministic fault injection: machine churn traces.
//!
//! A [`ChurnSpec`] describes *how* machines fail — either a seeded
//! MTBF/MTTR process (`mtbf:40,mttr:8`) or an explicit event list
//! (`down@3:1,up@7:1`) — and a [`ChurnTrace`] is its fully materialized,
//! per-slot realization for one cluster shape. The trace is what the
//! simulation engine and the service core consume: typed
//! [`ChurnEvent`]s applied at `SlotStart`, *before* replan rounds, so a
//! failed machine's capacity leaves the
//! [`AllocLedger`](crate::cluster::AllocLedger) before any planning at
//! that slot prices it.
//!
//! The default [`ChurnSpec::None`] is the byte-identical no-op (no trace
//! is built, no RNG is drawn, no events fire) — the same contract the
//! replan and arrival-process axes follow, extended by
//! `tests/churn_determinism.rs` and `tests/replan_parity.rs`.

use crate::util::Rng;

/// One typed churn event for one machine at one slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEvent {
    /// Hard failure: capacity leaves the ledger from this slot on and
    /// admissions with remaining work on the machine are interrupted
    /// (migrated or evicted).
    Down,
    /// Graceful drain: no *new* work may be planned on the machine from
    /// this slot on, but committed schedules run to completion.
    Drain,
    /// The machine returns to service from this slot on.
    Rejoin,
}

impl ChurnEvent {
    fn key_char(&self) -> char {
        match self {
            ChurnEvent::Down => 'd',
            ChurnEvent::Drain => 'g',
            ChurnEvent::Rejoin => 'u',
        }
    }
}

/// Declarative churn model, parsed from `--churn` / `[cluster] churn` /
/// `[sweep] churn`.
#[derive(Debug, Clone, PartialEq)]
pub enum ChurnSpec {
    /// No churn — the default, and a strict no-op end to end.
    None,
    /// Memoryless failures: while up, a machine fails each slot with
    /// probability `1/mtbf`; while down, it rejoins with probability
    /// `1/mttr` (slot-resolution MTBF/MTTR in expectation).
    Mtbf { mtbf: f64, mttr: f64 },
    /// Explicit `(slot, machine, event)` list, applied verbatim.
    Events(Vec<(usize, usize, ChurnEvent)>),
}

impl Default for ChurnSpec {
    fn default() -> ChurnSpec {
        ChurnSpec::None
    }
}

impl ChurnSpec {
    /// Parse a churn spec string:
    ///
    /// * `none` / `off` / empty — no churn;
    /// * `mtbf:<slots>,mttr:<slots>` — the seeded memoryless process;
    /// * comma-separated `<kind>@<slot>:<machine>` events, with kind one
    ///   of `down`, `drain`, `up` — e.g. `down@3:1,up@7:1`.
    pub fn parse(s: &str) -> Result<ChurnSpec, String> {
        let s = s.trim().to_ascii_lowercase();
        if s.is_empty() || s == "none" || s == "off" {
            return Ok(ChurnSpec::None);
        }
        if s.contains('@') {
            let mut events = Vec::new();
            for part in s.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                let (kind, rest) = part.split_once('@').ok_or_else(|| {
                    format!("invalid churn event {part:?} (expected kind@slot:machine)")
                })?;
                let event = match kind.trim() {
                    "down" => ChurnEvent::Down,
                    "drain" => ChurnEvent::Drain,
                    "up" => ChurnEvent::Rejoin,
                    other => {
                        return Err(format!(
                            "invalid churn event kind {other:?} \
                             (expected down|drain|up)"
                        ))
                    }
                };
                let (slot, machine) = rest.split_once(':').ok_or_else(|| {
                    format!("invalid churn event {part:?} (expected kind@slot:machine)")
                })?;
                let slot = slot
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| format!("invalid churn event slot {slot:?}"))?;
                let machine = machine
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| format!("invalid churn event machine {machine:?}"))?;
                events.push((slot, machine, event));
            }
            if events.is_empty() {
                return Err("empty churn event list".to_string());
            }
            events.sort_by_key(|&(t, h, _)| (t, h));
            return Ok(ChurnSpec::Events(events));
        }
        let mut mtbf = None;
        let mut mttr = None;
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part.split_once(':').ok_or_else(|| {
                format!("invalid churn field {part:?} (expected mtbf:<n>,mttr:<n>)")
            })?;
            let value = value.trim().parse::<f64>().map_err(|_| {
                format!("invalid churn value {:?} in {part:?}", value.trim())
            })?;
            if !(value >= 1.0 && value.is_finite()) {
                return Err(format!("churn {key} must be >= 1 slot (got {value})"));
            }
            match key.trim() {
                "mtbf" => mtbf = Some(value),
                "mttr" => mttr = Some(value),
                other => {
                    return Err(format!(
                        "invalid churn field {other:?} (expected mtbf|mttr)"
                    ))
                }
            }
        }
        match (mtbf, mttr) {
            (Some(mtbf), Some(mttr)) => Ok(ChurnSpec::Mtbf { mtbf, mttr }),
            _ => Err(format!(
                "invalid churn spec {s:?} (expected \"none\", \
                 \"mtbf:<n>,mttr:<n>\", or a down@slot:machine event list)"
            )),
        }
    }

    /// Is any churn configured at all?
    pub fn is_enabled(&self) -> bool {
        !matches!(self, ChurnSpec::None)
    }

    /// Human-readable form (the inverse of [`ChurnSpec::parse`]).
    pub fn label(&self) -> String {
        match self {
            ChurnSpec::None => "none".to_string(),
            ChurnSpec::Mtbf { mtbf, mttr } => format!("mtbf:{mtbf},mttr:{mttr}"),
            ChurnSpec::Events(events) => {
                let parts: Vec<String> = events
                    .iter()
                    .map(|&(t, h, e)| {
                        let kind = match e {
                            ChurnEvent::Down => "down",
                            ChurnEvent::Drain => "drain",
                            ChurnEvent::Rejoin => "up",
                        };
                        format!("{kind}@{t}:{h}")
                    })
                    .collect();
                parts.join(",")
            }
        }
    }

    /// Stable identity token for scenario keys (`|ch…`); `None` for the
    /// default no-churn spec, so every pre-existing store key is
    /// unchanged.
    pub fn key_token(&self) -> Option<String> {
        match self {
            ChurnSpec::None => None,
            ChurnSpec::Mtbf { mtbf, mttr } => Some(format!("chm{mtbf}r{mttr}")),
            ChurnSpec::Events(events) => {
                let parts: Vec<String> = events
                    .iter()
                    .map(|&(t, h, e)| format!("{}{t}m{h}", e.key_char()))
                    .collect();
                Some(format!("ch{}", parts.join("-")))
            }
        }
    }
}

impl std::fmt::Display for ChurnSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// A fully materialized churn realization: for each slot, the typed
/// events to apply at `SlotStart`. Generation is deterministic in
/// `(spec, machines, horizon, seed)` and draws from its own RNG stream,
/// so the workload and scheduler streams are untouched — the first half
/// of the `churn = none` byte-identity contract.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnTrace {
    /// `events[t]` = the `(machine, event)` list for slot `t`, sorted by
    /// machine.
    events: Vec<Vec<(usize, ChurnEvent)>>,
}

impl ChurnTrace {
    /// Materialize `spec` for a cluster of `machines` over `horizon`
    /// slots. Returns `None` for [`ChurnSpec::None`] — callers skip all
    /// churn bookkeeping in that case.
    pub fn generate(
        spec: &ChurnSpec,
        machines: usize,
        horizon: usize,
        seed: u64,
    ) -> Option<ChurnTrace> {
        let mut events: Vec<Vec<(usize, ChurnEvent)>> = vec![Vec::new(); horizon];
        match spec {
            ChurnSpec::None => return None,
            ChurnSpec::Mtbf { mtbf, mttr } => {
                // dedicated stream, decoupled from the scheduler's
                // Rng::new(seed) by a fixed tweak
                let mut rng = Rng::new(seed ^ 0xC0FF_EE00_5EED);
                let p_fail = 1.0 / mtbf;
                let p_heal = 1.0 / mttr;
                // never fail the whole cluster: keep machine 0 immortal so
                // every slot retains some capacity to migrate onto
                for h in 1..machines {
                    let mut up = true;
                    for (t, slot) in events.iter_mut().enumerate() {
                        if up {
                            // no failures at t=0: jobs must exist to interrupt
                            if t > 0 && rng.chance(p_fail) {
                                up = false;
                                slot.push((h, ChurnEvent::Down));
                            }
                        } else if rng.chance(p_heal) {
                            up = true;
                            slot.push((h, ChurnEvent::Rejoin));
                        }
                    }
                }
                for slot in &mut events {
                    slot.sort_by_key(|&(h, _)| h);
                }
            }
            ChurnSpec::Events(list) => {
                for &(t, h, e) in list {
                    if t < horizon && h < machines {
                        events[t].push((h, e));
                    }
                }
            }
        }
        Some(ChurnTrace { events })
    }

    /// The `(machine, event)` list to apply at the start of slot `t`.
    pub fn events_at(&self, t: usize) -> &[(usize, ChurnEvent)] {
        self.events.get(t).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Total number of events in the trace.
    pub fn len(&self) -> usize {
        self.events.iter().map(|v| v.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_none_and_empty() {
        for s in ["", "none", "off", "  NONE "] {
            assert_eq!(ChurnSpec::parse(s).unwrap(), ChurnSpec::None);
        }
        assert!(!ChurnSpec::None.is_enabled());
        assert_eq!(ChurnSpec::None.key_token(), None);
    }

    #[test]
    fn parse_mtbf_round_trip() {
        let spec = ChurnSpec::parse("mtbf:40,mttr:8").unwrap();
        assert_eq!(spec, ChurnSpec::Mtbf { mtbf: 40.0, mttr: 8.0 });
        assert_eq!(ChurnSpec::parse(&spec.label()).unwrap(), spec);
        assert_eq!(spec.key_token().as_deref(), Some("chm40r8"));
        assert!(spec.is_enabled());
    }

    #[test]
    fn parse_event_list_round_trip() {
        let spec = ChurnSpec::parse("down@3:1,up@7:1,drain@2:0").unwrap();
        let ChurnSpec::Events(events) = &spec else { panic!("not events") };
        // sorted by (slot, machine)
        assert_eq!(
            events,
            &vec![
                (2, 0, ChurnEvent::Drain),
                (3, 1, ChurnEvent::Down),
                (7, 1, ChurnEvent::Rejoin),
            ]
        );
        assert_eq!(ChurnSpec::parse(&spec.label()).unwrap(), spec);
        assert_eq!(spec.key_token().as_deref(), Some("chg2m0-d3m1-u7m1"));
    }

    #[test]
    fn parse_rejects_malformed() {
        for s in [
            "mtbf:40",
            "mttr:8",
            "mtbf:0,mttr:8",
            "mtbf:x,mttr:8",
            "explode@3:1",
            "down@x:1",
            "down@3:y",
            "gibberish",
        ] {
            assert!(ChurnSpec::parse(s).is_err(), "{s:?} should not parse");
        }
    }

    #[test]
    fn none_generates_no_trace() {
        assert!(ChurnTrace::generate(&ChurnSpec::None, 8, 20, 1).is_none());
    }

    #[test]
    fn mtbf_trace_is_deterministic_and_well_formed() {
        let spec = ChurnSpec::parse("mtbf:10,mttr:3").unwrap();
        let a = ChurnTrace::generate(&spec, 6, 40, 7).unwrap();
        let b = ChurnTrace::generate(&spec, 6, 40, 7).unwrap();
        assert_eq!(a, b, "same seed, same trace");
        let c = ChurnTrace::generate(&spec, 6, 40, 8).unwrap();
        assert_ne!(a, c, "different seed should realize differently");
        assert!(!a.is_empty(), "mtbf 10 over 40 slots x 5 machines must fire");
        // machine 0 is immortal; events alternate Down/Rejoin per machine
        let mut up = vec![true; 6];
        for t in 0..40 {
            for &(h, e) in a.events_at(t) {
                assert_ne!(h, 0, "machine 0 never churns");
                match e {
                    ChurnEvent::Down => {
                        assert!(up[h], "down while down");
                        up[h] = false;
                    }
                    ChurnEvent::Rejoin => {
                        assert!(!up[h], "rejoin while up");
                        up[h] = true;
                    }
                    ChurnEvent::Drain => panic!("mtbf traces never drain"),
                }
            }
        }
    }

    #[test]
    fn event_trace_clips_out_of_range() {
        let spec = ChurnSpec::parse("down@3:1,down@99:1,down@3:42").unwrap();
        let trace = ChurnTrace::generate(&spec, 4, 10, 0).unwrap();
        assert_eq!(trace.len(), 1, "out-of-range slot/machine entries drop");
        assert_eq!(trace.events_at(3), &[(1, ChurnEvent::Down)]);
    }
}
