//! Google-cluster-trace-style workload (Figs 12–17).
//!
//! **Substitution note (DESIGN.md):** the 2011 Google trace file is not
//! available in this offline environment; the paper only consumes two of
//! its properties — (i) the *arrival timestamps* of a scaled-down snippet
//! and (ii) the *scheduling-class mix* (class 0 → time-insensitive,
//! classes 1–2 → time-sensitive, class 3 → time-critical, ≈ 30/69/1).
//! We regenerate those marginals: a non-homogeneous Poisson arrival
//! process with the diurnal + bursty shape reported in the trace analyses
//! ([38], [44]), and the class mix passed by the caller.

use crate::jobs::Job;
use crate::util::Rng;

use super::mix::ClassMix;
use super::synthetic::{synthetic_jobs, SynthConfig};

/// Per-slot arrival intensity profile of the regenerated snippet:
/// diurnal sinusoid + random bursts (occasional crowded slots), matching
/// the "heterogeneity and dynamicity" character of the trace.
pub fn trace_intensity(horizon: usize, rng: &mut Rng) -> Vec<f64> {
    let period = (horizon as f64 / 3.0).max(4.0);
    (0..horizon)
        .map(|t| {
            let diurnal =
                1.0 + 0.6 * (2.0 * std::f64::consts::PI * t as f64 / period).sin();
            let burst = if rng.chance(0.15) { rng.range_f64(1.5, 3.0) } else { 1.0 };
            (diurnal * burst).max(0.05)
        })
        .collect()
}

/// Generate `num_jobs` jobs whose arrival slots follow the regenerated
/// trace intensity and whose parameters follow the §5 synthetic ranges
/// (the paper does the same: trace for arrivals/classes, synthetic for
/// job internals).
pub fn google_trace_jobs(
    num_jobs: usize,
    horizon: usize,
    mix: ClassMix,
    rng: &mut Rng,
) -> Vec<Job> {
    let cfg = SynthConfig::paper(num_jobs, horizon, mix);
    let mut jobs = synthetic_jobs(&cfg, rng);
    // Overwrite arrivals with the trace process (keep job ids arrival-sorted).
    let latest = (horizon * 3 / 4).max(1);
    let intensity = trace_intensity(latest, rng);
    for j in jobs.iter_mut() {
        j.arrival = rng.weighted(&intensity);
    }
    jobs.sort_by_key(|j| j.arrival);
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mix::MIX_TRACE;

    #[test]
    fn arrivals_within_window_and_sorted() {
        let mut rng = Rng::new(1);
        let jobs = google_trace_jobs(100, 80, MIX_TRACE, &mut rng);
        assert_eq!(jobs.len(), 100);
        for j in &jobs {
            assert!(j.arrival < 60); // 3/4 of 80
        }
        for w in jobs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn intensity_positive_and_bursty() {
        let mut rng = Rng::new(2);
        let i = trace_intensity(80, &mut rng);
        assert_eq!(i.len(), 80);
        assert!(i.iter().all(|&x| x > 0.0));
        let max = i.iter().cloned().fold(0.0, f64::max);
        let min = i.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 2.0, "profile should vary");
    }

    #[test]
    fn trace_mix_is_mostly_non_critical() {
        let mut rng = Rng::new(3);
        let jobs = google_trace_jobs(2_000, 80, MIX_TRACE, &mut rng);
        let critical = jobs.iter().filter(|j| j.utility.theta2 >= 4.0).count();
        assert!((critical as f64 / jobs.len() as f64) < 0.03);
    }
}
