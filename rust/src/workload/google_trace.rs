//! Google-cluster-trace-style workload (Figs 12–17).
//!
//! **Substitution note (DESIGN.md):** the 2011 Google trace file is not
//! available in this offline environment; the paper only consumes two of
//! its properties — (i) the *arrival timestamps* of a scaled-down snippet
//! and (ii) the *scheduling-class mix* (class 0 → time-insensitive,
//! classes 1–2 → time-sensitive, class 3 → time-critical, ≈ 30/69/1).
//! We regenerate those marginals: a non-homogeneous Poisson arrival
//! process with the diurnal + bursty shape reported in the trace analyses
//! ([38], [44]), and the class mix passed by the caller.
//!
//! When a real trace snippet *is* on hand, [`parse_trace_csv`] reads it
//! directly: CSV rows `timestamp,job_id,scheduling_class[,...]` (the
//! three task-events columns the paper consumes; extra columns are
//! ignored). Parsing is hardened for the real files' warts — malformed or
//! short rows are skipped with one counted warning instead of panicking —
//! and [`google_trace_jobs_from_events`] turns the parsed events into a
//! job list whose arrivals follow the empirical per-slot intensity and
//! whose class mix matches the snippet (`dmlrs ... --trace-file PATH`).

use crate::jobs::Job;
use crate::util::Rng;

use super::mix::ClassMix;
use super::synthetic::{synthetic_jobs, SynthConfig};

/// One well-formed trace row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRow {
    /// Arrival timestamp (any monotone unit; only relative spacing is used).
    pub timestamp: f64,
    /// Google scheduling class 0–3.
    pub class: u8,
}

/// The parsed snippet: well-formed rows plus the count of skipped ones.
#[derive(Debug, Clone, Default)]
pub struct TraceEvents {
    pub rows: Vec<TraceRow>,
    /// Malformed/short rows that were skipped (blank and `#` comment
    /// lines are not counted).
    pub skipped: usize,
}

impl TraceEvents {
    /// Scheduling-class mix of the snippet (class 0 → insensitive, 1–2 →
    /// sensitive, 3 → critical); [`super::mix::MIX_TRACE`] when empty.
    pub fn class_mix(&self) -> ClassMix {
        if self.rows.is_empty() {
            return super::mix::MIX_TRACE;
        }
        let n = self.rows.len() as f64;
        let insensitive = self.rows.iter().filter(|r| r.class == 0).count() as f64 / n;
        let critical = self.rows.iter().filter(|r| r.class == 3).count() as f64 / n;
        ClassMix { insensitive, sensitive: 1.0 - insensitive - critical, critical }
    }

    /// Empirical per-slot arrival weights: timestamps normalized onto
    /// `[0, slots)` and histogrammed. All-ones when the snippet is empty
    /// or has zero time spread.
    pub fn slot_weights(&self, slots: usize) -> Vec<f64> {
        let slots = slots.max(1);
        let mut w = vec![0.0f64; slots];
        let lo = self.rows.iter().map(|r| r.timestamp).fold(f64::INFINITY, f64::min);
        let hi = self.rows.iter().map(|r| r.timestamp).fold(f64::NEG_INFINITY, f64::max);
        if hi <= lo {
            return vec![1.0; slots];
        }
        for r in &self.rows {
            let x = (r.timestamp - lo) / (hi - lo) * slots as f64;
            let i = (x as usize).min(slots - 1);
            w[i] += 1.0;
        }
        w
    }
}

/// Parse a trace snippet, skipping malformed rows (see module docs).
/// Emits one counted `WARN` log line (see [`crate::util::logger`]) with
/// the skip count when any row was bad.
pub fn parse_trace_csv(text: &str) -> TraceEvents {
    let mut ev = TraceEvents::default();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut fields = line.split(',');
        let ts = fields.next().map(str::trim).and_then(|f| f.parse::<f64>().ok());
        let _job_id = fields.next();
        let class = fields.next().map(str::trim).and_then(|f| f.parse::<u8>().ok());
        match (ts, class) {
            (Some(ts), Some(class)) if ts.is_finite() && ts >= 0.0 && class <= 3 => {
                ev.rows.push(TraceRow { timestamp: ts, class });
            }
            _ => ev.skipped += 1,
        }
    }
    if ev.skipped > 0 {
        crate::log_warn!(
            "google trace: skipped {} malformed row{} ({} parsed)",
            ev.skipped,
            if ev.skipped == 1 { "" } else { "s" },
            ev.rows.len()
        );
    }
    ev
}

/// [`parse_trace_csv`] over a file.
pub fn load_trace_csv(path: &str) -> Result<TraceEvents, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    Ok(parse_trace_csv(&text))
}

/// Generate `num_jobs` jobs whose arrival slots follow the snippet's
/// empirical intensity and whose utility mix follows its class mix (job
/// internals follow the §5 synthetic ranges, as in the paper).
pub fn google_trace_jobs_from_events(
    events: &TraceEvents,
    num_jobs: usize,
    horizon: usize,
    rng: &mut Rng,
) -> Vec<Job> {
    let cfg = SynthConfig::paper(num_jobs, horizon, events.class_mix());
    let mut jobs = synthetic_jobs(&cfg, rng);
    let latest = (horizon * 3 / 4).max(1);
    let weights = events.slot_weights(latest);
    for j in jobs.iter_mut() {
        j.arrival = rng.weighted(&weights);
    }
    jobs.sort_by_key(|j| j.arrival);
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
    }
    jobs
}

/// Per-slot arrival intensity profile of the regenerated snippet:
/// diurnal sinusoid + random bursts (occasional crowded slots), matching
/// the "heterogeneity and dynamicity" character of the trace.
pub fn trace_intensity(horizon: usize, rng: &mut Rng) -> Vec<f64> {
    let period = (horizon as f64 / 3.0).max(4.0);
    (0..horizon)
        .map(|t| {
            let diurnal =
                1.0 + 0.6 * (2.0 * std::f64::consts::PI * t as f64 / period).sin();
            let burst = if rng.chance(0.15) { rng.range_f64(1.5, 3.0) } else { 1.0 };
            (diurnal * burst).max(0.05)
        })
        .collect()
}

/// Generate `num_jobs` jobs whose arrival slots follow the regenerated
/// trace intensity and whose parameters follow the §5 synthetic ranges
/// (the paper does the same: trace for arrivals/classes, synthetic for
/// job internals).
pub fn google_trace_jobs(
    num_jobs: usize,
    horizon: usize,
    mix: ClassMix,
    rng: &mut Rng,
) -> Vec<Job> {
    let cfg = SynthConfig::paper(num_jobs, horizon, mix);
    let mut jobs = synthetic_jobs(&cfg, rng);
    // Overwrite arrivals with the trace process (keep job ids arrival-sorted).
    let latest = (horizon * 3 / 4).max(1);
    let intensity = trace_intensity(latest, rng);
    for j in jobs.iter_mut() {
        j.arrival = rng.weighted(&intensity);
    }
    jobs.sort_by_key(|j| j.arrival);
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mix::MIX_TRACE;

    #[test]
    fn arrivals_within_window_and_sorted() {
        let mut rng = Rng::new(1);
        let jobs = google_trace_jobs(100, 80, MIX_TRACE, &mut rng);
        assert_eq!(jobs.len(), 100);
        for j in &jobs {
            assert!(j.arrival < 60); // 3/4 of 80
        }
        for w in jobs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn intensity_positive_and_bursty() {
        let mut rng = Rng::new(2);
        let i = trace_intensity(80, &mut rng);
        assert_eq!(i.len(), 80);
        assert!(i.iter().all(|&x| x > 0.0));
        let max = i.iter().cloned().fold(0.0, f64::max);
        let min = i.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 2.0, "profile should vary");
    }

    /// A deliberately dirty snippet: short rows, non-numeric fields, an
    /// out-of-range class, a negative timestamp, comments, and blanks.
    const DIRTY_TRACE: &str = "\
# task_events snippet: timestamp,job_id,scheduling_class,...
0,6251,0,extra,columns,ignored
100,6252,1
not-a-number,6253,2
250,6254
300,6255,9
-50,6256,1
400,6257,3

600,6258,2,0.5
750,6259,0
";

    #[test]
    fn dirty_rows_are_skipped_with_a_count_not_a_panic() {
        let ev = parse_trace_csv(DIRTY_TRACE);
        assert_eq!(ev.rows.len(), 5, "{:?}", ev.rows);
        assert_eq!(ev.skipped, 4, "bad number, short row, class 9, negative ts");
        assert_eq!(ev.rows[0], TraceRow { timestamp: 0.0, class: 0 });
        assert_eq!(ev.rows.last().unwrap().class, 0);
    }

    #[test]
    fn dirty_trace_still_drives_job_generation() {
        let ev = parse_trace_csv(DIRTY_TRACE);
        let mix = ev.class_mix();
        assert!((mix.insensitive - 2.0 / 5.0).abs() < 1e-12);
        assert!((mix.critical - 1.0 / 5.0).abs() < 1e-12);
        assert!((mix.insensitive + mix.sensitive + mix.critical - 1.0).abs() < 1e-12);

        let mut rng = Rng::new(4);
        let jobs = google_trace_jobs_from_events(&ev, 50, 40, &mut rng);
        assert_eq!(jobs.len(), 50);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i);
            assert!(j.arrival < 30, "within the 3/4 arrival window");
        }
        for w in jobs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn empty_snippet_falls_back_to_trace_mix_and_flat_weights() {
        let ev = parse_trace_csv("# only comments\n\n");
        assert_eq!(ev.rows.len(), 0);
        assert_eq!(ev.skipped, 0);
        assert_eq!(ev.class_mix(), MIX_TRACE);
        assert_eq!(ev.slot_weights(5), vec![1.0; 5]);
    }

    #[test]
    fn slot_weights_histogram_the_timestamps() {
        let ev = parse_trace_csv("0,1,0\n1,2,0\n1,3,0\n3,4,0\n4,5,0\n");
        let w = ev.slot_weights(5);
        // timestamps 0,1,1,3,4 over [0,4] → slots 0,1,1,3,4 (max clamps)
        assert_eq!(w, vec![1.0, 2.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn trace_mix_is_mostly_non_critical() {
        let mut rng = Rng::new(3);
        let jobs = google_trace_jobs(2_000, 80, MIX_TRACE, &mut rng);
        let critical = jobs.iter().filter(|j| j.utility.theta2 >= 4.0).count();
        assert!((critical as f64 / jobs.len() as f64) < 0.03);
    }
}
