//! Synthetic workload following the paper's §5 parameter ranges exactly:
//!
//! * `E_i ∈ [50, 200]`, `K_i ∈ [20000, 500000]`, `g_i ∈ [30, 575]` MB,
//!   `τ_i ∈ [1e-5, 1e-4]` slots, `γ_i ∈ [1, 10]`, `F_i ∈ [1, 200]`;
//! * worker demand: 0–4 GPUs, 1–10 vCPUs, 2–32 GB memory, 5–10 GB storage;
//! * PS demand: 1–10 vCPUs, 2–32 GB memory, 5–10 GB storage (no GPU);
//! * machine capacity ≈ 18× a worker/PS demand (EC2 C5n-class);
//! * arrivals: normalized rates alternating 1/3 (odd slots) and 2/3 (even
//!   slots), after the Google-trace pattern;
//! * sigmoid utilities drawn from a [`ClassMix`].
//!
//! Bandwidths are not numerically specified in the paper; we pick
//! `b_e ∈ [6e5, 2.4e6]` MB/slot with `b_i = 10 · b_e`, which makes external
//! communication cost the same order as compute (`τ`) and internal nearly
//! free — exactly the locality trade-off the paper studies (co-location
//! speeds a job up ~1.5–3×, while spread placements remain viable).

use crate::cluster::{Cluster, MachineClass, ResVec};
use crate::jobs::Job;
use crate::util::Rng;

use super::mix::ClassMix;

/// The arrival-slot process jobs are drawn from.
///
/// `Alternating` is the paper's §5 pattern; `Diurnal` is a
/// time-varying-rate profile (one sinusoidal day over the arrival
/// window) whose peak:trough rate ratio is `peak_ratio` — the scenario
/// axis the sweep matrix and the `dmlrs load` generator use to stress
/// the online service with rush-hour traffic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Normalized rates alternating 2/3 (even slots) / 1/3 (odd slots).
    Alternating,
    /// Sinusoidal rate with peak/trough ratio `peak_ratio` (≥ 1; 1 is a
    /// constant rate).
    Diurnal { peak_ratio: f64 },
}

impl ArrivalProcess {
    /// Parse the `arrivals` spec string used by config keys and CLI
    /// flags: `alternating` or `diurnal:<peak_ratio>`.
    pub fn parse(s: &str) -> Result<ArrivalProcess, String> {
        let s = s.trim().to_ascii_lowercase();
        if s == "alternating" || s.is_empty() {
            return Ok(ArrivalProcess::Alternating);
        }
        if let Some(ratio) = s.strip_prefix("diurnal:") {
            return match ratio.trim().parse::<f64>() {
                Ok(r) if r >= 1.0 && r.is_finite() => {
                    Ok(ArrivalProcess::Diurnal { peak_ratio: r })
                }
                _ => Err(format!("invalid diurnal peak ratio {ratio:?} (need >= 1)")),
            };
        }
        Err(format!(
            "invalid arrivals spec {s:?} (expected \"alternating\" or \"diurnal:<peak_ratio>\")"
        ))
    }

    /// Stable identity token for scenario keys; `None` for the default
    /// alternating process (so pre-existing keys are unchanged).
    pub fn key_token(&self) -> Option<String> {
        match self {
            ArrivalProcess::Alternating => None,
            ArrivalProcess::Diurnal { peak_ratio } => Some(format!("adi{peak_ratio}")),
        }
    }

    /// Per-slot arrival weights over `[0, latest)`.
    pub fn weights(&self, latest: usize) -> Vec<f64> {
        match *self {
            ArrivalProcess::Alternating => (0..latest)
                .map(|t| if t % 2 == 0 { 2.0 / 3.0 } else { 1.0 / 3.0 })
                .collect(),
            ArrivalProcess::Diurnal { peak_ratio } => {
                // amplitude a gives (1+a)/(1-a) = peak_ratio
                let a = (peak_ratio - 1.0) / (peak_ratio + 1.0);
                let period = latest.max(1) as f64;
                (0..latest)
                    .map(|t| {
                        1.0 + a * (2.0 * std::f64::consts::PI * t as f64 / period).sin()
                    })
                    .collect()
            }
        }
    }
}

/// Tunable generator parameters (defaults = the paper's §5 setting).
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub num_jobs: usize,
    pub horizon: usize,
    pub mix: ClassMix,
    pub arrivals: ArrivalProcess,
    pub epochs: (u64, u64),
    pub samples: (f64, f64),
    pub grad_mb: (f64, f64),
    pub tau: (f64, f64),
    pub gamma: (f64, f64),
    pub batch: (u64, u64),
    pub b_ext: (f64, f64),
    pub b_int_factor: f64,
}

impl SynthConfig {
    pub fn paper(num_jobs: usize, horizon: usize, mix: ClassMix) -> SynthConfig {
        SynthConfig {
            num_jobs,
            horizon,
            mix,
            arrivals: ArrivalProcess::Alternating,
            epochs: (50, 200),
            samples: (20_000.0, 500_000.0),
            grad_mb: (30.0, 575.0),
            tau: (1e-5, 1e-4),
            gamma: (1.0, 10.0),
            batch: (1, 200),
            b_ext: (6e5, 2.4e6),
            b_int_factor: 10.0,
        }
    }

    pub fn with_arrivals(mut self, arrivals: ArrivalProcess) -> SynthConfig {
        self.arrivals = arrivals;
        self
    }
}

/// The EC2 C5n-class machine capacity used in §5: roughly 18× the mean
/// worker/PS demand per resource (GPU, vCPU, mem GB, storage GB).
pub fn paper_machine_capacity() -> ResVec {
    ResVec::new([32.0, 96.0, 256.0, 128.0])
}

/// Homogeneous paper-style cluster of `h` machines.
pub fn paper_cluster(h: usize) -> Cluster {
    Cluster::homogeneous(h, paper_machine_capacity())
}

/// The `(count, capacity scale)` machine classes of the standard skewed
/// cluster shape: a quarter of the `h` machines are "big" (`skew ×`), a
/// quarter "small" (`1/skew ×`), the rest standard. The single source of
/// the shape — [`paper_cluster_skewed`] and the sweep subsystem's
/// `ClusterSpec::skewed` both derive from it.
pub fn skewed_classes(h: usize, skew: f64) -> [(usize, f64); 3] {
    let big = h / 4;
    let small = h / 4;
    [(big, skew), (h - big - small, 1.0), (small, 1.0 / skew.max(1e-9))]
}

/// Heterogeneous paper-style cluster from `(count, capacity scale)`
/// machine classes, scale 1.0 being the paper capacity — the one place
/// class lists become machines (the sweep subsystem's `ClusterSpec`
/// builds through here too).
pub fn paper_cluster_classes(classes: &[(usize, f64)]) -> Cluster {
    let cap = paper_machine_capacity();
    let classes: Vec<MachineClass> = classes
        .iter()
        .map(|&(count, scale)| MachineClass::new(count, cap.scaled(scale)))
        .collect();
    Cluster::heterogeneous(&classes)
}

/// Heterogeneous paper-style cluster of `h` machines with the
/// [`skewed_classes`] shape — same machine count as [`paper_cluster`]
/// but skewed per-machine capacities (the sweep subsystem's
/// homogeneous-vs-skewed scenario axis). `skew = 1` recovers the
/// homogeneous cluster.
pub fn paper_cluster_skewed(h: usize, skew: f64) -> Cluster {
    paper_cluster_classes(&skewed_classes(h, skew))
}

/// Draw the arrival slot from the configured [`ArrivalProcess`].
fn sample_arrival(rng: &mut Rng, horizon: usize, arrivals: &ArrivalProcess) -> usize {
    // restrict arrivals to the first 3/4 of the horizon so late jobs have
    // at least a few slots to run (the paper's T=20 with target completion
    // times θ3 ≤ 15 implies the same).
    let latest = (horizon * 3 / 4).max(1);
    rng.weighted(&arrivals.weights(latest))
}

/// Generate `cfg.num_jobs` jobs with ids `0..n` sorted by arrival slot.
pub fn synthetic_jobs(cfg: &SynthConfig, rng: &mut Rng) -> Vec<Job> {
    let mut jobs: Vec<Job> = (0..cfg.num_jobs)
        .map(|_| {
            let b_ext = rng.range_f64(cfg.b_ext.0, cfg.b_ext.1);
            let gamma = rng.range_f64(cfg.gamma.0, cfg.gamma.1).round().max(1.0);
            // F_i ≥ γ_i so one PS can serve its ratio of workers; the
            // paper's F ∈ [1, 200] with γ ∈ [1, 10] implicitly needs the
            // same to make Eq. (2) satisfiable with integer counts.
            let batch_lo = cfg.batch.0.max(gamma as u64);
            let batch = rng.range_u64(batch_lo, cfg.batch.1.max(batch_lo));
            Job {
                id: 0, // assigned after the arrival sort
                arrival: sample_arrival(rng, cfg.horizon, &cfg.arrivals),
                epochs: rng.range_u64(cfg.epochs.0, cfg.epochs.1),
                samples: rng.range_f64(cfg.samples.0, cfg.samples.1),
                grad_size_mb: rng.range_f64(cfg.grad_mb.0, cfg.grad_mb.1),
                tau: rng.range_f64(cfg.tau.0, cfg.tau.1),
                gamma,
                batch,
                worker_demand: ResVec::new([
                    rng.range_u64(0, 4) as f64,
                    rng.range_u64(1, 10) as f64,
                    rng.range_u64(2, 32) as f64,
                    rng.range_u64(5, 10) as f64,
                ]),
                ps_demand: ResVec::new([
                    0.0,
                    rng.range_u64(1, 10) as f64,
                    rng.range_u64(2, 32) as f64,
                    rng.range_u64(5, 10) as f64,
                ]),
                b_int: b_ext * cfg.b_int_factor,
                b_ext,
                utility: cfg.mix.sample_utility(rng),
            }
        })
        .collect();
    jobs.sort_by_key(|j| j.arrival);
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mix::MIX_DEFAULT;

    #[test]
    fn ranges_respected() {
        let mut rng = Rng::new(0);
        let cfg = SynthConfig::paper(200, 20, MIX_DEFAULT);
        let jobs = synthetic_jobs(&cfg, &mut rng);
        assert_eq!(jobs.len(), 200);
        for j in &jobs {
            assert!((50..=200).contains(&j.epochs));
            assert!((20_000.0..=500_000.0).contains(&j.samples));
            assert!((30.0..=575.0).contains(&j.grad_size_mb));
            assert!((1e-5..=1e-4).contains(&j.tau));
            assert!((1.0..=10.0).contains(&j.gamma));
            assert!(j.batch >= j.gamma as u64 && j.batch <= 200);
            assert!(j.b_int > j.b_ext);
            assert!(j.arrival < 20);
            assert!(j.worker_demand.get(crate::cluster::Resource::Cpu) >= 1.0);
            assert_eq!(j.ps_demand.get(crate::cluster::Resource::Gpu), 0.0);
        }
        // ids sorted by arrival
        for w in jobs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert!(w[0].id < w[1].id);
        }
    }

    #[test]
    fn skewed_cluster_preserves_machine_count_and_skews_capacity() {
        let c = paper_cluster_skewed(10, 2.0);
        assert_eq!(c.len(), 10);
        let cap = paper_machine_capacity();
        // 2 big, 6 standard, 2 small; ids sequential
        assert_eq!(c.machines[0].capacity, cap.scaled(2.0));
        assert_eq!(c.machines[3].capacity, cap);
        assert_eq!(c.machines[9].capacity, cap.scaled(0.5));
        for (i, m) in c.machines.iter().enumerate() {
            assert_eq!(m.id, i);
        }
        // skew = 1 recovers the homogeneous cluster
        assert_eq!(paper_cluster_skewed(7, 1.0).machines, paper_cluster(7).machines);
    }

    #[test]
    fn arrival_rates_alternate() {
        let mut rng = Rng::new(7);
        let cfg = SynthConfig::paper(20_000, 20, MIX_DEFAULT);
        let jobs = synthetic_jobs(&cfg, &mut rng);
        let even = jobs.iter().filter(|j| j.arrival % 2 == 0).count() as f64;
        let ratio = even / jobs.len() as f64;
        // arrivals land in [0, 15): 8 even slots at weight 2/3, 7 odd at 1/3
        let expect = (8.0 * 2.0) / (8.0 * 2.0 + 7.0 * 1.0);
        assert!((ratio - expect).abs() < 0.02, "even-slot share {ratio} vs {expect}");
    }

    #[test]
    fn diurnal_weights_hit_the_peak_ratio() {
        let p = ArrivalProcess::Diurnal { peak_ratio: 3.0 };
        let w = p.weights(64);
        assert_eq!(w.len(), 64);
        let max = w.iter().cloned().fold(f64::MIN, f64::max);
        let min = w.iter().cloned().fold(f64::MAX, f64::min);
        assert!(w.iter().all(|&x| x > 0.0));
        // sampled sinusoid: the realized ratio approaches peak_ratio
        assert!(max / min > 2.5 && max / min <= 3.0 + 1e-9, "ratio {}", max / min);
        // ratio 1 is a constant rate
        let flat = ArrivalProcess::Diurnal { peak_ratio: 1.0 }.weights(16);
        assert!(flat.iter().all(|&x| (x - 1.0).abs() < 1e-12));
    }

    #[test]
    fn diurnal_arrivals_concentrate_in_the_peak_half() {
        let mut rng = Rng::new(9);
        let cfg = SynthConfig::paper(20_000, 40, MIX_DEFAULT)
            .with_arrivals(ArrivalProcess::Diurnal { peak_ratio: 4.0 });
        let jobs = synthetic_jobs(&cfg, &mut rng);
        // arrival window is [0, 30); sin > 0 on the first half
        let first_half = jobs.iter().filter(|j| j.arrival < 15).count() as f64;
        let share = first_half / jobs.len() as f64;
        assert!(share > 0.6, "peak-half share {share}");
        for j in &jobs {
            assert!(j.arrival < 30);
        }
    }

    #[test]
    fn arrival_spec_parsing() {
        assert_eq!(
            ArrivalProcess::parse("alternating").unwrap(),
            ArrivalProcess::Alternating
        );
        assert_eq!(
            ArrivalProcess::parse("Diurnal:3.0").unwrap(),
            ArrivalProcess::Diurnal { peak_ratio: 3.0 }
        );
        assert!(ArrivalProcess::parse("diurnal:0.5").is_err());
        assert!(ArrivalProcess::parse("poisson").is_err());
        assert_eq!(ArrivalProcess::Alternating.key_token(), None);
        assert_eq!(
            ArrivalProcess::Diurnal { peak_ratio: 3.0 }.key_token().unwrap(),
            "adi3"
        );
        assert_eq!(
            ArrivalProcess::Diurnal { peak_ratio: 2.5 }.key_token().unwrap(),
            "adi2.5"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SynthConfig::paper(10, 20, MIX_DEFAULT);
        let a = synthetic_jobs(&cfg, &mut Rng::new(3));
        let b = synthetic_jobs(&cfg, &mut Rng::new(3));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.epochs, y.epochs);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.utility, y.utility);
        }
    }
}
