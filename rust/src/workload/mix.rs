//! Job time-sensitivity class mixes (§5).
//!
//! The paper runs two mixes: the OASiS default (10% insensitive, 55%
//! sensitive, 35% critical; Figs 6–14, 16) and the Google-trace-derived
//! mix (30%, 69%, 1%; Figs 15, 17) obtained by mapping trace scheduling
//! class 0 → insensitive, classes 1–2 → sensitive, class 3 → critical.

use crate::jobs::utility::Sigmoid;
use crate::util::Rng;

/// Fractions of (insensitive, sensitive, critical) jobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassMix {
    pub insensitive: f64,
    pub sensitive: f64,
    pub critical: f64,
}

/// The OASiS-default mix used in most figures: (10%, 55%, 35%).
pub const MIX_DEFAULT: ClassMix =
    ClassMix { insensitive: 0.10, sensitive: 0.55, critical: 0.35 };

/// The Google-trace mix: (30%, 69%, 1%).
pub const MIX_TRACE: ClassMix =
    ClassMix { insensitive: 0.30, sensitive: 0.69, critical: 0.01 };

impl ClassMix {
    /// Draw a sigmoid utility according to the mix. θ1 ∈ [1,100] is the
    /// priority, θ3 ∈ [1,15] the target completion time; θ2 per class.
    pub fn sample_utility(&self, rng: &mut Rng) -> Sigmoid {
        let theta1 = rng.range_f64(1.0, 100.0);
        let theta3 = rng.range_f64(1.0, 15.0);
        let x = rng.f64();
        let theta2 = if x < self.insensitive {
            0.0
        } else if x < self.insensitive + self.sensitive {
            rng.range_f64(0.01, 1.0)
        } else {
            rng.range_f64(4.0, 6.0)
        };
        Sigmoid { theta1, theta2, theta3 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixes_sum_to_one() {
        for m in [MIX_DEFAULT, MIX_TRACE] {
            assert!((m.insensitive + m.sensitive + m.critical - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn class_frequencies_follow_mix() {
        let mut rng = Rng::new(0);
        let mut flat = 0;
        let mut crit = 0;
        let n = 20_000;
        for _ in 0..n {
            let u = MIX_DEFAULT.sample_utility(&mut rng);
            if u.theta2 == 0.0 {
                flat += 1;
            } else if u.theta2 >= 4.0 {
                crit += 1;
            }
        }
        assert!((flat as f64 / n as f64 - 0.10).abs() < 0.02);
        assert!((crit as f64 / n as f64 - 0.35).abs() < 0.02);
    }

    #[test]
    fn theta_ranges() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let u = MIX_TRACE.sample_utility(&mut rng);
            assert!((1.0..=100.0).contains(&u.theta1));
            assert!((1.0..=15.0).contains(&u.theta3));
            assert!(u.theta2 == 0.0 || (0.01..=6.0).contains(&u.theta2));
        }
    }
}
