//! Workload generation: the paper's §5 synthetic distribution and a
//! statistical regeneration of the Google cluster trace arrivals.

pub mod google_trace;
pub mod mix;
pub mod synthetic;

pub use google_trace::{
    google_trace_jobs, google_trace_jobs_from_events, load_trace_csv, parse_trace_csv,
    TraceEvents, TraceRow,
};
pub use mix::{ClassMix, MIX_DEFAULT, MIX_TRACE};
pub use synthetic::{synthetic_jobs, ArrivalProcess, SynthConfig};
