//! # dmlrs — online scheduling for distributed ML systems (PD-ORS)
//!
//! Reproduction of *"Toward Efficient Online Scheduling for Distributed
//! Machine Learning Systems"* (Yu, Liu, Wu, Ji, Bentley; cs.DC 2021).
//!
//! The crate is the L3 coordinator of a three-layer rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`sched`] — the paper's contribution: the PD-ORS primal-dual online
//!   scheduler (Algorithms 1–4), including the exponential price function,
//!   the per-job dynamic program, and the randomized-rounding
//!   approximation for the per-slot mixed cover/packing integer program.
//!   `sched::registry` maps scheduler names to constructors — the single
//!   place a new policy is registered.
//! * [`cluster`], [`jobs`], [`workload`] — the analytical model of §3:
//!   machines with multi-type resource capacities, PS-architecture
//!   training jobs with locality-dependent communication (Eq. (1)), and
//!   the paper's synthetic / Google-trace workload generators.
//! * [`lp`], [`ilp`] — from-scratch two-phase simplex and branch-and-bound
//!   solvers (the offline-oracle / Gurobi substitute).
//! * [`baselines`] — FIFO, DRF, Dorm, OASiS and the offline optimum.
//! * [`sim`] — the event-driven cluster simulator driving every figure:
//!   one `SimEngine` + the unified object-safe `Scheduler` trait, with
//!   typed `SimEvent`s streamed to pluggable observers.
//! * [`runtime`], [`exec`] — PJRT runtime loading the AOT-compiled JAX/
//!   Pallas artifacts and a BSP parameter-server executor that *actually
//!   trains* the scheduled jobs' transformer payloads.
//! * [`sweep`] — parallel scenario sweeps: a declarative
//!   scheduler × workload × cluster × seed `ScenarioMatrix`, a
//!   work-stealing executor on `std::thread::scope`, and a resumable
//!   JSONL `ResultStore` (`dmlrs sweep`).
//! * [`service`] — the online admission service: a long-running scheduler
//!   daemon behind an NDJSON-over-TCP wire protocol (`dmlrs serve`), with
//!   an op-log for crash recovery and an open-loop load generator with
//!   latency benchmarks (`dmlrs load`). Shares the simulator's
//!   `AdmissionCore`, so daemon and `SimEngine` decide identically.
//! * [`chaos`] — deterministic fault injection: seeded machine-churn
//!   traces (`ChurnSpec`/`ChurnTrace`) that take capacity out of the
//!   ledger mid-horizon, forcing started jobs to migrate (or be evicted)
//!   and surfacing finish-time fairness as a first-class metric.
//! * [`obs`] — unified telemetry: RAII pipeline spans into mergeable
//!   log₂ histograms, a bounded flight recorder, Chrome-trace/Perfetto
//!   export for any engine run, and Prometheus text exposition from the
//!   daemon. Deterministically inert: no RNG, no schedule perturbation,
//!   one relaxed atomic load when disabled.
//! * [`experiments`] — one driver per paper figure (5–17), executed
//!   through the sweep runner.
//! * [`util`], [`testkit`], [`cli`], [`config`] — substrates built from
//!   scratch (RNG, stats, JSON, arg parsing, property testing) because the
//!   build environment is offline.

// CI runs `cargo clippy --all-targets -- -D warnings`. The crate's
// numeric-kernel style intentionally indexes parallel arrays by position
// (tableaux, per-machine vectors, per-resource loops), so the
// corresponding style lints are allowed crate-wide instead of per-site.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::new_without_default,
    clippy::len_without_is_empty,
    clippy::type_complexity,
    clippy::manual_range_contains,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::comparison_chain
)]

pub mod baselines;
pub mod chaos;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod exec;
pub mod experiments;
pub mod ilp;
pub mod jobs;
pub mod lp;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod service;
pub mod sim;
pub mod sweep;
pub mod testkit;
pub mod util;
pub mod workload;
