//! The paper's training-speed model — Eq. (1), Fact 1, and the per-slot
//! trained-sample count used by both the scheduler and the executor.

use super::job::Job;

/// Locality of a slot's placement (Fact 1 of the paper): the *internal*
/// rate applies iff exactly one machine hosts all workers **and** all
/// parameter servers (`|P| = |W| = 1 ∧ P = W`); any other configuration is
/// bottlenecked by the external link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Locality {
    Internal,
    External,
}

impl Locality {
    /// Classify a placement given the per-machine (worker, ps) counts.
    pub fn of_placement(placements: &[(usize, u64, u64)]) -> Locality {
        let mut worker_machines = 0usize;
        let mut ps_machines = 0usize;
        let mut w_host = usize::MAX;
        let mut s_host = usize::MAX;
        for &(h, w, s) in placements {
            if w > 0 {
                worker_machines += 1;
                w_host = h;
            }
            if s > 0 {
                ps_machines += 1;
                s_host = h;
            }
        }
        if worker_machines == 1 && ps_machines == 1 && w_host == s_host {
            Locality::Internal
        } else {
            Locality::External
        }
    }
}

/// Per-sample wall time (slots) for one worker of `job` under `loc`:
/// `τ_i + (γ_i / F_i) · 2 g_i / b` — the denominator of Eq. (1) after the
/// γ substitution of Eq. (2).
pub fn per_sample_time(job: &Job, loc: Locality) -> f64 {
    let b = match loc {
        Locality::Internal => job.b_int,
        Locality::External => job.b_ext,
    };
    job.tau + (job.gamma / job.batch as f64) * (2.0 * job.grad_size_mb / b)
}

/// Samples per slot contributed by a single worker (Eq. (1) numerator=1).
pub fn per_worker_rate(job: &Job, loc: Locality) -> f64 {
    1.0 / per_sample_time(job, loc)
}

/// Total samples trained in one slot by a placement (Eq. (1) summed over
/// machines; BSP makes every worker run at the slowest-link rate).
pub fn samples_in_slot(job: &Job, placements: &[(usize, u64, u64)]) -> f64 {
    let total_workers: u64 = placements.iter().map(|&(_, w, _)| w).sum();
    if total_workers == 0 {
        return 0.0;
    }
    let loc = Locality::of_placement(placements);
    total_workers as f64 * per_worker_rate(job, loc)
}

/// Workers needed (at the given locality) to train `v` samples in one slot.
pub fn workers_needed(job: &Job, v: f64, loc: Locality) -> u64 {
    if v <= 0.0 {
        return 0;
    }
    (v * per_sample_time(job, loc)).ceil() as u64
}

/// Maximum samples trainable in one slot at the given locality, subject to
/// the Eq.-(4) worker cap `Σ_h w ≤ F_i`.
pub fn max_samples_per_slot(job: &Job, loc: Locality) -> f64 {
    job.batch as f64 * per_worker_rate(job, loc)
}

#[cfg(test)]
mod tests {
    use super::super::test_support::test_job;
    use super::*;

    #[test]
    fn fact1_locality() {
        // single machine, both workers and PS => internal
        assert_eq!(Locality::of_placement(&[(3, 2, 1)]), Locality::Internal);
        // worker and PS on different machines => external
        assert_eq!(
            Locality::of_placement(&[(0, 2, 0), (1, 0, 1)]),
            Locality::External
        );
        // multiple worker machines => external even if one has the PS
        assert_eq!(
            Locality::of_placement(&[(0, 2, 1), (1, 1, 0)]),
            Locality::External
        );
        // multiple PS machines => external
        assert_eq!(
            Locality::of_placement(&[(0, 2, 1), (1, 0, 1)]),
            Locality::External
        );
    }

    #[test]
    fn internal_is_faster() {
        let j = test_job(0);
        assert!(per_worker_rate(&j, Locality::Internal) > per_worker_rate(&j, Locality::External));
    }

    #[test]
    fn samples_scale_with_workers() {
        let j = test_job(0);
        let one = samples_in_slot(&j, &[(0, 1, 1)]);
        let four = samples_in_slot(&j, &[(0, 4, 1)]);
        assert!((four / one - 4.0).abs() < 1e-9);
    }

    #[test]
    fn workers_needed_round_trip() {
        let j = test_job(0);
        let v = 123.0;
        let w = workers_needed(&j, v, Locality::External);
        let placements = vec![(0, w, 0), (1, 0, 1)];
        assert!(samples_in_slot(&j, &placements) >= v);
        // and w−1 workers would not be enough
        if w > 1 {
            let fewer = vec![(0, w - 1, 0), (1, 0, 1)];
            assert!(samples_in_slot(&j, &fewer) < v);
        }
    }

    #[test]
    fn empty_placement_trains_nothing() {
        let j = test_job(0);
        assert_eq!(samples_in_slot(&j, &[]), 0.0);
        assert_eq!(samples_in_slot(&j, &[(0, 0, 1)]), 0.0);
    }
}
