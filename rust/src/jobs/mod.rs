//! ML training jobs: the analytical model of paper §3.2.

pub mod job;
pub mod schedule;
pub mod speed;
pub mod utility;

pub use job::Job;
pub use schedule::{Schedule, SlotPlacement};
pub use speed::{per_worker_rate, samples_in_slot, Locality};
pub use utility::Sigmoid;

/// Helpers shared by unit tests across modules.
pub mod test_support {
    use super::*;
    use crate::cluster::ResVec;

    /// A small deterministic job used by many unit tests.
    pub fn test_job(id: usize) -> Job {
        Job {
            id,
            arrival: 0,
            epochs: 2,
            samples: 2_000.0,
            grad_size_mb: 100.0,
            tau: 1e-4,
            gamma: 2.0,
            batch: 16,
            worker_demand: ResVec::new([1.0, 2.0, 4.0, 1.0]),
            ps_demand: ResVec::new([0.0, 2.0, 4.0, 1.0]),
            b_int: 1.0e6,
            b_ext: 1.0e5,
            utility: Sigmoid { theta1: 50.0, theta2: 0.5, theta3: 5.0 },
        }
    }
}
