//! The sigmoid job-utility function of the paper's §5:
//! `u_i(x) = θ1 / (1 + exp(θ2 · (x − θ3)))`,
//! where x = completion delay (slots), θ1 = priority, θ2 = time
//! criticality, θ3 = target completion time.

/// Sigmoid utility parameters (one per job).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sigmoid {
    pub theta1: f64,
    pub theta2: f64,
    pub theta3: f64,
}

/// The three time-sensitivity classes used throughout §5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeClass {
    /// θ2 = 0: utility is flat in time.
    Insensitive,
    /// θ2 ∈ [0.01, 1].
    Sensitive,
    /// θ2 ∈ [4, 6].
    Critical,
}

impl Sigmoid {
    pub fn eval(&self, delay_slots: f64) -> f64 {
        let e = (self.theta2 * (delay_slots - self.theta3)).exp();
        self.theta1 / (1.0 + e)
    }

    /// Largest attainable utility (delay → 0⁺ is bounded by eval(1)); we
    /// use eval at one slot since completion takes at least one slot.
    pub fn max_value(&self) -> f64 {
        self.eval(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_nonincreasing() {
        let u = Sigmoid { theta1: 80.0, theta2: 0.7, theta3: 6.0 };
        let mut prev = f64::INFINITY;
        for d in 0..30 {
            let v = u.eval(d as f64);
            assert!(v <= prev + 1e-12, "not non-increasing at {d}");
            assert!(v > 0.0 && v <= 80.0);
            prev = v;
        }
    }

    #[test]
    fn insensitive_is_flat() {
        let u = Sigmoid { theta1: 10.0, theta2: 0.0, theta3: 5.0 };
        assert_eq!(u.eval(0.0), u.eval(100.0));
        assert_eq!(u.eval(3.0), 5.0);
    }

    #[test]
    fn critical_decays_fast() {
        let u = Sigmoid { theta1: 100.0, theta2: 5.0, theta3: 4.0 };
        assert!(u.eval(2.0) > 99.0);
        assert!(u.eval(8.0) < 1.0);
    }
}
