//! The training-job record (Table 1 of the paper).

use super::utility::Sigmoid;
use crate::cluster::ResVec;

/// An ML training job `i ∈ I`.
///
/// All quantities use the paper's notation and units:
/// * time is measured in scheduling slots,
/// * data sizes (`g_i`) in MB, bandwidths in MB/slot,
/// * `tau` (τ_i) is the compute time to train one sample, in slots.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: usize,
    /// Arrival slot `a_i`.
    pub arrival: usize,
    /// Required epochs `E_i`.
    pub epochs: u64,
    /// Samples per epoch `K_i` (kept in f64 — up to 5·10^5 per the paper).
    pub samples: f64,
    /// Gradient/parameter size `g_i` (MB).
    pub grad_size_mb: f64,
    /// Per-sample training time `τ_i` (slots).
    pub tau: f64,
    /// Worker:PS ratio `γ_i` (Eq. (2)).
    pub gamma: f64,
    /// Global batch size `F_i` — also the max concurrent workers (Eq. (4)).
    pub batch: u64,
    /// Worker resource demand `α_i^r`.
    pub worker_demand: ResVec,
    /// Parameter-server resource demand `β_i^r`.
    pub ps_demand: ResVec,
    /// Internal (same-machine) link rate `b_i^{(i)}` (MB/slot).
    pub b_int: f64,
    /// External (cross-machine) link rate `b_i^{(e)}` (MB/slot).
    pub b_ext: f64,
    /// Utility `u_i(·)` of the completion delay.
    pub utility: Sigmoid,
}

impl Job {
    /// Total training workload `V_i = E_i · K_i` (samples; Eq. (3) RHS).
    pub fn total_workload(&self) -> f64 {
        self.epochs as f64 * self.samples
    }

    /// Utility of completing at slot `t` (`u_i(t − a_i)`); clamped to the
    /// smallest value if `t < a_i` never happens by construction.
    pub fn utility_at(&self, t: usize) -> f64 {
        self.utility.eval((t as f64) - (self.arrival as f64))
    }

    /// Earliest possible completion delay (slots), all-internal
    /// communication at full batch — the numerator of `U^r` in Eq. (13).
    pub fn min_completion_slots(&self) -> f64 {
        let per_sample = self.tau
            + 2.0 * self.grad_size_mb * self.gamma / (self.b_int * self.batch as f64);
        (self.total_workload() / self.batch as f64 * per_sample).ceil().max(1.0)
    }

    /// Worst-case resource-time product (denominator of `L` in Eq. (14)):
    /// `⌈E_i K_i (τ_i + 2 g_i γ_i / (b_e F_i))⌉ Σ_r (α_i^r + β_i^r)`.
    pub fn max_resource_time(&self) -> f64 {
        let per_sample = self.tau
            + 2.0 * self.grad_size_mb * self.gamma / (self.b_ext * self.batch as f64);
        let slots = (self.total_workload() * per_sample).ceil().max(1.0);
        let mut demand_sum = 0.0;
        for r in 0..crate::cluster::NUM_RESOURCES {
            demand_sum += self.worker_demand[r] + self.ps_demand[r];
        }
        slots * demand_sum
    }

    /// Resource demand of `w` workers + `s` parameter servers.
    pub fn demand(&self, w: u64, s: u64) -> ResVec {
        self.worker_demand
            .scaled(w as f64)
            .axpy(s as f64, &self.ps_demand)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::test_job;

    #[test]
    fn workload_and_bounds() {
        let j = test_job(0);
        assert_eq!(j.total_workload(), 4_000.0);
        assert!(j.min_completion_slots() >= 1.0);
        // internal comm is faster than external => earliest completion
        // uses fewer slot-resources than the worst case bound
        assert!(j.max_resource_time() > j.min_completion_slots());
    }

    #[test]
    fn demand_combines_worker_and_ps() {
        let j = test_job(0);
        let d = j.demand(3, 2);
        assert_eq!(d.0[0], 3.0); // GPU: workers only
        assert_eq!(d.0[1], 3.0 * 2.0 + 2.0 * 2.0);
    }
}
