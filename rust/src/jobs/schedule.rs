//! A concrete schedule π_i: per-slot worker/PS placements (§4.1).

use super::job::Job;
use super::speed::samples_in_slot;

/// Placement for one time slot: sparse list of `(machine, workers, ps)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotPlacement {
    pub t: usize,
    /// `(h, w_ih[t], s_ih[t])`, entries with w = s = 0 are omitted.
    pub placements: Vec<(usize, u64, u64)>,
}

impl SlotPlacement {
    pub fn total_workers(&self) -> u64 {
        self.placements.iter().map(|&(_, w, _)| w).sum()
    }

    pub fn total_ps(&self) -> u64 {
        self.placements.iter().map(|&(_, _, s)| s).sum()
    }
}

/// A full schedule π for one job.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Schedule {
    pub job_id: usize,
    /// Non-empty slots, sorted by `t`.
    pub slots: Vec<SlotPlacement>,
}

impl Schedule {
    pub fn empty(job_id: usize) -> Schedule {
        Schedule { job_id, slots: Vec::new() }
    }

    /// Completion slot `t̃_i` (Eq. (6)): the last slot with active workers.
    pub fn completion_time(&self) -> Option<usize> {
        self.slots
            .iter()
            .filter(|s| s.total_workers() > 0)
            .map(|s| s.t)
            .max()
    }

    /// Total samples trained over the schedule (LHS of Eq. (3)).
    pub fn total_samples(&self, job: &Job) -> f64 {
        self.slots
            .iter()
            .map(|s| samples_in_slot(job, &s.placements))
            .sum()
    }

    /// True iff the schedule covers the job's full workload `E_i K_i`
    /// (`frac` < 1 allows the paper's cover-violation tolerance; see the
    /// Fig. 11 discussion — rounding may undershoot by a bounded factor).
    pub fn covers_workload(&self, job: &Job, frac: f64) -> bool {
        self.total_samples(job) + 1e-9 >= frac * job.total_workload()
    }

    /// Worker cap check, Eq. (4): `Σ_h w_ih[t] ≤ F_i` in every slot.
    pub fn respects_worker_cap(&self, job: &Job) -> bool {
        self.slots.iter().all(|s| s.total_workers() <= job.batch)
    }

    /// No placement precedes the arrival slot (Eq. (7)).
    pub fn respects_arrival(&self, job: &Job) -> bool {
        self.slots.iter().all(|s| s.t >= job.arrival)
    }

    /// The worker:PS ratio is maintained within integer rounding each slot
    /// (Eq. (2)): `s = ⌈w/γ⌉` up to slack 1 (the paper keeps γ_i fixed;
    /// integer counts force ceil).
    pub fn respects_gamma(&self, job: &Job) -> bool {
        self.slots.iter().all(|s| {
            let w = s.total_workers();
            let ps = s.total_ps();
            if w == 0 {
                return true;
            }
            let need = (w as f64 / job.gamma).ceil() as u64;
            ps >= need.max(1)
        })
    }

    /// Drop empty slots and sort by t — normal form used by tests.
    pub fn normalize(&mut self) {
        self.slots.retain(|s| {
            s.placements.iter().any(|&(_, w, ps)| w > 0 || ps > 0)
        });
        self.slots.sort_by_key(|s| s.t);
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::test_job;
    use super::*;

    #[test]
    fn completion_ignores_ps_only_slots() {
        let s = Schedule {
            job_id: 0,
            slots: vec![
                SlotPlacement { t: 2, placements: vec![(0, 2, 1)] },
                SlotPlacement { t: 5, placements: vec![(0, 0, 1)] },
            ],
        };
        assert_eq!(s.completion_time(), Some(2));
    }

    #[test]
    fn constraint_checks() {
        let j = test_job(0);
        let good = Schedule {
            job_id: 0,
            slots: vec![SlotPlacement { t: 0, placements: vec![(0, 4, 2)] }],
        };
        assert!(good.respects_worker_cap(&j));
        assert!(good.respects_arrival(&j));
        assert!(good.respects_gamma(&j));

        let too_many = Schedule {
            job_id: 0,
            slots: vec![SlotPlacement { t: 0, placements: vec![(0, 100, 50)] }],
        };
        assert!(!too_many.respects_worker_cap(&j));

        let no_ps = Schedule {
            job_id: 0,
            slots: vec![SlotPlacement { t: 0, placements: vec![(0, 4, 1)] }],
        };
        assert!(!no_ps.respects_gamma(&j)); // needs ceil(4/2)=2
    }

    #[test]
    fn normalize_sorts_and_prunes() {
        let mut s = Schedule {
            job_id: 0,
            slots: vec![
                SlotPlacement { t: 3, placements: vec![(0, 1, 1)] },
                SlotPlacement { t: 1, placements: vec![(0, 0, 0)] },
                SlotPlacement { t: 0, placements: vec![(1, 2, 1)] },
            ],
        };
        s.normalize();
        assert_eq!(s.slots.len(), 2);
        assert_eq!(s.slots[0].t, 0);
    }
}
