//! Parallel scenario sweeps: declarative grids of
//! scheduler × workload × cluster × seed, executed on a zero-dependency
//! work-stealing thread pool with a resumable JSONL result store.
//!
//! The paper's evaluation (§5) — and the broader scenario matrices of
//! OASiS (arXiv:1801.00936) and DL2 (arXiv:1909.06040) — is exactly such
//! a grid; this subsystem makes it first-class instead of per-figure
//! copy-paste:
//!
//! * [`scenario`] — [`ScenarioMatrix`] expands into self-contained
//!   [`Scenario`] cells (own deterministic RNG stream per cell);
//!   [`ClusterSpec`] spans homogeneous and heterogeneous (skewed machine
//!   class) clusters, [`WorkloadSpec`] the synthetic / Google-trace
//!   generators.
//! * [`runner`] — [`run_matrix`] executes cells in parallel
//!   (`std::thread::scope` + per-worker deques with stealing) and streams
//!   each cell through the [`SimObserver`](crate::sim::SimObserver)
//!   machinery; `--jobs 1` and `--jobs N` produce byte-identical per-cell
//!   metrics.
//! * [`store`] — [`ResultStore`] appends one JSON line per completed cell
//!   to `results/*.jsonl`, skips cells already on disk (resumable
//!   sweeps), and aggregates order-insensitively.
//!
//! The figure drivers ([`crate::experiments::figures`]) and the CLI
//! `compare`/`sweep` commands build their grids as matrices and run
//! through [`run_matrix`] — multi-core speedup and persisted results come
//! for free. Typical use:
//!
//! ```text
//! let matrix = ScenarioMatrix::new()
//!     .schedulers(&["pd-ors", "fifo", "drf"])
//!     .workload(WorkloadSpec::synthetic(40, 20, 100))
//!     .cluster(ClusterSpec::homogeneous(20))
//!     .cluster(ClusterSpec::skewed(20, 2.0))
//!     .seeds(3);
//! let mut store = ResultStore::open("results/sweep.jsonl")?;
//! let outcomes = run_matrix(&matrix, 0 /* auto */, Some(&mut store))?;
//! ```

pub mod runner;
pub mod scenario;
pub mod store;

pub use runner::{run_cell, run_matrix, run_matrix_with, CellOutcome, SweepSpec};
pub use scenario::{ClusterSpec, Scenario, ScenarioMatrix, WorkloadSource, WorkloadSpec};
pub use store::{CellRecord, ResultStore, SummaryRow};
