//! Declarative scenario grids: `ScenarioMatrix` expands registered
//! scheduler names × workload specs × cluster specs × seeds into
//! self-contained [`Scenario`] cells.
//!
//! A cell owns everything needed to run it — workload generator inputs,
//! cluster shape, scheduler name, and the seed of its deterministic
//! [`Rng`](crate::util::Rng) stream — so cells can execute in any order,
//! on any thread, and still produce byte-identical metrics. The stable
//! [`Scenario::key`] is what the [`ResultStore`](super::store::ResultStore)
//! uses to skip cells already on disk (resumable sweeps).

use crate::chaos::ChurnSpec;
use crate::cluster::Cluster;
use crate::config::Config;
use crate::jobs::Job;
use crate::sched::replan::ReplanPolicy;
use crate::util::Rng;
use crate::workload::synthetic::{paper_cluster, paper_cluster_classes, skewed_classes};
use crate::workload::{
    google_trace_jobs, synthetic_jobs, ArrivalProcess, ClassMix, SynthConfig,
    MIX_DEFAULT, MIX_TRACE,
};

/// Which workload generator a cell draws its jobs from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadSource {
    /// The paper's §5 synthetic distribution.
    Synthetic,
    /// The regenerated Google-trace arrival process.
    GoogleTrace,
}

/// One workload axis value: generator inputs plus a base seed. The cell's
/// job list is drawn from `Rng::new(base_seed + scenario.seed)`, matching
/// the `base + seed` convention the figure drivers always used — so a
/// figure rewired through the sweep reproduces its fixed-seed output
/// exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadSpec {
    pub source: WorkloadSource,
    pub num_jobs: usize,
    /// Simulation horizon T (also bounds the arrival slots).
    pub horizon: usize,
    pub mix: ClassMix,
    /// Arrival-slot process (synthetic source only; the trace source has
    /// its own regenerated arrival process).
    pub arrivals: ArrivalProcess,
    pub base_seed: u64,
}

impl WorkloadSpec {
    pub fn synthetic(num_jobs: usize, horizon: usize, base_seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            source: WorkloadSource::Synthetic,
            num_jobs,
            horizon,
            mix: MIX_DEFAULT,
            arrivals: ArrivalProcess::Alternating,
            base_seed,
        }
    }

    pub fn trace(num_jobs: usize, horizon: usize, base_seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            source: WorkloadSource::GoogleTrace,
            num_jobs,
            horizon,
            mix: MIX_DEFAULT,
            arrivals: ArrivalProcess::Alternating,
            base_seed,
        }
    }

    pub fn with_mix(mut self, mix: ClassMix) -> WorkloadSpec {
        self.mix = mix;
        self
    }

    /// Override the arrival process (e.g. `diurnal:3` — the
    /// time-varying-rate scenario axis).
    pub fn with_arrivals(mut self, arrivals: ArrivalProcess) -> WorkloadSpec {
        self.arrivals = arrivals;
        self
    }

    fn mix_label(&self) -> String {
        if self.mix == MIX_DEFAULT {
            "mixD".to_string()
        } else if self.mix == MIX_TRACE {
            "mixT".to_string()
        } else {
            format!(
                "mix{:.0}-{:.0}-{:.0}",
                self.mix.insensitive * 100.0,
                self.mix.sensitive * 100.0,
                self.mix.critical * 100.0
            )
        }
    }

    /// Stable identity string (part of [`Scenario::key`]). The arrival
    /// process contributes a token only when non-default, so pre-existing
    /// store keys are unchanged.
    pub fn key(&self) -> String {
        let src = match self.source {
            WorkloadSource::Synthetic => "synth",
            WorkloadSource::GoogleTrace => "trace",
        };
        let arr = self
            .arrivals
            .key_token()
            .map(|t| format!("-{t}"))
            .unwrap_or_default();
        format!(
            "{src}-i{}-t{}-{}{arr}-b{}",
            self.num_jobs,
            self.horizon,
            self.mix_label(),
            self.base_seed
        )
    }

    /// Draw this workload's job list for one cell (deterministic in
    /// `base_seed + cell_seed`).
    pub fn jobs(&self, cell_seed: u64) -> Vec<Job> {
        let mut rng = Rng::new(self.base_seed.wrapping_add(cell_seed));
        match self.source {
            WorkloadSource::Synthetic => synthetic_jobs(
                &SynthConfig::paper(self.num_jobs, self.horizon, self.mix)
                    .with_arrivals(self.arrivals),
                &mut rng,
            ),
            WorkloadSource::GoogleTrace => {
                google_trace_jobs(self.num_jobs, self.horizon, self.mix, &mut rng)
            }
        }
    }
}

/// One cluster axis value. Capacities are multiples of the paper's EC2
/// C5n-class machine (`paper_machine_capacity`).
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterSpec {
    /// `machines` identical paper-capacity machines.
    Homogeneous { machines: usize },
    /// Machine classes as `(count, capacity scale)` pairs; scale 1.0 is
    /// the paper capacity.
    Heterogeneous { classes: Vec<(usize, f64)> },
}

impl ClusterSpec {
    pub fn homogeneous(machines: usize) -> ClusterSpec {
        ClusterSpec::Homogeneous { machines }
    }

    /// The standard skewed shape: `machines` total, a quarter big
    /// (`skew ×`), a quarter small (`1/skew ×`), the rest standard — the
    /// shape is defined once in
    /// [`crate::workload::synthetic::skewed_classes`].
    pub fn skewed(machines: usize, skew: f64) -> ClusterSpec {
        ClusterSpec::Heterogeneous {
            classes: skewed_classes(machines, skew).to_vec(),
        }
    }

    /// Total machine count.
    pub fn machines(&self) -> usize {
        match self {
            ClusterSpec::Homogeneous { machines } => *machines,
            ClusterSpec::Heterogeneous { classes } => {
                classes.iter().map(|(n, _)| n).sum()
            }
        }
    }

    /// The sub-cluster spec covering machines `[start, end)` of this
    /// spec's machine order (homogeneous stays homogeneous; heterogeneous
    /// classes are cut at the range boundaries). This is how the sharded
    /// admission service derives each cell's cluster: contiguous machine
    /// ranges of the full spec, so global machine id = cell base + local
    /// id and the concatenation of the cell clusters is the whole
    /// cluster.
    pub fn slice(&self, start: usize, end: usize) -> ClusterSpec {
        assert!(start <= end && end <= self.machines(), "slice out of range");
        match self {
            ClusterSpec::Homogeneous { .. } => {
                ClusterSpec::Homogeneous { machines: end - start }
            }
            ClusterSpec::Heterogeneous { classes } => {
                let mut out = Vec::new();
                let mut base = 0usize;
                for &(n, scale) in classes {
                    let class_end = base + n;
                    let lo = start.max(base);
                    let hi = end.min(class_end);
                    if lo < hi {
                        out.push((hi - lo, scale));
                    }
                    base = class_end;
                }
                ClusterSpec::Heterogeneous { classes: out }
            }
        }
    }

    /// Stable identity string (part of [`Scenario::key`]).
    pub fn key(&self) -> String {
        match self {
            ClusterSpec::Homogeneous { machines } => format!("homog-h{machines}"),
            ClusterSpec::Heterogeneous { classes } => {
                let parts: Vec<String> =
                    classes.iter().map(|(n, s)| format!("{n}x{s}")).collect();
                format!("hetero-{}", parts.join("+"))
            }
        }
    }

    /// Materialize the cluster.
    pub fn build(&self) -> Cluster {
        match self {
            ClusterSpec::Homogeneous { machines } => paper_cluster(*machines),
            ClusterSpec::Heterogeneous { classes } => paper_cluster_classes(classes),
        }
    }

    /// Parse a `[cluster]` config section:
    ///
    /// ```text
    /// [cluster]
    /// machines = 20          # total machine count
    /// skew = 2.0             # optional: quarter big / quarter small shape
    /// classes = 4x2.0,12x1.0,4x0.5   # optional: explicit count x scale list
    /// ```
    ///
    /// `classes` wins over `skew`; with neither, the cluster is
    /// homogeneous with `default_machines` (overridden by
    /// `cluster.machines`).
    pub fn from_config(cfg: &Config, default_machines: usize) -> ClusterSpec {
        let machines = cfg.usize("cluster.machines", default_machines);
        if let Some(spec) = cfg.get("cluster.classes") {
            let mut classes = Vec::new();
            for part in spec.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                match part.split_once('x') {
                    Some((n, s)) => {
                        match (n.trim().parse::<usize>(), s.trim().parse::<f64>()) {
                            (Ok(n), Ok(s)) if s > 0.0 => classes.push((n, s)),
                            _ => eprintln!(
                                "warning: ignoring invalid cluster.classes entry {part:?} \
                                 (expected COUNTxSCALE, e.g. 4x2.0)"
                            ),
                        }
                    }
                    None => eprintln!(
                        "warning: ignoring invalid cluster.classes entry {part:?} \
                         (expected COUNTxSCALE, e.g. 4x2.0)"
                    ),
                }
            }
            if !classes.is_empty() {
                return ClusterSpec::Heterogeneous { classes };
            }
        }
        let skew = cfg.f64("cluster.skew", 1.0);
        if skew != 1.0 {
            return ClusterSpec::skewed(machines, skew);
        }
        ClusterSpec::homogeneous(machines)
    }
}

/// One self-contained grid cell: everything needed to reproduce a single
/// simulation run.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Registry key (see [`crate::sched::registry`]).
    pub scheduler: String,
    pub workload: WorkloadSpec,
    pub cluster: ClusterSpec,
    /// Cell seed: the scheduler's seed, and the offset added to the
    /// workload's base seed.
    pub seed: u64,
    /// Elastic re-planning cadence for this cell (an independent sweep
    /// axis; replan-incapable schedulers no-op).
    pub replan: ReplanPolicy,
    /// Machine-churn spec for this cell (an independent sweep axis; the
    /// default [`ChurnSpec::None`] is the byte-identical no-op). Seeded
    /// specs draw their trace from the cell seed.
    pub churn: ChurnSpec,
}

impl Scenario {
    /// Stable cell identity — the [`ResultStore`](super::store::ResultStore)
    /// dedup key. The replan and churn axes contribute trailing tokens only
    /// when enabled, so every pre-existing store key is unchanged.
    pub fn key(&self) -> String {
        let replan = self
            .replan
            .key_token()
            .map(|t| format!("|{t}"))
            .unwrap_or_default();
        let churn = self
            .churn
            .key_token()
            .map(|t| format!("|{t}"))
            .unwrap_or_default();
        format!(
            "{}|{}|{}|seed{}{replan}{churn}",
            self.scheduler,
            self.workload.key(),
            self.cluster.key(),
            self.seed
        )
    }
}

/// A declarative scenario grid. Either give the matrix independent
/// workload/cluster axes (crossed cartesian-product style) or paired
/// `case(workload, cluster)` columns (the figure drivers vary one of the
/// two per x-value); schedulers and seeds always cross everything.
#[derive(Debug, Clone, Default)]
pub struct ScenarioMatrix {
    schedulers: Vec<String>,
    workloads: Vec<WorkloadSpec>,
    clusters: Vec<ClusterSpec>,
    seeds: Vec<u64>,
    cases: Vec<(WorkloadSpec, ClusterSpec)>,
    replans: Vec<ReplanPolicy>,
    churns: Vec<ChurnSpec>,
}

impl ScenarioMatrix {
    pub fn new() -> ScenarioMatrix {
        ScenarioMatrix::default()
    }

    pub fn scheduler(mut self, name: &str) -> ScenarioMatrix {
        self.schedulers.push(name.to_string());
        self
    }

    pub fn schedulers(mut self, names: &[&str]) -> ScenarioMatrix {
        self.schedulers.extend(names.iter().map(|n| n.to_string()));
        self
    }

    pub fn workload(mut self, w: WorkloadSpec) -> ScenarioMatrix {
        self.workloads.push(w);
        self
    }

    pub fn cluster(mut self, c: ClusterSpec) -> ScenarioMatrix {
        self.clusters.push(c);
        self
    }

    /// Add one paired (workload, cluster) column (not crossed with the
    /// independent axes).
    pub fn case(mut self, w: WorkloadSpec, c: ClusterSpec) -> ScenarioMatrix {
        self.cases.push((w, c));
        self
    }

    /// Use seeds `0..n`.
    pub fn seeds(mut self, n: usize) -> ScenarioMatrix {
        self.seeds = (0..n as u64).collect();
        self
    }

    /// Use an explicit seed list.
    pub fn seed_list(mut self, seeds: &[u64]) -> ScenarioMatrix {
        self.seeds = seeds.to_vec();
        self
    }

    /// Add one replan-cadence axis value (crossed with everything else,
    /// second-innermost in cell order). An empty axis means `[none]` — the
    /// pre-replan matrix, cell for cell.
    pub fn replan(mut self, policy: ReplanPolicy) -> ScenarioMatrix {
        self.replans.push(policy);
        self
    }

    /// Add one machine-churn axis value (crossed with everything else,
    /// innermost in cell order). An empty axis means `[none]` — the
    /// pre-churn matrix, cell for cell.
    pub fn churn(mut self, spec: ChurnSpec) -> ScenarioMatrix {
        self.churns.push(spec);
        self
    }

    /// The effective (workload, cluster) columns: explicit cases first,
    /// then the cartesian product of the independent axes.
    pub fn columns(&self) -> Vec<(WorkloadSpec, ClusterSpec)> {
        let mut out = self.cases.clone();
        for w in &self.workloads {
            for c in &self.clusters {
                out.push((*w, c.clone()));
            }
        }
        out
    }

    fn seed_values(&self) -> Vec<u64> {
        if self.seeds.is_empty() {
            vec![0]
        } else {
            self.seeds.clone()
        }
    }

    fn replan_values(&self) -> Vec<ReplanPolicy> {
        if self.replans.is_empty() {
            vec![ReplanPolicy::None]
        } else {
            self.replans.clone()
        }
    }

    fn churn_values(&self) -> Vec<ChurnSpec> {
        if self.churns.is_empty() {
            vec![ChurnSpec::None]
        } else {
            self.churns.clone()
        }
    }

    /// Number of cells the matrix expands to.
    pub fn len(&self) -> usize {
        self.columns().len()
            * self.schedulers.len()
            * self.seed_values().len()
            * self.replan_values().len()
            * self.churn_values().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand into cells. Ordering contract (callers aggregate by index
    /// arithmetic): columns outermost, then schedulers, then seeds, then
    /// replan policies, then churn specs — i.e. with single-valued replan
    /// and churn axes (the default), cell `(ci, si, ki)` lives at index
    /// `ci * (num_schedulers * num_seeds) + si * num_seeds + ki`, exactly
    /// as before those axes existed.
    pub fn cells(&self) -> Vec<Scenario> {
        let seeds = self.seed_values();
        let replans = self.replan_values();
        let churns = self.churn_values();
        let mut out = Vec::with_capacity(self.len());
        for (w, c) in self.columns() {
            for s in &self.schedulers {
                for &seed in &seeds {
                    for &replan in &replans {
                        for churn in &churns {
                            out.push(Scenario {
                                scheduler: s.clone(),
                                workload: w,
                                cluster: c.clone(),
                                seed,
                                replan,
                                churn: churn.clone(),
                            });
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::synthetic::paper_cluster_skewed;
    use std::collections::BTreeSet;

    #[test]
    fn cluster_slices_concatenate_to_the_whole() {
        let homog = ClusterSpec::homogeneous(10);
        assert_eq!(homog.slice(0, 4).machines(), 4);
        assert_eq!(homog.slice(4, 10).machines(), 6);
        let het = ClusterSpec::Heterogeneous {
            classes: vec![(2, 2.0), (4, 1.0), (2, 0.5)],
        };
        // cut points inside and across class boundaries
        let a = het.slice(0, 3);
        let b = het.slice(3, 8);
        assert_eq!(a, ClusterSpec::Heterogeneous { classes: vec![(2, 2.0), (1, 1.0)] });
        assert_eq!(
            b,
            ClusterSpec::Heterogeneous { classes: vec![(3, 1.0), (2, 0.5)] }
        );
        // machine-by-machine, the concatenated slices ARE the cluster
        let whole = het.build();
        let mut joined = a.build().machines;
        joined.extend(b.build().machines);
        assert_eq!(whole.machines.len(), joined.len());
        for (w, j) in whole.machines.iter().zip(&joined) {
            assert_eq!(w.capacity, j.capacity);
        }
    }

    #[test]
    fn matrix_expands_cartesian_product() {
        let m = ScenarioMatrix::new()
            .schedulers(&["pd-ors", "fifo"])
            .workload(WorkloadSpec::synthetic(10, 10, 100))
            .workload(WorkloadSpec::trace(20, 15, 200))
            .cluster(ClusterSpec::homogeneous(8))
            .cluster(ClusterSpec::skewed(8, 2.0))
            .seeds(3);
        assert_eq!(m.len(), 2 * 2 * 2 * 3);
        let cells = m.cells();
        assert_eq!(cells.len(), 24);
        let keys: BTreeSet<String> = cells.iter().map(|c| c.key()).collect();
        assert_eq!(keys.len(), 24, "cell keys must be unique");

        // the replan axis crosses everything and keeps keys unique across
        // policies
        let m = m.replan(ReplanPolicy::None).replan(ReplanPolicy::Every(2));
        assert_eq!(m.len(), 48);
        let cells = m.cells();
        assert_eq!(cells[0].replan, ReplanPolicy::None);
        assert_eq!(cells[1].replan, ReplanPolicy::Every(2));
        assert_eq!(cells[0].seed, cells[1].seed, "replan is inside the seed axis");
        let keys: BTreeSet<String> = cells.iter().map(|c| c.key()).collect();
        assert_eq!(keys.len(), 48);

        // the churn axis is innermost of all
        let m = m
            .churn(ChurnSpec::None)
            .churn(ChurnSpec::Mtbf { mtbf: 40.0, mttr: 8.0 });
        assert_eq!(m.len(), 96);
        let cells = m.cells();
        assert_eq!(cells[0].churn, ChurnSpec::None);
        assert_eq!(cells[1].churn, ChurnSpec::Mtbf { mtbf: 40.0, mttr: 8.0 });
        assert_eq!(cells[0].replan, cells[1].replan, "churn is the innermost axis");
        let keys: BTreeSet<String> = cells.iter().map(|c| c.key()).collect();
        assert_eq!(keys.len(), 96);
    }

    #[test]
    fn paired_cases_are_not_crossed() {
        let m = ScenarioMatrix::new()
            .scheduler("fifo")
            .case(WorkloadSpec::synthetic(5, 10, 0), ClusterSpec::homogeneous(4))
            .case(WorkloadSpec::synthetic(9, 10, 0), ClusterSpec::homogeneous(8))
            .seeds(2);
        assert_eq!(m.len(), 2 * 1 * 2);
        let cells = m.cells();
        // ordering contract: columns outer, schedulers, then seeds
        assert_eq!(cells[0].workload.num_jobs, 5);
        assert_eq!(cells[0].seed, 0);
        assert_eq!(cells[1].seed, 1);
        assert_eq!(cells[2].workload.num_jobs, 9);
        assert_eq!(cells[2].cluster.machines(), 8);
    }

    #[test]
    fn workload_jobs_match_direct_generation() {
        let w = WorkloadSpec::synthetic(8, 12, 1000);
        let jobs = w.jobs(3);
        let direct = synthetic_jobs(
            &SynthConfig::paper(8, 12, MIX_DEFAULT),
            &mut Rng::new(1003),
        );
        assert_eq!(jobs.len(), direct.len());
        for (a, b) in jobs.iter().zip(&direct) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.epochs, b.epochs);
            assert_eq!(a.utility, b.utility);
        }
    }

    #[test]
    fn cluster_spec_builds_expected_shapes() {
        assert_eq!(ClusterSpec::homogeneous(6).build().len(), 6);
        let skewed = ClusterSpec::skewed(8, 2.0);
        assert_eq!(skewed.machines(), 8);
        let built = skewed.build();
        assert_eq!(built.len(), 8);
        assert_eq!(built.machines, paper_cluster_skewed(8, 2.0).machines);
    }

    #[test]
    fn keys_are_stable_and_distinguish_axes() {
        let s = Scenario {
            scheduler: "pd-ors".into(),
            workload: WorkloadSpec::synthetic(50, 20, 1000),
            cluster: ClusterSpec::homogeneous(20),
            seed: 2,
            replan: ReplanPolicy::None,
            churn: ChurnSpec::None,
        };
        assert_eq!(s.key(), "pd-ors|synth-i50-t20-mixD-b1000|homog-h20|seed2");
        // the replan axis gets its own trailing token; the default policy
        // leaves pre-existing keys untouched
        let r = Scenario { replan: ReplanPolicy::Every(4), ..s.clone() };
        assert_eq!(r.key(), "pd-ors|synth-i50-t20-mixD-b1000|homog-h20|seed2|re4");
        // churn appends after replan, and alone when replan is off
        let c = Scenario {
            churn: ChurnSpec::Mtbf { mtbf: 40.0, mttr: 8.0 },
            ..s.clone()
        };
        assert_eq!(
            c.key(),
            "pd-ors|synth-i50-t20-mixD-b1000|homog-h20|seed2|chm40r8"
        );
        let rc = Scenario {
            replan: ReplanPolicy::Every(4),
            churn: ChurnSpec::Mtbf { mtbf: 40.0, mttr: 8.0 },
            ..s.clone()
        };
        assert_eq!(
            rc.key(),
            "pd-ors|synth-i50-t20-mixD-b1000|homog-h20|seed2|re4|chm40r8"
        );
        let t = Scenario { cluster: ClusterSpec::skewed(20, 2.0), ..s.clone() };
        assert_ne!(s.key(), t.key());
        let u = Scenario {
            workload: s.workload.with_mix(MIX_TRACE),
            ..s.clone()
        };
        assert_ne!(s.key(), u.key());
        // the diurnal arrival axis gets its own key token; the default
        // alternating process leaves pre-existing keys untouched
        let v = Scenario {
            workload: s
                .workload
                .with_arrivals(ArrivalProcess::Diurnal { peak_ratio: 3.0 }),
            ..s.clone()
        };
        assert_eq!(
            v.key(),
            "pd-ors|synth-i50-t20-mixD-adi3-b1000|homog-h20|seed2"
        );
    }

    #[test]
    fn diurnal_workload_differs_only_in_arrivals() {
        let base = WorkloadSpec::synthetic(30, 20, 500);
        let diurnal = base.with_arrivals(ArrivalProcess::Diurnal { peak_ratio: 3.0 });
        let a = base.jobs(1);
        let b = diurnal.jobs(1);
        assert_eq!(a.len(), b.len());
        // the arrival-slot draw count per job is identical, so the job
        // populations match; only the arrival distribution moves
        let arr_a: Vec<usize> = a.iter().map(|j| j.arrival).collect();
        let arr_b: Vec<usize> = b.iter().map(|j| j.arrival).collect();
        assert_ne!(arr_a, arr_b, "diurnal arrivals must actually differ");
        let mut ep_a: Vec<u64> = a.iter().map(|j| j.epochs).collect();
        let mut ep_b: Vec<u64> = b.iter().map(|j| j.epochs).collect();
        ep_a.sort_unstable();
        ep_b.sort_unstable();
        assert_eq!(ep_a, ep_b, "non-arrival draws are unchanged");
    }

    #[test]
    fn cluster_spec_from_config() {
        let cfg = Config::parse("[cluster]\nmachines = 30\n").unwrap();
        assert_eq!(
            ClusterSpec::from_config(&cfg, 20),
            ClusterSpec::homogeneous(30)
        );

        let cfg = Config::parse("[cluster]\nmachines = 16\nskew = 2.0\n").unwrap();
        assert_eq!(
            ClusterSpec::from_config(&cfg, 20),
            ClusterSpec::skewed(16, 2.0)
        );

        let cfg =
            Config::parse("[cluster]\nclasses = 4x2.0, 12x1.0, 4x0.5\n").unwrap();
        assert_eq!(
            ClusterSpec::from_config(&cfg, 20),
            ClusterSpec::Heterogeneous {
                classes: vec![(4, 2.0), (12, 1.0), (4, 0.5)]
            }
        );

        // no [cluster] section at all: homogeneous default
        let cfg = Config::parse("").unwrap();
        assert_eq!(
            ClusterSpec::from_config(&cfg, 20),
            ClusterSpec::homogeneous(20)
        );
    }
}
