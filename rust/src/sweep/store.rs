//! JSONL result store: one line per completed sweep cell.
//!
//! [`ResultStore::open`] loads any lines already on disk (that is what
//! makes sweeps resumable — the runner skips cells whose key is present)
//! and [`ResultStore::append`] writes each new [`CellRecord`] as a single
//! compact JSON line, flushed per cell so a killed sweep loses at most
//! the in-flight cell. Aggregation ([`ResultStore::summary`]) groups by
//! (scheduler, workload, cluster) and is insensitive to record order, so
//! serial and parallel sweeps summarize identically.

use std::collections::BTreeMap;
use std::io::Write as _;

use crate::obs;
use crate::util::json::{self, Json};

/// One completed cell: the scenario identity plus its metrics, solver
/// diagnostics, and wall time. `wall_secs` and the solver counters are
/// the diagnostic fields — [`CellRecord::metrics_line`] excludes them for
/// determinism/parity comparisons (wall time is non-deterministic; the
/// counters legitimately differ between cached and `--no-theta-cache`
/// runs of byte-identical schedules).
#[derive(Debug, Clone, PartialEq)]
pub struct CellRecord {
    /// Stable scenario key (`Scenario::key`).
    pub key: String,
    pub scheduler: String,
    pub workload: String,
    pub cluster: String,
    pub seed: u64,
    pub jobs: usize,
    pub admitted: usize,
    pub completed: usize,
    /// Jobs whose plan an elastic replan round changed (0 with
    /// `replan = none`; deterministic, so part of the metrics line).
    pub replanned: usize,
    /// Stranded admissions dropped by machine churn (0 with
    /// `churn = none`; deterministic, so part of the metrics line).
    pub evicted: usize,
    /// Stranded admissions re-solved onto surviving machines.
    pub migrated: usize,
    /// Mean finish-time fairness over completed jobs (0 when none
    /// completed).
    pub ftf: f64,
    pub total_utility: f64,
    pub median_training_time: f64,
    /// Rejection-reason breakdown from decision provenance (the sweep
    /// runner runs every cell with provenance on; deterministic, so part
    /// of the metrics line): rejections because the dual prices beat the
    /// utility, and rejections because no feasible θ-schedule existed.
    pub rej_price: usize,
    pub rej_infeasible: usize,
    /// Mean λ margin (utility − price) over admitted jobs (0 when none).
    pub mean_admit_margin: f64,
    /// Mean scalar price level over the cell's slot samples (0 for
    /// non-pricing policies).
    pub mean_price_level: f64,
    /// Solver diagnostics (zeros for non-θ policies; see
    /// [`crate::sched::SolverStats`]).
    pub theta_solves: u64,
    pub memo_hits: u64,
    pub lp_solves: u64,
    pub lp_pivots: u64,
    pub rounding_attempts: u64,
    /// Incremental-solver reuse counters (all zero under `--cold-solver`
    /// and for non-θ policies; see [`crate::sched::SolverStats`]).
    pub warm_hits: u64,
    pub warm_fallbacks: u64,
    pub memo_invalidated: u64,
    pub snapshot_delta_updates: u64,
    /// Machine-normalized solver ratios (counter quotients, not wall
    /// time — safe to trend-gate across machines): memo hits per
    /// θ-solve, simplex pivots per LP solve, θ-solves per admission,
    /// warm-simplex hits per θ-solve.
    pub memo_hit_rate: f64,
    pub pivots_per_solve: f64,
    pub theta_per_admission: f64,
    pub warm_hit_rate: f64,
    /// Telemetry: per-stage span time (µs) spent inside this cell, in
    /// [`obs::ALL_STAGES`] order (all zeros when telemetry is off).
    /// Serialized as `us_<stage_name>` fields.
    pub stage_us: [f64; obs::NUM_STAGES],
    pub wall_secs: f64,
}

impl CellRecord {
    fn metric_fields(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("key", json::s(&self.key)),
            ("scheduler", json::s(&self.scheduler)),
            ("workload", json::s(&self.workload)),
            ("cluster", json::s(&self.cluster)),
            ("seed", json::num(self.seed as f64)),
            ("jobs", json::num(self.jobs as f64)),
            ("admitted", json::num(self.admitted as f64)),
            ("completed", json::num(self.completed as f64)),
            ("replanned", json::num(self.replanned as f64)),
            ("evicted", json::num(self.evicted as f64)),
            ("migrated", json::num(self.migrated as f64)),
            ("ftf", json::num(self.ftf)),
            ("total_utility", json::num(self.total_utility)),
            ("median_training_time", json::num(self.median_training_time)),
            ("rej_price", json::num(self.rej_price as f64)),
            ("rej_infeasible", json::num(self.rej_infeasible as f64)),
            ("mean_admit_margin", json::num(self.mean_admit_margin)),
            ("mean_price_level", json::num(self.mean_price_level)),
        ]
    }

    pub fn to_json(&self) -> Json {
        let mut fields = self.metric_fields();
        fields.push(("theta_solves", json::num(self.theta_solves as f64)));
        fields.push(("memo_hits", json::num(self.memo_hits as f64)));
        fields.push(("lp_solves", json::num(self.lp_solves as f64)));
        fields.push(("lp_pivots", json::num(self.lp_pivots as f64)));
        fields.push(("rounding_attempts", json::num(self.rounding_attempts as f64)));
        fields.push(("warm_hits", json::num(self.warm_hits as f64)));
        fields.push(("warm_fallbacks", json::num(self.warm_fallbacks as f64)));
        fields.push(("memo_invalidated", json::num(self.memo_invalidated as f64)));
        fields
            .push(("snapshot_delta_updates", json::num(self.snapshot_delta_updates as f64)));
        fields.push(("memo_hit_rate", json::num(self.memo_hit_rate)));
        fields.push(("pivots_per_solve", json::num(self.pivots_per_solve)));
        fields.push(("theta_per_admission", json::num(self.theta_per_admission)));
        fields.push(("warm_hit_rate", json::num(self.warm_hit_rate)));
        fields.push(("wall_secs", json::num(self.wall_secs)));
        let mut out = json::obj(fields);
        if let Json::Obj(m) = &mut out {
            for (i, st) in obs::ALL_STAGES.iter().enumerate() {
                m.insert(format!("us_{}", st.name()), json::num(self.stage_us[i]));
            }
        }
        out
    }

    /// One compact JSONL line (what [`ResultStore::append`] writes).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    /// The record serialized *without* the diagnostic fields (wall time
    /// and solver counters): byte-identical across `--jobs 1` and
    /// `--jobs N` runs of the same matrix, and across cached and
    /// `--no-theta-cache` runs (the determinism/parity contracts).
    pub fn metrics_line(&self) -> String {
        json::obj(self.metric_fields()).to_string()
    }

    pub fn from_json(v: &Json) -> Result<CellRecord, String> {
        let str_field = |k: &str| -> Result<String, String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(|s| s.to_string())
                .ok_or_else(|| format!("missing string field {k:?}"))
        };
        let num_field = |k: &str| -> Result<f64, String> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("missing numeric field {k:?}"))
        };
        Ok(CellRecord {
            key: str_field("key")?,
            scheduler: str_field("scheduler")?,
            workload: str_field("workload")?,
            cluster: str_field("cluster")?,
            seed: num_field("seed")? as u64,
            jobs: num_field("jobs")? as usize,
            admitted: num_field("admitted")? as usize,
            completed: num_field("completed")? as usize,
            // tolerate pre-replan lines without the field
            replanned: opt_u64(v, "replanned") as usize,
            // tolerate pre-churn lines without the fields
            evicted: opt_u64(v, "evicted") as usize,
            migrated: opt_u64(v, "migrated") as usize,
            ftf: opt_f64(v, "ftf"),
            total_utility: num_field("total_utility")?,
            median_training_time: num_field("median_training_time")?,
            // tolerate pre-provenance lines without the reason breakdown
            rej_price: opt_u64(v, "rej_price") as usize,
            rej_infeasible: opt_u64(v, "rej_infeasible") as usize,
            mean_admit_margin: opt_f64(v, "mean_admit_margin"),
            mean_price_level: opt_f64(v, "mean_price_level"),
            // tolerate older/foreign lines without the diagnostic fields
            theta_solves: opt_u64(v, "theta_solves"),
            memo_hits: opt_u64(v, "memo_hits"),
            lp_solves: opt_u64(v, "lp_solves"),
            lp_pivots: opt_u64(v, "lp_pivots"),
            rounding_attempts: opt_u64(v, "rounding_attempts"),
            warm_hits: opt_u64(v, "warm_hits"),
            warm_fallbacks: opt_u64(v, "warm_fallbacks"),
            memo_invalidated: opt_u64(v, "memo_invalidated"),
            snapshot_delta_updates: opt_u64(v, "snapshot_delta_updates"),
            memo_hit_rate: opt_f64(v, "memo_hit_rate"),
            pivots_per_solve: opt_f64(v, "pivots_per_solve"),
            theta_per_admission: opt_f64(v, "theta_per_admission"),
            warm_hit_rate: opt_f64(v, "warm_hit_rate"),
            stage_us: {
                let mut us = [0.0; obs::NUM_STAGES];
                for (i, st) in obs::ALL_STAGES.iter().enumerate() {
                    us[i] = opt_f64(v, &format!("us_{}", st.name()));
                }
                us
            },
            wall_secs: v.get("wall_secs").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }

    pub fn from_line(line: &str) -> Result<CellRecord, String> {
        CellRecord::from_json(&Json::parse(line)?)
    }
}

/// Optional non-negative integer field (0 when absent — older lines
/// predate the solver diagnostics).
fn opt_u64(v: &Json, key: &str) -> u64 {
    v.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
}

/// Optional float field (0.0 when absent — older lines predate the churn
/// metrics).
fn opt_f64(v: &Json, key: &str) -> f64 {
    v.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

/// One aggregated row of [`ResultStore::summary`]: all seeds of one
/// (scheduler, workload, cluster) scenario group.
#[derive(Debug, Clone, PartialEq)]
pub struct SummaryRow {
    pub scheduler: String,
    pub workload: String,
    pub cluster: String,
    pub seeds: usize,
    pub mean_utility: f64,
    pub mean_completed: f64,
    pub mean_median_training_time: f64,
    /// Mean finish-time fairness across seeds (0 when no jobs completed).
    pub mean_ftf: f64,
    /// Totals across seeds for the elastic/churn counters.
    pub total_replanned: usize,
    pub total_evicted: usize,
    pub total_migrated: usize,
    /// Totals across seeds for the rejection-reason breakdown.
    pub total_rej_price: usize,
    pub total_rej_infeasible: usize,
    /// Means across seeds of the provenance economics.
    pub mean_admit_margin: f64,
    pub mean_price_level: f64,
    pub total_wall_secs: f64,
}

/// Append-only JSONL store over `results/*.jsonl` (see module docs).
#[derive(Debug)]
pub struct ResultStore {
    path: std::path::PathBuf,
    records: Vec<CellRecord>,
    /// Scenario key → position in `records` (resume lookups are O(log n),
    /// not a scan — matrices can have thousands of cells).
    index: BTreeMap<String, usize>,
}

impl ResultStore {
    /// Open (or create) the store at `path`, loading existing records.
    /// Parent directories are created. A malformed line is a hard error
    /// (a sweep must not silently resume over a corrupt store) — except a
    /// *truncated final line* from a crashed writer, which is dropped
    /// with a warning and the file truncated back to the last complete
    /// record (see [`crate::util::jsonl::load_tolerant`]).
    pub fn open(path: &str) -> Result<ResultStore, String> {
        let pb = std::path::PathBuf::from(path);
        if let Some(dir) = pb.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("{path}: {e}"))?;
            }
        }
        let mut records = Vec::new();
        let mut index = BTreeMap::new();
        for (lineno, value) in crate::util::jsonl::load_tolerant(path)?.lines {
            let rec = CellRecord::from_json(&value)
                .map_err(|e| format!("{path}:{lineno}: {e}"))?;
            index.insert(rec.key.clone(), records.len());
            records.push(rec);
        }
        Ok(ResultStore { path: pb, records, index })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// Is this scenario key already on disk? (The runner skips such cells.)
    pub fn contains(&self, key: &str) -> bool {
        self.index.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[CellRecord] {
        &self.records
    }

    pub fn get(&self, key: &str) -> Option<&CellRecord> {
        self.index.get(key).map(|&i| &self.records[i])
    }

    /// Append one record (one JSON line, flushed immediately). A key
    /// already in the store is an error — the runner's skip logic should
    /// have filtered it.
    pub fn append(&mut self, rec: CellRecord) -> Result<(), String> {
        if self.index.contains_key(&rec.key) {
            return Err(format!("duplicate cell key {:?}", rec.key));
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .map_err(|e| format!("{}: {e}", self.path.display()))?;
        let mut line = rec.to_line();
        line.push('\n');
        f.write_all(line.as_bytes())
            .and_then(|_| f.flush())
            .map_err(|e| format!("{}: {e}", self.path.display()))?;
        self.index.insert(rec.key.clone(), self.records.len());
        self.records.push(rec);
        Ok(())
    }

    /// Aggregate over seeds per (scheduler, workload, cluster) group,
    /// sorted by group key — the result does not depend on the order in
    /// which records were appended.
    pub fn summary(&self) -> Vec<SummaryRow> {
        let mut groups: BTreeMap<(String, String, String), Vec<&CellRecord>> =
            BTreeMap::new();
        for r in &self.records {
            groups
                .entry((r.scheduler.clone(), r.workload.clone(), r.cluster.clone()))
                .or_default()
                .push(r);
        }
        groups
            .into_iter()
            .map(|((scheduler, workload, cluster), rs)| {
                let n = rs.len() as f64;
                SummaryRow {
                    scheduler,
                    workload,
                    cluster,
                    seeds: rs.len(),
                    mean_utility: rs.iter().map(|r| r.total_utility).sum::<f64>() / n,
                    mean_completed: rs.iter().map(|r| r.completed as f64).sum::<f64>()
                        / n,
                    mean_median_training_time: rs
                        .iter()
                        .map(|r| r.median_training_time)
                        .sum::<f64>()
                        / n,
                    mean_ftf: rs.iter().map(|r| r.ftf).sum::<f64>() / n,
                    total_replanned: rs.iter().map(|r| r.replanned).sum(),
                    total_evicted: rs.iter().map(|r| r.evicted).sum(),
                    total_migrated: rs.iter().map(|r| r.migrated).sum(),
                    total_rej_price: rs.iter().map(|r| r.rej_price).sum(),
                    total_rej_infeasible: rs.iter().map(|r| r.rej_infeasible).sum(),
                    mean_admit_margin: rs.iter().map(|r| r.mean_admit_margin).sum::<f64>()
                        / n,
                    mean_price_level: rs.iter().map(|r| r.mean_price_level).sum::<f64>()
                        / n,
                    total_wall_secs: rs.iter().map(|r| r.wall_secs).sum(),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(key: &str, seed: u64, utility: f64) -> CellRecord {
        CellRecord {
            key: key.to_string(),
            scheduler: "pd-ors".into(),
            workload: "synth-i10-t10-mixD-b100".into(),
            cluster: "homog-h8".into(),
            seed,
            jobs: 10,
            admitted: 7,
            completed: 6,
            replanned: 2,
            evicted: 1,
            migrated: 3,
            ftf: 1.25,
            total_utility: utility,
            median_training_time: 4.5,
            rej_price: 2,
            rej_infeasible: 1,
            mean_admit_margin: 3.5,
            mean_price_level: 0.8,
            theta_solves: 200,
            memo_hits: 150,
            lp_solves: 50,
            lp_pivots: 900,
            rounding_attempts: 40,
            warm_hits: 30,
            warm_fallbacks: 20,
            memo_invalidated: 12,
            snapshot_delta_updates: 44,
            memo_hit_rate: 0.75,
            pivots_per_solve: 18.0,
            theta_per_admission: 28.5,
            warm_hit_rate: 0.15,
            stage_us: [10.0, 9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0],
            wall_secs: 0.012,
        }
    }

    fn tmp_path(tag: &str) -> String {
        let dir = std::env::temp_dir();
        dir.join(format!("dmlrs_store_{tag}_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn record_json_round_trip() {
        let r = sample("k1", 3, 123.456);
        let back = CellRecord::from_line(&r.to_line()).unwrap();
        assert_eq!(r, back);
        // metrics_line drops the diagnostic fields, keeps the metrics
        assert!(r.to_line().contains("wall_secs"));
        assert!(r.to_line().contains("memo_hits"));
        assert!(r.to_line().contains("memo_hit_rate"));
        assert!(r.to_line().contains("pivots_per_solve"));
        assert!(r.to_line().contains("warm_hits"));
        assert!(r.to_line().contains("warm_fallbacks"));
        assert!(r.to_line().contains("memo_invalidated"));
        assert!(r.to_line().contains("snapshot_delta_updates"));
        assert!(r.to_line().contains("warm_hit_rate"));
        assert!(r.to_line().contains("us_theta_solve"));
        assert!(r.to_line().contains("us_queue_wait"));
        assert!(!r.metrics_line().contains("wall_secs"));
        assert!(!r.metrics_line().contains("memo_hits"));
        assert!(!r.metrics_line().contains("theta_solves"));
        assert!(!r.metrics_line().contains("lp_solves"));
        assert!(!r.metrics_line().contains("memo_hit_rate"));
        assert!(!r.metrics_line().contains("warm_hits"));
        assert!(!r.metrics_line().contains("snapshot_delta_updates"));
        assert!(!r.metrics_line().contains("us_"));
        assert!(r.metrics_line().contains("total_utility"));
        // the reason breakdown is deterministic and part of the metrics line
        assert!(r.metrics_line().contains("rej_price"));
        assert!(r.metrics_line().contains("rej_infeasible"));
        assert!(r.metrics_line().contains("mean_admit_margin"));
        assert!(r.metrics_line().contains("mean_price_level"));
    }

    #[test]
    fn lines_without_solver_fields_parse_as_zero() {
        let r = sample("k1", 3, 1.0);
        let mut line = r.metrics_line();
        line.push('\n');
        let back = CellRecord::from_line(line.trim()).unwrap();
        assert_eq!(back.theta_solves, 0);
        assert_eq!(back.memo_hits, 0);
        assert_eq!(back.wall_secs, 0.0);
        assert_eq!(back.total_utility, 1.0);
    }

    #[test]
    fn store_appends_and_reopens() {
        let path = tmp_path("reopen");
        let _ = std::fs::remove_file(&path);
        {
            let mut st = ResultStore::open(&path).unwrap();
            assert!(st.is_empty());
            st.append(sample("a", 0, 1.0)).unwrap();
            st.append(sample("b", 1, 2.0)).unwrap();
            assert!(st.contains("a"));
            assert!(!st.contains("c"));
            // duplicate keys are rejected
            assert!(st.append(sample("a", 0, 1.0)).is_err());
        }
        let st = ResultStore::open(&path).unwrap();
        assert_eq!(st.len(), 2);
        assert_eq!(st.get("b").unwrap().total_utility, 2.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn summary_is_order_insensitive() {
        let mut fwd = Vec::new();
        for seed in 0..4u64 {
            let mut r = sample(&format!("k{seed}"), seed, seed as f64 * 10.0);
            r.wall_secs = 0.5;
            fwd.push(r);
        }
        let path_a = tmp_path("sum_a");
        let path_b = tmp_path("sum_b");
        let _ = std::fs::remove_file(&path_a);
        let _ = std::fs::remove_file(&path_b);
        let mut a = ResultStore::open(&path_a).unwrap();
        let mut b = ResultStore::open(&path_b).unwrap();
        for r in &fwd {
            a.append(r.clone()).unwrap();
        }
        for r in fwd.iter().rev() {
            b.append(r.clone()).unwrap();
        }
        assert_eq!(a.summary(), b.summary());
        let rows = a.summary();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].seeds, 4);
        assert!((rows[0].mean_utility - 15.0).abs() < 1e-12);
        assert!((rows[0].total_wall_secs - 2.0).abs() < 1e-12);
        assert!((rows[0].mean_ftf - 1.25).abs() < 1e-12);
        assert_eq!(rows[0].total_replanned, 8);
        assert_eq!(rows[0].total_evicted, 4);
        assert_eq!(rows[0].total_migrated, 12);
        assert_eq!(rows[0].total_rej_price, 8);
        assert_eq!(rows[0].total_rej_infeasible, 4);
        assert!((rows[0].mean_admit_margin - 3.5).abs() < 1e-12);
        assert!((rows[0].mean_price_level - 0.8).abs() < 1e-12);
        let _ = std::fs::remove_file(&path_a);
        let _ = std::fs::remove_file(&path_b);
    }

    #[test]
    fn malformed_line_is_an_error() {
        // valid JSON that is not a CellRecord is corruption, not crash
        // damage — still a hard error even on the final line
        let path = tmp_path("bad");
        std::fs::write(&path, "{\"not\": \"a record\"}\n").unwrap();
        let e = ResultStore::open(&path).unwrap_err();
        assert!(e.contains("missing"), "{e}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_final_line_is_repaired_and_appendable() {
        // a crashed writer leaves a partial last line; reopening must
        // drop it, keep the complete records, and accept new appends
        let path = tmp_path("trunc");
        let _ = std::fs::remove_file(&path);
        {
            let mut st = ResultStore::open(&path).unwrap();
            st.append(sample("a", 0, 1.0)).unwrap();
            st.append(sample("b", 1, 2.0)).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"key\": \"c\", \"schedu").unwrap();
        }
        let mut st = ResultStore::open(&path).unwrap();
        assert_eq!(st.len(), 2, "complete records survive");
        assert!(st.contains("a") && st.contains("b") && !st.contains("c"));
        st.append(sample("c", 2, 3.0)).unwrap();
        drop(st);
        // the rewritten file round-trips cleanly
        let again = ResultStore::open(&path).unwrap();
        assert_eq!(again.len(), 3);
        assert_eq!(again.get("c").unwrap().total_utility, 3.0);
        let _ = std::fs::remove_file(&path);
    }
}
