//! The parallel sweep executor: a zero-dependency work-stealing pool on
//! `std::thread::scope`.
//!
//! Cells are dealt round-robin into per-worker deques; a worker pops its
//! own queue from the front and, when empty, steals from the back of its
//! siblings' queues — so long cells (big clusters, PD-ORS dynamic
//! programs) do not serialize the sweep behind one unlucky worker. Every
//! cell is self-contained (own jobs, cluster, scheduler, and `Rng`
//! stream), which is what makes `--jobs 1` and `--jobs N` produce
//! byte-identical per-cell metrics; outcomes are re-sorted into matrix
//! cell order before they are returned or appended to the
//! [`ResultStore`], so the JSONL output is order-stable too.
//!
//! Each cell streams through the existing
//! [`SimObserver`](crate::sim::SimObserver) machinery: a
//! [`StreamingMetrics`] observer rides along with the engine's internal
//! `ResultCollector`, and its live counters are cross-checked against the
//! aggregated [`SimResult`].

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::config::Config;
use crate::sched::registry::{SchedulerRegistry, ZOO};
use crate::sim::metrics::median_training_time;
use crate::sim::{SimEngine, SimResult, StreamingMetrics};
use crate::util::error::{Error, Result};
use crate::util::timer::Timer;

use super::scenario::{Scenario, ScenarioMatrix};
use super::store::{CellRecord, ResultStore};

/// Typed `[sweep]` configuration (config keys mirror the CLI flags):
///
/// ```text
/// [sweep]
/// jobs = 4                  # worker threads; 0 = available parallelism
/// out = results/sweep.jsonl
/// quick = false
/// seeds = 3
/// schedulers = pd-ors, oasis, fifo
/// arrivals = diurnal:3      # arrival process for the synthetic workloads
/// replan = every:4          # elastic re-planning cadence (default none)
/// churn = mtbf:40,mttr:8    # machine churn injected per cell (default none)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    /// Worker threads; 0 means "use available parallelism".
    pub threads: usize,
    pub quick: bool,
    pub out: String,
    pub seeds: usize,
    /// Registry keys to sweep; empty means the built-in zoo.
    pub schedulers: Vec<String>,
    /// Arrival process applied to the matrix's synthetic workloads.
    pub arrivals: crate::workload::ArrivalProcess,
    /// Elastic re-planning cadence applied to every cell.
    pub replan: crate::sched::replan::ReplanPolicy,
    /// Machine churn injected into every cell.
    pub churn: crate::chaos::ChurnSpec,
}

impl Default for SweepSpec {
    fn default() -> SweepSpec {
        SweepSpec {
            threads: 0,
            quick: false,
            out: "results/sweep.jsonl".to_string(),
            seeds: 3,
            schedulers: Vec::new(),
            arrivals: crate::workload::ArrivalProcess::Alternating,
            replan: crate::sched::replan::ReplanPolicy::None,
            churn: crate::chaos::ChurnSpec::None,
        }
    }
}

impl SweepSpec {
    /// The machine's available parallelism (≥ 1).
    pub fn available_parallelism() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Worker-thread count with the 0 = auto rule applied.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            SweepSpec::available_parallelism()
        } else {
            self.threads
        }
    }

    /// Scheduler keys with the empty = zoo rule applied, deduplicated
    /// (first occurrence wins) so a repeated name cannot produce
    /// duplicate matrix cells.
    pub fn scheduler_keys(&self) -> Vec<String> {
        let list: Vec<String> = if self.schedulers.is_empty() {
            ZOO.iter().map(|s| s.to_string()).collect()
        } else {
            self.schedulers.clone()
        };
        let mut seen = std::collections::BTreeSet::new();
        list.into_iter().filter(|s| seen.insert(s.clone())).collect()
    }

    /// Parse a comma-separated scheduler list (shared by the
    /// `--schedulers` flag and the `sweep.schedulers` config key).
    pub fn parse_scheduler_list(list: &str) -> Vec<String> {
        list.split(',')
            .map(|s| s.trim().to_ascii_lowercase())
            .filter(|s| !s.is_empty())
            .collect()
    }

    /// Parse the `[sweep]` config section over the defaults.
    pub fn from_config(cfg: &Config) -> SweepSpec {
        let mut spec = SweepSpec::default();
        spec.threads = cfg.usize("sweep.jobs", spec.threads);
        spec.quick = cfg.bool("sweep.quick", spec.quick);
        spec.out = cfg.get_or("sweep.out", &spec.out);
        spec.seeds = cfg.usize("sweep.seeds", spec.seeds).max(1);
        if let Some(list) = cfg.get("sweep.schedulers") {
            spec.schedulers = SweepSpec::parse_scheduler_list(list);
        }
        if let Some(a) = cfg.get("sweep.arrivals") {
            match crate::workload::ArrivalProcess::parse(a) {
                Ok(p) => spec.arrivals = p,
                Err(e) => eprintln!("warning: ignoring sweep.arrivals: {e}"),
            }
        }
        if let Some(r) = cfg.get("sweep.replan") {
            match crate::sched::replan::ReplanPolicy::parse(r) {
                Ok(p) => spec.replan = p,
                Err(e) => eprintln!("warning: ignoring sweep.replan: {e}"),
            }
        }
        if let Some(c) = cfg.get("sweep.churn") {
            match crate::chaos::ChurnSpec::parse(c) {
                Ok(p) => spec.churn = p,
                Err(e) => eprintln!("warning: ignoring sweep.churn: {e}"),
            }
        }
        spec
    }
}

/// One executed (or store-resumed) cell.
#[derive(Debug, Clone)]
pub struct CellOutcome {
    /// Position in [`ScenarioMatrix::cells`] order.
    pub index: usize,
    pub scenario: Scenario,
    /// The full simulation result — `None` when the cell was skipped
    /// because its record was already in the store.
    pub result: Option<SimResult>,
    pub record: CellRecord,
    /// True when the record came from disk instead of a fresh run.
    pub cached: bool,
}

/// Run one cell: generate its workload, build its cluster and scheduler,
/// simulate with a streaming observer attached, and fold the metrics into
/// a [`CellRecord`].
pub fn run_cell(reg: &SchedulerRegistry, sc: &Scenario) -> Result<(SimResult, CellRecord)> {
    let timer = Timer::start();
    // Diff the thread-local span recorder around the run to attribute
    // per-stage time to this cell (all zeros with telemetry off).
    let stages_before = crate::obs::local_totals();
    let jobs = sc.workload.jobs(sc.seed);
    let cluster = sc.cluster.build();
    let horizon = sc.workload.horizon;
    let mut sched = reg.build_named(&sc.scheduler, sc.seed, &jobs, &cluster, horizon)?;
    let mut streaming = StreamingMetrics::new();
    // Provenance is on for every cell (per-run builder switch, not the
    // global flag — worker threads must not race on process state): the
    // rejection-reason breakdown below comes from the decision traces,
    // and provenance never perturbs the schedules themselves.
    let result = SimEngine::builder()
        .jobs(&jobs)
        .cluster(&cluster)
        .horizon(horizon)
        .replan(sc.replan)
        .churn(sc.churn.clone(), sc.seed)
        .provenance(true)
        .observer(&mut streaming)
        .run(sched.as_mut());
    debug_assert_eq!(streaming.admitted, result.admitted, "observer drift");
    debug_assert_eq!(streaming.completed, result.completed, "observer drift");
    debug_assert_eq!(streaming.replanned, result.replanned, "observer drift");
    debug_assert_eq!(streaming.evicted, result.evicted, "observer drift");
    debug_assert_eq!(streaming.migrated, result.migrated, "observer drift");
    debug_assert_eq!(streaming.solver, result.solver, "observer drift");
    debug_assert!((streaming.ftf() - result.ftf).abs() <= 1e-12, "observer drift");
    let stages_after = crate::obs::local_totals();
    let mut stage_us = [0.0; crate::obs::NUM_STAGES];
    for i in 0..crate::obs::NUM_STAGES {
        stage_us[i] = stages_after[i].1.saturating_sub(stages_before[i].1) as f64;
    }
    let ratio = |num: u64, den: u64| if den == 0 { 0.0 } else { num as f64 / den as f64 };
    let rej_price = result
        .decisions
        .iter()
        .filter(|d| d.decision == "reject" && d.reason == "price")
        .count();
    let rej_infeasible = result
        .decisions
        .iter()
        .filter(|d| d.decision == "reject" && d.reason == "infeasible")
        .count();
    let margins: Vec<f64> = result
        .decisions
        .iter()
        .filter(|d| d.decision == "admit")
        .map(|d| d.margin)
        .collect();
    let mean_admit_margin = if margins.is_empty() {
        0.0
    } else {
        margins.iter().sum::<f64>() / margins.len() as f64
    };
    let mean_price_level = if result.prices.is_empty() {
        0.0
    } else {
        result.prices.iter().map(|p| p.mean_price()).sum::<f64>()
            / result.prices.len() as f64
    };
    let record = CellRecord {
        key: sc.key(),
        scheduler: sc.scheduler.clone(),
        workload: sc.workload.key(),
        cluster: sc.cluster.key(),
        seed: sc.seed,
        jobs: jobs.len(),
        admitted: result.admitted,
        completed: result.completed,
        replanned: result.replanned,
        evicted: result.evicted,
        migrated: result.migrated,
        ftf: result.ftf,
        total_utility: result.total_utility,
        median_training_time: median_training_time(&result),
        rej_price,
        rej_infeasible,
        mean_admit_margin,
        mean_price_level,
        theta_solves: result.solver.theta_solves,
        memo_hits: result.solver.memo_hits,
        lp_solves: result.solver.lp_solves,
        lp_pivots: result.solver.lp_pivots,
        rounding_attempts: result.solver.rounding_attempts,
        warm_hits: result.solver.warm_hits,
        warm_fallbacks: result.solver.warm_fallbacks,
        memo_invalidated: result.solver.memo_invalidated,
        snapshot_delta_updates: result.solver.snapshot_delta_updates,
        memo_hit_rate: ratio(result.solver.memo_hits, result.solver.theta_solves),
        pivots_per_solve: ratio(result.solver.lp_pivots, result.solver.lp_solves),
        theta_per_admission: ratio(result.solver.theta_solves, result.admitted as u64),
        warm_hit_rate: ratio(result.solver.warm_hits, result.solver.theta_solves),
        stage_us,
        wall_secs: timer.elapsed_secs(),
    };
    Ok((result, record))
}

/// Pop the next cell index: own queue front first, then steal from the
/// back of sibling queues.
fn next_cell(queues: &[Mutex<VecDeque<usize>>], w: usize) -> Option<usize> {
    if let Some(i) = queues[w].lock().unwrap().pop_front() {
        return Some(i);
    }
    let n = queues.len();
    for off in 1..n {
        if let Some(i) = queues[(w + off) % n].lock().unwrap().pop_back() {
            return Some(i);
        }
    }
    None
}

/// Run every cell of `matrix` on up to `threads` workers (0 = available
/// parallelism), constructing each worker's scheduler registry through
/// `registry` (registries hold non-`Sync` constructors, so they cannot be
/// shared). Cells whose key is already in `store` are skipped and
/// returned as cached outcomes; freshly run cells are appended to the
/// store in matrix order. Outcomes come back in matrix order regardless
/// of thread count.
pub fn run_matrix_with(
    matrix: &ScenarioMatrix,
    threads: usize,
    registry: &(dyn Fn() -> SchedulerRegistry + Sync),
    mut store: Option<&mut ResultStore>,
) -> Result<Vec<CellOutcome>> {
    let cells = matrix.cells();
    let mut outcomes: Vec<Option<CellOutcome>> = Vec::with_capacity(cells.len());
    outcomes.resize_with(cells.len(), || None);

    // Resume: cells already on disk never hit the pool.
    let mut pending: Vec<usize> = Vec::new();
    for (i, sc) in cells.iter().enumerate() {
        let key = sc.key();
        let cached = store.as_ref().and_then(|st| st.get(&key).cloned());
        match cached {
            Some(record) => {
                outcomes[i] = Some(CellOutcome {
                    index: i,
                    scenario: sc.clone(),
                    result: None,
                    record,
                    cached: true,
                });
            }
            None => pending.push(i),
        }
    }

    let threads = if threads == 0 {
        SweepSpec::available_parallelism()
    } else {
        threads
    };
    let threads = threads.min(pending.len().max(1)).max(1);

    // Deal pending cells round-robin into per-worker deques.
    let mut queues: Vec<Mutex<VecDeque<usize>>> = Vec::new();
    for _ in 0..threads {
        queues.push(Mutex::new(VecDeque::new()));
    }
    for (k, &idx) in pending.iter().enumerate() {
        queues[k % threads].lock().unwrap().push_back(idx);
    }

    let done: Mutex<Vec<(usize, SimResult, CellRecord)>> =
        Mutex::new(Vec::with_capacity(pending.len()));
    let failure: Mutex<Option<Error>> = Mutex::new(None);
    {
        let queues = &queues;
        let done = &done;
        let failure = &failure;
        let cells = &cells;
        std::thread::scope(|scope| {
            for w in 0..threads {
                scope.spawn(move || {
                    let reg = registry();
                    loop {
                        if failure.lock().unwrap().is_some() {
                            break;
                        }
                        let Some(idx) = next_cell(queues, w) else { break };
                        match run_cell(&reg, &cells[idx]) {
                            Ok((result, record)) => {
                                done.lock().unwrap().push((idx, result, record));
                            }
                            Err(e) => {
                                let mut slot = failure.lock().unwrap();
                                if slot.is_none() {
                                    *slot = Some(e);
                                }
                                break;
                            }
                        }
                    }
                    // Fold this worker's span recorder into the global
                    // aggregate before the thread exits. Histogram merge
                    // is order-insensitive, so --jobs 1 and --jobs N
                    // aggregate identically.
                    crate::obs::flush_local();
                });
            }
        });
    }
    for (idx, result, record) in done.into_inner().unwrap() {
        outcomes[idx] = Some(CellOutcome {
            index: idx,
            scenario: cells[idx].clone(),
            result: Some(result),
            record,
            cached: false,
        });
    }

    // Persist fresh records in matrix order (deterministic JSONL layout)
    // BEFORE propagating any cell failure: completed work stays on disk,
    // so a re-run after fixing the bad cell resumes instead of redoing
    // everything. The contains() guard makes duplicate matrix cells
    // (same key twice) append once instead of erroring.
    if let Some(st) = store.as_mut() {
        for o in outcomes.iter().flatten() {
            if !o.cached && !st.contains(&o.record.key) {
                st.append(o.record.clone()).map_err(Error::from)?;
            }
        }
    }
    if let Some(e) = failure.into_inner().unwrap() {
        return Err(e);
    }
    let outcomes: Vec<CellOutcome> = outcomes
        .into_iter()
        .map(|o| o.expect("every cell is either cached or executed"))
        .collect();
    Ok(outcomes)
}

/// [`run_matrix_with`] over the built-in scheduler registry.
pub fn run_matrix(
    matrix: &ScenarioMatrix,
    threads: usize,
    store: Option<&mut ResultStore>,
) -> Result<Vec<CellOutcome>> {
    run_matrix_with(matrix, threads, &SchedulerRegistry::builtin, store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::simulate;
    use crate::sweep::scenario::{ClusterSpec, WorkloadSpec};

    fn small_matrix() -> ScenarioMatrix {
        ScenarioMatrix::new()
            .schedulers(&["fifo", "drf"])
            .workload(WorkloadSpec::synthetic(6, 8, 50))
            .cluster(ClusterSpec::homogeneous(3))
            .cluster(ClusterSpec::skewed(4, 2.0))
            .seeds(2)
    }

    #[test]
    fn parallel_matches_serial_metrics() {
        let m = small_matrix();
        let serial = run_matrix(&m, 1, None).unwrap();
        let parallel = run_matrix(&m, 4, None).unwrap();
        assert_eq!(serial.len(), m.len());
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.record.metrics_line(), b.record.metrics_line());
            assert_eq!(a.result, b.result);
        }
    }

    #[test]
    fn cell_matches_direct_simulation() {
        let sc = Scenario {
            scheduler: "fifo".into(),
            workload: WorkloadSpec::synthetic(5, 8, 90),
            cluster: ClusterSpec::homogeneous(3),
            seed: 1,
            replan: crate::sched::replan::ReplanPolicy::None,
            churn: crate::chaos::ChurnSpec::None,
        };
        let reg = SchedulerRegistry::builtin();
        let (mut result, record) = run_cell(&reg, &sc).unwrap();
        let jobs = sc.workload.jobs(sc.seed);
        let cluster = sc.cluster.build();
        let mut direct = reg.build_named("fifo", 1, &jobs, &cluster, 8).unwrap();
        let expect = simulate(&jobs, &cluster, 8, direct.as_mut());
        // run_cell runs with provenance on; the bare simulate() does not —
        // one fallback trace per arrival is the only allowed difference
        assert!(result.parity_eq(&expect));
        assert_eq!(result.decisions.len(), jobs.len());
        assert!(expect.decisions.is_empty());
        result.decisions.clear();
        result.prices.clear();
        assert_eq!(result, expect);
        assert_eq!(record.total_utility, expect.total_utility);
        assert_eq!(record.jobs, jobs.len());
        assert!(record.wall_secs >= 0.0);
    }

    #[test]
    fn unknown_scheduler_fails_the_sweep() {
        let m = ScenarioMatrix::new()
            .scheduler("no-such-policy")
            .workload(WorkloadSpec::synthetic(3, 6, 1))
            .cluster(ClusterSpec::homogeneous(2))
            .seeds(1);
        let e = run_matrix(&m, 2, None).unwrap_err();
        assert!(e.to_string().contains("no-such-policy"));
    }

    #[test]
    fn completed_cells_persist_even_when_a_later_cell_fails() {
        let path = std::env::temp_dir()
            .join(format!("dmlrs_runner_partial_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let _ = std::fs::remove_file(&path);
        // single worker, deterministic order: the fifo cell completes
        // before the unknown scheduler aborts the sweep
        let m = ScenarioMatrix::new()
            .schedulers(&["fifo", "no-such-policy"])
            .workload(WorkloadSpec::synthetic(4, 6, 10))
            .cluster(ClusterSpec::homogeneous(2))
            .seeds(1);
        {
            let mut st = ResultStore::open(&path).unwrap();
            assert!(run_matrix(&m, 1, Some(&mut st)).is_err());
            assert_eq!(st.len(), 1, "the completed fifo cell must be on disk");
            assert_eq!(st.records()[0].scheduler, "fifo");
        }
        // resuming after the failure reuses the persisted cell
        let good = ScenarioMatrix::new()
            .scheduler("fifo")
            .workload(WorkloadSpec::synthetic(4, 6, 10))
            .cluster(ClusterSpec::homogeneous(2))
            .seeds(1);
        let mut st = ResultStore::open(&path).unwrap();
        let outcomes = run_matrix(&good, 1, Some(&mut st)).unwrap();
        assert!(outcomes[0].cached);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn duplicate_matrix_cells_append_once() {
        let path = std::env::temp_dir()
            .join(format!("dmlrs_runner_dup_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let _ = std::fs::remove_file(&path);
        let m = ScenarioMatrix::new()
            .schedulers(&["fifo", "fifo"])
            .workload(WorkloadSpec::synthetic(4, 6, 10))
            .cluster(ClusterSpec::homogeneous(2))
            .seeds(1);
        let mut st = ResultStore::open(&path).unwrap();
        let outcomes = run_matrix(&m, 2, Some(&mut st)).unwrap();
        assert_eq!(outcomes.len(), 2);
        assert_eq!(st.len(), 1, "identical keys collapse to one JSONL line");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sweep_spec_scheduler_keys_dedup() {
        let mut spec = SweepSpec::default();
        assert_eq!(spec.scheduler_keys().len(), ZOO.len());
        spec.schedulers =
            vec!["fifo".into(), "drf".into(), "fifo".into(), "drf".into()];
        assert_eq!(spec.scheduler_keys(), vec!["fifo".to_string(), "drf".to_string()]);
    }

    #[test]
    fn store_makes_reruns_cached() {
        let path = std::env::temp_dir()
            .join(format!("dmlrs_runner_resume_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let _ = std::fs::remove_file(&path);
        let m = small_matrix();
        {
            let mut st = ResultStore::open(&path).unwrap();
            let first = run_matrix(&m, 2, Some(&mut st)).unwrap();
            assert!(first.iter().all(|o| !o.cached));
            assert_eq!(st.len(), m.len());
        }
        {
            let mut st = ResultStore::open(&path).unwrap();
            let second = run_matrix(&m, 2, Some(&mut st)).unwrap();
            assert!(second.iter().all(|o| o.cached));
            assert!(second.iter().all(|o| o.result.is_none()));
            // no duplicate lines appended
            assert_eq!(st.len(), m.len());
        }
        let _ = std::fs::remove_file(&path);
    }
}
