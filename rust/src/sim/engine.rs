//! The simulation engine.
//!
//! Two scheduler families share it:
//!
//! * **Arrival-driven** ([`ArrivalScheduler`]): PD-ORS and OASiS decide a
//!   job's *entire* future schedule at its arrival (the paper's online
//!   model) and commit it to the allocation ledger.
//! * **Slot-driven** ([`SlotScheduler`]): FIFO / DRF / Dorm decide
//!   placements slot by slot over the currently active jobs, which is how
//!   those systems actually operate.
//!
//! Both paths produce the same [`SimResult`] so the figure drivers can
//! compare them directly. Utility is credited only when a job's full
//! workload `E_i K_i` completes within the horizon (an unfinished job
//! earns 0 and reports training time `T`, as in Fig. 9).

use crate::cluster::{AllocLedger, Cluster};
use crate::jobs::{speed, Job, Schedule, SlotPlacement};

/// Per-job outcome record.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: usize,
    pub admitted: bool,
    pub completed: bool,
    pub completion: Option<usize>,
    pub utility: f64,
    /// Completion − arrival; horizon T when unfinished (Fig. 9 convention).
    pub training_time: f64,
}

/// Aggregate simulation result.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub scheduler: String,
    pub outcomes: Vec<JobOutcome>,
    pub total_utility: f64,
    pub admitted: usize,
    pub completed: usize,
}

impl SimResult {
    fn from_outcomes(scheduler: String, outcomes: Vec<JobOutcome>) -> SimResult {
        let total_utility = outcomes.iter().map(|o| o.utility).sum();
        let admitted = outcomes.iter().filter(|o| o.admitted).count();
        let completed = outcomes.iter().filter(|o| o.completed).count();
        SimResult { scheduler, outcomes, total_utility, admitted, completed }
    }

    pub fn training_times(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.training_time).collect()
    }
}

/// A scheduler that fixes a job's entire schedule at arrival (PD-ORS,
/// OASiS). The implementation commits to the ledger itself when admitting.
pub trait ArrivalScheduler {
    fn name(&self) -> String;
    fn on_arrival(&mut self, job: &Job, ledger: &mut AllocLedger) -> Option<Schedule>;
}

/// A job that has arrived and still has workload left (slot-driven path).
#[derive(Debug, Clone)]
pub struct ActiveJob {
    pub job: Job,
    pub remaining: f64,
}

/// A scheduler that assigns placements slot by slot (FIFO, DRF, Dorm).
pub trait SlotScheduler {
    fn name(&self) -> String;
    /// Decide this slot's placements for the active jobs. The returned
    /// entries are `(index into active, placements)`. Resources are only
    /// held for the current slot.
    fn allocate(
        &mut self,
        t: usize,
        active: &[ActiveJob],
        ledger: &AllocLedger,
    ) -> Vec<(usize, Vec<(usize, u64, u64)>)>;
}

/// Run an arrival-driven scheduler over the (arrival-sorted) job list.
pub fn run_arrival_sim(
    jobs: &[Job],
    cluster: &Cluster,
    horizon: usize,
    sched: &mut dyn ArrivalScheduler,
) -> SimResult {
    let mut ledger = AllocLedger::new(cluster, horizon);
    let mut outcomes = Vec::with_capacity(jobs.len());
    for job in jobs {
        match sched.on_arrival(job, &mut ledger) {
            Some(s) => {
                debug_assert!(s.respects_worker_cap(job));
                debug_assert!(s.respects_arrival(job));
                let completed = s.covers_workload(job, 1.0);
                let completion = s.completion_time();
                let utility = match (completed, completion) {
                    (true, Some(t)) => job.utility_at(t),
                    _ => 0.0,
                };
                let training_time = match (completed, completion) {
                    (true, Some(t)) => (t - job.arrival + 1) as f64,
                    _ => horizon as f64,
                };
                outcomes.push(JobOutcome {
                    job_id: job.id,
                    admitted: true,
                    completed,
                    completion,
                    utility,
                    training_time,
                });
            }
            None => outcomes.push(JobOutcome {
                job_id: job.id,
                admitted: false,
                completed: false,
                completion: None,
                utility: 0.0,
                training_time: horizon as f64,
            }),
        }
    }
    debug_assert!(ledger.within_capacity(1e-6));
    SimResult::from_outcomes(sched.name(), outcomes)
}

/// Run a slot-driven scheduler: jobs arrive into the active set, the
/// scheduler places them each slot, workload drains per Eq. (1).
pub fn run_slot_sim(
    jobs: &[Job],
    cluster: &Cluster,
    horizon: usize,
    sched: &mut dyn SlotScheduler,
) -> SimResult {
    let mut ledger = AllocLedger::new(cluster, horizon);
    let mut active: Vec<ActiveJob> = Vec::new();
    let mut outcomes: Vec<JobOutcome> = jobs
        .iter()
        .map(|job| JobOutcome {
            job_id: job.id,
            admitted: false,
            completed: false,
            completion: None,
            utility: 0.0,
            training_time: horizon as f64,
        })
        .collect();
    let mut next_arrival = 0usize;

    for t in 0..horizon {
        while next_arrival < jobs.len() && jobs[next_arrival].arrival <= t {
            active.push(ActiveJob {
                job: jobs[next_arrival].clone(),
                remaining: jobs[next_arrival].total_workload(),
            });
            next_arrival += 1;
        }
        if active.is_empty() {
            continue;
        }
        let grants = sched.allocate(t, &active, &ledger);
        let mut finished: Vec<usize> = Vec::new();
        for (idx, placements) in grants {
            let aj = &mut active[idx];
            if placements.is_empty() {
                continue;
            }
            let slot = SlotPlacement { t, placements };
            debug_assert!(slot.total_workers() <= aj.job.batch, "Eq. (4) violated");
            let sched_one = Schedule { job_id: aj.job.id, slots: vec![slot.clone()] };
            debug_assert!(
                ledger.fits(&aj.job, &sched_one, 1e-9),
                "slot scheduler exceeded capacity"
            );
            ledger.commit(&aj.job, &sched_one);
            outcomes[aj.job.id].admitted = true;
            aj.remaining -= speed::samples_in_slot(&aj.job, &slot.placements);
            if aj.remaining <= 1e-9 {
                let o = &mut outcomes[aj.job.id];
                o.completed = true;
                o.completion = Some(t);
                o.utility = aj.job.utility_at(t);
                o.training_time = (t - aj.job.arrival + 1) as f64;
                finished.push(idx);
            }
        }
        finished.sort_unstable_by(|a, b| b.cmp(a));
        for idx in finished {
            active.swap_remove(idx);
        }
    }
    debug_assert!(ledger.within_capacity(1e-6));
    SimResult::from_outcomes(sched.name(), outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ResVec;
    use crate::jobs::test_support::test_job;

    /// Trivial slot scheduler: gives the first active job 2 workers + 1 PS
    /// on machine 0 whenever they fit.
    struct Greedy1;

    impl SlotScheduler for Greedy1 {
        fn name(&self) -> String {
            "greedy1".into()
        }

        fn allocate(
            &mut self,
            t: usize,
            active: &[ActiveJob],
            ledger: &AllocLedger,
        ) -> Vec<(usize, Vec<(usize, u64, u64)>)> {
            let mut out = Vec::new();
            if let Some(aj) = active.first() {
                let need = aj.job.demand(2, 1);
                if need.fits_within(&ledger.residual(t, 0), 1e-9) {
                    out.push((0, vec![(0, 2, 1)]));
                }
            }
            out
        }
    }

    #[test]
    fn slot_sim_completes_small_job() {
        let cluster = Cluster::homogeneous(1, ResVec::new([16.0, 32.0, 64.0, 32.0]));
        let mut job = test_job(0);
        job.epochs = 1;
        job.samples = 1000.0; // 2 workers train ~2000/slot at internal rate
        let res = run_slot_sim(&[job.clone()], &cluster, 10, &mut Greedy1);
        assert_eq!(res.admitted, 1);
        assert_eq!(res.completed, 1);
        let o = &res.outcomes[0];
        assert!(o.utility > 0.0);
        assert!(o.training_time < 10.0);
    }

    #[test]
    fn unfinished_job_earns_zero() {
        let cluster = Cluster::homogeneous(1, ResVec::new([16.0, 32.0, 64.0, 32.0]));
        let mut job = test_job(0);
        job.epochs = 100;
        job.samples = 500_000.0; // far too much for 2 workers in 5 slots
        let res = run_slot_sim(&[job.clone()], &cluster, 5, &mut Greedy1);
        assert_eq!(res.completed, 0);
        assert_eq!(res.outcomes[0].utility, 0.0);
        assert_eq!(res.outcomes[0].training_time, 5.0);
    }
}
