//! The event-driven simulation engine and the unified [`Scheduler`] trait.
//!
//! Every scheduling policy — arrival-driven (PD-ORS, OASiS: a job's
//! *entire* future schedule is fixed at its arrival, the paper's online
//! model) and slot-driven (FIFO / DRF / Dorm: placements decided slot by
//! slot over the active jobs) — implements the same object-safe
//! [`Scheduler`] trait:
//!
//! * [`Scheduler::on_arrival`] returns an [`ArrivalDecision`]: `Admit` a
//!   committed full [`Schedule`], `Reject` permanently, or `Defer` the job
//!   into the engine's active set for per-slot allocation;
//! * [`Scheduler::on_slot`] (only meaningful for deferring schedulers)
//!   grants this slot's placements over the active jobs.
//!
//! [`SimEngine`] drives one pass over the horizon, emits typed
//! [`SimEvent`]s (Begin, SlotStart, Arrival, Admitted/Rejected/Deferred,
//! Granted, Completed, HorizonEnd) to pluggable [`SimObserver`]s, and
//! aggregates a [`SimResult`] through the built-in
//! [`ResultCollector`](super::events::ResultCollector) observer. Utility
//! is credited only when a job's full workload `E_i K_i` completes within
//! the horizon (an unfinished job earns 0 and reports training time `T`,
//! as in Fig. 9).
//!
//! Schedulers are constructed by name through
//! [`crate::sched::registry`]; [`simulate`] is the one-call convenience
//! wrapper, [`SimEngine::builder`] the full API:
//!
//! ```text
//! let result = SimEngine::builder()
//!     .jobs(&jobs)
//!     .cluster(&cluster)
//!     .horizon(t)
//!     .observer(&mut trace)
//!     .build()
//!     .run(scheduler.as_mut());
//! ```

use crate::chaos::{ChurnEvent, ChurnSpec, ChurnTrace};
use crate::cluster::{AllocLedger, Cluster, NUM_RESOURCES};
use crate::jobs::{Job, Schedule};
use crate::obs::provenance::{self, DecisionTrace, PriceSample};
use crate::sched::replan::{run_migration_pass, run_replan_pass, ReplanPolicy};
use crate::sched::solver::SolverStats;

use super::admission::{AdmissionCore, AdmissionOutcome};
use super::events::{ResultCollector, SimEvent, SimObserver, SimResult};

/// The scheduler's verdict on one arriving job.
#[derive(Debug, Clone)]
pub enum ArrivalDecision {
    /// Admit with a full schedule the implementation has already
    /// committed to the ledger (arrival-driven policies).
    Admit(Schedule),
    /// Reject permanently.
    Reject,
    /// Defer into the engine's active set; the engine will offer the job
    /// to [`Scheduler::on_slot`] every slot until it completes
    /// (slot-driven policies).
    Defer,
}

/// Worker/PS machine-placement style of a policy (diagnostic; the
/// registry and CLI report it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Workers and PSs may share any machine (PD-ORS co-location).
    Colocated,
    /// PSs and workers on disjoint machine halves (OASiS).
    Separated,
    /// Placement chosen round-robin over machines (the slot-driven
    /// baselines).
    RoundRobin,
}

/// One slot grant: `(index into the active set, [(machine, workers, ps)])`.
pub type SlotGrant = (usize, Vec<(usize, u64, u64)>);

/// A deferred job that has arrived and still has workload left.
#[derive(Debug, Clone)]
pub struct ActiveJob {
    pub job: Job,
    pub remaining: f64,
}

/// The unified, object-safe scheduler interface. See the module docs for
/// the lifecycle; register implementations in [`crate::sched::registry`].
pub trait Scheduler {
    /// Display name (the series label in figures and tables).
    fn name(&self) -> String;

    /// Placement style, for diagnostics.
    fn placement_policy(&self) -> PlacementPolicy {
        PlacementPolicy::Colocated
    }

    /// Called exactly once per job, at its arrival slot. An `Admit`
    /// schedule must already be committed to `ledger` and satisfy
    /// Eqs. (2), (4), (7).
    fn on_arrival(&mut self, job: &Job, ledger: &mut AllocLedger) -> ArrivalDecision;

    /// Called each slot with the deferred active jobs (skipped while the
    /// active set is empty). Grants hold resources for this slot only;
    /// the engine commits them. Default: no grants.
    fn on_slot(
        &mut self,
        _t: usize,
        _active: &[ActiveJob],
        _ledger: &AllocLedger,
    ) -> Vec<SlotGrant> {
        Vec::new()
    }

    /// Cumulative solver counters (θ-solves, memo hits, LP pivots,
    /// rounding attempts). The engine polls this once at the end of a run
    /// and emits it as [`SimEvent::Solver`] so observers and the
    /// [`SimResult`] can surface it. Default: all zeros (policies that do
    /// not run the θ-solver pipeline).
    fn solver_stats(&self) -> SolverStats {
        SolverStats::default()
    }

    /// Elastic re-planning (see [`crate::sched::replan`]): can this policy
    /// re-solve not-yet-started jobs at slot boundaries? While this is
    /// `false` the replan pass is a strict no-op around this scheduler —
    /// no RNG draws, no events, no ledger traffic. Default: not capable.
    fn replan_capable(&self) -> bool {
        false
    }

    /// Re-solve one job from slot `t` against the current ledger. For an
    /// admitted job, `old` is its previous schedule — already *released*
    /// from `ledger` by the caller; for a deferred job offered a full
    /// admission, `old` is `None`. Return a new schedule **already
    /// committed to `ledger`** (the `on_arrival` contract) to adopt it, or
    /// `None` to keep the status quo (the caller re-commits `old`
    /// byte-for-byte). Only called when [`Scheduler::replan_capable`].
    fn replan_job(
        &mut self,
        _job: &Job,
        _old: Option<&Schedule>,
        _t: usize,
        _ledger: &mut AllocLedger,
    ) -> Option<Schedule> {
        None
    }

    /// Re-solve an interrupted admission's *residual* workload from slot
    /// `t` (machine churn took its old machines away; `job` is the
    /// residual-demand clone from
    /// [`InterruptedAdmission::residual_job`](crate::sim::InterruptedAdmission::residual_job)).
    /// Return a tail schedule **already committed to `ledger`** to
    /// migrate, or `None` if no feasible migration exists — the caller
    /// evicts the job. Only called when [`Scheduler::replan_capable`]
    /// would allow planning at all; default: no migration.
    fn migrate_job(
        &mut self,
        _job: &Job,
        _t: usize,
        _ledger: &mut AllocLedger,
    ) -> Option<Schedule> {
        None
    }

    /// Take the [`DecisionTrace`] of the most recent `on_arrival` call
    /// (take-once: the scheduler hands it over and forgets it). Pricing
    /// schedulers capture one per arrival; the engine synthesizes a
    /// `"policy"` fallback for everyone else, so the default is `None`.
    fn take_decision_trace(&mut self) -> Option<DecisionTrace> {
        None
    }

    /// The cluster's machine-mean dual price per resource at slot `t`, or
    /// `None` for policies with no price concept (the engine then skips
    /// the slot's [`SimEvent::PriceSample`]).
    fn price_sample(&self, _ledger: &AllocLedger, _t: usize) -> Option<[f64; NUM_RESOURCES]> {
        None
    }
}

/// Builder for [`SimEngine`]; `jobs`, `cluster`, and `horizon` are
/// required. `jobs` must be sorted by arrival slot (the workload
/// generators guarantee this).
#[derive(Default)]
pub struct SimEngineBuilder<'a> {
    jobs: Option<&'a [Job]>,
    cluster: Option<&'a Cluster>,
    horizon: Option<usize>,
    observers: Vec<&'a mut dyn SimObserver>,
    replan: ReplanPolicy,
    churn: ChurnSpec,
    churn_seed: u64,
    provenance: bool,
}

impl<'a> SimEngineBuilder<'a> {
    pub fn jobs(mut self, jobs: &'a [Job]) -> Self {
        self.jobs = Some(jobs);
        self
    }

    pub fn cluster(mut self, cluster: &'a Cluster) -> Self {
        self.cluster = Some(cluster);
        self
    }

    pub fn horizon(mut self, horizon: usize) -> Self {
        self.horizon = Some(horizon);
        self
    }

    /// Subscribe an observer to the engine's event stream. May be called
    /// repeatedly; observers are notified in subscription order.
    pub fn observer(mut self, obs: &'a mut dyn SimObserver) -> Self {
        self.observers.push(obs);
        self
    }

    /// Enable elastic re-planning rounds (default: [`ReplanPolicy::None`],
    /// which is byte-identical to an engine without the knob).
    pub fn replan(mut self, policy: ReplanPolicy) -> Self {
        self.replan = policy;
        self
    }

    /// Inject machine churn (default: [`ChurnSpec::None`], byte-identical
    /// to an engine without the knob). `seed` drives the churn trace's own
    /// RNG stream for seeded specs like `mtbf:40,mttr:8`; explicit event
    /// lists ignore it.
    pub fn churn(mut self, spec: ChurnSpec, seed: u64) -> Self {
        self.churn = spec;
        self.churn_seed = seed;
        self
    }

    /// Emit decision provenance ([`SimEvent::Decision`] per arrival,
    /// [`SimEvent::PriceSample`] per slot) for this run regardless of the
    /// global [`crate::obs::PROV`] flag. Default: off — the run also
    /// emits provenance when the global flag is set. Provenance is
    /// deterministically inert either way: zero RNG draws, no ledger
    /// traffic, byte-identical schedules and metrics.
    pub fn provenance(mut self, on: bool) -> Self {
        self.provenance = on;
        self
    }

    /// Panics if a required field is missing.
    pub fn build(self) -> SimEngine<'a> {
        SimEngine {
            jobs: self.jobs.expect("SimEngine::builder(): jobs(..) is required"),
            cluster: self.cluster.expect("SimEngine::builder(): cluster(..) is required"),
            horizon: self.horizon.expect("SimEngine::builder(): horizon(..) is required"),
            observers: self.observers,
            replan: self.replan,
            churn: self.churn,
            churn_seed: self.churn_seed,
            provenance: self.provenance,
        }
    }

    /// Build and run in one call.
    pub fn run(self, sched: &mut dyn Scheduler) -> SimResult {
        let mut engine = self.build();
        engine.run(sched)
    }
}

/// The time-slotted cluster simulator (see module docs).
pub struct SimEngine<'a> {
    jobs: &'a [Job],
    cluster: &'a Cluster,
    horizon: usize,
    observers: Vec<&'a mut dyn SimObserver>,
    replan: ReplanPolicy,
    churn: ChurnSpec,
    churn_seed: u64,
    provenance: bool,
}

impl<'a> SimEngine<'a> {
    pub fn builder() -> SimEngineBuilder<'a> {
        SimEngineBuilder::default()
    }

    fn emit(&mut self, collector: &mut ResultCollector, ev: SimEvent) {
        collector.on_event(&ev);
        for obs in self.observers.iter_mut() {
            obs.on_event(&ev);
        }
    }

    /// Handle one arrival through the shared [`AdmissionCore`]; returns
    /// the planned completion entry when an admitted schedule covers the
    /// workload.
    fn arrive(
        &mut self,
        collector: &mut ResultCollector,
        sched: &mut dyn Scheduler,
        core: &mut AdmissionCore,
        t: usize,
        job: &Job,
        prov: bool,
    ) -> Option<(usize, f64, f64, f64)> {
        self.emit(collector, SimEvent::Arrival { t, job_id: job.id });
        let (decision, outcome_ev, finish) = match core.submit(sched, job) {
            AdmissionOutcome::Admitted { completion, finish, .. } => (
                "admit",
                SimEvent::Admitted { t, job_id: job.id, completion },
                finish.map(|f| (f.slot, f.utility, f.training_time, f.ftf)),
            ),
            AdmissionOutcome::Rejected => {
                ("reject", SimEvent::Rejected { t, job_id: job.id }, None)
            }
            AdmissionOutcome::Deferred => {
                ("defer", SimEvent::Deferred { t, job_id: job.id }, None)
            }
        };
        self.emit(collector, outcome_ev);
        if prov {
            let mut trace = sched
                .take_decision_trace()
                .filter(|tr| tr.job_id == job.id)
                .unwrap_or_else(|| DecisionTrace::fallback(job.id, decision));
            trace.t = t;
            trace.decision = decision;
            self.emit(collector, SimEvent::Decision { trace });
        }
        finish
    }

    /// Run the scheduler over the job list and return the aggregated
    /// result (the attached observers see every event along the way).
    pub fn run(&mut self, sched: &mut dyn Scheduler) -> SimResult {
        let jobs = self.jobs;
        let horizon = self.horizon;
        let mut core = AdmissionCore::new(self.cluster, horizon);
        if self.replan.is_enabled() && sched.replan_capable() {
            core.set_replan_tracking(true);
        }
        // With `churn = none` the trace is `None` and the whole block below
        // — tracking, masks, migration — never runs: byte-identical to the
        // pre-churn engine.
        let trace = ChurnTrace::generate(&self.churn, self.cluster.len(), horizon, self.churn_seed);
        if trace.is_some() {
            core.set_churn_tracking(true);
        }
        // Evaluated once per run: the builder switch (per-cell in sweeps)
        // or the process-global flag. When false the provenance sites
        // below are dead branches — no events, no extra work.
        let prov = self.provenance || crate::obs::prov_on();
        let mut collector = ResultCollector::new();
        let mut next_arrival = 0usize;
        // arrival-driven completions, keyed by completion slot
        let mut pending: Vec<Vec<(usize, f64, f64, f64)>> = vec![Vec::new(); horizon];

        self.emit(&mut collector, SimEvent::Begin { jobs: jobs.len(), horizon });

        for t in 0..horizon {
            self.emit(
                &mut collector,
                SimEvent::SlotStart { t, active: core.active().len() },
            );

            // Price & utilization sample at the slot boundary, before any
            // churn/replan/arrival touches the ledger — the prices this
            // slot's arrivals will be charged against.
            if prov {
                if let Some(price) = sched.price_sample(core.ledger(), t) {
                    let sample = PriceSample {
                        t,
                        price,
                        max_price: price.iter().fold(0.0f64, |a, &b| a.max(b)),
                        utilization: provenance::utilization(core.ledger(), t),
                    };
                    self.emit(&mut collector, SimEvent::PriceSample { sample });
                }
            }

            // Machine churn: apply this slot's events to the availability
            // mask, then interrupt/migrate/evict admissions stranded on
            // hard-failed machines — all before the replan round and this
            // slot's arrivals, so both plan against surviving capacity.
            if let Some(tr) = &trace {
                let mut down_now: Vec<usize> = Vec::new();
                for &(h, ev) in tr.events_at(t) {
                    match ev {
                        ChurnEvent::Down => {
                            core.ledger_mut().set_available_from(h, t, false);
                            self.emit(
                                &mut collector,
                                SimEvent::MachineDown { t, machine: h, drain: false },
                            );
                            down_now.push(h);
                        }
                        ChurnEvent::Drain => {
                            core.ledger_mut().set_available_from(h, t, false);
                            self.emit(
                                &mut collector,
                                SimEvent::MachineDown { t, machine: h, drain: true },
                            );
                        }
                        ChurnEvent::Rejoin => {
                            core.ledger_mut().set_available_from(h, t, true);
                            self.emit(
                                &mut collector,
                                SimEvent::MachineRejoined { t, machine: h },
                            );
                        }
                    }
                }
                let report = run_migration_pass(&mut core, sched, t, &down_now);
                for r in &report.records {
                    if let Some(of) = r.old_finish {
                        if of.slot < horizon {
                            pending[of.slot].retain(|&(id, _, _, _)| id != r.job_id);
                        }
                    }
                    if r.evicted {
                        self.emit(&mut collector, SimEvent::Evicted { t, job_id: r.job_id });
                        continue;
                    }
                    if let Some(nf) = r.new_finish {
                        debug_assert!(nf.slot < horizon, "migrated beyond horizon");
                        if nf.slot < horizon {
                            pending[nf.slot].push((
                                r.job_id,
                                nf.utility,
                                nf.training_time,
                                nf.ftf,
                            ));
                        }
                    }
                    self.emit(
                        &mut collector,
                        SimEvent::Migrated {
                            t,
                            job_id: r.job_id,
                            old_completion: r.old_completion,
                            new_completion: r.new_completion,
                            old_utility: r.old_finish.map_or(0.0, |f| f.utility),
                            new_utility: r.new_finish.map_or(0.0, |f| f.utility),
                        },
                    );
                }
            }

            // Elastic re-planning: revisit not-yet-started commitments at
            // the slot boundary, before this slot's arrivals see prices.
            if self.replan.fires_at(t) {
                let report = run_replan_pass(&mut core, sched, t);
                for r in &report.records {
                    if let Some(of) = r.old_finish {
                        if of.slot < horizon {
                            pending[of.slot].retain(|&(id, _, _, _)| id != r.job_id);
                        }
                    }
                    if let Some(nf) = r.new_finish {
                        debug_assert!(nf.slot < horizon, "replanned beyond horizon");
                        if nf.slot < horizon {
                            pending[nf.slot].push((
                                r.job_id,
                                nf.utility,
                                nf.training_time,
                                nf.ftf,
                            ));
                        }
                    }
                    self.emit(
                        &mut collector,
                        SimEvent::Replanned {
                            t,
                            job_id: r.job_id,
                            promoted: r.promoted,
                            old_completion: r.old_completion,
                            new_completion: r.new_completion,
                            old_utility: r.old_utility,
                            new_utility: r.new_utility,
                        },
                    );
                }
            }

            while next_arrival < jobs.len() && jobs[next_arrival].arrival <= t {
                let job = &jobs[next_arrival];
                next_arrival += 1;
                if let Some((ct, utility, training_time, ftf)) =
                    self.arrive(&mut collector, sched, &mut core, t, job, prov)
                {
                    debug_assert!(ct < horizon, "committed schedule beyond horizon");
                    if ct < horizon {
                        pending[ct].push((job.id, utility, training_time, ftf));
                    }
                }
            }

            for g in core.run_slot(sched, t) {
                self.emit(
                    &mut collector,
                    SimEvent::Granted { t, job_id: g.job_id, workers: g.workers, ps: g.ps },
                );
                if let Some(f) = g.finish {
                    self.emit(
                        &mut collector,
                        SimEvent::Completed {
                            t,
                            job_id: g.job_id,
                            utility: f.utility,
                            training_time: f.training_time,
                            ftf: f.ftf,
                        },
                    );
                }
            }

            for (job_id, utility, training_time, ftf) in std::mem::take(&mut pending[t]) {
                self.emit(
                    &mut collector,
                    SimEvent::Completed { t, job_id, utility, training_time, ftf },
                );
            }
        }

        // Jobs arriving at or beyond the horizon still see their arrival
        // hook (parity with the retired arrival-driven runner: every job
        // gets exactly one on_arrival call).
        while next_arrival < jobs.len() {
            let job = &jobs[next_arrival];
            next_arrival += 1;
            let t = job.arrival;
            if let Some((ct, utility, training_time, ftf)) =
                self.arrive(&mut collector, sched, &mut core, t, job, prov)
            {
                self.emit(
                    &mut collector,
                    SimEvent::Completed { t: ct, job_id: job.id, utility, training_time, ftf },
                );
            }
        }

        self.emit(&mut collector, SimEvent::Solver { stats: sched.solver_stats() });
        self.emit(&mut collector, SimEvent::HorizonEnd { horizon });
        debug_assert!(core.ledger().within_capacity(1e-6));
        collector.into_result(sched.name())
    }
}

/// One-call convenience: run `sched` over `jobs` on `cluster` for
/// `horizon` slots with no extra observers.
pub fn simulate(
    jobs: &[Job],
    cluster: &Cluster,
    horizon: usize,
    sched: &mut dyn Scheduler,
) -> SimResult {
    let mut engine =
        SimEngine::builder().jobs(jobs).cluster(cluster).horizon(horizon).build();
    engine.run(sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ResVec;
    use crate::jobs::test_support::test_job;
    use crate::jobs::SlotPlacement;
    use crate::sim::events::TraceObserver;

    /// Trivial slot-driven scheduler: gives the first active job 2 workers
    /// + 1 PS on machine 0 whenever they fit.
    struct Greedy1;

    impl Scheduler for Greedy1 {
        fn name(&self) -> String {
            "greedy1".into()
        }

        fn placement_policy(&self) -> PlacementPolicy {
            PlacementPolicy::RoundRobin
        }

        fn on_arrival(&mut self, _job: &Job, _ledger: &mut AllocLedger) -> ArrivalDecision {
            ArrivalDecision::Defer
        }

        fn on_slot(
            &mut self,
            t: usize,
            active: &[ActiveJob],
            ledger: &AllocLedger,
        ) -> Vec<SlotGrant> {
            let mut out = Vec::new();
            if let Some(aj) = active.first() {
                let need = aj.job.demand(2, 1);
                if need.fits_within(&ledger.residual(t, 0), 1e-9) {
                    out.push((0, vec![(0, 2, 1)]));
                }
            }
            out
        }
    }

    /// Arrival-driven scheduler that admits everything with a one-slot
    /// schedule (covers nothing — admission bookkeeping only).
    struct AdmitAll;

    impl Scheduler for AdmitAll {
        fn name(&self) -> String {
            "admit-all".into()
        }

        fn on_arrival(&mut self, job: &Job, ledger: &mut AllocLedger) -> ArrivalDecision {
            let s = Schedule {
                job_id: job.id,
                slots: vec![SlotPlacement {
                    t: job.arrival,
                    placements: vec![(0, 1, 1)],
                }],
            };
            ledger.commit(job, &s);
            ArrivalDecision::Admit(s)
        }
    }

    #[test]
    fn slot_sim_completes_small_job() {
        let cluster = Cluster::homogeneous(1, ResVec::new([16.0, 32.0, 64.0, 32.0]));
        let mut job = test_job(0);
        job.epochs = 1;
        job.samples = 1000.0; // 2 workers train ~2000/slot at internal rate
        let res = simulate(&[job.clone()], &cluster, 10, &mut Greedy1);
        assert_eq!(res.scheduler, "greedy1");
        assert_eq!(res.admitted, 1);
        assert_eq!(res.completed, 1);
        let o = &res.outcomes[0];
        assert!(o.utility > 0.0);
        assert!(o.training_time < 10.0);
    }

    #[test]
    fn unfinished_job_earns_zero() {
        let cluster = Cluster::homogeneous(1, ResVec::new([16.0, 32.0, 64.0, 32.0]));
        let mut job = test_job(0);
        job.epochs = 100;
        job.samples = 500_000.0; // far too much for 2 workers in 5 slots
        let res = simulate(&[job.clone()], &cluster, 5, &mut Greedy1);
        assert_eq!(res.completed, 0);
        assert_eq!(res.outcomes[0].utility, 0.0);
        assert_eq!(res.outcomes[0].training_time, 5.0);
    }

    #[test]
    fn observers_see_the_event_stream_in_order() {
        let cluster = Cluster::homogeneous(1, ResVec::new([16.0, 32.0, 64.0, 32.0]));
        let mut job = test_job(0);
        job.epochs = 1;
        job.samples = 1000.0;
        let jobs = [job];
        let mut trace = TraceObserver::new();
        let res = SimEngine::builder()
            .jobs(&jobs)
            .cluster(&cluster)
            .horizon(10)
            .observer(&mut trace)
            .run(&mut Greedy1);
        assert_eq!(res.completed, 1);
        let lines = trace.lines();
        assert!(lines[0].starts_with("begin"), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("arrives")));
        assert!(lines.iter().any(|l| l.contains("granted")));
        assert!(lines.iter().any(|l| l.contains("completed")));
        assert!(lines.last().unwrap().contains("horizon end"));
        // arrival precedes grant precedes completion
        let pos = |pat: &str| lines.iter().position(|l| l.contains(pat)).unwrap();
        assert!(pos("arrives") < pos("granted"));
        assert!(pos("granted") <= pos("completed"));
    }

    #[test]
    fn arrival_driven_admission_is_recorded() {
        let cluster = Cluster::homogeneous(2, ResVec::new([16.0, 32.0, 64.0, 32.0]));
        let mut a = test_job(0);
        a.arrival = 1;
        a.samples = 1e9; // a one-slot, one-worker schedule cannot cover this
        let mut b = test_job(1);
        b.arrival = 3;
        b.samples = 1e9;
        let res = simulate(&[a, b], &cluster, 6, &mut AdmitAll);
        assert_eq!(res.admitted, 2);
        assert_eq!(res.completed, 0, "one-slot schedules cover nothing");
        assert_eq!(res.outcomes[0].completion, Some(1));
        assert_eq!(res.outcomes[1].completion, Some(3));
    }

    #[test]
    #[should_panic(expected = "cluster(..) is required")]
    fn builder_requires_cluster() {
        let jobs: Vec<Job> = Vec::new();
        let _ = SimEngine::builder().jobs(&jobs).horizon(5).build();
    }

    #[test]
    fn provenance_switch_synthesizes_fallback_traces() {
        let cluster = Cluster::homogeneous(1, ResVec::new([16.0, 32.0, 64.0, 32.0]));
        let mut job = test_job(0);
        job.epochs = 1;
        job.samples = 1000.0;
        let jobs = [job];

        // Off by default: no decisions, no price samples.
        let off = SimEngine::builder()
            .jobs(&jobs)
            .cluster(&cluster)
            .horizon(10)
            .run(&mut Greedy1);
        assert!(off.decisions.is_empty() && off.prices.is_empty());

        // On: one fallback trace per arrival (Greedy1 reports neither
        // traces nor prices, so the price series stays empty).
        let on = SimEngine::builder()
            .jobs(&jobs)
            .cluster(&cluster)
            .horizon(10)
            .provenance(true)
            .run(&mut Greedy1);
        assert_eq!(on.decisions.len(), 1);
        let tr = &on.decisions[0];
        assert_eq!((tr.job_id, tr.decision, tr.reason), (0, "defer", "policy"));
        assert!(on.prices.is_empty());

        // Provenance never perturbs the run itself.
        assert_eq!(off.admitted, on.admitted);
        assert_eq!(off.outcomes, on.outcomes);
    }
}
