//! [`AdmissionCore`] — the one admission/grant code path shared by the
//! batch simulator ([`SimEngine`](super::SimEngine)) and the online
//! service daemon ([`crate::service`]).
//!
//! The core owns the mutable scheduling state — the [`AllocLedger`] and
//! the deferred-job active set — and exposes exactly two operations:
//!
//! * [`AdmissionCore::submit`] — hand one arriving job to the scheduler
//!   and fold its [`ArrivalDecision`] into a typed [`AdmissionOutcome`]
//!   (including the planned completion credit for covered arrival-driven
//!   schedules);
//! * [`AdmissionCore::run_slot`] — finalize one slot for slot-driven
//!   policies: collect the scheduler's grants, validate and commit them,
//!   decrement remaining workloads, and report completions.
//!
//! The engine wraps these in its event stream; the daemon wraps them in
//! the wire protocol. Neither layer re-implements admission semantics, so
//! the acceptance parity contract ("the same arrival sequence through the
//! daemon and through `SimEngine` yields identical decisions") holds by
//! construction.

use crate::cluster::{AllocLedger, Cluster};
use crate::jobs::{speed, Job, Schedule, SlotPlacement};

use super::engine::{ActiveJob, ArrivalDecision, Scheduler};

/// A planned or realized completion: the slot it lands on plus the
/// utility/training-time credit the metrics track.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlannedFinish {
    pub slot: usize,
    pub utility: f64,
    pub training_time: f64,
    /// Finish-time fairness: `training_time` divided by the job's ideal
    /// isolated completion time ([`Job::min_completion_slots`]) — 1.0 is
    /// a perfectly fair share, larger is slower than isolation.
    pub ftf: f64,
}

/// The typed result of submitting one job.
#[derive(Debug, Clone)]
pub enum AdmissionOutcome {
    /// Admitted with a committed schedule. `completion` is the planned
    /// completion slot (if any worker slots exist); `finish` is the
    /// completion credit when the schedule covers the full workload.
    Admitted {
        schedule: Schedule,
        completion: Option<usize>,
        finish: Option<PlannedFinish>,
    },
    /// Rejected permanently.
    Rejected,
    /// Deferred into the active set for per-slot allocation.
    Deferred,
}

/// One committed slot grant, reported by [`AdmissionCore::run_slot`].
#[derive(Debug, Clone)]
pub struct GrantOutcome {
    pub job_id: usize,
    pub workers: u64,
    pub ps: u64,
    /// Set when this grant finished the job's workload.
    pub finish: Option<PlannedFinish>,
}

/// One admitted job the core tracks for elastic re-planning: the job, its
/// currently committed schedule, and the planned completion credit.
/// Recorded only while [`AdmissionCore::replan_tracking`] or
/// [`AdmissionCore::churn_tracking`] is on.
#[derive(Debug, Clone)]
pub struct TrackedAdmission {
    pub job: Job,
    pub schedule: Schedule,
    pub finish: Option<PlannedFinish>,
}

impl TrackedAdmission {
    /// Has the schedule already started running before slot `t`?
    pub fn started_before(&self, t: usize) -> bool {
        self.schedule.slots.first().is_some_and(|s| s.t < t)
    }

    /// Does the schedule place any work at slot `t` or later on one of the
    /// given machines? `machines` is the set that went *Down* this slot —
    /// drained machines keep their committed work, so the migration pass
    /// cannot use the ledger's availability mask (it cannot tell Down from
    /// Drain) and receives the hard-failure list explicitly.
    pub fn strands_on(&self, machines: &[usize], t: usize) -> bool {
        self.schedule.slots.iter().filter(|s| s.t >= t).any(|s| {
            s.placements
                .iter()
                .any(|&(h, w, ps)| (w > 0 || ps > 0) && machines.contains(&h))
        })
    }
}

/// A started admission interrupted by machine churn: the already-run
/// prefix stays committed (and credited); the released future is re-solved
/// from the residual workload — or the job is evicted if no feasible
/// migration exists.
#[derive(Debug, Clone)]
pub struct InterruptedAdmission {
    pub job: Job,
    /// Slots before the interruption boundary — work that already ran.
    /// Still committed in the ledger.
    pub kept: Schedule,
    /// Samples the kept prefix already trained.
    pub done: f64,
    /// The completion credit the admission carried before interruption.
    pub old_finish: Option<PlannedFinish>,
}

impl InterruptedAdmission {
    /// The residual job the migration re-solve plans for: the same
    /// identity, arrival, and utility (so completion credits stay anchored
    /// at the true arrival), with the workload reduced by what the kept
    /// prefix already trained.
    pub fn residual_job(&self) -> Job {
        let mut j = self.job.clone();
        j.epochs = 1;
        j.samples = (self.job.total_workload() - self.done).max(1e-6);
        j
    }
}

/// Total resource-time a committed schedule holds in the ledger (summed
/// over slots, machines, and resource kinds) — the conservation quantity
/// the release/re-commit primitives check in debug builds (the property
/// tests run unoptimized, so they exercise it; release daemons skip the
/// ledger sweeps).
#[cfg(debug_assertions)]
fn schedule_demand(job: &Job, s: &Schedule) -> f64 {
    s.slots
        .iter()
        .flat_map(|slot| slot.placements.iter())
        .map(|&(_, w, ps)| job.demand(w, ps).sum())
        .sum()
}

/// The planned completion credit of a committed schedule: set iff the
/// schedule covers the full workload and has at least one worker slot.
pub fn planned_finish(job: &Job, s: &Schedule) -> Option<PlannedFinish> {
    match (s.covers_workload(job, 1.0), s.completion_time()) {
        (true, Some(ct)) => {
            let training_time = (ct - job.arrival + 1) as f64;
            Some(PlannedFinish {
                slot: ct,
                utility: job.utility_at(ct),
                training_time,
                ftf: training_time / job.min_completion_slots(),
            })
        }
        _ => None,
    }
}

/// Shared admission/grant state (see module docs).
pub struct AdmissionCore {
    ledger: AllocLedger,
    active: Vec<ActiveJob>,
    horizon: usize,
    /// Record admitted `(job, schedule)` pairs for the replan pass. Off by
    /// default — with `replan = none` nothing is tracked and the core's
    /// behavior is byte-identical to the pre-replan system.
    track_replan: bool,
    /// Keep tracking admissions *after* they start running — the churn
    /// migration pass needs started schedules. Off by default (`churn =
    /// none`): started admissions are pruned exactly as PR 5 did.
    track_churn: bool,
    tracked: Vec<TrackedAdmission>,
}

impl AdmissionCore {
    pub fn new(cluster: &Cluster, horizon: usize) -> AdmissionCore {
        AdmissionCore {
            ledger: AllocLedger::new(cluster, horizon),
            active: Vec::new(),
            horizon,
            track_replan: false,
            track_churn: false,
            tracked: Vec::new(),
        }
    }

    pub fn horizon(&self) -> usize {
        self.horizon
    }

    pub fn ledger(&self) -> &AllocLedger {
        &self.ledger
    }

    /// Mutable ledger access for the replan primitives: the scheduler's
    /// `replan_job` commits a re-solved schedule here, exactly as
    /// `on_arrival` does through [`AdmissionCore::submit`]. Not for
    /// general mutation.
    pub fn ledger_mut(&mut self) -> &mut AllocLedger {
        &mut self.ledger
    }

    /// Deferred jobs still holding workload.
    pub fn active(&self) -> &[ActiveJob] {
        &self.active
    }

    /// Start (or stop) recording admitted schedules for re-planning.
    pub fn set_replan_tracking(&mut self, on: bool) {
        self.track_replan = on;
    }

    pub fn replan_tracking(&self) -> bool {
        self.track_replan
    }

    /// Start (or stop) tracking admissions across their start slot, for
    /// the churn migration pass. Implies admission tracking.
    pub fn set_churn_tracking(&mut self, on: bool) {
        self.track_churn = on;
    }

    pub fn churn_tracking(&self) -> bool {
        self.track_churn
    }

    /// Admitted jobs currently eligible for re-planning (tracked since
    /// tracking was enabled, minus pruned/started ones).
    pub fn tracked_admissions(&self) -> &[TrackedAdmission] {
        &self.tracked
    }

    /// Drop tracked admissions whose schedule has already begun (first
    /// slot before `t`) — their allocation can no longer move. Under churn
    /// tracking this is a no-op: started admissions must stay visible so a
    /// later machine failure can interrupt them (the replan pass skips
    /// them by [`TrackedAdmission::started_before`] instead).
    pub fn prune_started_admissions(&mut self, t: usize) {
        if self.track_churn {
            return;
        }
        self.tracked
            .retain(|e| e.schedule.slots.first().map_or(false, |s| s.t >= t));
    }

    /// Release tracked admission `i` from the ledger and remove it from
    /// the tracked set, returning it. Checks ledger conservation: the
    /// total drops by exactly the schedule's committed demand.
    pub fn release_tracked(&mut self, i: usize) -> TrackedAdmission {
        let entry = self.tracked.remove(i);
        #[cfg(debug_assertions)]
        let before = self.ledger.total_used();
        self.ledger.release(&entry.job, &entry.schedule);
        #[cfg(debug_assertions)]
        {
            let released = schedule_demand(&entry.job, &entry.schedule);
            let after = self.ledger.total_used();
            debug_assert!(
                (before - after - released).abs() <= 1e-6 * (1.0 + before.abs()),
                "ledger conservation violated on release: {before} -> {after}, \
                 schedule holds {released}"
            );
        }
        entry
    }

    /// Re-commit a previously released admission unchanged (the scheduler
    /// declined to re-plan), restoring the ledger and the tracked entry at
    /// position `i`.
    pub fn recommit_tracked(&mut self, i: usize, entry: TrackedAdmission) {
        #[cfg(debug_assertions)]
        let before = self.ledger.total_used();
        self.ledger.commit(&entry.job, &entry.schedule);
        #[cfg(debug_assertions)]
        {
            let committed = schedule_demand(&entry.job, &entry.schedule);
            let after = self.ledger.total_used();
            debug_assert!(
                (after - before - committed).abs() <= 1e-6 * (1.0 + after.abs()),
                "ledger conservation violated on re-commit"
            );
        }
        debug_assert!(
            self.ledger.within_capacity(1e-6),
            "re-committing a released schedule exceeded capacity"
        );
        self.tracked.insert(i, entry);
    }

    /// Track the re-solved schedule the scheduler committed for a released
    /// admission (insert back at position `i`); returns the new planned
    /// completion credit.
    pub fn adopt_replanned(
        &mut self,
        i: usize,
        job: Job,
        schedule: Schedule,
    ) -> Option<PlannedFinish> {
        debug_assert!(
            self.ledger.within_capacity(1e-6),
            "replanned schedule exceeded capacity"
        );
        debug_assert!(schedule.respects_arrival(&job));
        debug_assert!(schedule.respects_worker_cap(&job));
        let finish = planned_finish(&job, &schedule);
        self.tracked.insert(i, TrackedAdmission { job, schedule, finish });
        finish
    }

    /// Interrupt tracked admission `i` at slot `t` (machine churn): the
    /// entry leaves the tracked set, its future slots (≥ `t`) leave the
    /// ledger (with a conservation check), and the already-run prefix
    /// stays committed with its trained samples credited. This is the
    /// started-job extension of the PR 5 not-yet-started-only release
    /// rule: only the part of the schedule that has not run yet is ever
    /// released.
    pub fn interrupt_tracked(&mut self, i: usize, t: usize) -> InterruptedAdmission {
        let entry = self.tracked.remove(i);
        let mut kept = Schedule::empty(entry.job.id);
        let mut future = Schedule::empty(entry.job.id);
        for slot in entry.schedule.slots {
            if slot.t < t {
                kept.slots.push(slot);
            } else {
                future.slots.push(slot);
            }
        }
        #[cfg(debug_assertions)]
        let before = self.ledger.total_used();
        self.ledger.release(&entry.job, &future);
        #[cfg(debug_assertions)]
        {
            let released = schedule_demand(&entry.job, &future);
            let after = self.ledger.total_used();
            debug_assert!(
                (before - after - released).abs() <= 1e-6 * (1.0 + before.abs()),
                "ledger conservation violated on interrupt: {before} -> {after}, \
                 future slots hold {released}"
            );
        }
        let done = kept
            .slots
            .iter()
            .map(|s| speed::samples_in_slot(&entry.job, &s.placements))
            .sum();
        InterruptedAdmission { job: entry.job, kept, done, old_finish: entry.finish }
    }

    /// Track a migrated admission: splice the re-solved tail (already
    /// committed to the ledger by the scheduler's `migrate_job`) onto the
    /// interrupted prefix and re-insert the merged schedule at position
    /// `i`. Returns the new completion credit of the *whole* job — kept
    /// prefix plus tail — still anchored at the true arrival.
    pub fn commit_migrated(
        &mut self,
        i: usize,
        intr: InterruptedAdmission,
        tail: Schedule,
    ) -> Option<PlannedFinish> {
        debug_assert!(
            self.ledger.within_capacity(1e-6),
            "migrated schedule exceeded capacity"
        );
        let mut schedule = intr.kept;
        schedule.slots.extend(tail.slots);
        debug_assert!(schedule.respects_arrival(&intr.job));
        debug_assert!(schedule.respects_worker_cap(&intr.job));
        let finish = planned_finish(&intr.job, &schedule);
        self.tracked.insert(i, TrackedAdmission { job: intr.job, schedule, finish });
        finish
    }

    /// Promote deferred active job `d` to a full admission under
    /// `schedule` (already committed to the ledger by the scheduler);
    /// returns the planned completion credit. Callers must only promote
    /// jobs that have received no grants yet.
    pub fn promote_deferred(
        &mut self,
        d: usize,
        schedule: Schedule,
    ) -> Option<PlannedFinish> {
        let aj = self.active.remove(d);
        debug_assert!(
            (aj.remaining - aj.job.total_workload()).abs() <= 1e-9,
            "promoting a deferred job that already received grants"
        );
        debug_assert!(self.ledger.within_capacity(1e-6));
        let finish = planned_finish(&aj.job, &schedule);
        if self.track_replan || self.track_churn {
            self.tracked.push(TrackedAdmission { job: aj.job, schedule, finish });
        }
        finish
    }

    /// Submit one job to the scheduler (its arrival slot is `job.arrival`).
    pub fn submit(
        &mut self,
        sched: &mut dyn Scheduler,
        job: &Job,
    ) -> AdmissionOutcome {
        let _span = crate::obs::span(crate::obs::Stage::AdmissionCommit);
        match sched.on_arrival(job, &mut self.ledger) {
            ArrivalDecision::Admit(s) => {
                debug_assert!(s.respects_worker_cap(job));
                debug_assert!(s.respects_arrival(job));
                let completion = s.completion_time();
                let finish = planned_finish(job, &s);
                if self.track_replan || self.track_churn {
                    self.tracked.push(TrackedAdmission {
                        job: job.clone(),
                        schedule: s.clone(),
                        finish,
                    });
                }
                AdmissionOutcome::Admitted { schedule: s, completion, finish }
            }
            ArrivalDecision::Reject => AdmissionOutcome::Rejected,
            ArrivalDecision::Defer => {
                self.active
                    .push(ActiveJob { job: job.clone(), remaining: job.total_workload() });
                AdmissionOutcome::Deferred
            }
        }
    }

    /// Finalize slot `t` for the deferred active set: ask the scheduler
    /// for this slot's grants, validate and commit them, and report each
    /// grant (with its completion, if the job finished). A no-op returning
    /// no grants while the active set is empty — the scheduler is not
    /// consulted, preserving its state/RNG stream exactly as the engine
    /// always did.
    pub fn run_slot(&mut self, sched: &mut dyn Scheduler, t: usize) -> Vec<GrantOutcome> {
        if self.active.is_empty() {
            return Vec::new();
        }
        let grants = sched.on_slot(t, &self.active, &self.ledger);
        let mut out = Vec::new();
        let mut finished: Vec<usize> = Vec::new();
        for (idx, placements) in grants {
            if placements.is_empty() {
                continue;
            }
            // the trait is open to third-party implementations:
            // never trust grant indices blindly
            debug_assert!(idx < self.active.len(), "on_slot grant index out of range");
            if idx >= self.active.len() || finished.contains(&idx) {
                continue;
            }
            let slot = SlotPlacement { t, placements };
            let (job_id, workers, ps, arrival, done) = {
                let aj = &mut self.active[idx];
                debug_assert!(slot.total_workers() <= aj.job.batch, "Eq. (4) violated");
                let sched_one = Schedule { job_id: aj.job.id, slots: vec![slot.clone()] };
                debug_assert!(
                    self.ledger.fits(&aj.job, &sched_one, 1e-9),
                    "slot scheduler exceeded capacity"
                );
                self.ledger.commit(&aj.job, &sched_one);
                aj.remaining -= speed::samples_in_slot(&aj.job, &slot.placements);
                (
                    aj.job.id,
                    slot.total_workers(),
                    slot.total_ps(),
                    aj.job.arrival,
                    aj.remaining <= 1e-9,
                )
            };
            let finish = if done {
                finished.push(idx);
                let training_time = (t - arrival + 1) as f64;
                Some(PlannedFinish {
                    slot: t,
                    utility: self.active[idx].job.utility_at(t),
                    training_time,
                    ftf: training_time / self.active[idx].job.min_completion_slots(),
                })
            } else {
                None
            };
            out.push(GrantOutcome { job_id, workers, ps, finish });
        }
        finished.sort_unstable_by(|a, b| b.cmp(a));
        for idx in finished {
            self.active.swap_remove(idx);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ResVec;
    use crate::jobs::test_support::test_job;
    use crate::sim::engine::SlotGrant;

    /// Grants the first active job 2 workers + 1 PS on machine 0.
    struct Greedy;

    impl Scheduler for Greedy {
        fn name(&self) -> String {
            "greedy".into()
        }

        fn on_arrival(&mut self, _job: &Job, _ledger: &mut AllocLedger) -> ArrivalDecision {
            ArrivalDecision::Defer
        }

        fn on_slot(
            &mut self,
            _t: usize,
            active: &[ActiveJob],
            _ledger: &AllocLedger,
        ) -> Vec<SlotGrant> {
            if active.is_empty() {
                Vec::new()
            } else {
                vec![(0, vec![(0, 2, 1)])]
            }
        }
    }

    #[test]
    fn submit_defers_and_slots_complete_the_job() {
        let cluster = Cluster::homogeneous(1, ResVec::new([16.0, 32.0, 64.0, 32.0]));
        let mut core = AdmissionCore::new(&cluster, 10);
        let mut sched = Greedy;
        let mut job = test_job(0);
        job.epochs = 1;
        job.samples = 1000.0;
        assert!(matches!(core.submit(&mut sched, &job), AdmissionOutcome::Deferred));
        assert_eq!(core.active().len(), 1);
        let mut finish = None;
        for t in 0..10 {
            for g in core.run_slot(&mut sched, t) {
                assert_eq!(g.workers, 2);
                if let Some(f) = g.finish {
                    finish = Some(f);
                }
            }
            if finish.is_some() {
                break;
            }
        }
        let f = finish.expect("job should complete");
        assert!(f.utility > 0.0);
        assert!(core.active().is_empty());
        assert!(core.ledger().within_capacity(1e-9));
    }

    #[test]
    fn run_slot_skips_scheduler_when_idle() {
        struct Panicky;
        impl Scheduler for Panicky {
            fn name(&self) -> String {
                "panicky".into()
            }
            fn on_arrival(&mut self, _j: &Job, _l: &mut AllocLedger) -> ArrivalDecision {
                ArrivalDecision::Reject
            }
            fn on_slot(
                &mut self,
                _t: usize,
                _active: &[ActiveJob],
                _ledger: &AllocLedger,
            ) -> Vec<SlotGrant> {
                panic!("must not be consulted while idle");
            }
        }
        let cluster = Cluster::homogeneous(1, ResVec::new([16.0, 32.0, 64.0, 32.0]));
        let mut core = AdmissionCore::new(&cluster, 4);
        assert!(core.run_slot(&mut Panicky, 0).is_empty());
    }
}
