//! Metrics over simulation outcomes: derived statistics on a finished
//! [`SimResult`] plus [`StreamingMetrics`], an observer that keeps running
//! aggregates *while* the engine runs (no second pass over the outcomes).

use crate::sched::solver::SolverStats;
use crate::util::stats;

use super::events::{SimEvent, SimObserver, SimResult};

/// Fig. 9's metric: the median of per-job training times, with unfinished
/// jobs pinned to the horizon T (already encoded in `training_time`).
pub fn median_training_time(res: &SimResult) -> f64 {
    stats::median(&res.training_times())
}

/// Utility gain of `a` over `b`, normalized by `b` (Figs. 14–17 plot this
/// against OASiS).
pub fn utility_gain(a: &SimResult, b: &SimResult) -> f64 {
    if b.total_utility <= 0.0 {
        if a.total_utility > 0.0 {
            return 1.0;
        }
        return 0.0;
    }
    (a.total_utility - b.total_utility) / b.total_utility
}

/// Streaming aggregates folded from the live event stream. Attach with
/// [`SimEngineBuilder::observer`](super::SimEngineBuilder::observer); the
/// counters are valid at any point mid-run (e.g. for progress output)
/// and match the final [`SimResult`] at `HorizonEnd`.
#[derive(Debug, Default, Clone)]
pub struct StreamingMetrics {
    pub arrivals: usize,
    pub rejected: usize,
    /// Jobs admitted so far (arrival-driven admissions plus deferred jobs
    /// that received their first grant).
    pub admitted: usize,
    pub completed: usize,
    pub total_utility: f64,
    /// Per-slot grant events (a job granted in k slots counts k times).
    pub grants: usize,
    /// Plan changes adopted by elastic replan rounds.
    pub replanned: usize,
    /// Stranded admissions dropped by machine churn.
    pub evicted: usize,
    /// Stranded admissions re-solved onto surviving machines.
    pub migrated: usize,
    /// Solver counters (arrives once, at the end of the run).
    pub solver: SolverStats,
    granted_jobs: std::collections::BTreeSet<usize>,
    sum_ftf: f64,
}

impl StreamingMetrics {
    pub fn new() -> StreamingMetrics {
        StreamingMetrics::default()
    }

    /// Mean finish-time fairness over completions so far (0 before the
    /// first completion); matches [`SimResult::ftf`] at `HorizonEnd`.
    pub fn ftf(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.sum_ftf / self.completed as f64
        }
    }
}

impl SimObserver for StreamingMetrics {
    fn on_event(&mut self, ev: &SimEvent) {
        match *ev {
            SimEvent::Arrival { .. } => self.arrivals += 1,
            SimEvent::Rejected { .. } => self.rejected += 1,
            SimEvent::Admitted { .. } => self.admitted += 1,
            SimEvent::Granted { job_id, .. } => {
                self.grants += 1;
                if self.granted_jobs.insert(job_id) {
                    self.admitted += 1;
                }
            }
            SimEvent::Completed { utility, ftf, .. } => {
                self.completed += 1;
                self.total_utility += utility;
                self.sum_ftf += ftf;
            }
            SimEvent::Migrated { .. } => self.migrated += 1,
            SimEvent::Evicted { .. } => self.evicted += 1,
            SimEvent::Replanned { promoted, .. } => {
                self.replanned += 1;
                if promoted {
                    // a deferred job lifted to a full admission (it will
                    // never see a Granted event)
                    self.admitted += 1;
                }
            }
            SimEvent::Solver { stats } => self.solver = stats,
            SimEvent::Begin { .. }
            | SimEvent::SlotStart { .. }
            | SimEvent::Deferred { .. }
            | SimEvent::MachineDown { .. }
            | SimEvent::MachineRejoined { .. }
            | SimEvent::Decision { .. }
            | SimEvent::PriceSample { .. }
            | SimEvent::HorizonEnd { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::events::JobOutcome;

    fn res(utility: f64, times: &[f64]) -> SimResult {
        let outcomes: Vec<JobOutcome> = times
            .iter()
            .enumerate()
            .map(|(i, &t)| JobOutcome {
                job_id: i,
                admitted: true,
                completed: true,
                completion: Some(t as usize),
                utility: utility / times.len() as f64,
                training_time: t,
                ftf: 1.0,
            })
            .collect();
        SimResult {
            scheduler: "x".into(),
            total_utility: utility,
            admitted: times.len(),
            completed: times.len(),
            outcomes,
            replanned: 0,
            evicted: 0,
            migrated: 0,
            ftf: 1.0,
            solver: SolverStats::default(),
            decisions: Vec::new(),
            prices: Vec::new(),
        }
    }

    #[test]
    fn median_time() {
        let r = res(10.0, &[1.0, 5.0, 9.0]);
        assert_eq!(median_training_time(&r), 5.0);
    }

    #[test]
    fn gain() {
        let a = res(15.0, &[1.0]);
        let b = res(10.0, &[1.0]);
        assert!((utility_gain(&a, &b) - 0.5).abs() < 1e-12);
        let z = res(0.0, &[1.0]);
        assert_eq!(utility_gain(&a, &z), 1.0);
        assert_eq!(utility_gain(&z, &z), 0.0);
    }

    #[test]
    fn streaming_counters_fold_grants_once_per_job() {
        let mut m = StreamingMetrics::new();
        for ev in [
            SimEvent::Arrival { t: 0, job_id: 0 },
            SimEvent::Deferred { t: 0, job_id: 0 },
            SimEvent::Granted { t: 0, job_id: 0, workers: 2, ps: 1 },
            SimEvent::Granted { t: 1, job_id: 0, workers: 2, ps: 1 },
            SimEvent::Completed { t: 1, job_id: 0, utility: 3.0, training_time: 2.0, ftf: 2.0 },
            SimEvent::Arrival { t: 1, job_id: 1 },
            SimEvent::Rejected { t: 1, job_id: 1 },
        ] {
            m.on_event(&ev);
        }
        assert_eq!(m.arrivals, 2);
        assert_eq!(m.admitted, 1);
        assert_eq!(m.grants, 2);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.completed, 1);
        assert_eq!(m.total_utility, 3.0);
        assert_eq!(m.ftf(), 2.0);
        assert_eq!(m.evicted, 0);
        assert_eq!(m.migrated, 0);
    }
}
