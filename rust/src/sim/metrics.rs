//! Derived metrics over [`super::SimResult`]s.

use crate::util::stats;

use super::engine::SimResult;

/// Fig. 9's metric: the median of per-job training times, with unfinished
/// jobs pinned to the horizon T (already encoded in `training_time`).
pub fn median_training_time(res: &SimResult) -> f64 {
    stats::median(&res.training_times())
}

/// Utility gain of `a` over `b`, normalized by `b` (Figs. 14–17 plot this
/// against OASiS).
pub fn utility_gain(a: &SimResult, b: &SimResult) -> f64 {
    if b.total_utility <= 0.0 {
        if a.total_utility > 0.0 {
            return 1.0;
        }
        return 0.0;
    }
    (a.total_utility - b.total_utility) / b.total_utility
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::JobOutcome;

    fn res(utility: f64, times: &[f64]) -> SimResult {
        let outcomes: Vec<JobOutcome> = times
            .iter()
            .enumerate()
            .map(|(i, &t)| JobOutcome {
                job_id: i,
                admitted: true,
                completed: true,
                completion: Some(t as usize),
                utility: utility / times.len() as f64,
                training_time: t,
            })
            .collect();
        SimResult {
            scheduler: "x".into(),
            total_utility: utility,
            admitted: times.len(),
            completed: times.len(),
            outcomes,
        }
    }

    #[test]
    fn median_time() {
        let r = res(10.0, &[1.0, 5.0, 9.0]);
        assert_eq!(median_training_time(&r), 5.0);
    }

    #[test]
    fn gain() {
        let a = res(15.0, &[1.0]);
        let b = res(10.0, &[1.0]);
        assert!((utility_gain(&a, &b) - 0.5).abs() < 1e-12);
        let z = res(0.0, &[1.0]);
        assert_eq!(utility_gain(&a, &z), 1.0);
        assert_eq!(utility_gain(&z, &z), 0.0);
    }
}
