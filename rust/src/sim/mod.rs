//! Time-slotted cluster simulator — drives every figure of §5.

pub mod engine;
pub mod metrics;

pub use engine::{
    run_arrival_sim, run_slot_sim, ActiveJob, ArrivalScheduler, JobOutcome, SimResult,
    SlotScheduler,
};
pub use metrics::median_training_time;
