//! Time-slotted cluster simulator — drives every figure of §5.
//!
//! One event-driven [`SimEngine`] serves every policy through the unified
//! [`Scheduler`] trait (the former `ArrivalScheduler` / `SlotScheduler`
//! split is retired): arrival-driven implementations answer
//! [`Scheduler::on_arrival`] with a committed schedule, slot-driven ones
//! defer and answer [`Scheduler::on_slot`] per slot. The engine emits
//! typed [`SimEvent`]s to pluggable [`SimObserver`]s — result aggregation
//! ([`ResultCollector`]), streaming counters
//! ([`metrics::StreamingMetrics`]), and trace output ([`TraceObserver`])
//! are all observers over the same single pass.

pub mod admission;
pub mod engine;
pub mod events;
pub mod metrics;

pub use admission::{
    planned_finish, AdmissionCore, AdmissionOutcome, GrantOutcome,
    InterruptedAdmission, PlannedFinish, TrackedAdmission,
};
pub use engine::{
    simulate, ActiveJob, ArrivalDecision, PlacementPolicy, Scheduler, SimEngine,
    SimEngineBuilder, SlotGrant,
};
pub use events::{
    JobOutcome, ResultCollector, SimEvent, SimObserver, SimResult, TraceObserver,
};
pub use metrics::{median_training_time, StreamingMetrics};
