//! Typed simulation events and the observer plumbing.
//!
//! [`SimEngine`](super::SimEngine) emits a [`SimEvent`] stream while it
//! runs; anything implementing [`SimObserver`] can subscribe through the
//! engine builder. Result aggregation is itself an observer
//! ([`ResultCollector`] — the engine attaches one internally to produce
//! the [`SimResult`]), so streaming metrics and trace output come for free
//! without re-running the simulation: see [`TraceObserver`] here and
//! [`StreamingMetrics`](super::metrics::StreamingMetrics).

use std::collections::BTreeMap;

use crate::obs::provenance::{DecisionTrace, PriceSample};
use crate::sched::solver::SolverStats;

/// One typed simulation event. `t` is the slot index; `job_id` refers to
/// [`crate::jobs::Job::id`].
#[derive(Debug, Clone, PartialEq)]
pub enum SimEvent {
    /// Emitted once before the first slot.
    Begin { jobs: usize, horizon: usize },
    /// A new slot begins; `active` is the deferred-job queue length.
    SlotStart { t: usize, active: usize },
    /// A job reached its arrival slot and is handed to the scheduler.
    Arrival { t: usize, job_id: usize },
    /// An arrival-driven scheduler admitted the job with a full committed
    /// schedule; `completion` is its planned completion slot (if any
    /// worker slots exist).
    Admitted { t: usize, job_id: usize, completion: Option<usize> },
    /// The scheduler rejected the job permanently.
    Rejected { t: usize, job_id: usize },
    /// A slot-driven scheduler deferred the job into the active set.
    Deferred { t: usize, job_id: usize },
    /// A deferred job received workers/PSs for this slot.
    Granted { t: usize, job_id: usize, workers: u64, ps: u64 },
    /// An elastic replan round moved this job's plan (see
    /// [`crate::sched::replan`]): its future-slot allocation was released
    /// and re-solved against current prices. `promoted` marks a deferred
    /// job lifted to a full admission; the before/after planned utilities
    /// quantify what the move was worth.
    Replanned {
        t: usize,
        job_id: usize,
        promoted: bool,
        old_completion: Option<usize>,
        new_completion: Option<usize>,
        old_utility: f64,
        new_utility: f64,
    },
    /// A job finished its full workload `E_i K_i` at slot `t`. `ftf` is
    /// its finish-time fairness: training time over the job's ideal
    /// isolated completion time (1.0 = a perfectly fair share).
    Completed { t: usize, job_id: usize, utility: f64, training_time: f64, ftf: f64 },
    /// Machine churn took machine `machine` out of service from slot `t`.
    /// `drain` distinguishes a graceful drain (committed work runs out;
    /// nothing is interrupted) from a hard failure.
    MachineDown { t: usize, machine: usize, drain: bool },
    /// Machine `machine` rejoined the cluster at slot `t`.
    MachineRejoined { t: usize, machine: usize },
    /// A started admission stranded on a failed machine was migrated: its
    /// future slots were re-solved onto surviving machines (the already-run
    /// prefix stays put).
    Migrated {
        t: usize,
        job_id: usize,
        old_completion: Option<usize>,
        new_completion: Option<usize>,
        old_utility: f64,
        new_utility: f64,
    },
    /// A stranded admission had no feasible migration and was dropped.
    Evicted { t: usize, job_id: usize },
    /// Cumulative solver counters, polled from the scheduler and emitted
    /// once at the end of the run (right before [`SimEvent::HorizonEnd`]).
    Solver { stats: SolverStats },
    /// Decision provenance of one arrival (emitted right after the
    /// Admitted/Rejected/Deferred event, only when provenance is on).
    Decision { trace: DecisionTrace },
    /// Cluster price & utilization sample at a slot boundary (emitted
    /// right after [`SimEvent::SlotStart`], only when provenance is on
    /// and the scheduler prices).
    PriceSample { sample: PriceSample },
    /// Emitted once after the last slot (and the late-arrival flush).
    HorizonEnd { horizon: usize },
}

impl SimEvent {
    /// Stable short label of the event kind (Perfetto instant-event
    /// names, flight-recorder labels).
    pub fn kind(&self) -> &'static str {
        match self {
            SimEvent::Begin { .. } => "begin",
            SimEvent::SlotStart { .. } => "slot_start",
            SimEvent::Arrival { .. } => "arrival",
            SimEvent::Admitted { .. } => "admitted",
            SimEvent::Rejected { .. } => "rejected",
            SimEvent::Deferred { .. } => "deferred",
            SimEvent::Granted { .. } => "granted",
            SimEvent::Replanned { .. } => "replanned",
            SimEvent::Completed { .. } => "completed",
            SimEvent::MachineDown { .. } => "machine_down",
            SimEvent::MachineRejoined { .. } => "machine_rejoined",
            SimEvent::Migrated { .. } => "migrated",
            SimEvent::Evicted { .. } => "evicted",
            SimEvent::Solver { .. } => "solver",
            SimEvent::Decision { .. } => "decision",
            SimEvent::PriceSample { .. } => "price_sample",
            SimEvent::HorizonEnd { .. } => "horizon_end",
        }
    }
}

/// Observer of the engine's event stream. Attach via
/// [`SimEngineBuilder::observer`](super::SimEngineBuilder::observer).
pub trait SimObserver {
    fn on_event(&mut self, ev: &SimEvent);
}

/// Per-job outcome record.
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    pub job_id: usize,
    pub admitted: bool,
    pub completed: bool,
    pub completion: Option<usize>,
    pub utility: f64,
    /// Completion − arrival; horizon T when unfinished (Fig. 9 convention).
    pub training_time: f64,
    /// Finish-time fairness (training time / ideal isolated completion
    /// time); 0 while unfinished.
    pub ftf: f64,
}

/// Aggregate simulation result.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    pub scheduler: String,
    pub outcomes: Vec<JobOutcome>,
    pub total_utility: f64,
    pub admitted: usize,
    pub completed: usize,
    /// Jobs whose plan an elastic replan round changed (0 with
    /// `replan = none` — part of the parity contract).
    pub replanned: usize,
    /// Stranded admissions dropped by machine churn (0 with `churn = none`).
    pub evicted: usize,
    /// Stranded admissions successfully re-solved onto surviving machines.
    pub migrated: usize,
    /// Mean finish-time fairness over completed jobs (0 when none
    /// completed). 1.0 = every job finished as fast as it would have run
    /// in isolation; larger = slower.
    pub ftf: f64,
    /// Solver counters polled at the end of the run (all zeros for
    /// policies outside the θ-solver pipeline). Diagnostic only: runs
    /// that differ solely in caching legitimately differ here, so parity
    /// comparisons go through [`SimResult::parity_eq`].
    pub solver: SolverStats,
    /// Decision provenance, one trace per arrival — empty unless
    /// provenance was on for the run. Diagnostic only (excluded from
    /// [`SimResult::parity_eq`]).
    pub decisions: Vec<DecisionTrace>,
    /// Per-slot cluster price & utilization series — empty unless
    /// provenance was on and the scheduler prices. Diagnostic only
    /// (excluded from [`SimResult::parity_eq`]).
    pub prices: Vec<PriceSample>,
}

impl SimResult {
    pub fn from_outcomes(scheduler: String, outcomes: Vec<JobOutcome>) -> SimResult {
        let total_utility = outcomes.iter().map(|o| o.utility).sum();
        let admitted = outcomes.iter().filter(|o| o.admitted).count();
        let completed = outcomes.iter().filter(|o| o.completed).count();
        let ftf = if completed == 0 {
            0.0
        } else {
            outcomes.iter().filter(|o| o.completed).map(|o| o.ftf).sum::<f64>()
                / completed as f64
        };
        SimResult {
            scheduler,
            outcomes,
            total_utility,
            admitted,
            completed,
            replanned: 0,
            evicted: 0,
            migrated: 0,
            ftf,
            solver: SolverStats::default(),
            decisions: Vec::new(),
            prices: Vec::new(),
        }
    }

    /// Semantic equality: everything except the diagnostic solver
    /// counters. This is what "byte-identical schedules" means for the
    /// cached vs `--no-theta-cache` parity contract.
    pub fn parity_eq(&self, other: &SimResult) -> bool {
        self.scheduler == other.scheduler
            && self.outcomes == other.outcomes
            && self.total_utility == other.total_utility
            && self.admitted == other.admitted
            && self.completed == other.completed
            && self.replanned == other.replanned
            && self.evicted == other.evicted
            && self.migrated == other.migrated
            && self.ftf == other.ftf
    }

    pub fn training_times(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.training_time).collect()
    }
}

/// The observer that folds the event stream into a [`SimResult`]. The
/// engine always attaches one internally; it is public as the reference
/// aggregation and for replaying recorded event streams.
#[derive(Debug, Default)]
pub struct ResultCollector {
    horizon: usize,
    outcomes: BTreeMap<usize, JobOutcome>,
    replanned: usize,
    evicted: usize,
    migrated: usize,
    solver: SolverStats,
    decisions: Vec<DecisionTrace>,
    prices: Vec<PriceSample>,
}

impl ResultCollector {
    pub fn new() -> ResultCollector {
        ResultCollector::default()
    }

    /// Finish aggregation (outcomes ordered by job id).
    pub fn into_result(self, scheduler: String) -> SimResult {
        let mut res =
            SimResult::from_outcomes(scheduler, self.outcomes.into_values().collect());
        res.replanned = self.replanned;
        res.evicted = self.evicted;
        res.migrated = self.migrated;
        res.solver = self.solver;
        res.decisions = self.decisions;
        res.prices = self.prices;
        res
    }
}

impl SimObserver for ResultCollector {
    fn on_event(&mut self, ev: &SimEvent) {
        match *ev {
            SimEvent::Begin { horizon, .. } => self.horizon = horizon,
            SimEvent::Arrival { job_id, .. } => {
                self.outcomes.insert(
                    job_id,
                    JobOutcome {
                        job_id,
                        admitted: false,
                        completed: false,
                        completion: None,
                        utility: 0.0,
                        training_time: self.horizon as f64,
                        ftf: 0.0,
                    },
                );
            }
            SimEvent::Admitted { job_id, completion, .. } => {
                if let Some(o) = self.outcomes.get_mut(&job_id) {
                    o.admitted = true;
                    o.completion = completion;
                }
            }
            SimEvent::Granted { job_id, .. } => {
                if let Some(o) = self.outcomes.get_mut(&job_id) {
                    o.admitted = true;
                }
            }
            SimEvent::Replanned { job_id, new_completion, .. } => {
                self.replanned += 1;
                if let Some(o) = self.outcomes.get_mut(&job_id) {
                    o.admitted = true;
                    if new_completion.is_some() {
                        o.completion = new_completion;
                    }
                }
            }
            SimEvent::Completed { t, job_id, utility, training_time, ftf } => {
                if let Some(o) = self.outcomes.get_mut(&job_id) {
                    o.completed = true;
                    o.completion = Some(t);
                    o.utility = utility;
                    o.training_time = training_time;
                    o.ftf = ftf;
                }
            }
            SimEvent::Migrated { job_id, new_completion, .. } => {
                self.migrated += 1;
                if let Some(o) = self.outcomes.get_mut(&job_id) {
                    o.completion = new_completion;
                }
            }
            SimEvent::Evicted { job_id, .. } => {
                self.evicted += 1;
                if let Some(o) = self.outcomes.get_mut(&job_id) {
                    // the job will never finish: no planned completion, no
                    // credit, training time pinned to the horizon
                    o.completion = None;
                    o.utility = 0.0;
                    o.training_time = self.horizon as f64;
                    o.ftf = 0.0;
                }
            }
            SimEvent::Solver { stats } => self.solver = stats,
            SimEvent::Decision { trace } => self.decisions.push(trace),
            SimEvent::PriceSample { sample } => self.prices.push(sample),
            SimEvent::SlotStart { .. }
            | SimEvent::Rejected { .. }
            | SimEvent::Deferred { .. }
            | SimEvent::MachineDown { .. }
            | SimEvent::MachineRejoined { .. }
            | SimEvent::HorizonEnd { .. } => {}
        }
    }
}

/// Records the event stream as human-readable lines (the CLI's
/// `schedule --events` output; also handy in tests).
#[derive(Debug, Default)]
pub struct TraceObserver {
    lines: Vec<String>,
}

impl TraceObserver {
    pub fn new() -> TraceObserver {
        TraceObserver::default()
    }

    pub fn lines(&self) -> &[String] {
        &self.lines
    }
}

impl SimObserver for TraceObserver {
    fn on_event(&mut self, ev: &SimEvent) {
        let line = match *ev {
            SimEvent::Begin { jobs, horizon } => {
                format!("begin: {jobs} jobs over horizon {horizon}")
            }
            SimEvent::SlotStart { t, active } => {
                format!("t={t:3} slot start ({active} active)")
            }
            SimEvent::Arrival { t, job_id } => format!("t={t:3} job {job_id} arrives"),
            SimEvent::Admitted { t, job_id, completion } => match completion {
                Some(c) => format!("t={t:3} job {job_id} admitted, completes t={c}"),
                None => format!("t={t:3} job {job_id} admitted"),
            },
            SimEvent::Rejected { t, job_id } => format!("t={t:3} job {job_id} rejected"),
            SimEvent::Deferred { t, job_id } => format!("t={t:3} job {job_id} queued"),
            SimEvent::Granted { t, job_id, workers, ps } => {
                format!("t={t:3} job {job_id} granted {workers} workers / {ps} ps")
            }
            SimEvent::Replanned {
                t,
                job_id,
                promoted,
                old_completion,
                new_completion,
                old_utility,
                new_utility,
            } => {
                let what = if promoted { "promoted" } else { "replanned" };
                let fmt = |c: Option<usize>| {
                    c.map_or("-".to_string(), |x| x.to_string())
                };
                format!(
                    "t={t:3} job {job_id} {what}: completes t={} (was t={}), \
                     utility {new_utility:.2} (was {old_utility:.2})",
                    fmt(new_completion),
                    fmt(old_completion)
                )
            }
            SimEvent::Completed { t, job_id, utility, .. } => {
                format!("t={t:3} job {job_id} completed, utility {utility:.2}")
            }
            SimEvent::MachineDown { t, machine, drain } => {
                let how = if drain { "draining" } else { "DOWN" };
                format!("t={t:3} machine {machine} {how}")
            }
            SimEvent::MachineRejoined { t, machine } => {
                format!("t={t:3} machine {machine} rejoined")
            }
            SimEvent::Migrated {
                t,
                job_id,
                old_completion,
                new_completion,
                old_utility,
                new_utility,
            } => {
                let fmt = |c: Option<usize>| {
                    c.map_or("-".to_string(), |x| x.to_string())
                };
                format!(
                    "t={t:3} job {job_id} migrated: completes t={} (was t={}), \
                     utility {new_utility:.2} (was {old_utility:.2})",
                    fmt(new_completion),
                    fmt(old_completion)
                )
            }
            SimEvent::Evicted { t, job_id } => {
                format!("t={t:3} job {job_id} evicted (no feasible migration)")
            }
            SimEvent::Solver { stats } => format!(
                "solver: {} theta-solves, {} memo hits, {} lp solves, {} pivots, {} roundings",
                stats.theta_solves,
                stats.memo_hits,
                stats.lp_solves,
                stats.lp_pivots,
                stats.rounding_attempts
            ),
            SimEvent::Decision { trace } => trace.explain_line(),
            SimEvent::PriceSample { sample } => format!(
                "t={:3} prices: mean {:.3}, max {:.3}",
                sample.t,
                sample.mean_price(),
                sample.max_price
            ),
            SimEvent::HorizonEnd { horizon } => format!("horizon end (T={horizon})"),
        };
        self.lines.push(line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_folds_slot_driven_lifecycle() {
        let mut c = ResultCollector::new();
        for ev in [
            SimEvent::Begin { jobs: 2, horizon: 10 },
            SimEvent::Arrival { t: 0, job_id: 0 },
            SimEvent::Deferred { t: 0, job_id: 0 },
            SimEvent::Arrival { t: 1, job_id: 1 },
            SimEvent::Deferred { t: 1, job_id: 1 },
            SimEvent::Granted { t: 1, job_id: 0, workers: 2, ps: 1 },
            SimEvent::Completed { t: 3, job_id: 0, utility: 5.0, training_time: 4.0, ftf: 2.0 },
            SimEvent::HorizonEnd { horizon: 10 },
        ] {
            c.on_event(&ev);
        }
        let res = c.into_result("test".into());
        assert_eq!(res.outcomes.len(), 2);
        assert_eq!(res.admitted, 1);
        assert_eq!(res.completed, 1);
        assert_eq!(res.total_utility, 5.0);
        assert_eq!(res.outcomes[0].completion, Some(3));
        assert_eq!(res.outcomes[0].training_time, 4.0);
        // job 1 never ran: pinned to the horizon, zero utility
        assert!(!res.outcomes[1].admitted);
        assert_eq!(res.outcomes[1].training_time, 10.0);
    }

    #[test]
    fn collector_keeps_planned_completion_of_uncovered_admission() {
        // arrival-driven admission whose schedule does not cover the
        // workload: admitted, completion recorded, but never Completed
        let mut c = ResultCollector::new();
        for ev in [
            SimEvent::Begin { jobs: 1, horizon: 8 },
            SimEvent::Arrival { t: 2, job_id: 0 },
            SimEvent::Admitted { t: 2, job_id: 0, completion: Some(6) },
            SimEvent::HorizonEnd { horizon: 8 },
        ] {
            c.on_event(&ev);
        }
        let res = c.into_result("test".into());
        let o = &res.outcomes[0];
        assert!(o.admitted && !o.completed);
        assert_eq!(o.completion, Some(6));
        assert_eq!(o.utility, 0.0);
        assert_eq!(o.training_time, 8.0);
    }

    #[test]
    fn collector_folds_solver_stats() {
        let mut c = ResultCollector::new();
        let stats = SolverStats {
            theta_solves: 42,
            memo_hits: 17,
            lp_solves: 25,
            lp_pivots: 300,
            rounding_attempts: 80,
            ..Default::default()
        };
        for ev in [
            SimEvent::Begin { jobs: 0, horizon: 4 },
            SimEvent::Solver { stats },
            SimEvent::HorizonEnd { horizon: 4 },
        ] {
            c.on_event(&ev);
        }
        let res = c.into_result("test".into());
        assert_eq!(res.solver, stats);
        // parity_eq ignores the diagnostic counters
        let mut other = res.clone();
        other.solver = SolverStats::default();
        assert!(res.parity_eq(&other));
        assert_ne!(res, other);
    }

    #[test]
    fn collector_folds_provenance_events() {
        let mut c = ResultCollector::new();
        let trace = DecisionTrace::fallback(7, "reject");
        let sample = PriceSample {
            t: 2,
            price: [1.0, 0.5, 0.0, 0.25],
            max_price: 1.0,
            utilization: [0.5; 4],
        };
        for ev in [
            SimEvent::Begin { jobs: 1, horizon: 4 },
            SimEvent::PriceSample { sample },
            SimEvent::Arrival { t: 2, job_id: 7 },
            SimEvent::Rejected { t: 2, job_id: 7 },
            SimEvent::Decision { trace },
            SimEvent::HorizonEnd { horizon: 4 },
        ] {
            c.on_event(&ev);
        }
        let res = c.into_result("test".into());
        assert_eq!(res.decisions, vec![trace]);
        assert_eq!(res.prices, vec![sample]);
        // provenance stays out of the parity contract
        let mut bare = res.clone();
        bare.decisions.clear();
        bare.prices.clear();
        assert!(res.parity_eq(&bare));
    }

    #[test]
    fn trace_lines_are_readable() {
        let mut tr = TraceObserver::new();
        tr.on_event(&SimEvent::Arrival { t: 4, job_id: 9 });
        tr.on_event(&SimEvent::Granted { t: 4, job_id: 9, workers: 3, ps: 1 });
        assert!(tr.lines()[0].contains("job 9 arrives"));
        assert!(tr.lines()[1].contains("3 workers"));
    }
}
