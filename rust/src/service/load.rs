//! `dmlrs load` — a multi-connection open-loop load generator for the
//! admission daemon.
//!
//! Replays any [`WorkloadSpec`] against a running daemon: job `k` has a
//! *scheduled* send time of `start + k / rate` seconds, round-robin
//! across `connections` parallel client connections. Each connection
//! keeps one request in flight (size `--connections` for the target
//! concurrency), and latency is measured from the **scheduled** send
//! time, not the actual one — so when the daemon falls behind, the
//! backlog a request spent waiting for its connection shows up in the
//! reported percentiles instead of being silently omitted (the standard
//! open-loop correction for coordinated omission). The report carries
//! throughput plus p50/p95/p99 latency and serializes to
//! `BENCH_service.json`.
//!
//! A failed connection does **not** skew or abort the send schedule:
//! its unsent jobs move to a shared orphan list that healthy
//! connections drain after their own share (latency still measured from
//! the original scheduled send time), the failure is counted in
//! `conn_failures`, and only jobs no connection could deliver count as
//! `errors`.
//!
//! `--ticks` additionally replays the workload's slot boundaries as
//! `tick` requests (virtual-clock mode) — every arrival slot and the
//! remaining horizon, which makes the daemon traverse the exact arrival
//! sequence and slot schedule a `SimEngine` run would see; it requires a
//! single connection, since slot ordering across connections is
//! unordered by design. Tick replay is a parity tool, not a soak tool,
//! so there a connection failure stays fatal.

use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::err;
use crate::jobs::Job;
use crate::sweep::WorkloadSpec;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Json};
use crate::util::stats;

use super::protocol::Request;

/// Load-run parameters.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Daemon address, e.g. `127.0.0.1:7171`.
    pub addr: String,
    pub connections: usize,
    /// Target aggregate submission rate (jobs/sec) across all
    /// connections.
    pub rate: f64,
    /// The workload to replay (jobs drawn with `seed`).
    pub workload: WorkloadSpec,
    pub seed: u64,
    /// Replay slot boundaries as `tick` requests (requires
    /// `connections == 1`).
    pub ticks: bool,
    /// Send a `shutdown` request after the run (lets scripts drain the
    /// daemon without a separate client).
    pub shutdown: bool,
}

/// Aggregated load-run results (latencies in milliseconds).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub requests: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub deferred: usize,
    pub errors: usize,
    /// Connections that failed (connect or mid-run I/O); their jobs were
    /// resent on healthy connections.
    pub conn_failures: usize,
    pub connections: usize,
    pub target_rate: f64,
    pub achieved_rate: f64,
    pub elapsed_secs: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("bench", json::s("service_load")),
            ("requests", json::num(self.requests as f64)),
            ("admitted", json::num(self.admitted as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("deferred", json::num(self.deferred as f64)),
            ("errors", json::num(self.errors as f64)),
            ("conn_failures", json::num(self.conn_failures as f64)),
            ("connections", json::num(self.connections as f64)),
            ("target_rate", json::num(self.target_rate)),
            ("achieved_rate", json::num(self.achieved_rate)),
            ("elapsed_secs", json::num(self.elapsed_secs)),
            ("p50_ms", json::num(self.p50_ms)),
            ("p95_ms", json::num(self.p95_ms)),
            ("p99_ms", json::num(self.p99_ms)),
            ("p999_ms", json::num(self.p999_ms)),
            ("mean_ms", json::num(self.mean_ms)),
            ("max_ms", json::num(self.max_ms)),
        ])
    }

    /// Write the report as one JSON line (the `BENCH_service.json`
    /// artifact).
    pub fn write_bench(&self, path: &str) -> Result<()> {
        let mut line = self.to_json().to_string();
        line.push('\n');
        std::fs::write(path, line).map_err(|e| err!("{path}: {e}"))
    }
}

#[derive(Default)]
struct ConnStats {
    latencies_ms: Vec<f64>,
    admitted: usize,
    rejected: usize,
    deferred: usize,
    errors: usize,
}

impl ConnStats {
    /// Record one submit response; latency from the *scheduled* send
    /// time (see module docs).
    fn record(&mut self, target: Instant, resp: &str) {
        self.latencies_ms
            .push(Instant::now().duration_since(target).as_secs_f64() * 1e3);
        match Json::parse(resp.trim()) {
            Ok(v) if v.get("ok") == Some(&Json::Bool(true)) => {
                match v.get("decision").and_then(Json::as_str) {
                    Some("admitted") => self.admitted += 1,
                    Some("rejected") => self.rejected += 1,
                    Some("deferred") => self.deferred += 1,
                    _ => self.errors += 1,
                }
            }
            _ => self.errors += 1,
        }
    }

    fn absorb(&mut self, other: ConnStats) {
        self.latencies_ms.extend_from_slice(&other.latencies_ms);
        self.admitted += other.admitted;
        self.rejected += other.rejected;
        self.deferred += other.deferred;
        self.errors += other.errors;
    }
}

/// One NDJSON client connection.
struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).map_err(|e| err!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone().map_err(Error::from)?);
        Ok(Client { reader, stream })
    }

    fn roundtrip(&mut self, req: &Request) -> Result<String> {
        let mut line = req.to_line();
        line.push('\n');
        self.stream.write_all(line.as_bytes()).map_err(Error::from)?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp).map_err(Error::from)?;
        if resp.is_empty() {
            return Err(err!("daemon closed the connection"));
        }
        Ok(resp)
    }
}

/// Jobs whose connection died before they could be sent, waiting for a
/// healthy connection to pick them up (in scheduled order).
type Orphans = Mutex<Vec<(usize, Job)>>;

/// One client connection worker: submit its share of the jobs at their
/// scheduled send times, then drain any orphans stranded by failed
/// sibling connections (`ticks` only ever true for the single-connection
/// case, where failures stay fatal; `horizon` bounds the post-arrival
/// tick drain).
fn run_connection(
    addr: &str,
    jobs: &[(usize, &Job)],
    start: Instant,
    interval_secs: f64,
    ticks: bool,
    horizon: usize,
    orphans: &Orphans,
    conn_failures: &AtomicUsize,
) -> Result<ConnStats> {
    let mut st = ConnStats::default();
    let mut client = match Client::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            if ticks {
                return Err(e);
            }
            // the schedule survives this connection: every job it owned
            // waits for a healthy sibling
            conn_failures.fetch_add(1, Ordering::Relaxed);
            let mut o = orphans.lock().unwrap();
            o.extend(jobs.iter().map(|&(k, job)| (k, job.clone())));
            return Ok(st);
        }
    };
    let mut slot = 0usize;
    for (idx, &(k, job)) in jobs.iter().enumerate() {
        if ticks {
            while slot < job.arrival {
                client.roundtrip(&Request::Tick)?;
                slot += 1;
            }
        }
        let target = start + Duration::from_secs_f64(k as f64 * interval_secs);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        match client.roundtrip(&Request::Submit { job: job.clone() }) {
            Ok(resp) => st.record(target, &resp),
            Err(e) => {
                if ticks {
                    return Err(e);
                }
                conn_failures.fetch_add(1, Ordering::Relaxed);
                let mut o = orphans.lock().unwrap();
                o.extend(jobs[idx..].iter().map(|&(k, job)| (k, job.clone())));
                return Ok(st);
            }
        }
    }
    if ticks {
        // finalize the remaining slots so slot-driven schedulers run
        // their whole horizon before any --shutdown drain
        while slot < horizon {
            client.roundtrip(&Request::Tick)?;
            slot += 1;
        }
        return Ok(st);
    }
    // own share delivered: adopt jobs stranded by failed siblings
    loop {
        let next = orphans.lock().unwrap().pop();
        let Some((k, job)) = next else { break };
        let target = start + Duration::from_secs_f64(k as f64 * interval_secs);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        match client.roundtrip(&Request::Submit { job: job.clone() }) {
            Ok(resp) => st.record(target, &resp),
            Err(_) => {
                conn_failures.fetch_add(1, Ordering::Relaxed);
                orphans.lock().unwrap().push((k, job));
                break;
            }
        }
    }
    Ok(st)
}

/// Run the load generator (see module docs).
pub fn run_load(cfg: &LoadConfig) -> Result<LoadReport> {
    let connections = cfg.connections.max(1);
    if cfg.ticks && connections != 1 {
        return Err(err!(
            "--ticks replays slot boundaries in submission order and needs \
             --connections 1 (got {connections})"
        ));
    }
    if cfg.rate <= 0.0 || cfg.rate.is_nan() {
        return Err(err!("--rate must be positive (got {})", cfg.rate));
    }
    let jobs = cfg.workload.jobs(cfg.seed);
    if jobs.is_empty() {
        return Err(err!("the workload generated no jobs"));
    }
    let interval_secs = 1.0 / cfg.rate;

    // Round-robin job assignment, keeping each connection's share in
    // global submission order.
    let mut shares: Vec<Vec<(usize, &Job)>> = vec![Vec::new(); connections];
    for (k, job) in jobs.iter().enumerate() {
        shares[k % connections].push((k, job));
    }

    let horizon = cfg.workload.horizon;
    let orphans: Orphans = Mutex::new(Vec::new());
    let conn_failures = AtomicUsize::new(0);
    let start = Instant::now();
    let results: Vec<Result<ConnStats>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shares
            .iter()
            .map(|share| {
                scope.spawn(|| {
                    run_connection(
                        &cfg.addr,
                        share,
                        start,
                        interval_secs,
                        cfg.ticks,
                        horizon,
                        &orphans,
                        &conn_failures,
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(err!("load worker panicked"))))
            .collect()
    });

    let mut total = ConnStats::default();
    for r in results {
        total.absorb(r?);
    }

    // Last resort: every connection died with jobs still owed. One fresh
    // connection tries to deliver them; what it cannot becomes errors.
    let mut leftovers = orphans.into_inner().unwrap();
    if !leftovers.is_empty() {
        if let Ok(mut client) = Client::connect(&cfg.addr) {
            while let Some((k, job)) = leftovers.pop() {
                let target = start + Duration::from_secs_f64(k as f64 * interval_secs);
                match client.roundtrip(&Request::Submit { job }) {
                    Ok(resp) => total.record(target, &resp),
                    Err(_) => {
                        conn_failures.fetch_add(1, Ordering::Relaxed);
                        total.errors += 1;
                        break;
                    }
                }
            }
        } else {
            conn_failures.fetch_add(1, Ordering::Relaxed);
        }
        total.errors += leftovers.len();
    }
    let elapsed_secs = start.elapsed().as_secs_f64();

    if cfg.shutdown {
        let mut client = Client::connect(&cfg.addr)?;
        let _ = client.roundtrip(&Request::Shutdown);
    }

    let tail = stats::Summary::of(&total.latencies_ms);
    Ok(LoadReport {
        requests: total.latencies_ms.len(),
        admitted: total.admitted,
        rejected: total.rejected,
        deferred: total.deferred,
        errors: total.errors,
        conn_failures: conn_failures.into_inner(),
        connections,
        target_rate: cfg.rate,
        achieved_rate: if elapsed_secs > 0.0 {
            total.latencies_ms.len() as f64 / elapsed_secs
        } else {
            0.0
        },
        elapsed_secs,
        p50_ms: tail.p50,
        p95_ms: tail.p95,
        p99_ms: tail.p99,
        p999_ms: tail.p999,
        mean_ms: tail.mean,
        max_ms: tail.max,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_has_the_acceptance_fields() {
        let r = LoadReport {
            requests: 100,
            admitted: 60,
            rejected: 30,
            deferred: 10,
            errors: 0,
            conn_failures: 2,
            connections: 4,
            target_rate: 500.0,
            achieved_rate: 480.5,
            elapsed_secs: 0.21,
            p50_ms: 1.5,
            p95_ms: 4.0,
            p99_ms: 9.75,
            p999_ms: 11.5,
            mean_ms: 2.0,
            max_ms: 12.0,
        };
        let line = r.to_json().to_string();
        for field in ["\"bench\":\"service_load\"", "\"p50_ms\":1.5", "\"p95_ms\":4", "\"p99_ms\":9.75", "\"p999_ms\":11.5", "\"achieved_rate\":480.5", "\"requests\":100", "\"conn_failures\":2"] {
            assert!(line.contains(field), "{field} missing from {line}");
        }
    }

    #[test]
    fn ticks_require_one_connection() {
        let cfg = LoadConfig {
            addr: "127.0.0.1:1".into(),
            connections: 4,
            rate: 100.0,
            workload: WorkloadSpec::synthetic(5, 8, 0),
            seed: 1,
            ticks: true,
            shutdown: false,
        };
        assert!(run_load(&cfg).unwrap_err().to_string().contains("connections 1"));
    }

    #[test]
    fn dead_daemon_counts_failures_instead_of_panicking() {
        // nothing listens on a reserved port: every connection fails,
        // every job ends up an error, and the run still reports
        let cfg = LoadConfig {
            addr: "127.0.0.1:1".into(),
            connections: 3,
            rate: 100000.0,
            workload: WorkloadSpec::synthetic(6, 8, 0),
            seed: 1,
            ticks: false,
            shutdown: false,
        };
        let report = run_load(&cfg).unwrap();
        assert_eq!(report.requests, 0);
        assert_eq!(report.errors, 6, "all jobs undeliverable");
        assert!(report.conn_failures >= 3, "{}", report.conn_failures);
    }
}
