//! `dmlrs load` — a multi-connection open-loop load generator for the
//! admission daemon.
//!
//! Replays any [`WorkloadSpec`] against a running daemon: job `k` has a
//! *scheduled* send time of `start + k / rate` seconds, round-robin
//! across `connections` parallel client connections. Each connection
//! keeps one request in flight (size `--connections` for the target
//! concurrency), and latency is measured from the **scheduled** send
//! time, not the actual one — so when the daemon falls behind, the
//! backlog a request spent waiting for its connection shows up in the
//! reported percentiles instead of being silently omitted (the standard
//! open-loop correction for coordinated omission). The report carries
//! throughput plus p50/p95/p99 latency and serializes to
//! `BENCH_service.json`.
//!
//! `--ticks` additionally replays the workload's slot boundaries as
//! `tick` requests (virtual-clock mode) — every arrival slot and the
//! remaining horizon, which makes the daemon traverse the exact arrival
//! sequence and slot schedule a `SimEngine` run would see; it requires a
//! single connection, since slot ordering across connections is
//! unordered by design.

use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::err;
use crate::sweep::WorkloadSpec;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Json};
use crate::util::stats;

use super::protocol::Request;

/// Load-run parameters.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Daemon address, e.g. `127.0.0.1:7171`.
    pub addr: String,
    pub connections: usize,
    /// Target aggregate submission rate (jobs/sec) across all
    /// connections.
    pub rate: f64,
    /// The workload to replay (jobs drawn with `seed`).
    pub workload: WorkloadSpec,
    pub seed: u64,
    /// Replay slot boundaries as `tick` requests (requires
    /// `connections == 1`).
    pub ticks: bool,
    /// Send a `shutdown` request after the run (lets scripts drain the
    /// daemon without a separate client).
    pub shutdown: bool,
}

/// Aggregated load-run results (latencies in milliseconds).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub requests: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub deferred: usize,
    pub errors: usize,
    pub connections: usize,
    pub target_rate: f64,
    pub achieved_rate: f64,
    pub elapsed_secs: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("bench", json::s("service_load")),
            ("requests", json::num(self.requests as f64)),
            ("admitted", json::num(self.admitted as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("deferred", json::num(self.deferred as f64)),
            ("errors", json::num(self.errors as f64)),
            ("connections", json::num(self.connections as f64)),
            ("target_rate", json::num(self.target_rate)),
            ("achieved_rate", json::num(self.achieved_rate)),
            ("elapsed_secs", json::num(self.elapsed_secs)),
            ("p50_ms", json::num(self.p50_ms)),
            ("p95_ms", json::num(self.p95_ms)),
            ("p99_ms", json::num(self.p99_ms)),
            ("p999_ms", json::num(self.p999_ms)),
            ("mean_ms", json::num(self.mean_ms)),
            ("max_ms", json::num(self.max_ms)),
        ])
    }

    /// Write the report as one JSON line (the `BENCH_service.json`
    /// artifact).
    pub fn write_bench(&self, path: &str) -> Result<()> {
        let mut line = self.to_json().to_string();
        line.push('\n');
        std::fs::write(path, line).map_err(|e| err!("{path}: {e}"))
    }
}

struct ConnStats {
    latencies_ms: Vec<f64>,
    admitted: usize,
    rejected: usize,
    deferred: usize,
    errors: usize,
}

/// One client connection worker: submit its share of the jobs at their
/// scheduled send times (`ticks` only ever true for the single-connection
/// case; `horizon` bounds the post-arrival tick drain).
fn run_connection(
    addr: &str,
    jobs: &[(usize, &crate::jobs::Job)],
    start: Instant,
    interval_secs: f64,
    ticks: bool,
    horizon: usize,
) -> Result<ConnStats> {
    let stream = TcpStream::connect(addr).map_err(|e| err!("connect {addr}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone().map_err(Error::from)?);
    let mut stream = stream;
    let mut st = ConnStats {
        latencies_ms: Vec::with_capacity(jobs.len()),
        admitted: 0,
        rejected: 0,
        deferred: 0,
        errors: 0,
    };
    let roundtrip = |stream: &mut TcpStream,
                     reader: &mut BufReader<TcpStream>,
                     req: &Request|
     -> Result<String> {
        let mut line = req.to_line();
        line.push('\n');
        stream.write_all(line.as_bytes()).map_err(Error::from)?;
        let mut resp = String::new();
        reader.read_line(&mut resp).map_err(Error::from)?;
        if resp.is_empty() {
            return Err(err!("daemon closed the connection"));
        }
        Ok(resp)
    };
    let mut slot = 0usize;
    for &(k, job) in jobs {
        if ticks {
            while slot < job.arrival {
                roundtrip(&mut stream, &mut reader, &Request::Tick)?;
                slot += 1;
            }
        }
        let target = start + Duration::from_secs_f64(k as f64 * interval_secs);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let resp = roundtrip(&mut stream, &mut reader, &Request::Submit { job: job.clone() })?;
        // latency from the *scheduled* send time: a request that had to
        // wait for its connection reports that wait (see module docs)
        st.latencies_ms
            .push(Instant::now().duration_since(target).as_secs_f64() * 1e3);
        match Json::parse(resp.trim()) {
            Ok(v) if v.get("ok") == Some(&Json::Bool(true)) => {
                match v.get("decision").and_then(Json::as_str) {
                    Some("admitted") => st.admitted += 1,
                    Some("rejected") => st.rejected += 1,
                    Some("deferred") => st.deferred += 1,
                    _ => st.errors += 1,
                }
            }
            _ => st.errors += 1,
        }
    }
    if ticks {
        // finalize the remaining slots so slot-driven schedulers run
        // their whole horizon before any --shutdown drain
        while slot < horizon {
            roundtrip(&mut stream, &mut reader, &Request::Tick)?;
            slot += 1;
        }
    }
    Ok(st)
}

/// Run the load generator (see module docs).
pub fn run_load(cfg: &LoadConfig) -> Result<LoadReport> {
    let connections = cfg.connections.max(1);
    if cfg.ticks && connections != 1 {
        return Err(err!(
            "--ticks replays slot boundaries in submission order and needs \
             --connections 1 (got {connections})"
        ));
    }
    if cfg.rate <= 0.0 || cfg.rate.is_nan() {
        return Err(err!("--rate must be positive (got {})", cfg.rate));
    }
    let jobs = cfg.workload.jobs(cfg.seed);
    if jobs.is_empty() {
        return Err(err!("the workload generated no jobs"));
    }
    let interval_secs = 1.0 / cfg.rate;

    // Round-robin job assignment, keeping each connection's share in
    // global submission order.
    let mut shares: Vec<Vec<(usize, &crate::jobs::Job)>> = vec![Vec::new(); connections];
    for (k, job) in jobs.iter().enumerate() {
        shares[k % connections].push((k, job));
    }

    let horizon = cfg.workload.horizon;
    let start = Instant::now();
    let results: Vec<Result<ConnStats>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shares
            .iter()
            .map(|share| {
                scope.spawn(|| {
                    run_connection(&cfg.addr, share, start, interval_secs, cfg.ticks, horizon)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(err!("load worker panicked"))))
            .collect()
    });
    let elapsed_secs = start.elapsed().as_secs_f64();

    let mut latencies: Vec<f64> = Vec::with_capacity(jobs.len());
    let mut admitted = 0;
    let mut rejected = 0;
    let mut deferred = 0;
    let mut errors = 0;
    for r in results {
        let st = r?;
        latencies.extend_from_slice(&st.latencies_ms);
        admitted += st.admitted;
        rejected += st.rejected;
        deferred += st.deferred;
        errors += st.errors;
    }

    if cfg.shutdown {
        let stream =
            TcpStream::connect(&cfg.addr).map_err(|e| err!("connect {}: {e}", cfg.addr))?;
        let mut reader = BufReader::new(stream.try_clone().map_err(Error::from)?);
        let mut stream = stream;
        let mut line = Request::Shutdown.to_line();
        line.push('\n');
        stream.write_all(line.as_bytes()).map_err(Error::from)?;
        let mut resp = String::new();
        let _ = reader.read_line(&mut resp);
    }

    let tail = stats::Summary::of(&latencies);
    Ok(LoadReport {
        requests: latencies.len(),
        admitted,
        rejected,
        deferred,
        errors,
        connections,
        target_rate: cfg.rate,
        achieved_rate: if elapsed_secs > 0.0 {
            latencies.len() as f64 / elapsed_secs
        } else {
            0.0
        },
        elapsed_secs,
        p50_ms: tail.p50,
        p95_ms: tail.p95,
        p99_ms: tail.p99,
        p999_ms: tail.p999,
        mean_ms: tail.mean,
        max_ms: tail.max,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_has_the_acceptance_fields() {
        let r = LoadReport {
            requests: 100,
            admitted: 60,
            rejected: 30,
            deferred: 10,
            errors: 0,
            connections: 4,
            target_rate: 500.0,
            achieved_rate: 480.5,
            elapsed_secs: 0.21,
            p50_ms: 1.5,
            p95_ms: 4.0,
            p99_ms: 9.75,
            p999_ms: 11.5,
            mean_ms: 2.0,
            max_ms: 12.0,
        };
        let line = r.to_json().to_string();
        for field in ["\"bench\":\"service_load\"", "\"p50_ms\":1.5", "\"p95_ms\":4", "\"p99_ms\":9.75", "\"p999_ms\":11.5", "\"achieved_rate\":480.5", "\"requests\":100"] {
            assert!(line.contains(field), "{field} missing from {line}");
        }
    }

    #[test]
    fn ticks_require_one_connection() {
        let cfg = LoadConfig {
            addr: "127.0.0.1:1".into(),
            connections: 4,
            rate: 100.0,
            workload: WorkloadSpec::synthetic(5, 8, 0),
            seed: 1,
            ticks: true,
            shutdown: false,
        };
        assert!(run_load(&cfg).unwrap_err().to_string().contains("connections 1"));
    }
}
