//! [`ServiceCore`] — the single-threaded scheduler core of the admission
//! daemon.
//!
//! One `ServiceCore` owns the boxed [`Scheduler`] (and therefore the
//! PD-ORS `PlannerScratch`) plus the shared
//! [`AdmissionCore`](crate::sim::AdmissionCore), a virtual slot clock,
//! running service metrics, and the optional [`OpLog`]. All of it is
//! mutated from exactly one thread — the daemon's scheduler-core thread —
//! so the PR-3 determinism contract holds: no locking anywhere inside the
//! solve path.
//!
//! The same type is the recovery engine: [`ServiceCore::recover`] replays
//! an op-log through a freshly built core, verifying the recorded
//! decisions as it goes, and resumes appending to the same log.

use std::collections::BTreeMap;

use crate::chaos::{ChurnEvent, ChurnSpec, ChurnTrace};
use crate::err;
use crate::jobs::Job;
use crate::obs;
use crate::obs::provenance::DecisionTrace;
use crate::sched::registry::{SchedulerRegistry, SchedulerSpec};
use crate::sched::replan::{run_migration_pass, run_replan_pass, ReplanReport};
use crate::sched::solver::SolverStats;
use crate::sim::{AdmissionCore, AdmissionOutcome, PlannedFinish, Scheduler};
use crate::sweep::{ClusterSpec, WorkloadSpec};
use crate::util::error::{Error, Result};
use crate::util::json::{self, Json};
use crate::util::stats;
use crate::util::timer::Timer;

use super::codec;
use super::oplog::{Op, OpLog};
use super::protocol::{err_response, ok_response, Request};

/// What the daemon serves: a registry scheduler over a cluster, with a
/// pricing population drawn from `workload` (the same `(jobs, cluster,
/// horizon)` triple a simulation cell would use, so daemon and simulator
/// build identical schedulers). The service horizon is
/// `workload.horizon`.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub scheduler: SchedulerSpec,
    pub cluster: ClusterSpec,
    pub workload: WorkloadSpec,
    /// Machine churn injected while serving (see [`crate::chaos`]).
    /// `ChurnSpec::None` (the default) is a strict no-op, and the wire
    /// `machine_down`/`machine_up` ops are refused so untracked started
    /// jobs can never be stranded silently.
    pub churn: ChurnSpec,
}

impl ServiceConfig {
    pub fn horizon(&self) -> usize {
        self.workload.horizon
    }

    /// The op-log header identifying this configuration. The `replan`
    /// field appears only when the cadence is enabled, so logs written by
    /// pre-replan daemons still replay under a `replan = none` config.
    pub fn header_json(&self) -> Json {
        let mut fields = vec![
            ("scheduler", json::s(&self.scheduler.name)),
            ("seed", json::num(self.scheduler.seed as f64)),
            ("cluster", json::s(&self.cluster.key())),
            ("workload", json::s(&self.workload.key())),
            ("horizon", json::num(self.horizon() as f64)),
        ];
        if self.scheduler.replan.is_enabled() {
            fields.push(("replan", json::s(&self.scheduler.replan.label())));
        }
        if self.churn.is_enabled() {
            fields.push(("churn", json::s(&self.churn.label())));
        }
        json::obj(fields)
    }
}

/// A cell's identity inside a sharded service: this core is shard
/// `index` of `stride`, owning machines `[machine_base, machine_base +
/// cluster.len())` of the whole cluster. Job ids are *interleaved*
/// across cells — `global = local * stride + index` — so each cell still
/// assigns sequential local ids (what the op-log replay contract
/// verifies) while global ids stay unique service-wide and the owning
/// cell of any global id is just `id % stride`. The default is the
/// identity cell: one shard, global == local, base 0 — byte-identical to
/// the pre-sharding core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellId {
    pub index: usize,
    pub stride: usize,
    pub machine_base: usize,
}

impl Default for CellId {
    fn default() -> CellId {
        CellId { index: 0, stride: 1, machine_base: 0 }
    }
}

impl CellId {
    /// The global id of this cell's `local`-th job.
    pub fn global_job_id(&self, local: usize) -> usize {
        local * self.stride + self.index
    }

    /// The global machine id of this cell's local machine `h` — or, via
    /// [`CellId::local_machine`], the inverse.
    pub fn global_machine(&self, local: usize) -> usize {
        local + self.machine_base
    }

    /// The cell-local index of a global machine id, if this cell (with
    /// `len` machines) owns it.
    pub fn local_machine(&self, global: usize, len: usize) -> Option<usize> {
        global.checked_sub(self.machine_base).filter(|&l| l < len)
    }
}

/// Deterministic end-of-run state snapshot: everything the recovery
/// contract promises to reproduce byte-identically (ledger allocations,
/// counters, solver stats — not wall-clock latencies).
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceReport {
    pub slot: usize,
    pub ended: bool,
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub deferred: usize,
    pub completed: usize,
    /// Plan changes adopted by elastic replan rounds (policy-driven and
    /// wire-triggered).
    pub replanned: usize,
    /// Started admissions dropped by churn (trace-driven and
    /// wire-triggered machine failures).
    pub evicted: usize,
    /// Started admissions re-solved onto surviving machines.
    pub migrated: usize,
    /// Mean finish-time fairness over completed jobs (0 when none
    /// completed).
    pub ftf: f64,
    pub total_utility: f64,
    /// Full ledger dump: `alloc[t][h]` = the four committed resource
    /// amounts.
    pub alloc: Vec<Vec<[f64; crate::cluster::NUM_RESOURCES]>>,
    pub solver: SolverStats,
}

/// The daemon's scheduler-core state (see module docs).
pub struct ServiceCore {
    cfg: ServiceConfig,
    cluster: crate::cluster::Cluster,
    sched: Box<dyn Scheduler>,
    core: AdmissionCore,
    slot: usize,
    ended: bool,
    next_id: usize,
    submitted: usize,
    admitted: usize,
    rejected: usize,
    deferred: usize,
    completed: usize,
    total_utility: f64,
    /// Planned completions of covered arrival-driven admissions, keyed by
    /// completion slot (credited when the clock passes the slot, exactly
    /// like the engine's pending table). Entries carry the job id so a
    /// replan round can move them between slots.
    pending: Vec<Vec<(usize, PlannedFinish)>>,
    /// Elastic replan rounds run (policy ticks + wire ops).
    replan_rounds: usize,
    /// Plan changes adopted across all rounds.
    replanned_total: usize,
    /// Materialized churn realization (`None` when churn is disabled —
    /// the strict no-op path).
    churn_trace: Option<ChurnTrace>,
    /// Started admissions dropped by machine failures.
    evicted: usize,
    /// Started admissions re-solved onto surviving machines.
    migrated: usize,
    /// Finish-time fairness accumulator over completed jobs.
    sum_ftf: f64,
    /// Core-side decision latency per submit, in microseconds.
    latencies_us: Vec<f64>,
    /// Decision provenance, one trace per submitted job (the `explain`
    /// wire op's store). Pure derived bookkeeping like `latencies_us`:
    /// never consulted by the scheduling path, rebuilt identically by
    /// op-log replay.
    traces: BTreeMap<usize, DecisionTrace>,
    /// `(decision, reason)` → count, fed to `metrics`/`metrics_prom` as
    /// `dmlrs_decisions_total{decision,reason}`.
    decision_counts: BTreeMap<(&'static str, &'static str), u64>,
    started: Timer,
    log: Option<OpLog>,
    /// This core's place in a sharded service (identity when unsharded).
    cell: CellId,
}

impl ServiceCore {
    /// Build a fresh core: generate the pricing population, build the
    /// cluster and the named scheduler, start at slot 0.
    pub fn new(cfg: ServiceConfig) -> Result<ServiceCore> {
        let horizon = cfg.horizon();
        if horizon == 0 {
            return Err(err!("service horizon must be positive"));
        }
        let jobs = cfg.workload.jobs(cfg.scheduler.seed);
        let cluster = cfg.cluster.build();
        let sched =
            SchedulerRegistry::builtin().build(&cfg.scheduler, &jobs, &cluster, horizon)?;
        let mut core = AdmissionCore::new(&cluster, horizon);
        // Track admissions only when a replan cadence is configured AND
        // the policy can re-plan (the engine's gating): tracking clones
        // every admitted job+schedule, and without rounds nothing would
        // ever prune the list — a daemon serving open-loop load must not
        // grow it forever.
        if cfg.scheduler.replan.is_enabled() && sched.replan_capable() {
            core.set_replan_tracking(true);
        }
        // Churn tracking mirrors the engine: enabled exactly when a trace
        // exists, so `churn = none` keeps the tracked-admission list (and
        // every byte of ledger state) identical to a churn-less build.
        // The daemon's horizon is finite, so the unpruned list is bounded.
        let churn_trace =
            ChurnTrace::generate(&cfg.churn, cluster.len(), horizon, cfg.scheduler.seed);
        if churn_trace.is_some() {
            core.set_churn_tracking(true);
        }
        let mut svc = ServiceCore {
            cfg,
            cluster,
            sched,
            core,
            slot: 0,
            ended: false,
            next_id: 0,
            submitted: 0,
            admitted: 0,
            rejected: 0,
            deferred: 0,
            completed: 0,
            total_utility: 0.0,
            pending: vec![Vec::new(); horizon],
            replan_rounds: 0,
            replanned_total: 0,
            churn_trace,
            evicted: 0,
            migrated: 0,
            sum_ftf: 0.0,
            latencies_us: Vec::new(),
            traces: BTreeMap::new(),
            decision_counts: BTreeMap::new(),
            started: Timer::start(),
            log: None,
            cell: CellId::default(),
        };
        // slot-0 trace events fire before any submission, matching the
        // engine's SlotStart ordering (nothing is tracked yet, so the
        // migration pass is a no-op; only the mask moves)
        svc.apply_trace_events(0);
        Ok(svc)
    }

    /// Attach a fresh op-log (writes the config header). Refuses an
    /// existing non-empty file — that is what `--recover` is for.
    pub fn attach_log(&mut self, path: &str) -> Result<()> {
        let header = self.cfg.header_json();
        self.log = Some(OpLog::create(path, &header).map_err(Error::from)?);
        Ok(())
    }

    /// Declare this core to be one cell of a sharded service (see
    /// [`CellId`]). Must be set before any traffic or replay: responses,
    /// provenance traces, and journaled `explain` ops carry ids in the
    /// global namespace the cell was configured with.
    pub fn set_cell(&mut self, cell: CellId) {
        assert!(cell.stride > 0 && cell.index < cell.stride, "invalid cell id");
        assert_eq!(self.submitted, 0, "cell identity must be set before traffic");
        self.cell = cell;
    }

    pub fn cell(&self) -> CellId {
        self.cell
    }

    /// Replay the op-log at `path` through a freshly built core and
    /// resume appending to it. Replay verifies the header against `cfg`
    /// and every recorded decision against the recomputed one, so silent
    /// nondeterminism cannot masquerade as a successful recovery.
    pub fn recover(cfg: ServiceConfig, path: &str) -> Result<ServiceCore> {
        ServiceCore::recover_cell(cfg, CellId::default(), path)
    }

    /// [`ServiceCore::recover`] for one cell of a sharded service: the
    /// cell identity is applied *before* replay so the rebuilt provenance
    /// store and journaled explain ids land in the same global namespace
    /// the original cell served.
    pub fn recover_cell(cfg: ServiceConfig, cell: CellId, path: &str) -> Result<ServiceCore> {
        let (ops, repaired) = OpLog::read(path).map_err(Error::from)?;
        if repaired {
            eprintln!("warning: op-log {path}: dropped a truncated in-flight entry");
        }
        let mut core = ServiceCore::new(cfg)?;
        core.set_cell(cell);
        let mut iter = ops.into_iter();
        let saw_header = match iter.next() {
            None => false, // empty/missing log: nothing to replay
            Some(Op::Open { header }) => {
                core.check_header(&header, path)?;
                true
            }
            Some(_) => {
                return Err(err!("op-log {path}: first entry must be the open header"))
            }
        };
        for op in iter {
            match op {
                Op::Open { .. } => {
                    return Err(err!("op-log {path}: duplicate open header"));
                }
                Op::Submit { slot, decision, job } => {
                    if slot != core.slot {
                        return Err(err!(
                            "op-log {path}: submit recorded at slot {slot} but replay \
                             is at slot {}",
                            core.slot
                        ));
                    }
                    if job.id != core.next_id {
                        return Err(err!(
                            "op-log {path}: submit recorded job id {} but replay \
                             assigns {}",
                            job.id,
                            core.next_id
                        ));
                    }
                    let (got, _) = core.submit_inner(job);
                    if got != decision {
                        return Err(err!(
                            "op-log {path}: recorded decision {decision:?} but replay \
                             decided {got:?} — scheduler nondeterminism or config drift"
                        ));
                    }
                }
                Op::Tick { slot } => {
                    core.tick_inner();
                    if slot != core.slot {
                        return Err(err!(
                            "op-log {path}: tick recorded slot {slot} but replay is at \
                             slot {}",
                            core.slot
                        ));
                    }
                }
                Op::Replan { slot, replanned } => {
                    if slot != core.slot {
                        return Err(err!(
                            "op-log {path}: replan recorded at slot {slot} but replay \
                             is at slot {}",
                            core.slot
                        ));
                    }
                    let report = core.replan_now();
                    if report.replanned() != replanned {
                        return Err(err!(
                            "op-log {path}: replan round recorded {replanned} plan \
                             changes but replay produced {} — scheduler \
                             nondeterminism or config drift",
                            report.replanned()
                        ));
                    }
                }
                Op::MachineDown { slot, machine, evicted, migrated } => {
                    if slot != core.slot {
                        return Err(err!(
                            "op-log {path}: machine_down recorded at slot {slot} but \
                             replay is at slot {}",
                            core.slot
                        ));
                    }
                    if !core.core.churn_tracking() {
                        return Err(err!(
                            "op-log {path}: machine_down recorded but the daemon is \
                             configured without churn — refusing to replay"
                        ));
                    }
                    core.core.ledger_mut().set_available_from(machine, slot, false);
                    let (_, ev, mi) = core.migrate_down(&[machine], slot);
                    if ev != evicted || mi != migrated {
                        return Err(err!(
                            "op-log {path}: machine_down recorded \
                             evicted={evicted}/migrated={migrated} but replay produced \
                             evicted={ev}/migrated={mi} — scheduler nondeterminism or \
                             config drift"
                        ));
                    }
                }
                Op::MachineUp { slot, machine } => {
                    if slot != core.slot {
                        return Err(err!(
                            "op-log {path}: machine_up recorded at slot {slot} but \
                             replay is at slot {}",
                            core.slot
                        ));
                    }
                    core.core.ledger_mut().set_available_from(machine, slot, true);
                }
                Op::Explain { slot, job_id } => {
                    if slot != core.slot {
                        return Err(err!(
                            "op-log {path}: explain recorded at slot {slot} but \
                             replay is at slot {}",
                            core.slot
                        ));
                    }
                    // a pure read: the original daemon answered it, so the
                    // rebuilt provenance store must be able to as well
                    let resp = core.explain_inner(job_id);
                    if resp.get("ok") != Some(&Json::Bool(true)) {
                        return Err(err!(
                            "op-log {path}: explain for job {job_id} was served but \
                             replay cannot answer it — provenance store drift"
                        ));
                    }
                }
            }
        }
        if saw_header {
            core.log = Some(OpLog::open_append(path).map_err(Error::from)?);
        } else {
            // nothing was on disk — start the log fresh (with its header)
            core.attach_log(path)?;
        }
        Ok(core)
    }

    fn check_header(&self, header: &Json, path: &str) -> Result<()> {
        let want = self.cfg.header_json();
        for key in
            ["scheduler", "seed", "cluster", "workload", "horizon", "replan", "churn"]
        {
            let got = header.get(key);
            let expect = want.get(key);
            if got != expect {
                return Err(err!(
                    "op-log {path}: header field {key:?} is {got:?} but the daemon \
                     is configured with {expect:?} — refusing to replay into a \
                     different configuration"
                ));
            }
        }
        Ok(())
    }

    pub fn slot(&self) -> usize {
        self.slot
    }

    pub fn horizon(&self) -> usize {
        self.cfg.horizon()
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Dispatch one request to its handler. `Shutdown` only answers here;
    /// the daemon owns the actual drain.
    pub fn apply(&mut self, req: &Request) -> Json {
        match req {
            Request::Submit { job } => self.submit(job.clone()),
            Request::Tick => self.tick(),
            Request::Status => self.status_json(),
            Request::Cluster => self.cluster_json(),
            Request::Metrics => self.metrics_json(),
            Request::MetricsProm => self.metrics_prom_json(),
            Request::DebugDump => {
                ok_response(vec![("flight", crate::obs::flight::dump_json())])
            }
            Request::Replan => self.replan(),
            Request::MachineDown { machine } => self.machine_down(*machine),
            Request::MachineUp { machine } => self.machine_up(*machine),
            Request::Explain { job_id } => self.explain(*job_id),
            Request::Cells => self.cells_json(),
            Request::Shutdown => ok_response(vec![("draining", Json::Bool(true))]),
        }
    }

    /// Submit one job at the current virtual slot (the daemon assigns the
    /// job id and arrival; client-supplied values are ignored). Appends
    /// to the op-log after the decision.
    pub fn submit(&mut self, job: Job) -> Json {
        self.submit_batch(vec![job]).pop().expect("one response per job")
    }

    /// Submit a drain burst of jobs in order, journaling the whole burst
    /// with **one** op-log write + flush. Decisions, responses, and the
    /// journaled bytes are identical to submitting the jobs one by one —
    /// the `--batch 1` oracle the sharding tests enforce; only the
    /// journal syscall count changes.
    pub fn submit_batch(&mut self, jobs: Vec<Job>) -> Vec<Json> {
        let mut ops = Vec::new();
        let mut out = Vec::with_capacity(jobs.len());
        for mut job in jobs {
            job.id = self.next_id;
            job.arrival = self.slot;
            let logged = if self.log.is_some() { Some(job.clone()) } else { None };
            let (decision, response) = self.submit_inner(job);
            if let Some(job) = logged {
                ops.push(Op::Submit { slot: job.arrival, decision, job });
            }
            out.push(response);
        }
        if let Some(log) = self.log.as_mut() {
            if let Err(e) = log.append_all(&ops) {
                eprintln!("warning: op-log append failed: {e}");
            }
        }
        out
    }

    /// The replay-shared submit path: counters, latency, pending credit,
    /// and the wire response. Expects `job.id`/`job.arrival` to be
    /// already assigned.
    fn submit_inner(&mut self, job: Job) -> (String, Json) {
        self.next_id += 1;
        self.submitted += 1;
        // everything internal (pending table, journal, scheduler) speaks
        // local ids; only the wire artifacts — response, provenance trace
        // — carry the cell's global namespace
        let global_id = self.cell.global_job_id(job.id);
        let timer = Timer::start();
        let outcome = self.core.submit(self.sched.as_mut(), &job);
        self.latencies_us.push(timer.elapsed_us());
        // Capture the decision trace (pricing schedulers hand one over;
        // everyone else gets the "policy" fallback) before the outcome is
        // consumed. Replay re-runs this path, so the provenance store and
        // the reason counters rebuild identically under --recover.
        let decision = match &outcome {
            AdmissionOutcome::Admitted { .. } => "admit",
            AdmissionOutcome::Rejected => "reject",
            AdmissionOutcome::Deferred => "defer",
        };
        let mut trace = self
            .sched
            .take_decision_trace()
            .filter(|tr| tr.job_id == job.id)
            .unwrap_or_else(|| DecisionTrace::fallback(job.id, decision));
        trace.t = job.arrival;
        trace.decision = decision;
        trace.job_id = global_id;
        *self.decision_counts.entry((decision, trace.reason)).or_insert(0) += 1;
        self.traces.insert(global_id, trace);
        match outcome {
            AdmissionOutcome::Admitted { schedule, completion, finish } => {
                self.admitted += 1;
                if let Some(f) = finish {
                    debug_assert!(f.slot < self.horizon());
                    if self.ended {
                        // the clock has saturated: no future tick will
                        // drain the pending table, so credit immediately
                        // (the engine's late-arrival path does the same)
                        self.completed += 1;
                        self.total_utility += f.utility;
                        self.sum_ftf += f.ftf;
                    } else if f.slot < self.horizon() {
                        self.pending[f.slot].push((job.id, f));
                    }
                }
                let completion_json =
                    completion.map_or(Json::Null, |c| json::num(c as f64));
                let resp = ok_response(vec![
                    ("job_id", json::num(global_id as f64)),
                    ("decision", json::s("admitted")),
                    ("completion", completion_json),
                    (
                        "schedule",
                        codec::schedule_to_json_cell(
                            &schedule,
                            global_id,
                            self.cell.machine_base,
                        ),
                    ),
                ]);
                ("admitted".to_string(), resp)
            }
            AdmissionOutcome::Rejected => {
                self.rejected += 1;
                let resp = ok_response(vec![
                    ("job_id", json::num(global_id as f64)),
                    ("decision", json::s("rejected")),
                ]);
                ("rejected".to_string(), resp)
            }
            AdmissionOutcome::Deferred => {
                self.deferred += 1;
                let resp = ok_response(vec![
                    ("job_id", json::num(global_id as f64)),
                    ("decision", json::s("deferred")),
                ]);
                ("deferred".to_string(), resp)
            }
        }
    }

    /// Advance the virtual clock one slot: finalize the current slot
    /// (slot-driven grants, then planned-completion credits — the
    /// engine's per-slot order) and move on. The clock saturates at the
    /// last slot: once `ended`, ticks are no-ops.
    pub fn tick(&mut self) -> Json {
        let was_ended = self.ended;
        self.tick_inner();
        // no-op ticks after the horizon ended are not journaled — a
        // wall-clock timer left running must not grow the op-log forever
        if !was_ended {
            if let Some(log) = self.log.as_mut() {
                if let Err(e) = log.append(&Op::Tick { slot: self.slot }) {
                    eprintln!("warning: op-log append failed: {e}");
                }
            }
        }
        ok_response(vec![
            ("slot", json::num(self.slot as f64)),
            ("ended", Json::Bool(self.ended)),
        ])
    }

    fn tick_inner(&mut self) {
        if self.ended {
            return;
        }
        let t = self.slot;
        for g in self.core.run_slot(self.sched.as_mut(), t) {
            if let Some(f) = g.finish {
                self.completed += 1;
                self.total_utility += f.utility;
                self.sum_ftf += f.ftf;
            }
        }
        for (_, f) in std::mem::take(&mut self.pending[t]) {
            self.completed += 1;
            self.total_utility += f.utility;
            self.sum_ftf += f.ftf;
        }
        if t + 1 < self.horizon() {
            self.slot = t + 1;
            // the engine's SlotStart ordering: churn trace events (and
            // their migration pass) land before the replan round, so a
            // replan never re-plans onto a machine that just died.
            self.apply_trace_events(self.slot);
            // the slot boundary the engine replans at: the start of the
            // new slot, before any of its submissions. Gated on tracking
            // so an incapable scheduler reports zero rounds, matching the
            // wire op's "unavailable" answer.
            if self.core.replan_tracking() && self.cfg.scheduler.replan.fires_at(self.slot)
            {
                self.replan_now();
            }
        } else {
            self.ended = true;
        }
    }

    /// Apply the churn trace's events for slot `t` (mask moves + the
    /// migration pass for hard failures). A strict no-op without a trace
    /// or when the trace has no events at `t`. Trace events are *not*
    /// journaled — replay rebuilds the same trace from the header config
    /// and re-fires them inside the replayed ticks.
    fn apply_trace_events(&mut self, t: usize) {
        let Some(trace) = &self.churn_trace else { return };
        let events: Vec<(usize, ChurnEvent)> = trace.events_at(t).to_vec();
        if events.is_empty() {
            return;
        }
        let mut down_now = Vec::new();
        for (h, e) in events {
            match e {
                ChurnEvent::Down => {
                    self.core.ledger_mut().set_available_from(h, t, false);
                    down_now.push(h);
                }
                ChurnEvent::Drain => {
                    self.core.ledger_mut().set_available_from(h, t, false);
                }
                ChurnEvent::Rejoin => {
                    self.core.ledger_mut().set_available_from(h, t, true);
                }
            }
        }
        self.migrate_down(&down_now, t);
    }

    /// Run the migration pass for machines that went hard-Down at `t` and
    /// fold the outcomes into the pending table and churn counters.
    /// Returns `(interrupted, evicted, migrated)` for this pass.
    fn migrate_down(&mut self, down: &[usize], t: usize) -> (usize, usize, usize) {
        let report = run_migration_pass(&mut self.core, self.sched.as_mut(), t, down);
        let mut evicted = 0usize;
        let mut migrated = 0usize;
        for r in &report.records {
            if let Some(of) = r.old_finish {
                if of.slot < self.horizon() {
                    self.pending[of.slot].retain(|&(id, _)| id != r.job_id);
                }
            }
            if r.evicted {
                evicted += 1;
            } else {
                migrated += 1;
                if let Some(nf) = r.new_finish {
                    if nf.slot < self.horizon() {
                        self.pending[nf.slot].push((r.job_id, nf));
                    }
                }
            }
        }
        self.evicted += evicted;
        self.migrated += migrated;
        (report.interrupted, evicted, migrated)
    }

    /// Shared gate for the wire churn ops: validates the op is available
    /// and maps the *global* machine id onto this cell's local range.
    fn churn_op_guard(&self, op: &str, machine: usize) -> Result<usize, Json> {
        if !self.core.churn_tracking() {
            return Err(err_response(&format!(
                "{op} is unavailable (serve with --churn so started \
                 admissions are tracked for migration, e.g. --churn \
                 mtbf:40,mttr:8)"
            )));
        }
        if self.ended {
            return Err(err_response(
                "the horizon has ended; the cluster state is frozen",
            ));
        }
        self.cell.local_machine(machine, self.cluster.len()).ok_or_else(|| {
            err_response(&format!(
                "machine {machine} out of range (this cell owns machines \
                 {}..{})",
                self.cell.machine_base,
                self.cell.machine_base + self.cluster.len()
            ))
        })
    }

    /// The wire `machine_down` op: fail one machine (global id) at the
    /// current slot. Its capacity leaves the ledger from this slot on,
    /// stranded started admissions are migrated or evicted, and the op is
    /// journaled — with the cell-local machine id, like every journaled
    /// op — with the pass outcome (re-checked on replay).
    pub fn machine_down(&mut self, machine: usize) -> Json {
        let local = match self.churn_op_guard("machine_down", machine) {
            Ok(local) => local,
            Err(resp) => return resp,
        };
        let t = self.slot;
        self.core.ledger_mut().set_available_from(local, t, false);
        let (interrupted, evicted, migrated) = self.migrate_down(&[local], t);
        if let Some(log) = self.log.as_mut() {
            let op = Op::MachineDown { slot: t, machine: local, evicted, migrated };
            if let Err(e) = log.append(&op) {
                eprintln!("warning: op-log append failed: {e}");
            }
        }
        ok_response(vec![
            ("slot", json::num(t as f64)),
            ("machine", json::num(machine as f64)),
            ("interrupted", json::num(interrupted as f64)),
            ("migrated", json::num(migrated as f64)),
            ("evicted", json::num(evicted as f64)),
        ])
    }

    /// The wire `machine_up` op: return one machine (global id) to
    /// service from the current slot on. Journaled so replay restores
    /// capacity at the same point in the op sequence.
    pub fn machine_up(&mut self, machine: usize) -> Json {
        let local = match self.churn_op_guard("machine_up", machine) {
            Ok(local) => local,
            Err(resp) => return resp,
        };
        let t = self.slot;
        self.core.ledger_mut().set_available_from(local, t, true);
        if let Some(log) = self.log.as_mut() {
            let op = Op::MachineUp { slot: t, machine: local };
            if let Err(e) = log.append(&op) {
                eprintln!("warning: op-log append failed: {e}");
            }
        }
        ok_response(vec![
            ("slot", json::num(t as f64)),
            ("machine", json::num(machine as f64)),
        ])
    }

    /// Answer one `explain` query without journaling (shared by the wire
    /// op and op-log replay): the job's decision trace as flat response
    /// fields plus an `explain` "why" line.
    fn explain_inner(&self, job_id: usize) -> Json {
        let Some(trace) = self.traces.get(&job_id) else {
            return err_response(&format!(
                "no decision trace for job {job_id} (ids are daemon-assigned; \
                 {} submitted so far)",
                self.submitted
            ));
        };
        let mut out = trace.to_json();
        if let Json::Obj(m) = &mut out {
            m.insert("ok".to_string(), Json::Bool(true));
            m.insert("explain".to_string(), json::s(&trace.explain_line()));
        }
        out
    }

    /// The wire `explain` op: why was this job admitted/rejected?
    /// Successful answers are journaled so `--recover` re-answers them
    /// against the rebuilt provenance store — a read-only replay check
    /// that the recovered daemon explains the same decisions.
    pub fn explain(&mut self, job_id: usize) -> Json {
        let resp = self.explain_inner(job_id);
        if resp.get("ok") == Some(&Json::Bool(true)) {
            if let Some(log) = self.log.as_mut() {
                let op = Op::Explain { slot: self.slot, job_id };
                if let Err(e) = log.append(&op) {
                    eprintln!("warning: op-log append failed: {e}");
                }
            }
        }
        resp
    }

    /// Run one elastic replan round at the current slot and fold the
    /// moved completions into the pending table. Shared by the policy
    /// ticks, the wire op, and op-log replay (which is why it does not
    /// journal itself — see [`ServiceCore::replan`]).
    fn replan_now(&mut self) -> ReplanReport {
        let t = self.slot;
        let report = run_replan_pass(&mut self.core, self.sched.as_mut(), t);
        for r in &report.records {
            if r.promoted {
                // a deferred job became a full admission: move it between
                // the decision counters, like the engine's event stream
                self.admitted += 1;
                self.deferred = self.deferred.saturating_sub(1);
            }
            if let Some(of) = r.old_finish {
                if of.slot < self.horizon() {
                    self.pending[of.slot].retain(|&(id, _)| id != r.job_id);
                }
            }
            if let Some(nf) = r.new_finish {
                if nf.slot < self.horizon() {
                    self.pending[nf.slot].push((r.job_id, nf));
                }
            }
        }
        self.replan_rounds += 1;
        self.replanned_total += report.replanned();
        report
    }

    /// The wire `replan` op: force one round now, journal it (so
    /// `--recover` replays it at the same point in the op sequence), and
    /// report what moved. An error when re-planning is unavailable — the
    /// daemon was started without `--replan` or the scheduler cannot
    /// re-plan — so clients are not silently told "0 jobs moved".
    pub fn replan(&mut self) -> Json {
        if !self.core.replan_tracking() {
            return err_response(
                "replan is unavailable (serve with --replan every:K and a \
                 replan-capable scheduler, e.g. pd-ors)",
            );
        }
        if self.ended {
            // the final slot has executed and its completions are
            // credited; releasing those allocations now would rewrite
            // history that can never take effect
            return err_response("the horizon has ended; nothing left to re-plan");
        }
        let report = self.replan_now();
        if let Some(log) = self.log.as_mut() {
            let op = Op::Replan { slot: report.slot, replanned: report.replanned() };
            if let Err(e) = log.append(&op) {
                eprintln!("warning: op-log append failed: {e}");
            }
        }
        ok_response(vec![
            ("slot", json::num(report.slot as f64)),
            ("revisited", json::num(report.revisited as f64)),
            ("replanned", json::num(report.replanned() as f64)),
            ("utility_delta", json::num(report.utility_delta())),
        ])
    }

    /// Total committed resource-time in this core's ledger (the router's
    /// least-loaded placement signal and the `status` op's
    /// `ledger_sum` field).
    pub fn ledger_sum(&self) -> f64 {
        self.core.ledger().total_used()
    }

    /// The `cells` op answered by a single core: its own cell entry. The
    /// sharded router answers this op itself with one entry per cell; a
    /// plain (or 1-shard) daemon reports the identity cell here, so the
    /// response shape is the same either way.
    fn cells_json(&self) -> Json {
        ok_response(vec![
            ("shards", json::num(self.cell.stride as f64)),
            ("cells", Json::Arr(vec![cell_entry_json(
                self.cell.index,
                self.cell.machine_base,
                self.cluster.len(),
                self.ledger_sum(),
            )])),
        ])
    }

    /// Mean finish-time fairness over completed jobs (0 when none).
    fn ftf(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.sum_ftf / self.completed as f64
        }
    }

    pub fn status_json(&self) -> Json {
        ok_response(vec![
            ("slot", json::num(self.slot as f64)),
            ("ended", Json::Bool(self.ended)),
            ("horizon", json::num(self.horizon() as f64)),
            ("scheduler", json::s(&self.sched.name())),
            ("submitted", json::num(self.submitted as f64)),
            ("admitted", json::num(self.admitted as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("deferred", json::num(self.deferred as f64)),
            ("completed", json::num(self.completed as f64)),
            ("active", json::num(self.core.active().len() as f64)),
            ("replan", json::s(&self.cfg.scheduler.replan.label())),
            ("replan_rounds", json::num(self.replan_rounds as f64)),
            ("replanned", json::num(self.replanned_total as f64)),
            ("churn", json::s(&self.cfg.churn.label())),
            ("evicted", json::num(self.evicted as f64)),
            ("migrated", json::num(self.migrated as f64)),
            ("ftf", json::num(self.ftf())),
            ("total_utility", json::num(self.total_utility)),
            ("ledger_sum", json::num(self.ledger_sum())),
        ])
    }

    pub fn cluster_json(&self) -> Json {
        let caps: Vec<Json> = self
            .cluster
            .machines
            .iter()
            .map(|m| codec::resvec_to_json(&m.capacity))
            .collect();
        ok_response(vec![
            ("machines", json::num(self.cluster.len() as f64)),
            ("horizon", json::num(self.horizon() as f64)),
            ("cluster", json::s(&self.cfg.cluster.key())),
            ("capacities", Json::Arr(caps)),
        ])
    }

    pub fn metrics_json(&self) -> Json {
        let s = stats::Summary::of(&self.latencies_us);
        let solve = json::obj(vec![
            ("count", json::num(s.count() as f64)),
            ("p50", json::num(s.p50)),
            ("p95", json::num(s.p95)),
            ("p99", json::num(s.p99)),
            ("p999", json::num(s.p999)),
            ("mean", json::num(s.mean)),
            ("max", json::num(s.max)),
        ]);
        let sv = self.sched.solver_stats();
        let solver = json::obj(vec![
            ("theta_solves", json::num(sv.theta_solves as f64)),
            ("memo_hits", json::num(sv.memo_hits as f64)),
            ("lp_solves", json::num(sv.lp_solves as f64)),
            ("lp_pivots", json::num(sv.lp_pivots as f64)),
            ("rounding_attempts", json::num(sv.rounding_attempts as f64)),
            ("warm_hits", json::num(sv.warm_hits as f64)),
            ("warm_fallbacks", json::num(sv.warm_fallbacks as f64)),
            ("memo_invalidated", json::num(sv.memo_invalidated as f64)),
            ("snapshot_delta_updates", json::num(sv.snapshot_delta_updates as f64)),
        ]);
        let mut by_reason = std::collections::BTreeMap::new();
        for (&(d, r), &v) in &self.decision_counts {
            by_reason.insert(format!("{d}/{r}"), json::num(v as f64));
        }
        ok_response(vec![
            ("decisions", json::num(s.count() as f64)),
            ("decisions_by_reason", Json::Obj(by_reason)),
            ("solve_us", solve),
            ("solver", solver),
            ("uptime_secs", json::num(self.started.elapsed_secs())),
        ])
    }

    /// This core's counter block of the Prometheus exposition —
    /// everything except the process-global stage histograms and logger
    /// warnings. Flushes this thread's local span recorders into the
    /// global set first, so a cell thread calling this hands its spans
    /// over before the router renders the merged body.
    pub fn prom_counters(&self) -> PromCounters {
        obs::flush_local();
        PromCounters {
            submitted: self.submitted,
            admitted: self.admitted,
            rejected: self.rejected,
            deferred: self.deferred,
            completed: self.completed,
            decisions: self
                .decision_counts
                .iter()
                .map(|(&(d, r), &v)| ((d.to_string(), r.to_string()), v))
                .collect(),
        }
    }

    /// The wire `metrics_prom` op: Prometheus text exposition 0.0.4 of
    /// the global per-stage span histograms plus the decision counters.
    /// Flushes this thread's local recorders first — an unsharded daemon
    /// core thread owns every span recorded inside the solve path, so the
    /// merged global set is complete at this point.
    fn metrics_prom_json(&self) -> Json {
        let body = render_prom_body(&self.prom_counters());
        ok_response(vec![("prom", json::s(&body))])
    }

    /// The deterministic end-state snapshot (see [`ServiceReport`]).
    pub fn report(&self) -> ServiceReport {
        let ledger = self.core.ledger();
        let mut alloc = Vec::with_capacity(ledger.horizon());
        for t in 0..ledger.horizon() {
            let mut row = Vec::with_capacity(ledger.num_machines());
            for h in 0..ledger.num_machines() {
                row.push(ledger.used(t, h).0);
            }
            alloc.push(row);
        }
        ServiceReport {
            slot: self.slot,
            ended: self.ended,
            submitted: self.submitted,
            admitted: self.admitted,
            rejected: self.rejected,
            deferred: self.deferred,
            completed: self.completed,
            replanned: self.replanned_total,
            evicted: self.evicted,
            migrated: self.migrated,
            ftf: self.ftf(),
            total_utility: self.total_utility,
            alloc,
            solver: self.sched.solver_stats(),
        }
    }
}

/// One core's counter block of the Prometheus exposition, detached from
/// the core so the sharded router can collect one per cell, merge them,
/// and render a single body (see [`render_prom_body`]).
#[derive(Debug, Clone, Default)]
pub struct PromCounters {
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub deferred: usize,
    pub completed: usize,
    /// `(decision, reason) → count`.
    pub decisions: BTreeMap<(String, String), u64>,
}

impl PromCounters {
    /// Fold another cell's counters in (sums everywhere).
    pub fn merge(&mut self, other: &PromCounters) {
        self.submitted += other.submitted;
        self.admitted += other.admitted;
        self.rejected += other.rejected;
        self.deferred += other.deferred;
        self.completed += other.completed;
        for (k, v) in &other.decisions {
            *self.decisions.entry(k.clone()).or_insert(0) += v;
        }
    }
}

/// Render the full Prometheus text body: the process-global stage
/// histograms, then the (possibly cell-merged) service counters, then
/// the logger warning counter. The single-core
/// `ServiceCore::metrics_prom_json` and the sharded router both go
/// through here, so the exposition format is defined once.
pub fn render_prom_body(counters: &PromCounters) -> String {
    let mut body = crate::obs::export::prometheus_text(&obs::global_stages());
    for (name, v) in [
        ("dmlrs_submitted_total", counters.submitted),
        ("dmlrs_admitted_total", counters.admitted),
        ("dmlrs_rejected_total", counters.rejected),
        ("dmlrs_deferred_total", counters.deferred),
        ("dmlrs_completed_total", counters.completed),
    ] {
        body.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    body.push_str("# TYPE dmlrs_decisions_total counter\n");
    for ((d, r), v) in &counters.decisions {
        body.push_str(&format!(
            "dmlrs_decisions_total{{decision=\"{d}\",reason=\"{r}\"}} {v}\n"
        ));
    }
    body.push_str(&format!(
        "# TYPE dmlrs_log_warnings_total counter\ndmlrs_log_warnings_total {}\n",
        crate::util::logger::warnings()
    ));
    body
}

/// One entry of a `cells` response: the cell's global machine range and
/// current ledger load. Shared by the single-core answer and the sharded
/// router's merged answer so both render the same shape.
pub fn cell_entry_json(index: usize, base: usize, machines: usize, load: f64) -> Json {
    json::obj(vec![
        ("cell", json::num(index as f64)),
        ("machines_start", json::num(base as f64)),
        ("machines_end", json::num((base + machines) as f64)),
        ("machines", json::num(machines as f64)),
        ("load", json::num(load)),
    ])
}

/// Convenience: the default service config over a synthetic workload —
/// `machines` paper machines, `num_jobs`/`horizon` pricing population.
pub fn synthetic_service_config(
    scheduler: &str,
    seed: u64,
    machines: usize,
    num_jobs: usize,
    horizon: usize,
) -> ServiceConfig {
    ServiceConfig {
        scheduler: SchedulerSpec::new(scheduler).with_seed(seed),
        cluster: ClusterSpec::homogeneous(machines),
        workload: WorkloadSpec::synthetic(num_jobs, horizon, 0),
        churn: ChurnSpec::None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn cfg() -> ServiceConfig {
        synthetic_service_config("pd-ors", 1, 8, 12, 12)
    }

    fn tmp(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("dmlrs_svccore_{tag}_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    /// Drive a core through the full pricing workload, slot by slot.
    fn drive(core: &mut ServiceCore) {
        let jobs = core.config().workload.jobs(core.config().scheduler.seed);
        let horizon = core.horizon();
        let mut next = 0usize;
        for t in 0..horizon {
            while next < jobs.len() && jobs[next].arrival <= t {
                core.submit(jobs[next].clone());
                next += 1;
            }
            core.tick();
        }
    }

    #[test]
    fn submissions_and_ticks_accumulate_metrics() {
        let mut core = ServiceCore::new(cfg()).unwrap();
        drive(&mut core);
        let r = core.report();
        assert_eq!(r.submitted, 12);
        assert_eq!(r.admitted + r.rejected + r.deferred, 12);
        assert!(r.admitted > 0, "PD-ORS should admit something");
        assert!(r.ended);
        assert!(r.total_utility > 0.0);
        assert!(core.core.ledger().within_capacity(1e-6));
        // metrics are live
        let m = core.metrics_json();
        assert_eq!(m.get("decisions").unwrap().as_usize(), Some(12));
        assert!(m.get("solve_us").unwrap().get("p99").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn recover_replays_to_identical_state() {
        let path = tmp("recover");
        let _ = std::fs::remove_file(&path);
        let expected = {
            let mut core = ServiceCore::new(cfg()).unwrap();
            core.attach_log(&path).unwrap();
            drive(&mut core);
            core.report()
        };
        let recovered = ServiceCore::recover(cfg(), &path).unwrap();
        assert_eq!(recovered.report(), expected, "replay must be byte-identical");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recover_rejects_config_drift() {
        let path = tmp("drift");
        let _ = std::fs::remove_file(&path);
        {
            let mut core = ServiceCore::new(cfg()).unwrap();
            core.attach_log(&path).unwrap();
            core.tick();
        }
        let mut other = cfg();
        other.scheduler = SchedulerSpec::new("fifo").with_seed(1);
        let e = ServiceCore::recover(other, &path).unwrap_err();
        assert!(e.to_string().contains("scheduler"), "{e}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recover_tolerates_truncated_tail_and_resumes_logging() {
        let path = tmp("tail");
        let _ = std::fs::remove_file(&path);
        {
            let mut core = ServiceCore::new(cfg()).unwrap();
            core.attach_log(&path).unwrap();
            let jobs = core.config().workload.jobs(1);
            core.submit(jobs[0].clone());
            core.tick();
        }
        {
            use std::io::Write as _;
            let mut f =
                std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"op\":\"submit\",\"slot\":1,\"j").unwrap();
        }
        let mut core = ServiceCore::recover(cfg(), &path).unwrap();
        assert_eq!(core.report().submitted, 1);
        // the repaired log accepts new ops and replays again cleanly
        core.tick();
        let report = core.report();
        drop(core);
        let again = ServiceCore::recover(cfg(), &path).unwrap();
        assert_eq!(again.report(), report);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ended_ticks_are_not_journaled() {
        let path = tmp("endtick");
        let _ = std::fs::remove_file(&path);
        {
            let mut core = ServiceCore::new(cfg()).unwrap();
            core.attach_log(&path).unwrap();
            for _ in 0..40 {
                core.tick();
            }
        }
        let (ops, _) = OpLog::read(&path).unwrap();
        let ticks = ops.iter().filter(|op| matches!(op, Op::Tick { .. })).count();
        assert_eq!(
            ticks, 12,
            "exactly horizon ticks are journaled; saturated ticks are no-ops"
        );
        // and the journal still replays cleanly to the saturated state
        let recovered = ServiceCore::recover(cfg(), &path).unwrap();
        assert!(recovered.report().ended);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn clock_saturates_at_the_horizon() {
        let mut core = ServiceCore::new(cfg()).unwrap();
        for _ in 0..40 {
            core.tick();
        }
        let r = core.report();
        assert!(r.ended);
        assert_eq!(r.slot, core.horizon() - 1);
        // submissions are still answered after the horizon ends
        let jobs = core.config().workload.jobs(1);
        let resp = core.submit(jobs[0].clone());
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn apply_dispatches_every_op() {
        let mut core = ServiceCore::new(cfg()).unwrap();
        for (req, field) in [
            (Request::Status, "submitted"),
            (Request::Cluster, "capacities"),
            (Request::Metrics, "solve_us"),
            (Request::MetricsProm, "prom"),
            (Request::DebugDump, "flight"),
            (Request::Tick, "slot"),
            (Request::Shutdown, "draining"),
        ] {
            let resp = core.apply(&req);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{field}");
            assert!(resp.get(field).is_some(), "{field} missing: {}", resp.to_string());
        }
        let status = core.apply(&Request::Status);
        assert_eq!(status.get("slot").unwrap().as_usize(), Some(1), "tick advanced");
        // the Prometheus body is the text exposition, not JSON
        let prom = core.apply(&Request::MetricsProm);
        let body = prom.get("prom").unwrap().as_str().unwrap();
        assert!(body.contains("dmlrs_submitted_total 0"), "{body}");
        assert!(body.contains("# TYPE dmlrs_stage_duration_us histogram"), "{body}");
    }

    #[test]
    fn churn_ops_require_churn_serving() {
        // default config (churn = none): the wire ops are honest errors —
        // started jobs are untracked, so a silent mask flip would strand
        // their committed work on a dead machine
        let mut off = ServiceCore::new(cfg()).unwrap();
        for req in [Request::MachineDown { machine: 1 }, Request::MachineUp { machine: 1 }]
        {
            let resp = off.apply(&req);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{}", resp.to_string());
            assert!(resp.get("error").unwrap().as_str().unwrap().contains("--churn"));
        }

        // an out-of-horizon event list is the manual-injection idiom: the
        // trace is empty but tracking is on, so wire ops are accepted
        let mut c = cfg();
        c.churn = ChurnSpec::parse("down@900:1").unwrap();
        let mut on = ServiceCore::new(c).unwrap();
        let jobs = on.config().workload.jobs(1);
        for j in jobs.iter().take(4) {
            on.submit(j.clone());
        }
        on.tick();
        let resp = on.apply(&Request::MachineDown { machine: 1 });
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string());
        assert!(resp.get("interrupted").is_some());
        assert!(on.core.ledger().has_unavailable());
        let resp = on.apply(&Request::MachineUp { machine: 1 });
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string());
        let resp = on.apply(&Request::MachineDown { machine: 99 });
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{}", resp.to_string());
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("out of range"));
    }

    #[test]
    fn recover_replays_churny_run_identically() {
        let path = tmp("churny");
        let _ = std::fs::remove_file(&path);
        let mut c = cfg();
        c.churn = ChurnSpec::parse("down@3:1,down@5:2,up@8:1").unwrap();
        let expected = {
            let mut core = ServiceCore::new(c.clone()).unwrap();
            core.attach_log(&path).unwrap();
            drive(&mut core);
            core.report()
        };
        let recovered = ServiceCore::recover(c.clone(), &path).unwrap();
        assert_eq!(recovered.report(), expected, "churny replay must be byte-identical");
        // ...and a churn-less config refuses the churny log outright
        let e = ServiceCore::recover(cfg(), &path).unwrap_err();
        assert!(e.to_string().contains("churn"), "{e}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recover_replays_wire_churn_ops_identically() {
        let path = tmp("wirechurn");
        let _ = std::fs::remove_file(&path);
        let mut c = cfg();
        c.churn = ChurnSpec::parse("down@900:1").unwrap();
        let expected = {
            let mut core = ServiceCore::new(c.clone()).unwrap();
            core.attach_log(&path).unwrap();
            let jobs = core.config().workload.jobs(1);
            let mut next = 0usize;
            for t in 0..core.horizon() {
                while next < jobs.len() && jobs[next].arrival <= t {
                    core.submit(jobs[next].clone());
                    next += 1;
                }
                if t == 2 {
                    core.apply(&Request::MachineDown { machine: 1 });
                }
                if t == 6 {
                    core.apply(&Request::MachineUp { machine: 1 });
                }
                core.tick();
            }
            core.report()
        };
        let recovered = ServiceCore::recover(c, &path).unwrap();
        assert_eq!(recovered.report(), expected, "wire churn ops must replay");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replan_op_requires_an_enabled_cadence() {
        use crate::sched::replan::ReplanPolicy;
        // default config (replan = none): the wire op is an honest error,
        // not a silent "0 jobs moved", and nothing is tracked
        let mut off = ServiceCore::new(cfg()).unwrap();
        let resp = off.apply(&Request::Replan);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{}", resp.to_string());
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("--replan"));
        let jobs = off.config().workload.jobs(1);
        off.submit(jobs[0].clone());
        assert!(
            off.core.tracked_admissions().is_empty(),
            "a replan-less daemon must not accumulate tracked admissions"
        );

        // cadence enabled: the op answers with the round's counters
        let mut c = cfg();
        c.scheduler = c.scheduler.with_replan(ReplanPolicy::Every(4));
        let mut on = ServiceCore::new(c).unwrap();
        let resp = on.apply(&Request::Replan);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string());
        assert!(resp.get("replanned").is_some());
        assert!(resp.get("revisited").is_some());

        // ...but not once the horizon has ended: the final slot already
        // executed, so there is nothing left that could legally move
        for _ in 0..40 {
            on.tick();
        }
        let resp = on.apply(&Request::Replan);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{}", resp.to_string());
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("horizon"));

        // a cadence on a replan-incapable scheduler runs zero rounds (the
        // tick path is gated exactly like the wire op)
        let mut f = cfg();
        f.scheduler = SchedulerSpec::new("fifo").with_seed(1).with_replan(ReplanPolicy::Every(2));
        let mut fifo = ServiceCore::new(f).unwrap();
        for _ in 0..6 {
            fifo.tick();
        }
        let status = fifo.status_json();
        assert_eq!(status.get("replan_rounds").unwrap().as_usize(), Some(0));
        let resp = fifo.apply(&Request::Replan);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{}", resp.to_string());
    }

    #[test]
    fn cell_namespace_translates_ids_at_the_wire_edge() {
        // cell 1 of 2, owning global machines 4..8 (a 4-machine slice)
        let mut c = synthetic_service_config("pd-ors", 1, 4, 12, 12);
        c.churn = ChurnSpec::parse("down@900:1").unwrap();
        let mut core = ServiceCore::new(c).unwrap();
        core.set_cell(CellId { index: 1, stride: 2, machine_base: 4 });
        let jobs = core.config().workload.jobs(1);
        let mut admitted_global = None;
        for (k, job) in jobs.iter().take(6).enumerate() {
            let resp = core.submit(job.clone());
            let gid = resp.get("job_id").unwrap().as_usize().unwrap();
            assert_eq!(gid, k * 2 + 1, "interleaved global ids");
            if resp.get("decision").unwrap().as_str() == Some("admitted") {
                admitted_global = Some((gid, resp.clone()));
            }
        }
        let (gid, resp) = admitted_global.expect("pd-ors should admit something");
        // the reported schedule lives in the global namespace
        let sched = resp.get("schedule").unwrap();
        assert_eq!(sched.get("job_id").unwrap().as_usize(), Some(gid));
        for slot in sched.get("slots").unwrap().as_arr().unwrap() {
            for p in slot.get("placements").unwrap().as_arr().unwrap() {
                let h = p.as_arr().unwrap()[0].as_usize().unwrap();
                assert!((4..8).contains(&h), "global machine id {h} outside 4..8");
            }
        }
        // explain answers under the global id (and echoes it); ids homed
        // on the other cell are honest errors
        let e = core.apply(&Request::Explain { job_id: gid });
        assert_eq!(e.get("ok"), Some(&Json::Bool(true)), "{}", e.to_string());
        assert_eq!(e.get("job_id").unwrap().as_usize(), Some(gid));
        let e = core.apply(&Request::Explain { job_id: 2 });
        assert_eq!(e.get("ok"), Some(&Json::Bool(false)), "{}", e.to_string());
        // machine ops speak global ids; ids outside the cell's range are
        // honest errors
        let down = core.apply(&Request::MachineDown { machine: 5 });
        assert_eq!(down.get("ok"), Some(&Json::Bool(true)), "{}", down.to_string());
        assert_eq!(down.get("machine").unwrap().as_usize(), Some(5));
        let bad = core.apply(&Request::MachineDown { machine: 2 });
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)), "{}", bad.to_string());
        assert!(bad.get("error").unwrap().as_str().unwrap().contains("out of range"));
        // the cells op reports the global range
        let cells = core.apply(&Request::Cells);
        assert_eq!(cells.get("shards").unwrap().as_usize(), Some(2));
        let entry = &cells.get("cells").unwrap().as_arr().unwrap()[0];
        assert_eq!(entry.get("machines_start").unwrap().as_usize(), Some(4));
        assert_eq!(entry.get("machines_end").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn cell_recovery_replays_the_global_namespace() {
        let path = tmp("cellrec");
        let _ = std::fs::remove_file(&path);
        let cell = CellId { index: 1, stride: 4, machine_base: 2 };
        let expected = {
            let mut core = ServiceCore::new(cfg()).unwrap();
            core.set_cell(cell);
            core.attach_log(&path).unwrap();
            let jobs = core.config().workload.jobs(1);
            for j in jobs.iter().take(4) {
                core.submit(j.clone());
            }
            // journal an explain under the global id — replay must
            // re-answer it against the rebuilt (global-keyed) store
            let e = core.apply(&Request::Explain { job_id: 5 });
            assert_eq!(e.get("ok"), Some(&Json::Bool(true)), "{}", e.to_string());
            core.tick();
            core.report()
        };
        let recovered = ServiceCore::recover_cell(cfg(), cell, &path).unwrap();
        assert_eq!(recovered.report(), expected);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn submit_batch_matches_singles_byte_for_byte() {
        let (p1, p2) = (tmp("single"), tmp("batch"));
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
        let jobs = cfg().workload.jobs(1);
        let (singles, report1) = {
            let mut core = ServiceCore::new(cfg()).unwrap();
            core.attach_log(&p1).unwrap();
            let out: Vec<String> = jobs
                .iter()
                .take(6)
                .map(|j| core.submit(j.clone()).to_string())
                .collect();
            core.tick();
            (out, core.report())
        };
        let (batched, report2) = {
            let mut core = ServiceCore::new(cfg()).unwrap();
            core.attach_log(&p2).unwrap();
            let out: Vec<String> = core
                .submit_batch(jobs.iter().take(6).cloned().collect())
                .iter()
                .map(Json::to_string)
                .collect();
            core.tick();
            (out, core.report())
        };
        assert_eq!(singles, batched, "responses must be byte-identical");
        assert_eq!(report1, report2, "end state must be byte-identical");
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "journal bytes must be identical"
        );
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }

    #[test]
    fn explain_answers_for_submitted_jobs() {
        let mut core = ServiceCore::new(cfg()).unwrap();
        let jobs = core.config().workload.jobs(1);
        for j in jobs.iter().take(3) {
            core.submit(j.clone());
        }
        let resp = core.apply(&Request::Explain { job_id: 0 });
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string());
        assert!(resp.get("margin").unwrap().as_f64().is_some());
        let line = resp.get("explain").unwrap().as_str().unwrap();
        assert!(line.contains("job"), "{line}");
        let reason = resp.get("reason").unwrap().as_str().unwrap();
        assert!(
            ["margin", "price", "infeasible"].contains(&reason),
            "PD-ORS decisions carry a pricing reason, got {reason:?}"
        );
        // unknown ids are honest errors
        let resp = core.apply(&Request::Explain { job_id: 99 });
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{}", resp.to_string());
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("99"));
        // decision counters surface in both metrics flavors
        let m = core.metrics_json();
        assert!(m.get("decisions_by_reason").is_some());
        let prom = core.apply(&Request::MetricsProm);
        let body = prom.get("prom").unwrap().as_str().unwrap();
        assert!(body.contains("dmlrs_decisions_total{decision="), "{body}");
    }

    #[test]
    fn recover_replays_explain_ops() {
        let path = tmp("explain");
        let _ = std::fs::remove_file(&path);
        {
            let mut core = ServiceCore::new(cfg()).unwrap();
            core.attach_log(&path).unwrap();
            let jobs = core.config().workload.jobs(1);
            core.submit(jobs[0].clone());
            let resp = core.apply(&Request::Explain { job_id: 0 });
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            // failed lookups are not journaled
            core.apply(&Request::Explain { job_id: 77 });
            core.tick();
        }
        let (ops, _) = OpLog::read(&path).unwrap();
        let explains = ops.iter().filter(|op| matches!(op, Op::Explain { .. })).count();
        assert_eq!(explains, 1, "only the answered explain is journaled");
        let mut rec = ServiceCore::recover(cfg(), &path).unwrap();
        let resp = rec.apply(&Request::Explain { job_id: 0 });
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string());
        let _ = std::fs::remove_file(&path);
    }
}
