//! The admission service's newline-delimited JSON wire protocol.
//!
//! One request per line, one response line per request, over a plain TCP
//! stream. Requests are objects with an `"op"` field:
//!
//! | op         | request fields | response fields |
//! |------------|----------------|-----------------|
//! | `submit`   | `job` (see [`super::codec::job_to_json`]) | `job_id`, `decision` (`admitted`/`rejected`/`deferred`), `completion`, `schedule` |
//! | `tick`     | —              | `slot` (the new current slot), `ended` |
//! | `status`   | —              | `slot`, `submitted`, `admitted`, `rejected`, `deferred`, `completed`, `total_utility`, `ledger_sum`, … |
//! | `cluster`  | —              | `machines`, `horizon`, `capacities` |
//! | `metrics`  | —              | `decisions`, `solve_us` percentiles, `solver` counters, `uptime_secs` |
//! | `replan`   | —              | `slot`, `revisited`, `replanned`, `utility_delta` — force one elastic replan round now (see [`crate::sched::replan`]; rounds also run automatically with `--replan every:k`, and the op is an `"ok":false` error on a daemon serving without that flag) |
//! | `machine_down` | `machine`  | `slot`, `machine`, `interrupted`, `migrated`, `evicted` — take one machine down now: its capacity leaves the ledger from the current slot and stranded started jobs are migrated or evicted (see [`crate::chaos`]) |
//! | `machine_up` | `machine`    | `slot`, `machine` — bring a downed machine back from the current slot |
//! | `explain`  | `job_id`       | the job's decision trace (`decision`, `reason`, `utility`, `price`, `margin`, window/locality/reuse fields) + `explain`, a human-readable "why" line — requires the daemon's provenance store (see [`crate::obs::provenance`]) |
//! | `cells`    | —              | `shards`, `cells` — the sharded daemon's cell layout: one entry per cell with its global machine range (`machines_start`/`machines_end`) and current ledger load (see [`super::shard`]); a single-core daemon answers for its one cell |
//! | `metrics_prom` | —          | `prom` — Prometheus text exposition (per-stage span histograms + decision counters); also served raw over HTTP by `--prom-addr` |
//! | `debug_dump` | —            | `flight` — the telemetry flight recorder's ring of recent spans (see [`crate::obs::flight`]) |
//! | `shutdown` | —              | `draining: true` (the daemon then drains and exits) |
//!
//! Every response carries `"ok": true` or `"ok": false` + `"error"`. The
//! submitted job's `id` and `arrival` fields are *assigned by the daemon*
//! (sequential ids, the current virtual slot); client-supplied values are
//! ignored.

use crate::jobs::Job;
use crate::util::json::{self, Json};

use super::codec;

/// A parsed request.
#[derive(Debug, Clone)]
pub enum Request {
    Submit { job: Job },
    Tick,
    Status,
    Cluster,
    Metrics,
    Replan,
    MachineDown { machine: usize },
    MachineUp { machine: usize },
    Explain { job_id: usize },
    Cells,
    MetricsProm,
    DebugDump,
    Shutdown,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request, String> {
        let v = Json::parse(line.trim())?;
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or("request needs a string \"op\" field")?;
        match op {
            "submit" => {
                let job = v.get("job").ok_or("submit needs a \"job\" field")?;
                Ok(Request::Submit { job: codec::job_from_json(job)? })
            }
            "tick" => Ok(Request::Tick),
            "status" => Ok(Request::Status),
            "cluster" => Ok(Request::Cluster),
            "metrics" => Ok(Request::Metrics),
            "replan" => Ok(Request::Replan),
            "machine_down" | "machine_up" => {
                let machine = v
                    .get("machine")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("{op} needs a numeric \"machine\" field"))?
                    as usize;
                if op == "machine_down" {
                    Ok(Request::MachineDown { machine })
                } else {
                    Ok(Request::MachineUp { machine })
                }
            }
            "explain" => {
                let job_id = v
                    .get("job_id")
                    .and_then(Json::as_f64)
                    .ok_or("explain needs a numeric \"job_id\" field")?
                    as usize;
                Ok(Request::Explain { job_id })
            }
            "cells" => Ok(Request::Cells),
            "metrics_prom" => Ok(Request::MetricsProm),
            "debug_dump" => Ok(Request::DebugDump),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!(
                "unknown op {other:?} (expected \
                 submit|tick|status|cluster|cells|metrics|metrics_prom|debug_dump|\
                 replan|machine_down|machine_up|explain|shutdown)"
            )),
        }
    }

    /// Serialize back to a request line (what clients and the load
    /// generator send).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Submit { job } => json::obj(vec![
                ("op", json::s("submit")),
                ("job", codec::job_to_json(job)),
            ]),
            Request::Tick => json::obj(vec![("op", json::s("tick"))]),
            Request::Status => json::obj(vec![("op", json::s("status"))]),
            Request::Cluster => json::obj(vec![("op", json::s("cluster"))]),
            Request::Metrics => json::obj(vec![("op", json::s("metrics"))]),
            Request::Replan => json::obj(vec![("op", json::s("replan"))]),
            Request::MachineDown { machine } => json::obj(vec![
                ("op", json::s("machine_down")),
                ("machine", json::num(*machine as f64)),
            ]),
            Request::MachineUp { machine } => json::obj(vec![
                ("op", json::s("machine_up")),
                ("machine", json::num(*machine as f64)),
            ]),
            Request::Explain { job_id } => json::obj(vec![
                ("op", json::s("explain")),
                ("job_id", json::num(*job_id as f64)),
            ]),
            Request::Cells => json::obj(vec![("op", json::s("cells"))]),
            Request::MetricsProm => json::obj(vec![("op", json::s("metrics_prom"))]),
            Request::DebugDump => json::obj(vec![("op", json::s("debug_dump"))]),
            Request::Shutdown => json::obj(vec![("op", json::s("shutdown"))]),
        }
    }

    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }
}

/// Build a success response from `fields` (prepends `"ok": true`).
pub fn ok_response(mut fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("ok", Json::Bool(true))];
    all.append(&mut fields);
    json::obj(all)
}

/// Build an error response.
pub fn err_response(msg: &str) -> Json {
    json::obj(vec![("ok", Json::Bool(false)), ("error", json::s(msg))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::test_support::test_job;

    #[test]
    fn ops_round_trip() {
        for req in [
            Request::Tick,
            Request::Status,
            Request::Cluster,
            Request::Metrics,
            Request::Replan,
            Request::MachineDown { machine: 2 },
            Request::MachineUp { machine: 2 },
            Request::Explain { job_id: 7 },
            Request::Cells,
            Request::MetricsProm,
            Request::DebugDump,
            Request::Shutdown,
        ] {
            let line = req.to_line();
            let back = Request::parse(&line).unwrap();
            assert_eq!(back.to_line(), line);
        }
        let req = Request::Submit { job: test_job(3) };
        let back = Request::parse(&req.to_line()).unwrap();
        match back {
            Request::Submit { job } => assert_eq!(job.id, 3),
            other => panic!("wrong op: {other:?}"),
        }
    }

    #[test]
    fn bad_requests_are_reported() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("{\"op\": \"fly\"}").unwrap_err().contains("fly"));
        assert!(Request::parse("{\"op\": \"submit\"}").unwrap_err().contains("job"));
        assert!(Request::parse("{\"op\": \"machine_down\"}")
            .unwrap_err()
            .contains("machine"));
        assert!(Request::parse("{\"op\": \"explain\"}")
            .unwrap_err()
            .contains("job_id"));
        assert!(Request::parse("{}").is_err());
    }

    #[test]
    fn responses_carry_ok() {
        let ok = ok_response(vec![("slot", json::num(3.0))]).to_string();
        assert!(ok.contains("\"ok\":true"));
        assert!(ok.contains("\"slot\":3"));
        let e = err_response("busy").to_string();
        assert!(e.contains("\"ok\":false"));
        assert!(e.contains("busy"));
    }
}
