//! The admission daemon: std-only TCP frontend around a [`ServiceCore`].
//!
//! Architecture (one box per thread):
//!
//! ```text
//!  client ──► connection handler ─┐
//!  client ──► connection handler ─┼─► bounded MPSC queue ─► scheduler core
//!  slot timer (optional) ─────────┘        (backpressure)     (owns the
//!                                                              ledger +
//!                                                              solver
//!                                                              scratch)
//! ```
//!
//! * One handler thread per accepted connection reads NDJSON requests and
//!   forwards them through a *bounded* `sync_channel`; a full queue blocks
//!   the handler — natural backpressure toward the client — while the
//!   single core thread preserves PR 3's no-locks-in-the-solve-path
//!   determinism contract.
//! * Responses travel back on a per-request channel, so each connection
//!   sees its own request/response ordering.
//! * `--slot-ms N` starts a wall-clock timer thread that enqueues a
//!   `tick` every N ms; with `N = 0` the clock is purely virtual (driven
//!   by `tick` requests — what the parity tests and `dmlrs load --ticks`
//!   use).
//! * Graceful drain: a `shutdown` request (or SIGTERM/SIGINT in
//!   `dmlrs serve`) sets the shared stop flag; the acceptor stops
//!   accepting, handlers finish their in-flight request and close, and
//!   the core exits once every sender is gone — no request is dropped
//!   after it was accepted into the queue.

use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::err;
use crate::obs::{self, Stage};
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::{log_debug, log_info};

use super::core::{ServiceConfig, ServiceCore, ServiceReport};
use super::protocol::{err_response, Request};

/// Daemon configuration on top of the core's [`ServiceConfig`].
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Bind address; port 0 picks an ephemeral port (the bound address is
    /// reported on the handle).
    pub addr: String,
    pub service: ServiceConfig,
    /// Wall-clock slot length in ms; 0 = virtual clock (tick requests
    /// only).
    pub slot_ms: u64,
    /// Bound of the request queue between the connection handlers and
    /// the scheduler core.
    pub queue_cap: usize,
    /// Start a fresh op-log at this path.
    pub oplog: Option<String>,
    /// Replay this op-log at startup, then continue appending to it.
    pub recover: Option<String>,
    /// Also serve the Prometheus text exposition over plain HTTP at this
    /// address (`GET` anything → the `metrics_prom` body).
    pub prom_addr: Option<String>,
}

impl DaemonConfig {
    pub fn new(service: ServiceConfig) -> DaemonConfig {
        DaemonConfig {
            addr: "127.0.0.1:0".to_string(),
            service,
            slot_ms: 0,
            queue_cap: 64,
            oplog: None,
            recover: None,
            prom_addr: None,
        }
    }
}

struct CoreMsg {
    req: Request,
    /// Response channel; `None` for internally generated ticks.
    resp: Option<Sender<String>>,
    /// When the message entered the queue — the core measures the gap
    /// into the `queue_wait` telemetry stage on receipt.
    enqueued: Instant,
}

impl CoreMsg {
    fn new(req: Request, resp: Option<Sender<String>>) -> CoreMsg {
        CoreMsg { req, resp, enqueued: Instant::now() }
    }
}

/// A running daemon. Dropping the handle does not stop the daemon; call
/// [`DaemonHandle::shutdown`] (or send a `shutdown` request) and then
/// [`DaemonHandle::join`].
pub struct DaemonHandle {
    /// The actually bound address (resolves port 0).
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    /// `None` only when startup failed (which `start` already reported).
    core: JoinHandle<Option<ServiceReport>>,
    accept: JoinHandle<()>,
    timer: Option<JoinHandle<()>>,
    prom: Option<JoinHandle<()>>,
    /// The bound Prometheus scrape address, when `--prom-addr` was given.
    pub prom_addr: Option<SocketAddr>,
}

impl DaemonHandle {
    /// Request a graceful drain (idempotent).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Has a drain been requested (via this handle, a `shutdown` request,
    /// or a termination signal forwarded by the CLI)?
    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Wait for the daemon to finish draining and return the core's
    /// final deterministic state snapshot. Blocks until a shutdown was
    /// requested by someone.
    pub fn join(self) -> Result<ServiceReport> {
        self.accept.join().map_err(|_| err!("accept thread panicked"))?;
        if let Some(t) = self.timer {
            t.join().map_err(|_| err!("slot-timer thread panicked"))?;
        }
        if let Some(p) = self.prom {
            p.join().map_err(|_| err!("prometheus thread panicked"))?;
        }
        self.core
            .join()
            .map_err(|_| err!("scheduler-core thread panicked"))?
            .ok_or_else(|| err!("scheduler core never started"))
    }
}

/// Build the core (fresh, fresh+log, or recovered) per the config.
fn build_core(cfg: &DaemonConfig) -> Result<ServiceCore> {
    if let (Some(o), Some(r)) = (&cfg.oplog, &cfg.recover) {
        if o != r {
            return Err(err!(
                "--oplog {o} and --recover {r} must name the same file (recovery \
                 resumes appending to the replayed log)"
            ));
        }
    }
    match &cfg.recover {
        Some(path) => ServiceCore::recover(cfg.service.clone(), path),
        None => {
            let mut core = ServiceCore::new(cfg.service.clone())?;
            if let Some(path) = &cfg.oplog {
                core.attach_log(path)?;
            }
            Ok(core)
        }
    }
}

/// Start the daemon: bind, spawn the scheduler-core / acceptor / optional
/// slot-timer threads, and return once the core is up.
pub fn start(cfg: DaemonConfig) -> Result<DaemonHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| err!("bind {}: {e}", cfg.addr))?;
    let addr = listener.local_addr().map_err(Error::from)?;
    listener.set_nonblocking(true).map_err(Error::from)?;

    let shutdown = Arc::new(AtomicBool::new(false));
    let (tx, rx) = sync_channel::<CoreMsg>(cfg.queue_cap.max(1));

    // The boxed scheduler is not Send by contract (the registry builds
    // per-thread, like the sweep pool), so the core is CONSTRUCTED on
    // the thread that will own it; startup errors come back over a
    // ready-channel before any traffic is accepted.
    let core_flag = shutdown.clone();
    let core_cfg = cfg.clone();
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    let core_thread = std::thread::spawn(move || {
        let core = match build_core(&core_cfg) {
            Ok(core) => {
                let _ = ready_tx.send(Ok(()));
                core
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return None;
            }
        };
        Some(core_loop(core, rx, core_flag))
    });
    match ready_rx.recv() {
        Ok(Ok(())) => {}
        Ok(Err(e)) => {
            let _ = core_thread.join();
            return Err(e);
        }
        Err(_) => {
            let _ = core_thread.join();
            return Err(err!("scheduler-core thread died during startup"));
        }
    }

    let accept_flag = shutdown.clone();
    let accept_tx = tx.clone();
    let accept_thread =
        std::thread::spawn(move || accept_loop(listener, accept_tx, accept_flag));

    // Optional Prometheus scrape endpoint: a second listener whose
    // connections fetch the `metrics_prom` body through the same bounded
    // queue (so the core thread renders it — no shared counters).
    let (prom_thread, prom_addr) = match &cfg.prom_addr {
        Some(addr) => {
            let prom_listener = TcpListener::bind(addr)
                .map_err(|e| err!("bind --prom-addr {addr}: {e}"))?;
            let bound = prom_listener.local_addr().map_err(Error::from)?;
            prom_listener.set_nonblocking(true).map_err(Error::from)?;
            log_info!("prometheus exposition at http://{bound}/metrics");
            let prom_flag = shutdown.clone();
            let prom_tx = tx.clone();
            let t = std::thread::spawn(move || {
                prom_loop(prom_listener, prom_tx, prom_flag)
            });
            (Some(t), Some(bound))
        }
        None => (None, None),
    };

    let timer_thread = if cfg.slot_ms > 0 {
        let timer_flag = shutdown.clone();
        let timer_tx = tx;
        let ms = cfg.slot_ms;
        Some(std::thread::spawn(move || 'timer: loop {
            // sleep the slot in small chunks so a drain request never
            // waits out a long slot period
            let mut remaining = ms;
            while remaining > 0 {
                let chunk = remaining.min(20);
                std::thread::sleep(Duration::from_millis(chunk));
                if timer_flag.load(Ordering::SeqCst) {
                    break 'timer;
                }
                remaining -= chunk;
            }
            if timer_tx.send(CoreMsg::new(Request::Tick, None)).is_err() {
                break;
            }
        }))
    } else {
        None
    };

    Ok(DaemonHandle {
        addr,
        shutdown,
        core: core_thread,
        accept: accept_thread,
        timer: timer_thread,
        prom: prom_thread,
        prom_addr,
    })
}

/// The single scheduler-core thread: applies requests in queue order and
/// exits when every sender is gone (acceptor + handlers + timer have
/// drained and closed). Requests accepted into the queue are always
/// answered, shutdown or not.
fn core_loop(
    mut core: ServiceCore,
    rx: Receiver<CoreMsg>,
    shutdown: Arc<AtomicBool>,
) -> ServiceReport {
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(msg) => {
                if obs::flags() != 0 {
                    obs::record(
                        Stage::QueueWait,
                        msg.enqueued.elapsed().as_micros() as u64,
                    );
                }
                let response = core.apply(&msg.req);
                if matches!(msg.req, Request::Shutdown) {
                    shutdown.store(true, Ordering::SeqCst);
                }
                if let Some(ch) = msg.resp {
                    let _ = ch.send(response.to_string());
                }
            }
            Err(RecvTimeoutError::Timeout) => {} // keep serving until senders drop
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    log_debug!("core: queue drained, computing final report");
    core.report()
}

/// Serve the Prometheus text exposition over plain HTTP: any request on
/// the `--prom-addr` listener is answered with the `metrics_prom` body
/// (fetched through the bounded queue, so the core thread renders it).
fn prom_loop(listener: TcpListener, tx: SyncSender<CoreMsg>, shutdown: Arc<AtomicBool>) {
    use std::io::Read as _;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((mut stream, peer)) => {
                log_debug!("prom: scrape from {peer}");
                let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
                // consume the request head best-effort; every path is
                // answered with the exposition
                let mut head = [0u8; 1024];
                let _ = stream.read(&mut head);
                let Some(body) = fetch_prom_body(&tx) else { break };
                let resp = format!(
                    "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                     Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = stream.write_all(resp.as_bytes());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Round-trip a `metrics_prom` request through the core queue and pull
/// the text body out of the JSON response. `None` when the daemon is
/// draining (the queue or core is gone).
fn fetch_prom_body(tx: &SyncSender<CoreMsg>) -> Option<String> {
    let (rtx, rrx) = channel();
    tx.send(CoreMsg::new(Request::MetricsProm, Some(rtx))).ok()?;
    let line = rrx.recv().ok()?;
    let v = Json::parse(&line).ok()?;
    v.get("prom").and_then(Json::as_str).map(str::to_string)
}

/// Accept connections until shutdown, spawning one handler thread per
/// connection; joins the handlers before exiting (so `DaemonHandle::join`
/// observes a fully drained frontend).
fn accept_loop(listener: TcpListener, tx: SyncSender<CoreMsg>, shutdown: Arc<AtomicBool>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                log_debug!("conn: accepted {peer}");
                let tx = tx.clone();
                let flag = shutdown.clone();
                handlers.push(std::thread::spawn(move || handle_connection(stream, tx, flag)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    log_debug!("drain: joining {} connection handler(s)", handlers.len());
    for h in handlers {
        let _ = h.join();
    }
    log_debug!("drain: frontend closed");
}

/// One connection: read NDJSON request lines, forward each through the
/// bounded queue (blocking on queue-full = backpressure), write the
/// response line. Closes on EOF, I/O error, or shutdown.
fn handle_connection(stream: TcpStream, tx: SyncSender<CoreMsg>, shutdown: Arc<AtomicBool>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    let mut line = String::new();
    'conn: loop {
        // Accumulate one full line; a read timeout leaves partial data in
        // `line` and is retried (checking the shutdown flag in between).
        let at_eof = loop {
            match reader.read_line(&mut line) {
                Ok(0) => break true,
                Ok(_) => break !line.ends_with('\n'),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if shutdown.load(Ordering::SeqCst) {
                        break 'conn;
                    }
                }
                Err(_) => break 'conn,
            }
        };
        let trimmed = line.trim();
        if !trimmed.is_empty() {
            let response = match Request::parse(trimmed) {
                Err(e) => err_response(&e).to_string(),
                Ok(req) => {
                    let (rtx, rrx) = channel();
                    if tx.send(CoreMsg::new(req, Some(rtx))).is_err() {
                        break 'conn;
                    }
                    match rrx.recv() {
                        Ok(s) => s,
                        Err(_) => break 'conn,
                    }
                }
            };
            if stream
                .write_all(response.as_bytes())
                .and_then(|_| stream.write_all(b"\n"))
                .and_then(|_| stream.flush())
                .is_err()
            {
                break 'conn;
            }
        }
        line.clear();
        if at_eof || shutdown.load(Ordering::SeqCst) {
            break 'conn;
        }
    }
    if let Ok(peer) = stream.peer_addr() {
        log_debug!("conn: closed {peer}");
    }
}

// ---------------------------------------------------------------------------
// Termination signals (SIGTERM/SIGINT → graceful drain), used by
// `dmlrs serve`. Std-only: the `signal(2)` symbol is declared directly
// against the always-linked platform libc; the handler only touches an
// atomic flag (async-signal-safe).

static TERM_FLAG: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sig {
    use std::sync::atomic::Ordering;

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_term(_signum: i32) {
        super::TERM_FLAG.store(true, Ordering::SeqCst);
    }

    #[allow(clippy::fn_to_numeric_cast, clippy::fn_to_numeric_cast_with_truncation)]
    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
            signal(SIGINT, on_term as extern "C" fn(i32) as usize);
        }
    }
}

/// Install the SIGTERM/SIGINT → drain-flag handler (no-op off Unix).
pub fn install_term_handler() {
    #[cfg(unix)]
    sig::install();
}

/// Has a termination signal been received since
/// [`install_term_handler`]?
pub fn termination_requested() -> bool {
    TERM_FLAG.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::super::core::synthetic_service_config;
    use super::*;

    fn client(addr: SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (reader, stream)
    }

    fn roundtrip(
        reader: &mut BufReader<TcpStream>,
        stream: &mut TcpStream,
        line: &str,
    ) -> String {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    }

    #[test]
    fn daemon_serves_status_and_drains_on_shutdown() {
        let cfg = DaemonConfig::new(synthetic_service_config("fifo", 1, 4, 6, 8));
        let handle = start(cfg).unwrap();
        let (mut reader, mut stream) = client(handle.addr);
        let status = roundtrip(&mut reader, &mut stream, "{\"op\":\"status\"}");
        assert!(status.contains("\"ok\":true"), "{status}");
        assert!(status.contains("\"slot\":0"), "{status}");
        let bad = roundtrip(&mut reader, &mut stream, "{\"op\":\"warp\"}");
        assert!(bad.contains("\"ok\":false"), "{bad}");
        let tick = roundtrip(&mut reader, &mut stream, "{\"op\":\"tick\"}");
        assert!(tick.contains("\"slot\":1"), "{tick}");
        let down = roundtrip(&mut reader, &mut stream, "{\"op\":\"shutdown\"}");
        assert!(down.contains("\"draining\":true"), "{down}");
        let report = handle.join().unwrap();
        assert_eq!(report.slot, 1);
        assert_eq!(report.submitted, 0);
    }

    #[test]
    fn prom_endpoint_serves_text_exposition_over_http() {
        let mut cfg = DaemonConfig::new(synthetic_service_config("fifo", 1, 4, 6, 8));
        cfg.prom_addr = Some("127.0.0.1:0".to_string());
        let handle = start(cfg).unwrap();
        let prom = handle.prom_addr.expect("prom listener bound");
        let mut stream = TcpStream::connect(prom).unwrap();
        stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        use std::io::Read as _;
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        assert!(resp.contains("# TYPE dmlrs_stage_duration_us histogram"), "{resp}");
        assert!(resp.contains("dmlrs_submitted_total 0"), "{resp}");
        // the NDJSON op answers with the same body wrapped in JSON
        let (mut reader, mut ndstream) = client(handle.addr);
        let m = roundtrip(&mut reader, &mut ndstream, "{\"op\":\"metrics_prom\"}");
        assert!(m.contains("\"ok\":true"), "{m}");
        assert!(m.contains("dmlrs_stage_duration_us"), "{m}");
        let dump = roundtrip(&mut reader, &mut ndstream, "{\"op\":\"debug_dump\"}");
        assert!(dump.contains("\"flight\""), "{dump}");
        handle.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn wall_clock_timer_drives_the_slot_forward() {
        let mut cfg = DaemonConfig::new(synthetic_service_config("fifo", 1, 4, 6, 8));
        cfg.slot_ms = 20;
        let handle = start(cfg).unwrap();
        // wait for at least one auto-tick
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let (mut reader, mut stream) = client(handle.addr);
        let mut advanced = false;
        while std::time::Instant::now() < deadline {
            let status = roundtrip(&mut reader, &mut stream, "{\"op\":\"status\"}");
            if !status.contains("\"slot\":0") {
                advanced = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(advanced, "the slot timer never ticked");
        handle.shutdown();
        let report = handle.join().unwrap();
        assert!(report.slot > 0);
    }
}
