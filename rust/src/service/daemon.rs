//! The admission daemon: std-only TCP frontend around the sharded
//! service ([`super::shard`]).
//!
//! Architecture (one box per thread; `R` reactors, `k` cells):
//!
//! ```text
//!  clients ──► acceptor ─┬─► reactor 0 ─┐                    ┌─► cell 0
//!   (10k conns, no       ├─► reactor 1 ─┼─► bounded MPSC ─► router ─► cell 1
//!    thread per conn)    └─► reactor ⋯ ─┘   (backpressure)   └─► cell ⋯
//!  slot timer (optional) ────────────────────────┘
//! ```
//!
//! * The acceptor drains a **nonblocking** listener and deals accepted
//!   sockets round-robin to a small fixed pool of reactor threads; each
//!   reactor polls its connections' nonblocking sockets in a readiness
//!   loop (read what's ready, parse complete NDJSON lines, flush what's
//!   writable) — 10k concurrent `dmlrs load` connections cost 10k
//!   buffers, not 10k OS threads.
//! * Parsed requests flow through a *bounded* `sync_channel` into the
//!   router; a full queue blocks the reactor — natural backpressure
//!   toward the clients — while each single-threaded cell core preserves
//!   PR 3's no-locks-in-the-solve-path determinism contract.
//! * Responses travel back on a per-request channel and are written in
//!   request order per connection, so every connection sees its own
//!   request/response ordering.
//! * `--slot-ms N` starts a wall-clock timer thread that enqueues a
//!   `tick` every N ms; with `N = 0` the clock is purely virtual (driven
//!   by `tick` requests — what the parity tests and `dmlrs load --ticks`
//!   use).
//! * Graceful drain: a `shutdown` request (or SIGTERM/SIGINT in
//!   `dmlrs serve`) sets the shared stop flag; the acceptor stops
//!   accepting, reactors stop reading, flush every in-flight response,
//!   and close; the router and cells exit once every sender is gone — no
//!   request is dropped after it was accepted into the queue.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::err;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::{log_debug, log_info};

use super::core::{ServiceConfig, ServiceReport};
use super::protocol::{err_response, Request};
use super::shard::{self, RouterMsg, ShardConfig};

/// Daemon configuration on top of the core's [`ServiceConfig`].
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Bind address; port 0 picks an ephemeral port (the bound address is
    /// reported on the handle).
    pub addr: String,
    pub service: ServiceConfig,
    /// Wall-clock slot length in ms; 0 = virtual clock (tick requests
    /// only).
    pub slot_ms: u64,
    /// Bound of the request queue between the reactors and the router.
    pub queue_cap: usize,
    /// Start a fresh op-log at this path (cell `i` of a multi-shard
    /// daemon appends to `<path>.cell<i>`).
    pub oplog: Option<String>,
    /// Replay this op-log at startup (same per-cell suffix rule), then
    /// continue appending to it.
    pub recover: Option<String>,
    /// Also serve the Prometheus text exposition over plain HTTP at this
    /// address (`GET` anything → the `metrics_prom` body).
    pub prom_addr: Option<String>,
    /// Number of cluster cells (`--shards`); 1 = the unsharded
    /// byte-parity passthrough.
    pub shards: usize,
    /// Cell drain-batch bound (`--batch`); 1 = decide strictly one
    /// message at a time (the byte-parity oracle).
    pub batch: usize,
    /// Readiness-loop reactor threads (`--reactors`).
    pub reactors: usize,
}

impl DaemonConfig {
    pub fn new(service: ServiceConfig) -> DaemonConfig {
        DaemonConfig {
            addr: "127.0.0.1:0".to_string(),
            service,
            slot_ms: 0,
            queue_cap: 64,
            oplog: None,
            recover: None,
            prom_addr: None,
            shards: 1,
            batch: 8,
            reactors: 4,
        }
    }
}

/// A running daemon. Dropping the handle does not stop the daemon; call
/// [`DaemonHandle::shutdown`] (or send a `shutdown` request) and then
/// [`DaemonHandle::join`].
pub struct DaemonHandle {
    /// The actually bound address (resolves port 0).
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    /// The router thread; `None` only when startup failed (which `start`
    /// already reported).
    core: JoinHandle<Option<ServiceReport>>,
    accept: JoinHandle<()>,
    timer: Option<JoinHandle<()>>,
    prom: Option<JoinHandle<()>>,
    /// The bound Prometheus scrape address, when `--prom-addr` was given.
    pub prom_addr: Option<SocketAddr>,
}

impl DaemonHandle {
    /// Request a graceful drain (idempotent).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Has a drain been requested (via this handle, a `shutdown` request,
    /// or a termination signal forwarded by the CLI)?
    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Wait for the daemon to finish draining and return the merged
    /// final deterministic state snapshot. Blocks until a shutdown was
    /// requested by someone.
    pub fn join(self) -> Result<ServiceReport> {
        self.accept.join().map_err(|_| err!("accept thread panicked"))?;
        if let Some(t) = self.timer {
            t.join().map_err(|_| err!("slot-timer thread panicked"))?;
        }
        if let Some(p) = self.prom {
            p.join().map_err(|_| err!("prometheus thread panicked"))?;
        }
        self.core
            .join()
            .map_err(|_| err!("router thread panicked"))?
            .ok_or_else(|| err!("scheduler cells never started"))
    }
}

/// Start the daemon: bind, spawn the cell / router / acceptor / reactor
/// threads (plus the optional slot timer and Prometheus listener), and
/// return once every cell is up.
pub fn start(cfg: DaemonConfig) -> Result<DaemonHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| err!("bind {}: {e}", cfg.addr))?;
    let addr = listener.local_addr().map_err(Error::from)?;
    listener.set_nonblocking(true).map_err(Error::from)?;

    if let (Some(o), Some(r)) = (&cfg.oplog, &cfg.recover) {
        if o != r {
            return Err(err!(
                "--oplog {o} and --recover {r} must name the same file (recovery \
                 resumes appending to the replayed log)"
            ));
        }
    }

    let shutdown = Arc::new(AtomicBool::new(false));
    let (tx, rx) = sync_channel::<RouterMsg>(cfg.queue_cap.max(1));

    // Cells are constructed on their owning threads (the boxed scheduler
    // is not Send by contract, like the sweep pool); shard::spawn blocks
    // until every cell reported ready, so startup errors surface here
    // before any traffic is accepted.
    let core_thread = shard::spawn(
        ShardConfig {
            service: cfg.service.clone(),
            shards: cfg.shards,
            batch: cfg.batch,
            oplog: cfg.oplog.clone(),
            recover: cfg.recover.clone(),
        },
        rx,
        shutdown.clone(),
    )?;

    let accept_flag = shutdown.clone();
    let accept_tx = tx.clone();
    let reactors = cfg.reactors.max(1);
    let accept_thread =
        std::thread::spawn(move || accept_loop(listener, accept_tx, accept_flag, reactors));

    // Optional Prometheus scrape endpoint: a second listener whose
    // connections fetch the `metrics_prom` body through the same bounded
    // queue (so the router renders the merged exposition — no shared
    // counters).
    let (prom_thread, prom_addr) = match &cfg.prom_addr {
        Some(addr) => {
            let prom_listener = TcpListener::bind(addr)
                .map_err(|e| err!("bind --prom-addr {addr}: {e}"))?;
            let bound = prom_listener.local_addr().map_err(Error::from)?;
            prom_listener.set_nonblocking(true).map_err(Error::from)?;
            log_info!("prometheus exposition at http://{bound}/metrics");
            let prom_flag = shutdown.clone();
            let prom_tx = tx.clone();
            let t = std::thread::spawn(move || {
                prom_loop(prom_listener, prom_tx, prom_flag)
            });
            (Some(t), Some(bound))
        }
        None => (None, None),
    };

    let timer_thread = if cfg.slot_ms > 0 {
        let timer_flag = shutdown.clone();
        let timer_tx = tx;
        let ms = cfg.slot_ms;
        Some(std::thread::spawn(move || 'timer: loop {
            // sleep the slot in small chunks so a drain request never
            // waits out a long slot period
            let mut remaining = ms;
            while remaining > 0 {
                let chunk = remaining.min(20);
                std::thread::sleep(Duration::from_millis(chunk));
                if timer_flag.load(Ordering::SeqCst) {
                    break 'timer;
                }
                remaining -= chunk;
            }
            if timer_tx.send(RouterMsg::new(Request::Tick, None)).is_err() {
                break;
            }
        }))
    } else {
        None
    };

    Ok(DaemonHandle {
        addr,
        shutdown,
        core: core_thread,
        accept: accept_thread,
        timer: timer_thread,
        prom: prom_thread,
        prom_addr,
    })
}

/// Serve the Prometheus text exposition over plain HTTP: any request on
/// the `--prom-addr` listener is answered with the `metrics_prom` body
/// (fetched through the bounded queue, so the router renders it).
fn prom_loop(listener: TcpListener, tx: SyncSender<RouterMsg>, shutdown: Arc<AtomicBool>) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((mut stream, peer)) => {
                log_debug!("prom: scrape from {peer}");
                let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
                // consume the request head best-effort; every path is
                // answered with the exposition
                let mut head = [0u8; 1024];
                let _ = stream.read(&mut head);
                let Some(body) = fetch_prom_body(&tx) else { break };
                let resp = format!(
                    "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                     Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = stream.write_all(resp.as_bytes());
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Round-trip a `metrics_prom` request through the router queue and pull
/// the text body out of the JSON response. `None` when the daemon is
/// draining (the queue or router is gone).
fn fetch_prom_body(tx: &SyncSender<RouterMsg>) -> Option<String> {
    let (rtx, rrx) = channel();
    tx.send(RouterMsg::new(Request::MetricsProm, Some(rtx))).ok()?;
    let line = rrx.recv().ok()?;
    let v = Json::parse(&line).ok()?;
    v.get("prom").and_then(Json::as_str).map(str::to_string)
}

/// Accept connections until shutdown, dealing accepted sockets
/// round-robin to a fixed pool of reactor threads; joins the reactors
/// before exiting (so `DaemonHandle::join` observes a fully drained
/// frontend).
fn accept_loop(
    listener: TcpListener,
    tx: SyncSender<RouterMsg>,
    shutdown: Arc<AtomicBool>,
    reactors: usize,
) {
    let mut deals: Vec<Sender<TcpStream>> = Vec::with_capacity(reactors);
    let mut handles: Vec<JoinHandle<()>> = Vec::with_capacity(reactors);
    for _ in 0..reactors {
        let (deal_tx, deal_rx) = channel::<TcpStream>();
        let tx = tx.clone();
        let flag = shutdown.clone();
        handles.push(std::thread::spawn(move || reactor_loop(deal_rx, tx, flag)));
        deals.push(deal_tx);
    }
    drop(tx);
    let mut next = 0usize;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // drain the whole accept backlog before sleeping: a load test
        // opening thousands of connections at once lands in one sweep
        let mut accepted = false;
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    log_debug!("conn: accepted {peer}");
                    let _ = deals[next % deals.len()].send(stream);
                    next = next.wrapping_add(1);
                    accepted = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        if !accepted {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    log_debug!("drain: closing {} reactor(s)", handles.len());
    drop(deals); // reactors stop adopting, drain, and exit
    for h in handles {
        let _ = h.join();
    }
    log_debug!("drain: frontend closed");
}

/// An in-flight response slot: answers are written back in request
/// order, so a parse error answered inline queues behind earlier
/// requests still at the router.
enum Pending {
    Ready(String),
    Waiting(Receiver<String>),
}

/// Reject request lines above this size without a newline — a hostile
/// client streaming an endless line would otherwise grow the read
/// buffer without bound.
const MAX_LINE_BYTES: usize = 1 << 20;

/// One nonblocking connection owned by a reactor.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet terminated by a newline.
    rbuf: Vec<u8>,
    /// In-flight responses, in request order.
    pending: VecDeque<Pending>,
    /// Serialized responses not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// Peer sent EOF (or the daemon is draining): read no further
    /// requests, but flush what is owed.
    closing: bool,
    /// Tear down regardless of owed bytes (I/O error, hostile input).
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        let _ = stream.set_nodelay(true);
        let _ = stream.set_nonblocking(true);
        Conn {
            stream,
            rbuf: Vec::new(),
            pending: VecDeque::new(),
            wbuf: Vec::new(),
            closing: false,
            dead: false,
        }
    }

    /// Read everything the socket has ready and enqueue a response slot
    /// per complete line. Returns true if any progress was made.
    fn pump_reads(&mut self, chunk: &mut [u8], tx: &SyncSender<RouterMsg>) -> bool {
        if self.closing || self.dead {
            return false;
        }
        let mut progress = false;
        loop {
            match self.stream.read(chunk) {
                Ok(0) => {
                    self.closing = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return progress;
                }
            }
        }
        while let Some(pos) = self.rbuf.iter().position(|&b| b == b'\n') {
            let raw: Vec<u8> = self.rbuf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&raw);
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            match Request::parse(trimmed) {
                Err(e) => self.pending.push_back(Pending::Ready(err_response(&e).to_string())),
                Ok(req) => {
                    let (rtx, rrx) = channel();
                    // blocking on a full queue = backpressure toward
                    // every connection this reactor owns
                    if tx.send(RouterMsg::new(req, Some(rtx))).is_err() {
                        self.dead = true;
                        return true;
                    }
                    self.pending.push_back(Pending::Waiting(rrx));
                }
            }
            progress = true;
        }
        if self.rbuf.len() > MAX_LINE_BYTES {
            log_debug!("conn: dropping peer with an unterminated {}-byte line", self.rbuf.len());
            self.dead = true;
        }
        progress
    }

    /// Move arrived responses (in request order) into the write buffer
    /// and flush what the socket will take. Returns true on progress.
    fn pump_writes(&mut self) -> bool {
        if self.dead {
            return false;
        }
        let mut progress = false;
        while let Some(front) = self.pending.front_mut() {
            match front {
                Pending::Ready(_) => {
                    let Some(Pending::Ready(s)) = self.pending.pop_front() else {
                        unreachable!()
                    };
                    self.wbuf.extend_from_slice(s.as_bytes());
                    self.wbuf.push(b'\n');
                    progress = true;
                }
                Pending::Waiting(rx) => match rx.try_recv() {
                    Ok(s) => {
                        self.wbuf.extend_from_slice(s.as_bytes());
                        self.wbuf.push(b'\n');
                        self.pending.pop_front();
                        progress = true;
                    }
                    Err(TryRecvError::Empty) => break, // preserve order
                    Err(TryRecvError::Disconnected) => {
                        self.dead = true;
                        return progress;
                    }
                },
            }
        }
        let mut written = 0;
        while written < self.wbuf.len() {
            match self.stream.write(&self.wbuf[written..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    written += n;
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if written > 0 {
            self.wbuf.drain(..written);
        }
        progress
    }

    /// Nothing left to serve: every accepted request answered and
    /// flushed.
    fn drained(&self) -> bool {
        self.pending.is_empty() && self.wbuf.is_empty()
    }
}

/// One reactor thread: adopt connections dealt by the acceptor and poll
/// them in a readiness loop. Exits when the acceptor is gone and every
/// owned connection has drained.
fn reactor_loop(
    deal_rx: Receiver<TcpStream>,
    tx: SyncSender<RouterMsg>,
    shutdown: Arc<AtomicBool>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut chunk = vec![0u8; 64 * 1024];
    let mut acceptor_gone = false;
    loop {
        let draining = shutdown.load(Ordering::SeqCst);
        loop {
            match deal_rx.try_recv() {
                Ok(stream) => conns.push(Conn::new(stream)),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    acceptor_gone = true;
                    break;
                }
            }
        }
        let mut progress = false;
        for conn in conns.iter_mut() {
            if draining {
                // stop reading; finish answering what was accepted
                conn.closing = true;
            }
            progress |= conn.pump_reads(&mut chunk, &tx);
            progress |= conn.pump_writes();
        }
        conns.retain(|c| !c.dead && !(c.closing && c.drained()));
        if acceptor_gone && conns.is_empty() {
            break;
        }
        if !progress {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

// ---------------------------------------------------------------------------
// Termination signals (SIGTERM/SIGINT → graceful drain), used by
// `dmlrs serve`. Std-only: the `signal(2)` symbol is declared directly
// against the always-linked platform libc; the handler only touches an
// atomic flag (async-signal-safe).

static TERM_FLAG: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sig {
    use std::sync::atomic::Ordering;

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_term(_signum: i32) {
        super::TERM_FLAG.store(true, Ordering::SeqCst);
    }

    #[allow(clippy::fn_to_numeric_cast, clippy::fn_to_numeric_cast_with_truncation)]
    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
            signal(SIGINT, on_term as extern "C" fn(i32) as usize);
        }
    }
}

/// Install the SIGTERM/SIGINT → drain-flag handler (no-op off Unix).
pub fn install_term_handler() {
    #[cfg(unix)]
    sig::install();
}

/// Has a termination signal been received since
/// [`install_term_handler`]?
pub fn termination_requested() -> bool {
    TERM_FLAG.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::super::core::synthetic_service_config;
    use super::*;
    use std::io::{BufRead, BufReader};

    fn client(addr: SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (reader, stream)
    }

    fn roundtrip(
        reader: &mut BufReader<TcpStream>,
        stream: &mut TcpStream,
        line: &str,
    ) -> String {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    }

    #[test]
    fn daemon_serves_status_and_drains_on_shutdown() {
        let cfg = DaemonConfig::new(synthetic_service_config("fifo", 1, 4, 6, 8));
        let handle = start(cfg).unwrap();
        let (mut reader, mut stream) = client(handle.addr);
        let status = roundtrip(&mut reader, &mut stream, "{\"op\":\"status\"}");
        assert!(status.contains("\"ok\":true"), "{status}");
        assert!(status.contains("\"slot\":0"), "{status}");
        let bad = roundtrip(&mut reader, &mut stream, "{\"op\":\"warp\"}");
        assert!(bad.contains("\"ok\":false"), "{bad}");
        let tick = roundtrip(&mut reader, &mut stream, "{\"op\":\"tick\"}");
        assert!(tick.contains("\"slot\":1"), "{tick}");
        let down = roundtrip(&mut reader, &mut stream, "{\"op\":\"shutdown\"}");
        assert!(down.contains("\"draining\":true"), "{down}");
        let report = handle.join().unwrap();
        assert_eq!(report.slot, 1);
        assert_eq!(report.submitted, 0);
    }

    #[test]
    fn prom_endpoint_serves_text_exposition_over_http() {
        let mut cfg = DaemonConfig::new(synthetic_service_config("fifo", 1, 4, 6, 8));
        cfg.prom_addr = Some("127.0.0.1:0".to_string());
        let handle = start(cfg).unwrap();
        let prom = handle.prom_addr.expect("prom listener bound");
        let mut stream = TcpStream::connect(prom).unwrap();
        stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        assert!(resp.contains("# TYPE dmlrs_stage_duration_us histogram"), "{resp}");
        assert!(resp.contains("dmlrs_submitted_total 0"), "{resp}");
        // the NDJSON op answers with the same body wrapped in JSON
        let (mut reader, mut ndstream) = client(handle.addr);
        let m = roundtrip(&mut reader, &mut ndstream, "{\"op\":\"metrics_prom\"}");
        assert!(m.contains("\"ok\":true"), "{m}");
        assert!(m.contains("dmlrs_stage_duration_us"), "{m}");
        let dump = roundtrip(&mut reader, &mut ndstream, "{\"op\":\"debug_dump\"}");
        assert!(dump.contains("\"flight\""), "{dump}");
        handle.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn wall_clock_timer_drives_the_slot_forward() {
        let mut cfg = DaemonConfig::new(synthetic_service_config("fifo", 1, 4, 6, 8));
        cfg.slot_ms = 20;
        let handle = start(cfg).unwrap();
        // wait for at least one auto-tick
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let (mut reader, mut stream) = client(handle.addr);
        let mut advanced = false;
        while std::time::Instant::now() < deadline {
            let status = roundtrip(&mut reader, &mut stream, "{\"op\":\"status\"}");
            if !status.contains("\"slot\":0") {
                advanced = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(advanced, "the slot timer never ticked");
        handle.shutdown();
        let report = handle.join().unwrap();
        assert!(report.slot > 0);
    }

    #[test]
    fn pipelined_requests_answer_in_order() {
        // several requests written before any response is read: the
        // readiness loop must answer them strictly in request order
        let cfg = DaemonConfig::new(synthetic_service_config("fifo", 1, 4, 6, 8));
        let handle = start(cfg).unwrap();
        let (mut reader, mut stream) = client(handle.addr);
        let mut batch = String::new();
        batch.push_str("{\"op\":\"status\"}\n");
        batch.push_str("not json\n");
        batch.push_str("{\"op\":\"tick\"}\n");
        batch.push_str("{\"op\":\"status\"}\n");
        stream.write_all(batch.as_bytes()).unwrap();
        stream.flush().unwrap();
        let mut lines = Vec::new();
        for _ in 0..4 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(line);
        }
        assert!(lines[0].contains("\"slot\":0"), "{}", lines[0]);
        assert!(lines[1].contains("\"ok\":false"), "{}", lines[1]);
        assert!(lines[2].contains("\"slot\":1"), "{}", lines[2]);
        assert!(lines[3].contains("\"slot\":1"), "{}", lines[3]);
        handle.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn sharded_daemon_serves_and_merges_over_the_wire() {
        let mut cfg = DaemonConfig::new(synthetic_service_config("fifo", 1, 8, 12, 8));
        cfg.shards = 4;
        cfg.batch = 4;
        let handle = start(cfg).unwrap();
        let (mut reader, mut stream) = client(handle.addr);
        let cells = roundtrip(&mut reader, &mut stream, "{\"op\":\"cells\"}");
        assert!(cells.contains("\"shards\":4"), "{cells}");
        let cluster = roundtrip(&mut reader, &mut stream, "{\"op\":\"cluster\"}");
        assert!(cluster.contains("\"machines\":8"), "{cluster}");
        let tick = roundtrip(&mut reader, &mut stream, "{\"op\":\"tick\"}");
        assert!(tick.contains("\"slot\":1"), "{tick}");
        let status = roundtrip(&mut reader, &mut stream, "{\"op\":\"status\"}");
        assert!(status.contains("\"slot\":1"), "{status}");
        assert!(status.contains("\"submitted\":0"), "{status}");
        let down = roundtrip(&mut reader, &mut stream, "{\"op\":\"shutdown\"}");
        assert!(down.contains("\"draining\":true"), "{down}");
        let report = handle.join().unwrap();
        assert_eq!(report.slot, 1);
        assert_eq!(report.alloc[0].len(), 8);
    }
}
