//! Append-only JSONL op-log: the admission daemon's crash-recovery
//! journal.
//!
//! The scheduler-core thread appends one flushed line per state-mutating
//! operation (`submit`, `tick`) after applying it, preceded by one
//! `open` header line recording the serving configuration. `--recover`
//! replays the ops through a freshly built core — the scheduler is
//! deterministic in the op sequence, so replay reproduces byte-identical
//! ledger state and metrics. The header guards against replaying a log
//! into a differently configured daemon.
//!
//! Reading reuses [`crate::util::jsonl::load_tolerant`] (the
//! `ResultStore` resume idiom): a truncated final line from a crashed
//! writer is dropped and the file truncated back, so at most the
//! in-flight operation is lost and appending resumes cleanly.

use std::io::Write as _;

use crate::jobs::Job;
use crate::util::json::{self, Json};

use super::codec;

/// One replayable operation.
#[derive(Debug, Clone)]
pub enum Op {
    /// Header: the serving configuration the log was recorded under.
    Open { header: Json },
    /// A job submission at virtual slot `slot`; `decision` is the
    /// recorded outcome (`admitted`/`rejected`/`deferred`), re-checked on
    /// replay to catch nondeterminism.
    Submit { slot: usize, decision: String, job: Job },
    /// A clock advance; `slot` is the slot *after* the tick.
    Tick { slot: usize },
    /// A wire-triggered elastic replan round at `slot`; `replanned` is the
    /// number of adopted plan changes, re-checked on replay. (Rounds the
    /// `--replan every:k` policy runs inside a tick are *not* journaled —
    /// replaying the tick re-runs them deterministically.)
    Replan { slot: usize, replanned: usize },
    /// A wire-triggered machine failure at `slot`; `evicted`/`migrated`
    /// record the migration pass outcome, re-checked on replay. (Churn
    /// events a `--churn` trace injects inside a tick are *not* journaled
    /// — replaying the tick re-runs them deterministically.)
    MachineDown { slot: usize, machine: usize, evicted: usize, migrated: usize },
    /// A wire-triggered machine rejoin at `slot`.
    MachineUp { slot: usize, machine: usize },
    /// A served `explain` query at `slot`. A pure read — replay just
    /// re-answers it against the rebuilt provenance store, proving the
    /// recovered daemon explains the same decisions the original did.
    Explain { slot: usize, job_id: usize },
}

impl Op {
    pub fn to_json(&self) -> Json {
        match self {
            Op::Open { header } => {
                let mut fields = vec![("op", json::s("open"))];
                // splice the header object's fields in
                if let Json::Obj(m) = header {
                    let mut out = std::collections::BTreeMap::new();
                    out.insert("op".to_string(), json::s("open"));
                    for (k, v) in m {
                        out.insert(k.clone(), v.clone());
                    }
                    return Json::Obj(out);
                }
                fields.push(("header", header.clone()));
                json::obj(fields)
            }
            Op::Submit { slot, decision, job } => json::obj(vec![
                ("op", json::s("submit")),
                ("slot", json::num(*slot as f64)),
                ("decision", json::s(decision)),
                ("job", codec::job_to_json(job)),
            ]),
            Op::Tick { slot } => json::obj(vec![
                ("op", json::s("tick")),
                ("slot", json::num(*slot as f64)),
            ]),
            Op::Replan { slot, replanned } => json::obj(vec![
                ("op", json::s("replan")),
                ("slot", json::num(*slot as f64)),
                ("replanned", json::num(*replanned as f64)),
            ]),
            Op::MachineDown { slot, machine, evicted, migrated } => json::obj(vec![
                ("op", json::s("machine_down")),
                ("slot", json::num(*slot as f64)),
                ("machine", json::num(*machine as f64)),
                ("evicted", json::num(*evicted as f64)),
                ("migrated", json::num(*migrated as f64)),
            ]),
            Op::MachineUp { slot, machine } => json::obj(vec![
                ("op", json::s("machine_up")),
                ("slot", json::num(*slot as f64)),
                ("machine", json::num(*machine as f64)),
            ]),
            Op::Explain { slot, job_id } => json::obj(vec![
                ("op", json::s("explain")),
                ("slot", json::num(*slot as f64)),
                ("job_id", json::num(*job_id as f64)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Op, String> {
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or("op-log line needs an \"op\" field")?;
        match op {
            "open" => Ok(Op::Open { header: v.clone() }),
            "submit" => Ok(Op::Submit {
                slot: v
                    .get("slot")
                    .and_then(Json::as_f64)
                    .ok_or("submit op needs slot")? as usize,
                decision: v
                    .get("decision")
                    .and_then(Json::as_str)
                    .ok_or("submit op needs decision")?
                    .to_string(),
                job: codec::job_from_json(v.get("job").ok_or("submit op needs job")?)?,
            }),
            "tick" => Ok(Op::Tick {
                slot: v
                    .get("slot")
                    .and_then(Json::as_f64)
                    .ok_or("tick op needs slot")? as usize,
            }),
            "replan" => Ok(Op::Replan {
                slot: v
                    .get("slot")
                    .and_then(Json::as_f64)
                    .ok_or("replan op needs slot")? as usize,
                replanned: v
                    .get("replanned")
                    .and_then(Json::as_f64)
                    .ok_or("replan op needs replanned")? as usize,
            }),
            "machine_down" => Ok(Op::MachineDown {
                slot: v
                    .get("slot")
                    .and_then(Json::as_f64)
                    .ok_or("machine_down op needs slot")? as usize,
                machine: v
                    .get("machine")
                    .and_then(Json::as_f64)
                    .ok_or("machine_down op needs machine")?
                    as usize,
                evicted: v
                    .get("evicted")
                    .and_then(Json::as_f64)
                    .ok_or("machine_down op needs evicted")?
                    as usize,
                migrated: v
                    .get("migrated")
                    .and_then(Json::as_f64)
                    .ok_or("machine_down op needs migrated")?
                    as usize,
            }),
            "machine_up" => Ok(Op::MachineUp {
                slot: v
                    .get("slot")
                    .and_then(Json::as_f64)
                    .ok_or("machine_up op needs slot")? as usize,
                machine: v
                    .get("machine")
                    .and_then(Json::as_f64)
                    .ok_or("machine_up op needs machine")?
                    as usize,
            }),
            "explain" => Ok(Op::Explain {
                slot: v
                    .get("slot")
                    .and_then(Json::as_f64)
                    .ok_or("explain op needs slot")? as usize,
                job_id: v
                    .get("job_id")
                    .and_then(Json::as_f64)
                    .ok_or("explain op needs job_id")?
                    as usize,
            }),
            other => Err(format!("unknown op-log entry {other:?}")),
        }
    }
}

/// The append side of the log.
#[derive(Debug)]
pub struct OpLog {
    path: String,
    file: std::fs::File,
}

impl OpLog {
    /// Create a fresh log at `path`, writing the `open` header. Refuses
    /// to overwrite an existing non-empty log (pass it to `--recover`
    /// instead — silently appending to a foreign log would corrupt it).
    pub fn create(path: &str, header: &Json) -> Result<OpLog, String> {
        if let Ok(meta) = std::fs::metadata(path) {
            if meta.len() > 0 {
                return Err(format!(
                    "op-log {path} already exists; use --recover {path} to resume it"
                ));
            }
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("{path}: {e}"))?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)
            .map_err(|e| format!("{path}: {e}"))?;
        let mut log = OpLog { path: path.to_string(), file };
        log.append(&Op::Open { header: header.clone() })?;
        Ok(log)
    }

    /// Reopen an existing (already replayed and possibly repaired) log
    /// for appending.
    pub fn open_append(path: &str) -> Result<OpLog, String> {
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| format!("{path}: {e}"))?;
        Ok(OpLog { path: path.to_string(), file })
    }

    /// Append one op as a flushed JSONL line.
    pub fn append(&mut self, op: &Op) -> Result<(), String> {
        self.append_all(std::slice::from_ref(op))
    }

    /// Append a burst of ops with **one** write + flush: the byte stream
    /// is identical to appending them one by one, but the batched core
    /// drain pays a single fsync-adjacent syscall per burst instead of
    /// one per admission (what makes `--batch N` cheaper than
    /// `--batch 1` without changing a single journaled byte).
    pub fn append_all(&mut self, ops: &[Op]) -> Result<(), String> {
        if ops.is_empty() {
            return Ok(());
        }
        let mut buf = String::new();
        for op in ops {
            buf.push_str(&op.to_json().to_string());
            buf.push('\n');
        }
        self.file
            .write_all(buf.as_bytes())
            .and_then(|_| self.file.flush())
            .map_err(|e| format!("{}: {e}", self.path))
    }

    /// Read a log for replay: tolerant of a truncated final line (which
    /// is dropped and the file truncated back). Returns the ops plus
    /// whether a repair happened.
    pub fn read(path: &str) -> Result<(Vec<Op>, bool), String> {
        let load = crate::util::jsonl::load_tolerant(path)?;
        let mut ops = Vec::with_capacity(load.lines.len());
        for (lineno, v) in load.lines {
            ops.push(Op::from_json(&v).map_err(|e| format!("{path}:{lineno}: {e}"))?);
        }
        Ok((ops, load.repaired))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::test_support::test_job;

    fn tmp(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("dmlrs_oplog_{tag}_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn header() -> Json {
        json::obj(vec![("scheduler", json::s("pd-ors")), ("horizon", json::num(8.0))])
    }

    #[test]
    fn write_then_read_round_trips() {
        let p = tmp("rt");
        let _ = std::fs::remove_file(&p);
        {
            let mut log = OpLog::create(&p, &header()).unwrap();
            log.append(&Op::Submit {
                slot: 0,
                decision: "admitted".into(),
                job: test_job(0),
            })
            .unwrap();
            log.append(&Op::Tick { slot: 1 }).unwrap();
            log.append(&Op::Replan { slot: 1, replanned: 2 }).unwrap();
            log.append(&Op::MachineDown {
                slot: 1,
                machine: 3,
                evicted: 1,
                migrated: 2,
            })
            .unwrap();
            log.append(&Op::MachineUp { slot: 2, machine: 3 }).unwrap();
            log.append(&Op::Explain { slot: 2, job_id: 0 }).unwrap();
        }
        let (ops, repaired) = OpLog::read(&p).unwrap();
        assert!(!repaired);
        assert_eq!(ops.len(), 7);
        assert!(matches!(ops[6], Op::Explain { slot: 2, job_id: 0 }));
        assert!(matches!(ops[3], Op::Replan { slot: 1, replanned: 2 }));
        assert!(matches!(
            ops[4],
            Op::MachineDown { slot: 1, machine: 3, evicted: 1, migrated: 2 }
        ));
        assert!(matches!(ops[5], Op::MachineUp { slot: 2, machine: 3 }));
        assert!(matches!(&ops[0], Op::Open { header }
            if header.get("scheduler").and_then(Json::as_str) == Some("pd-ors")));
        assert!(matches!(&ops[1], Op::Submit { slot: 0, decision, job }
            if decision == "admitted" && job.id == 0));
        assert!(matches!(ops[2], Op::Tick { slot: 1 }));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn truncated_tail_is_repaired_then_appendable() {
        let p = tmp("crash");
        let _ = std::fs::remove_file(&p);
        {
            let mut log = OpLog::create(&p, &header()).unwrap();
            log.append(&Op::Tick { slot: 1 }).unwrap();
        }
        {
            let mut f =
                std::fs::OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(b"{\"op\":\"submit\",\"slot\":1,\"jo").unwrap();
        }
        let (ops, repaired) = OpLog::read(&p).unwrap();
        assert!(repaired);
        assert_eq!(ops.len(), 2, "the in-flight op is dropped");
        // appending after the repair keeps the file clean
        let mut log = OpLog::open_append(&p).unwrap();
        log.append(&Op::Tick { slot: 2 }).unwrap();
        let (ops, repaired) = OpLog::read(&p).unwrap();
        assert!(!repaired);
        assert_eq!(ops.len(), 3);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn batched_append_writes_identical_bytes() {
        let (p1, p2) = (tmp("one"), tmp("all"));
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
        let ops = vec![
            Op::Submit { slot: 0, decision: "admitted".into(), job: test_job(0) },
            Op::Submit { slot: 0, decision: "rejected".into(), job: test_job(1) },
            Op::Tick { slot: 1 },
        ];
        {
            let mut log = OpLog::create(&p1, &header()).unwrap();
            for op in &ops {
                log.append(op).unwrap();
            }
        }
        {
            let mut log = OpLog::create(&p2, &header()).unwrap();
            log.append_all(&ops).unwrap();
            log.append_all(&[]).unwrap(); // a no-op, not an empty line
        }
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }

    #[test]
    fn create_refuses_existing_nonempty_log() {
        let p = tmp("exists");
        std::fs::write(&p, "{\"op\":\"open\"}\n").unwrap();
        let e = OpLog::create(&p, &header()).unwrap_err();
        assert!(e.contains("--recover"), "{e}");
        let _ = std::fs::remove_file(&p);
    }
}
