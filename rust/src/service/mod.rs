//! The online admission service: PD-ORS (or any registry scheduler)
//! served live, the way the paper means it — jobs arrive one by one over
//! the wire and Algorithm 1 admits/rejects and places them on the spot.
//!
//! * [`core`]     — [`ServiceCore`]: the single-threaded scheduler core
//!   (boxed scheduler + the shared
//!   [`AdmissionCore`](crate::sim::AdmissionCore) + virtual slot clock +
//!   metrics + op-log). Also the `--recover` replay engine.
//! * [`daemon`]   — `dmlrs serve`: std-only TCP daemon; a nonblocking
//!   readiness loop (fixed reactor-thread pool, no thread per
//!   connection) feeds a bounded MPSC queue into the sharded router
//!   (backpressure on queue-full, graceful drain on shutdown/SIGTERM).
//! * [`shard`]    — `--shards k`: the cluster partitioned into cells,
//!   each a full [`ServiceCore`] over a disjoint ledger slice on its own
//!   thread, behind a router that places submits on the least-loaded
//!   compatible cell and fans cluster-wide ops out to all cells.
//! * [`protocol`] — the NDJSON wire protocol (`submit`, `tick`, `status`,
//!   `cluster`, `metrics`, `metrics_prom`, `debug_dump`, `shutdown`).
//! * [`codec`]    — `Job`/`Schedule` ⇄ JSON with bit-identical `f64`
//!   round-trips (what makes op-log replay exact).
//! * [`oplog`]    — the append-only JSONL crash-recovery journal
//!   (truncated-tail tolerant, like the sweep `ResultStore`).
//! * [`load`]     — `dmlrs load`: multi-connection open-loop load
//!   generator reporting throughput + p50/p95/p99 admission latency into
//!   `BENCH_service.json`.
//!
//! Because daemon and simulator share the `AdmissionCore` code path and
//! schedulers are built from the same `(workload, cluster, horizon)`
//! triple, feeding a workload's arrival sequence through the daemon in
//! virtual-clock mode (`dmlrs load --ticks`) reproduces a `SimEngine`
//! run's admit/reject decisions exactly
//! (`rust/tests/service_roundtrip.rs`).

pub mod codec;
pub mod core;
pub mod daemon;
pub mod load;
pub mod oplog;
pub mod protocol;
pub mod shard;

pub use self::core::{
    synthetic_service_config, CellId, PromCounters, ServiceConfig, ServiceCore,
    ServiceReport,
};
pub use daemon::{
    install_term_handler, start as start_daemon, termination_requested, DaemonConfig,
    DaemonHandle,
};
pub use load::{run_load, LoadConfig, LoadReport};
pub use oplog::{Op, OpLog};
pub use protocol::Request;
pub use shard::{merge_reports, RouterMsg, ShardConfig, ShardSpec};
