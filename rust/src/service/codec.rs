//! Wire codec: [`Job`] and [`Schedule`] ⇄ JSON.
//!
//! Everything the admission service exchanges — submit requests, decision
//! responses, and op-log entries — round-trips through these encoders.
//! Numbers are serialized with Rust's shortest-round-trip `f64`
//! formatting, so a decode(encode(x)) is *bit-identical*: replaying an
//! op-log reproduces the exact ledger state (the `--recover` contract).

use crate::cluster::{ResVec, NUM_RESOURCES};
use crate::jobs::{Job, Schedule, Sigmoid, SlotPlacement};
use crate::util::json::{self, Json};

pub fn resvec_to_json(v: &ResVec) -> Json {
    json::arr_f64(&v.0)
}

pub fn resvec_from_json(v: &Json) -> Result<ResVec, String> {
    let arr = v.as_arr().ok_or("resource vector must be an array")?;
    if arr.len() != NUM_RESOURCES {
        return Err(format!("resource vector needs {NUM_RESOURCES} entries"));
    }
    let mut out = ResVec::zero();
    for (i, x) in arr.iter().enumerate() {
        out.0[i] = x.as_f64().ok_or("resource vector entries must be numbers")?;
    }
    Ok(out)
}

pub fn job_to_json(job: &Job) -> Json {
    json::obj(vec![
        ("id", json::num(job.id as f64)),
        ("arrival", json::num(job.arrival as f64)),
        ("epochs", json::num(job.epochs as f64)),
        ("samples", json::num(job.samples)),
        ("grad_size_mb", json::num(job.grad_size_mb)),
        ("tau", json::num(job.tau)),
        ("gamma", json::num(job.gamma)),
        ("batch", json::num(job.batch as f64)),
        ("worker_demand", resvec_to_json(&job.worker_demand)),
        ("ps_demand", resvec_to_json(&job.ps_demand)),
        ("b_int", json::num(job.b_int)),
        ("b_ext", json::num(job.b_ext)),
        ("theta1", json::num(job.utility.theta1)),
        ("theta2", json::num(job.utility.theta2)),
        ("theta3", json::num(job.utility.theta3)),
    ])
}

pub fn job_from_json(v: &Json) -> Result<Job, String> {
    let num = |k: &str| -> Result<f64, String> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("job: missing numeric field {k:?}"))
    };
    let res = |k: &str| -> Result<ResVec, String> {
        resvec_from_json(v.get(k).ok_or_else(|| format!("job: missing field {k:?}"))?)
            .map_err(|e| format!("job.{k}: {e}"))
    };
    Ok(Job {
        id: num("id")? as usize,
        arrival: num("arrival")? as usize,
        epochs: num("epochs")? as u64,
        samples: num("samples")?,
        grad_size_mb: num("grad_size_mb")?,
        tau: num("tau")?,
        gamma: num("gamma")?,
        batch: num("batch")? as u64,
        worker_demand: res("worker_demand")?,
        ps_demand: res("ps_demand")?,
        b_int: num("b_int")?,
        b_ext: num("b_ext")?,
        utility: Sigmoid {
            theta1: num("theta1")?,
            theta2: num("theta2")?,
            theta3: num("theta3")?,
        },
    })
}

pub fn schedule_to_json(s: &Schedule) -> Json {
    let slots: Vec<Json> = s
        .slots
        .iter()
        .map(|slot| {
            let placements: Vec<Json> = slot
                .placements
                .iter()
                .map(|&(h, w, ps)| {
                    Json::Arr(vec![
                        json::num(h as f64),
                        json::num(w as f64),
                        json::num(ps as f64),
                    ])
                })
                .collect();
            json::obj(vec![
                ("t", json::num(slot.t as f64)),
                ("placements", Json::Arr(placements)),
            ])
        })
        .collect();
    json::obj(vec![
        ("job_id", json::num(s.job_id as f64)),
        ("slots", Json::Arr(slots)),
    ])
}

pub fn schedule_from_json(v: &Json) -> Result<Schedule, String> {
    let job_id = v
        .get("job_id")
        .and_then(Json::as_f64)
        .ok_or("schedule: missing job_id")? as usize;
    let mut slots = Vec::new();
    for slot in v.get("slots").and_then(Json::as_arr).ok_or("schedule: missing slots")? {
        let t = slot.get("t").and_then(Json::as_f64).ok_or("slot: missing t")? as usize;
        let mut placements = Vec::new();
        for p in slot
            .get("placements")
            .and_then(Json::as_arr)
            .ok_or("slot: missing placements")?
        {
            let triple = p.as_arr().ok_or("placement must be [h, w, ps]")?;
            if triple.len() != 3 {
                return Err("placement must be [h, w, ps]".into());
            }
            let f = |i: usize| -> Result<f64, String> {
                triple[i].as_f64().ok_or_else(|| "placement entries must be numbers".into())
            };
            placements.push((f(0)? as usize, f(1)? as u64, f(2)? as u64));
        }
        slots.push(SlotPlacement { t, placements });
    }
    Ok(Schedule { job_id, slots })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::test_support::test_job;

    #[test]
    fn job_round_trips_bit_identically() {
        let mut job = test_job(7);
        job.samples = 123456.789012345;
        job.tau = 3.1e-5;
        job.utility = Sigmoid { theta1: 99.25, theta2: 0.375, theta3: 11.5 };
        let back = job_from_json(&job_to_json(&job)).unwrap();
        assert_eq!(back.id, job.id);
        assert_eq!(back.samples.to_bits(), job.samples.to_bits());
        assert_eq!(back.tau.to_bits(), job.tau.to_bits());
        assert_eq!(back.utility, job.utility);
        assert_eq!(back.worker_demand, job.worker_demand);
        // and through the serialized text, too
        let line = job_to_json(&job).to_string();
        let reparsed = job_from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(reparsed.samples.to_bits(), job.samples.to_bits());
        assert_eq!(reparsed.b_ext.to_bits(), job.b_ext.to_bits());
    }

    #[test]
    fn schedule_round_trips() {
        let s = Schedule {
            job_id: 3,
            slots: vec![
                SlotPlacement { t: 2, placements: vec![(0, 2, 1), (4, 1, 0)] },
                SlotPlacement { t: 3, placements: vec![(1, 3, 2)] },
            ],
        };
        let text = schedule_to_json(&s).to_string();
        let back = schedule_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn missing_fields_are_reported() {
        let e = job_from_json(&Json::parse("{\"id\": 1}").unwrap()).unwrap_err();
        assert!(e.contains("missing"), "{e}");
        let bad = Json::parse("{\"job_id\": 1, \"slots\": [{\"t\": 0}]}").unwrap();
        assert!(schedule_from_json(&bad).is_err());
    }
}
