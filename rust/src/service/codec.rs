//! Wire codec: [`Job`] and [`Schedule`] ⇄ JSON.
//!
//! Everything the admission service exchanges — submit requests, decision
//! responses, and op-log entries — round-trips through these encoders.
//! Numbers are serialized with Rust's shortest-round-trip `f64`
//! formatting, so a decode(encode(x)) is *bit-identical*: replaying an
//! op-log reproduces the exact ledger state (the `--recover` contract).

use crate::cluster::{ResVec, NUM_RESOURCES};
use crate::jobs::{speed, Job, Locality, Schedule, Sigmoid, SlotPlacement};
use crate::util::json::{self, Json};

pub fn resvec_to_json(v: &ResVec) -> Json {
    json::arr_f64(&v.0)
}

pub fn resvec_from_json(v: &Json) -> Result<ResVec, String> {
    let arr = v.as_arr().ok_or("resource vector must be an array")?;
    if arr.len() != NUM_RESOURCES {
        return Err(format!("resource vector needs {NUM_RESOURCES} entries"));
    }
    let mut out = ResVec::zero();
    for (i, x) in arr.iter().enumerate() {
        let x = x.as_f64().ok_or("resource vector entries must be numbers")?;
        if !x.is_finite() || x < 0.0 {
            return Err(format!(
                "resource vector entry {i} must be finite and ≥ 0, got {x}"
            ));
        }
        out.0[i] = x;
    }
    Ok(out)
}

pub fn job_to_json(job: &Job) -> Json {
    json::obj(vec![
        ("id", json::num(job.id as f64)),
        ("arrival", json::num(job.arrival as f64)),
        ("epochs", json::num(job.epochs as f64)),
        ("samples", json::num(job.samples)),
        ("grad_size_mb", json::num(job.grad_size_mb)),
        ("tau", json::num(job.tau)),
        ("gamma", json::num(job.gamma)),
        ("batch", json::num(job.batch as f64)),
        ("worker_demand", resvec_to_json(&job.worker_demand)),
        ("ps_demand", resvec_to_json(&job.ps_demand)),
        ("b_int", json::num(job.b_int)),
        ("b_ext", json::num(job.b_ext)),
        ("theta1", json::num(job.utility.theta1)),
        ("theta2", json::num(job.utility.theta2)),
        ("theta3", json::num(job.utility.theta3)),
    ])
}

/// Largest count accepted for integer-like fields (ids, slots, epochs,
/// batch sizes): every f64 below it is exactly representable, so the
/// `as` casts below are lossless — and a fuzzer's `1e999` or `-1` is an
/// error response instead of a saturated cast silently entering the
/// scheduler core.
const MAX_COUNT: f64 = 9.0e15;

pub fn job_from_json(v: &Json) -> Result<Job, String> {
    let num = |k: &str| -> Result<f64, String> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("job: missing numeric field {k:?}"))
    };
    // A malformed submit must be an `"ok":false` response, never a panic,
    // an absurd allocation, or NaN poisoning the solver: every number is
    // validated at the wire boundary.
    let finite = |k: &str| -> Result<f64, String> {
        let x = num(k)?;
        if !x.is_finite() {
            return Err(format!("job: field {k:?} must be finite, got {x}"));
        }
        Ok(x)
    };
    let nonneg = |k: &str| -> Result<f64, String> {
        let x = finite(k)?;
        if x < 0.0 {
            return Err(format!("job: field {k:?} must be ≥ 0, got {x}"));
        }
        Ok(x)
    };
    let positive = |k: &str| -> Result<f64, String> {
        let x = finite(k)?;
        if x <= 0.0 {
            return Err(format!("job: field {k:?} must be > 0, got {x}"));
        }
        Ok(x)
    };
    let count = |k: &str| -> Result<f64, String> {
        let x = nonneg(k)?;
        if x > MAX_COUNT {
            return Err(format!("job: field {k:?} is out of range ({x})"));
        }
        Ok(x)
    };
    let res = |k: &str| -> Result<ResVec, String> {
        resvec_from_json(v.get(k).ok_or_else(|| format!("job: missing field {k:?}"))?)
            .map_err(|e| format!("job.{k}: {e}"))
    };
    let job = Job {
        id: count("id")? as usize,
        arrival: count("arrival")? as usize,
        epochs: count("epochs")? as u64,
        samples: nonneg("samples")?,
        grad_size_mb: nonneg("grad_size_mb")?,
        tau: nonneg("tau")?,
        // gamma and the link rates are divisors in the speed model
        gamma: positive("gamma")?,
        batch: {
            let b = count("batch")?;
            if b < 1.0 {
                return Err(format!("job: field \"batch\" must be ≥ 1, got {b}"));
            }
            b as u64
        },
        worker_demand: res("worker_demand")?,
        ps_demand: res("ps_demand")?,
        b_int: positive("b_int")?,
        b_ext: positive("b_ext")?,
        utility: Sigmoid {
            theta1: finite("theta1")?,
            theta2: finite("theta2")?,
            theta3: finite("theta3")?,
        },
    };
    // tau and grad_size_mb are individually allowed to be 0, but a job
    // with BOTH zero has a zero per-sample time — per_worker_rate would
    // divide by it and feed infinity into the solver
    let per_sample = speed::per_sample_time(&job, Locality::Internal);
    if !(per_sample > 0.0 && per_sample.is_finite()) {
        return Err(format!(
            "job: per-sample time must be positive and finite, got {per_sample} \
             (tau and grad_size_mb cannot both be 0)"
        ));
    }
    Ok(job)
}

pub fn schedule_to_json(s: &Schedule) -> Json {
    schedule_to_json_cell(s, s.job_id, 0)
}

/// Serialize a schedule in a cell's *global* namespace: the reported
/// `job_id` is the caller-supplied global id and every placement's
/// machine index is offset by `machine_base` (a cell shard owns machines
/// `[base, base + len)` of the whole cluster). With `machine_base = 0`
/// and the schedule's own id this is exactly [`schedule_to_json`].
pub fn schedule_to_json_cell(s: &Schedule, job_id: usize, machine_base: usize) -> Json {
    let slots: Vec<Json> = s
        .slots
        .iter()
        .map(|slot| {
            let placements: Vec<Json> = slot
                .placements
                .iter()
                .map(|&(h, w, ps)| {
                    Json::Arr(vec![
                        json::num((h + machine_base) as f64),
                        json::num(w as f64),
                        json::num(ps as f64),
                    ])
                })
                .collect();
            json::obj(vec![
                ("t", json::num(slot.t as f64)),
                ("placements", Json::Arr(placements)),
            ])
        })
        .collect();
    json::obj(vec![
        ("job_id", json::num(job_id as f64)),
        ("slots", Json::Arr(slots)),
    ])
}

pub fn schedule_from_json(v: &Json) -> Result<Schedule, String> {
    let checked = |x: Option<f64>, what: &str| -> Result<f64, String> {
        let x = x.ok_or_else(|| format!("{what} must be a number"))?;
        if !x.is_finite() || !(0.0..=MAX_COUNT).contains(&x) {
            return Err(format!("{what} is out of range ({x})"));
        }
        Ok(x)
    };
    let job_id =
        checked(v.get("job_id").and_then(Json::as_f64), "schedule: job_id")? as usize;
    let mut slots = Vec::new();
    for slot in v.get("slots").and_then(Json::as_arr).ok_or("schedule: missing slots")? {
        let t = checked(slot.get("t").and_then(Json::as_f64), "slot: t")? as usize;
        let mut placements = Vec::new();
        for p in slot
            .get("placements")
            .and_then(Json::as_arr)
            .ok_or("slot: missing placements")?
        {
            let triple = p.as_arr().ok_or("placement must be [h, w, ps]")?;
            if triple.len() != 3 {
                return Err("placement must be [h, w, ps]".into());
            }
            let f = |i: usize| -> Result<f64, String> {
                checked(triple[i].as_f64(), "placement entry")
            };
            placements.push((f(0)? as usize, f(1)? as u64, f(2)? as u64));
        }
        slots.push(SlotPlacement { t, placements });
    }
    Ok(Schedule { job_id, slots })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::test_support::test_job;

    #[test]
    fn job_round_trips_bit_identically() {
        let mut job = test_job(7);
        job.samples = 123456.789012345;
        job.tau = 3.1e-5;
        job.utility = Sigmoid { theta1: 99.25, theta2: 0.375, theta3: 11.5 };
        let back = job_from_json(&job_to_json(&job)).unwrap();
        assert_eq!(back.id, job.id);
        assert_eq!(back.samples.to_bits(), job.samples.to_bits());
        assert_eq!(back.tau.to_bits(), job.tau.to_bits());
        assert_eq!(back.utility, job.utility);
        assert_eq!(back.worker_demand, job.worker_demand);
        // and through the serialized text, too
        let line = job_to_json(&job).to_string();
        let reparsed = job_from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(reparsed.samples.to_bits(), job.samples.to_bits());
        assert_eq!(reparsed.b_ext.to_bits(), job.b_ext.to_bits());
    }

    #[test]
    fn schedule_round_trips() {
        let s = Schedule {
            job_id: 3,
            slots: vec![
                SlotPlacement { t: 2, placements: vec![(0, 2, 1), (4, 1, 0)] },
                SlotPlacement { t: 3, placements: vec![(1, 3, 2)] },
            ],
        };
        let text = schedule_to_json(&s).to_string();
        let back = schedule_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn missing_fields_are_reported() {
        let e = job_from_json(&Json::parse("{\"id\": 1}").unwrap()).unwrap_err();
        assert!(e.contains("missing"), "{e}");
        let bad = Json::parse("{\"job_id\": 1, \"slots\": [{\"t\": 0}]}").unwrap();
        assert!(schedule_from_json(&bad).is_err());
    }
}
