//! Sharded admission service: cluster **cells** behind a router.
//!
//! The cluster is partitioned into `k` cells; each cell is a full
//! [`ServiceCore`] shard owning a disjoint machine range of the cluster
//! (an [`AllocLedger`](crate::cluster::AllocLedger) slice via
//! [`ClusterSpec::slice`](crate::sweep::ClusterSpec::slice)) and running
//! on its own thread — so `k` independent solver scratches admit jobs in
//! parallel while each cell keeps PR 3's single-threaded determinism
//! contract intact.
//!
//! ```text
//!                       ┌─► cell 0 (machines 0..m₁,  ids ≡ 0 mod k)
//!  frontend queue ─► router ─► cell 1 (machines m₁..m₂, ids ≡ 1 mod k)
//!                       └─► cell ⋯
//! ```
//!
//! * **Submit** routes to the least-loaded *compatible* cell (every
//!   demand dimension fits some machine of the cell) and the client's
//!   response channel travels with it — the router never blocks on a
//!   decision, so cells solve concurrently.
//! * **tick / status / metrics / replan / metrics_prom** fan out to all
//!   cells and the responses are merged (counters sum, fairness is
//!   completion-weighted, latency percentiles report the worst cell).
//! * **machine_down / machine_up / explain** forward to the owning cell
//!   (machine ranges; job ids are interleaved, owner = `id % k`).
//! * Each cell appends to its **own op-log** (`<path>.cell<i>` when
//!   `k > 1`), so `--recover` replays every cell independently.
//! * Inside a cell the queue drains in **batches** (`--batch M`): a run
//!   of consecutive submits goes through
//!   [`ServiceCore::submit_batch`], amortizing the journal write +
//!   queue wakeup while staying byte-identical to `--batch 1` (the
//!   oracle the sharding tests enforce).
//!
//! With `k = 1` the router is a pure passthrough — every message is
//! forwarded to cell 0 verbatim, response channel and all — so a
//! 1-shard daemon is byte-identical to the unsharded one.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::{ResVec, NUM_RESOURCES};
use crate::err;
use crate::jobs::Job;
use crate::obs::{self, Stage};
use crate::sched::solver::SolverStats;
use crate::util::error::Result;
use crate::util::json::{self, Json};
use crate::log_debug;

use super::codec;
use super::core::{
    cell_entry_json, render_prom_body, CellId, PromCounters, ServiceConfig,
    ServiceCore, ServiceReport,
};
use super::protocol::{err_response, ok_response, Request};

/// How a `k`-shard service splits `machines` into contiguous cells:
/// cell `i` owns global machines `[i·M/k, (i+1)·M/k)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub shards: usize,
    pub machines: usize,
}

impl ShardSpec {
    pub fn new(shards: usize, machines: usize) -> Result<ShardSpec> {
        if shards == 0 {
            return Err(err!("--shards must be ≥ 1"));
        }
        if shards > machines {
            return Err(err!(
                "--shards {shards} exceeds the cluster's {machines} machines \
                 (every cell needs at least one machine)"
            ));
        }
        Ok(ShardSpec { shards, machines })
    }

    /// Cell `i`'s global machine range `[start, end)`.
    pub fn range(&self, i: usize) -> (usize, usize) {
        debug_assert!(i < self.shards);
        (i * self.machines / self.shards, (i + 1) * self.machines / self.shards)
    }

    /// The cell owning global machine `m`, if any.
    pub fn of_machine(&self, m: usize) -> Option<usize> {
        (0..self.shards).find(|&i| {
            let (start, end) = self.range(i);
            (start..end).contains(&m)
        })
    }
}

/// Sharded-service configuration (the daemon carves this out of its own
/// config).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    pub service: ServiceConfig,
    /// Number of cells; 1 = the unsharded passthrough.
    pub shards: usize,
    /// Cell drain-batch bound (≥ 1); consecutive submits in one drain go
    /// through [`ServiceCore::submit_batch`].
    pub batch: usize,
    /// Op-log path base; cell `i` of a `k > 1` service appends to
    /// `<path>.cell<i>`.
    pub oplog: Option<String>,
    /// Replay path base at startup (same per-cell suffix rule), then
    /// continue appending.
    pub recover: Option<String>,
}

/// One message into the router (the daemon frontend's queue element).
pub struct RouterMsg {
    pub req: Request,
    /// Response channel; `None` for internally generated ticks.
    pub resp: Option<Sender<String>>,
    /// When the message entered the queue — the router measures the gap
    /// into the `queue_wait` telemetry stage on receipt.
    pub enqueued: Instant,
}

impl RouterMsg {
    pub fn new(req: Request, resp: Option<Sender<String>>) -> RouterMsg {
        RouterMsg { req, resp, enqueued: Instant::now() }
    }
}

/// One message into a cell.
struct CellMsg {
    req: CellReq,
    resp: Option<Sender<String>>,
}

enum CellReq {
    /// A wire request; the cell serializes its own response.
    Wire(Request),
    /// Hand over the cell's Prometheus counter block (flushing the cell
    /// thread's local span recorders) for the router to merge.
    Prom(Sender<PromCounters>),
}

/// Everything the router knows about one cell.
struct Cell {
    tx: Sender<CellMsg>,
    /// The cell's current ledger sum (`f64` bits), stored by the cell
    /// thread after every drain burst — the router's placement signal.
    load: Arc<AtomicU64>,
    /// Elementwise max machine capacity of the cell: a job is
    /// *compatible* when every demand dimension fits some machine.
    max_cap: ResVec,
    /// Total capacity (normalizes `load` so unequal cells compare
    /// fairly).
    cap_norm: f64,
    base: usize,
    len: usize,
}

/// Start the sharded service: spawn `k` cell threads (each constructing
/// its core on its own thread — the boxed scheduler is not `Send`) and
/// the router thread draining `rx`. Returns the router's join handle;
/// joining it (after the queue's senders drop) yields the merged final
/// report.
pub fn spawn(
    cfg: ShardConfig,
    rx: Receiver<RouterMsg>,
    shutdown: Arc<AtomicBool>,
) -> Result<JoinHandle<Option<ServiceReport>>> {
    let spec = ShardSpec::new(cfg.shards, cfg.service.cluster.machines())?;
    let batch = cfg.batch.max(1);

    let mut cells = Vec::with_capacity(spec.shards);
    let mut joins: Vec<JoinHandle<Option<ServiceReport>>> =
        Vec::with_capacity(spec.shards);
    for i in 0..spec.shards {
        let (start, end) = spec.range(i);
        let slice = cfg.service.cluster.slice(start, end).build();
        let mut max_cap = ResVec::zero();
        let mut cap_norm = 0.0;
        for m in &slice.machines {
            for r in 0..NUM_RESOURCES {
                max_cap.0[r] = max_cap.0[r].max(m.capacity.0[r]);
            }
            cap_norm += m.capacity.sum();
        }
        let (tx, cell_rx) = channel::<CellMsg>();
        let load = Arc::new(AtomicU64::new(0));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let cell_cfg = cfg.clone();
        let cell_load = load.clone();
        let cell_flag = shutdown.clone();
        joins.push(std::thread::spawn(move || {
            let core = match build_cell_core(&cell_cfg, spec, i) {
                Ok(core) => {
                    let _ = ready_tx.send(Ok(()));
                    core
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return None;
                }
            };
            Some(cell_loop(core, cell_rx, batch, cell_load, cell_flag))
        }));
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            outcome => {
                // tear down the cells spawned so far and fail startup
                drop(tx);
                drop(cells);
                for j in joins {
                    let _ = j.join();
                }
                return Err(match outcome {
                    Ok(Err(e)) => e,
                    _ => err!("cell {i} thread died during startup"),
                });
            }
        }
        cells.push(Cell {
            tx,
            load,
            max_cap,
            cap_norm: cap_norm.max(1e-12),
            base: start,
            len: end - start,
        });
    }

    let router_flag = shutdown;
    let router_cfg = cfg;
    Ok(std::thread::spawn(move || {
        Some(router_loop(router_cfg, spec, cells, joins, rx, router_flag))
    }))
}

/// Build cell `i`'s core: sliced cluster, interleaved id namespace,
/// per-cell op-log / recovery.
fn build_cell_core(cfg: &ShardConfig, spec: ShardSpec, i: usize) -> Result<ServiceCore> {
    let (start, end) = spec.range(i);
    let mut service = cfg.service.clone();
    service.cluster = cfg.service.cluster.slice(start, end);
    let cell = CellId { index: i, stride: spec.shards, machine_base: start };
    match &cfg.recover {
        Some(path) => {
            ServiceCore::recover_cell(service, cell, &cell_log_path(path, i, spec.shards))
        }
        None => {
            let mut core = ServiceCore::new(service)?;
            core.set_cell(cell);
            if let Some(path) = &cfg.oplog {
                core.attach_log(&cell_log_path(path, i, spec.shards))?;
            }
            Ok(core)
        }
    }
}

/// Cell `i`'s op-log path: the base path itself for an unsharded (or
/// 1-shard) service, `<base>.cell<i>` otherwise.
pub fn cell_log_path(base: &str, i: usize, shards: usize) -> String {
    if shards == 1 {
        base.to_string()
    } else {
        format!("{base}.cell{i}")
    }
}

/// One cell thread: drain the queue in batches, serving runs of
/// consecutive submits through [`ServiceCore::submit_batch`] (one
/// journal write per run). Exits — returning the cell's final report —
/// when the router drops the sender.
fn cell_loop(
    mut core: ServiceCore,
    rx: Receiver<CellMsg>,
    batch: usize,
    load: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
) -> ServiceReport {
    load.store(core.ledger_sum().to_bits(), Ordering::Relaxed);
    let mut burst: Vec<CellMsg> = Vec::new();
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(msg) => {
                burst.push(msg);
                while burst.len() < batch {
                    match rx.try_recv() {
                        Ok(m) => burst.push(m),
                        Err(_) => break,
                    }
                }
                serve_burst(&mut core, &mut burst, &shutdown);
                load.store(core.ledger_sum().to_bits(), Ordering::Relaxed);
            }
            Err(RecvTimeoutError::Timeout) => {} // serve until the router drops us
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    log_debug!("cell {}: queue drained, computing final report", core.cell().index);
    core.report()
}

/// Serve one drain burst in arrival order. Runs of consecutive submits
/// are decided through the batch path; everything else applies singly.
fn serve_burst(core: &mut ServiceCore, burst: &mut Vec<CellMsg>, shutdown: &AtomicBool) {
    let mut i = 0;
    while i < burst.len() {
        let mut j = i;
        while j < burst.len()
            && matches!(&burst[j].req, CellReq::Wire(Request::Submit { .. }))
        {
            j += 1;
        }
        if j > i {
            let jobs: Vec<Job> = burst[i..j]
                .iter()
                .map(|m| match &m.req {
                    CellReq::Wire(Request::Submit { job }) => job.clone(),
                    _ => unreachable!("run contains only submits"),
                })
                .collect();
            let responses = core.submit_batch(jobs);
            for (m, r) in burst[i..j].iter().zip(responses) {
                if let Some(ch) = &m.resp {
                    let _ = ch.send(r.to_string());
                }
            }
            i = j;
            continue;
        }
        let msg = &burst[i];
        match &msg.req {
            CellReq::Wire(req) => {
                let response = core.apply(req);
                if matches!(req, Request::Shutdown) {
                    shutdown.store(true, Ordering::SeqCst);
                }
                if let Some(ch) = &msg.resp {
                    let _ = ch.send(response.to_string());
                }
            }
            CellReq::Prom(ch) => {
                let _ = ch.send(core.prom_counters());
            }
        }
        i += 1;
    }
    burst.clear();
}

/// The router thread: place/forward/fan-out until the frontend drops its
/// senders, then drop the cell senders, join the cells, and merge their
/// final reports.
fn router_loop(
    cfg: ShardConfig,
    spec: ShardSpec,
    cells: Vec<Cell>,
    joins: Vec<JoinHandle<Option<ServiceReport>>>,
    rx: Receiver<RouterMsg>,
    shutdown: Arc<AtomicBool>,
) -> ServiceReport {
    // `cluster` never changes: answer it from the spec without a fan-out
    // (byte-identical to the unsharded core's answer).
    let cluster_answer = {
        let full = cfg.service.cluster.build();
        let caps: Vec<Json> =
            full.machines.iter().map(|m| codec::resvec_to_json(&m.capacity)).collect();
        ok_response(vec![
            ("machines", json::num(full.machines.len() as f64)),
            ("horizon", json::num(cfg.service.horizon() as f64)),
            ("cluster", json::s(&cfg.service.cluster.key())),
            ("capacities", Json::Arr(caps)),
        ])
    };
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(msg) => {
                if obs::flags() != 0 {
                    obs::record(
                        Stage::QueueWait,
                        msg.enqueued.elapsed().as_micros() as u64,
                    );
                }
                route(&cfg, spec, &cells, &shutdown, &cluster_answer, msg);
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    log_debug!("router: frontend gone, draining {} cell(s)", cells.len());
    drop(cells); // cells see Disconnected and return their reports
    let mut reports = Vec::new();
    for j in joins {
        if let Ok(Some(r)) = j.join() {
            reports.push(r);
        }
    }
    merge_reports(&reports)
}

fn reply(resp: &Option<Sender<String>>, body: Json) {
    if let Some(ch) = resp {
        let _ = ch.send(body.to_string());
    }
}

/// Route one frontend message. With one cell this is a pure passthrough
/// (the byte-parity contract); with `k > 1` submits place, point ops
/// forward to their owner, and cluster-wide ops fan out and merge.
fn route(
    cfg: &ShardConfig,
    spec: ShardSpec,
    cells: &[Cell],
    shutdown: &AtomicBool,
    cluster_answer: &Json,
    msg: RouterMsg,
) {
    if cells.len() == 1 {
        let _ = cells[0].tx.send(CellMsg { req: CellReq::Wire(msg.req), resp: msg.resp });
        return;
    }
    match msg.req {
        Request::Submit { job } => {
            let cell = pick_cell(&job, cells);
            let _ = cells[cell]
                .tx
                .send(CellMsg { req: CellReq::Wire(Request::Submit { job }), resp: msg.resp });
        }
        Request::Explain { job_id } => {
            // interleaved id namespace: the owner is the residue class
            let cell = job_id % cells.len();
            let _ = cells[cell]
                .tx
                .send(CellMsg { req: CellReq::Wire(Request::Explain { job_id }), resp: msg.resp });
        }
        Request::MachineDown { machine } | Request::MachineUp { machine } => {
            match spec.of_machine(machine) {
                Some(cell) => {
                    let _ = cells[cell]
                        .tx
                        .send(CellMsg { req: CellReq::Wire(msg.req), resp: msg.resp });
                }
                None => reply(
                    &msg.resp,
                    err_response(&format!(
                        "machine {machine} out of range (cluster has {} machines)",
                        spec.machines
                    )),
                ),
            }
        }
        Request::Tick => match fan_out(cells, &Request::Tick) {
            Some(responses) => reply(&msg.resp, responses[0].clone()),
            None => reply(&msg.resp, err_response("daemon is draining")),
        },
        Request::Status => match fan_out(cells, &Request::Status) {
            Some(responses) => reply(&msg.resp, merge_status(&responses)),
            None => reply(&msg.resp, err_response("daemon is draining")),
        },
        Request::Metrics => match fan_out(cells, &Request::Metrics) {
            Some(responses) => reply(&msg.resp, merge_metrics(&responses)),
            None => reply(&msg.resp, err_response("daemon is draining")),
        },
        Request::Replan => match fan_out(cells, &Request::Replan) {
            Some(responses) => reply(&msg.resp, merge_replan(&responses)),
            None => reply(&msg.resp, err_response("daemon is draining")),
        },
        Request::MetricsProm => {
            let mut waits = Vec::with_capacity(cells.len());
            for c in cells {
                let (ptx, prx) = channel();
                let _ = c.tx.send(CellMsg { req: CellReq::Prom(ptx), resp: None });
                waits.push(prx);
            }
            let mut merged = PromCounters::default();
            let mut got = 0;
            for w in waits {
                if let Ok(c) = w.recv() {
                    merged.merge(&c);
                    got += 1;
                }
            }
            if got < cells.len() {
                reply(&msg.resp, err_response("daemon is draining"));
            } else {
                // the router's own spans (queue_wait) live in this
                // thread's local recorders — hand them over too
                obs::flush_local();
                let body = render_prom_body(&merged);
                reply(&msg.resp, ok_response(vec![("prom", json::s(&body))]));
            }
        }
        Request::Cluster => reply(&msg.resp, cluster_answer.clone()),
        Request::Cells => {
            let entries: Vec<Json> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    let load = f64::from_bits(c.load.load(Ordering::Relaxed));
                    cell_entry_json(i, c.base, c.len, load)
                })
                .collect();
            reply(
                &msg.resp,
                ok_response(vec![
                    ("shards", json::num(cfg.shards as f64)),
                    ("cells", Json::Arr(entries)),
                ]),
            );
        }
        Request::DebugDump => reply(
            &msg.resp,
            ok_response(vec![("flight", crate::obs::flight::dump_json())]),
        ),
        Request::Shutdown => {
            shutdown.store(true, Ordering::SeqCst);
            reply(&msg.resp, ok_response(vec![("draining", Json::Bool(true))]));
        }
    }
}

/// Least-loaded *compatible* cell for `job` (every demand dimension must
/// fit the cell's biggest machine); falls back to least-loaded overall
/// when no cell is compatible — the owning cell then rejects honestly,
/// exactly like an unsharded cluster that cannot place the job.
fn pick_cell(job: &Job, cells: &[Cell]) -> usize {
    let load_of = |c: &Cell| f64::from_bits(c.load.load(Ordering::Relaxed)) / c.cap_norm;
    let mut best: Option<(usize, f64)> = None;
    for (i, c) in cells.iter().enumerate() {
        if !job.worker_demand.fits_within(&c.max_cap, 1e-9)
            || !job.ps_demand.fits_within(&c.max_cap, 1e-9)
        {
            continue;
        }
        let load = load_of(c);
        if best.map_or(true, |(_, b)| load < b) {
            best = Some((i, load));
        }
    }
    if let Some((i, _)) = best {
        return i;
    }
    let mut fallback = (0, f64::INFINITY);
    for (i, c) in cells.iter().enumerate() {
        let load = load_of(c);
        if load < fallback.1 {
            fallback = (i, load);
        }
    }
    fallback.0
}

/// Send `req` to every cell and wait for all responses, in cell order.
/// `None` when any cell is gone (the service is draining).
fn fan_out(cells: &[Cell], req: &Request) -> Option<Vec<Json>> {
    let mut waits = Vec::with_capacity(cells.len());
    for c in cells {
        let (rtx, rrx) = channel();
        c.tx.send(CellMsg { req: CellReq::Wire(req.clone()), resp: Some(rtx) }).ok()?;
        waits.push(rrx);
    }
    let mut out = Vec::with_capacity(cells.len());
    for w in waits {
        out.push(Json::parse(&w.recv().ok()?).ok()?);
    }
    Some(out)
}

fn num_of(v: &Json, k: &str) -> f64 {
    v.get(k).and_then(Json::as_f64).unwrap_or(0.0)
}

fn field_sum(cells: &[Json], k: &str) -> f64 {
    cells.iter().map(|c| num_of(c, k)).sum()
}

fn field_max(cells: &[Json], k: &str) -> f64 {
    cells.iter().map(|c| num_of(c, k)).fold(0.0, f64::max)
}

/// Sum every numeric field of an object across cells (key union).
fn merge_obj_sum(cells: &[&Json]) -> Json {
    let mut out: BTreeMap<String, Json> = BTreeMap::new();
    for c in cells {
        if let Json::Obj(map) = c {
            for (k, v) in map {
                let cur = out.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                out.insert(k.clone(), json::num(cur + v.as_f64().unwrap_or(0.0)));
            }
        }
    }
    Json::Obj(out)
}

/// Merge per-cell `status` responses: counters sum, fairness is
/// completion-weighted, labels/clock come from cell 0 (identical
/// everywhere by construction).
fn merge_status(cells: &[Json]) -> Json {
    let c0 = &cells[0];
    let completed = field_sum(cells, "completed");
    let ftf = if completed > 0.0 {
        cells.iter().map(|c| num_of(c, "ftf") * num_of(c, "completed")).sum::<f64>()
            / completed
    } else {
        0.0
    };
    let label = |k: &str| c0.get(k).cloned().unwrap_or(Json::Null);
    ok_response(vec![
        ("slot", json::num(num_of(c0, "slot"))),
        ("ended", label("ended")),
        ("horizon", json::num(num_of(c0, "horizon"))),
        ("scheduler", label("scheduler")),
        ("submitted", json::num(field_sum(cells, "submitted"))),
        ("admitted", json::num(field_sum(cells, "admitted"))),
        ("rejected", json::num(field_sum(cells, "rejected"))),
        ("deferred", json::num(field_sum(cells, "deferred"))),
        ("completed", json::num(completed)),
        ("active", json::num(field_sum(cells, "active"))),
        ("replan", label("replan")),
        ("replan_rounds", json::num(field_sum(cells, "replan_rounds"))),
        ("replanned", json::num(field_sum(cells, "replanned"))),
        ("churn", label("churn")),
        ("evicted", json::num(field_sum(cells, "evicted"))),
        ("migrated", json::num(field_sum(cells, "migrated"))),
        ("ftf", json::num(ftf)),
        ("total_utility", json::num(field_sum(cells, "total_utility"))),
        ("ledger_sum", json::num(field_sum(cells, "ledger_sum"))),
    ])
}

/// Merge per-cell `metrics` responses. Counters and reason/solver maps
/// sum; latency percentiles report the **worst cell** (a merged
/// percentile cannot be recovered from per-cell summaries, and the
/// worst-cell tail is the operationally honest bound); the mean is
/// count-weighted.
fn merge_metrics(cells: &[Json]) -> Json {
    let solves: Vec<Json> =
        cells.iter().map(|c| c.get("solve_us").cloned().unwrap_or(Json::Null)).collect();
    let count = field_sum(&solves, "count");
    let mean = if count > 0.0 {
        solves.iter().map(|s| num_of(s, "mean") * num_of(s, "count")).sum::<f64>() / count
    } else {
        0.0
    };
    let solve = json::obj(vec![
        ("count", json::num(count)),
        ("p50", json::num(field_max(&solves, "p50"))),
        ("p95", json::num(field_max(&solves, "p95"))),
        ("p99", json::num(field_max(&solves, "p99"))),
        ("p999", json::num(field_max(&solves, "p999"))),
        ("mean", json::num(mean)),
        ("max", json::num(field_max(&solves, "max"))),
    ]);
    let solver_cells: Vec<&Json> =
        cells.iter().filter_map(|c| c.get("solver")).collect();
    let reason_cells: Vec<&Json> =
        cells.iter().filter_map(|c| c.get("decisions_by_reason")).collect();
    ok_response(vec![
        ("decisions", json::num(field_sum(cells, "decisions"))),
        ("decisions_by_reason", merge_obj_sum(&reason_cells)),
        ("solve_us", solve),
        ("solver", merge_obj_sum(&solver_cells)),
        ("uptime_secs", json::num(field_max(cells, "uptime_secs"))),
    ])
}

/// Merge per-cell `replan` responses; an error (replanning not enabled)
/// is identical across cells, so the first one speaks for all.
fn merge_replan(cells: &[Json]) -> Json {
    if let Some(bad) = cells.iter().find(|c| c.get("ok") != Some(&Json::Bool(true))) {
        return bad.clone();
    }
    ok_response(vec![
        ("slot", json::num(num_of(&cells[0], "slot"))),
        ("revisited", json::num(field_sum(cells, "revisited"))),
        ("replanned", json::num(field_sum(cells, "replanned"))),
        ("utility_delta", json::num(field_sum(cells, "utility_delta"))),
    ])
}

/// Merge per-cell final reports into one whole-cluster report: counters
/// sum, fairness is completion-weighted, the alloc dump concatenates the
/// cells' machine columns in cell order (= global machine order), solver
/// counters accumulate. A single report passes through unchanged.
pub fn merge_reports(reports: &[ServiceReport]) -> ServiceReport {
    assert!(!reports.is_empty(), "merge_reports needs at least one cell report");
    if reports.len() == 1 {
        return reports[0].clone();
    }
    let completed: usize = reports.iter().map(|r| r.completed).sum();
    let ftf = if completed == 0 {
        0.0
    } else {
        reports.iter().map(|r| r.ftf * r.completed as f64).sum::<f64>()
            / completed as f64
    };
    let horizon = reports[0].alloc.len();
    let mut alloc = Vec::with_capacity(horizon);
    for t in 0..horizon {
        let mut row = Vec::new();
        for r in reports {
            row.extend_from_slice(&r.alloc[t]);
        }
        alloc.push(row);
    }
    let mut solver = SolverStats::default();
    for r in reports {
        solver.merge(&r.solver);
    }
    ServiceReport {
        slot: reports[0].slot,
        ended: reports[0].ended,
        submitted: reports.iter().map(|r| r.submitted).sum(),
        admitted: reports.iter().map(|r| r.admitted).sum(),
        rejected: reports.iter().map(|r| r.rejected).sum(),
        deferred: reports.iter().map(|r| r.deferred).sum(),
        completed,
        replanned: reports.iter().map(|r| r.replanned).sum(),
        evicted: reports.iter().map(|r| r.evicted).sum(),
        migrated: reports.iter().map(|r| r.migrated).sum(),
        ftf,
        total_utility: reports.iter().map(|r| r.total_utility).sum(),
        alloc,
        solver,
    }
}

#[cfg(test)]
mod tests {
    use super::super::core::synthetic_service_config;
    use super::*;

    #[test]
    fn shard_spec_partitions_the_machines() {
        let spec = ShardSpec::new(4, 10).unwrap();
        let mut covered = Vec::new();
        for i in 0..4 {
            let (start, end) = spec.range(i);
            assert!(start < end, "cell {i} must own at least one machine");
            for m in start..end {
                assert_eq!(spec.of_machine(m), Some(i));
                covered.push(m);
            }
        }
        assert_eq!(covered, (0..10).collect::<Vec<_>>());
        assert_eq!(spec.of_machine(10), None);
        assert!(ShardSpec::new(0, 10).is_err());
        assert!(ShardSpec::new(11, 10).is_err());
    }

    #[test]
    fn report_merge_sums_and_concatenates() {
        let mk = |submitted: usize, completed: usize, ftf: f64, util: f64, col: f64| {
            ServiceReport {
                slot: 12,
                ended: true,
                submitted,
                admitted: submitted,
                rejected: 0,
                deferred: 0,
                completed,
                replanned: 1,
                evicted: 0,
                migrated: 0,
                ftf,
                total_utility: util,
                alloc: vec![vec![[col, 0.0, 0.0, 0.0]; 2]; 3],
                solver: SolverStats { lp_solves: 5, ..SolverStats::default() },
            }
        };
        let merged = merge_reports(&[mk(3, 2, 1.0, 10.0, 1.0), mk(5, 6, 2.0, 4.0, 2.0)]);
        assert_eq!(merged.submitted, 8);
        assert_eq!(merged.completed, 8);
        assert_eq!(merged.replanned, 2);
        assert!((merged.ftf - (1.0 * 2.0 + 2.0 * 6.0) / 8.0).abs() < 1e-12);
        assert!((merged.total_utility - 14.0).abs() < 1e-12);
        assert_eq!(merged.solver.lp_solves, 10);
        // alloc columns concatenate in cell order: 2 + 2 machines
        assert_eq!(merged.alloc.len(), 3);
        assert_eq!(merged.alloc[0].len(), 4);
        assert_eq!(merged.alloc[0][1][0], 1.0);
        assert_eq!(merged.alloc[0][2][0], 2.0);
        // a single report passes through unchanged
        let one = mk(3, 2, 1.0, 10.0, 1.0);
        assert_eq!(merge_reports(&[one.clone()]), one);
    }

    #[test]
    fn status_merge_weights_fairness_by_completions() {
        let cell = |submitted: f64, completed: f64, ftf: f64| {
            ok_response(vec![
                ("slot", json::num(4.0)),
                ("ended", Json::Bool(false)),
                ("horizon", json::num(12.0)),
                ("scheduler", json::s("pd-ors")),
                ("submitted", json::num(submitted)),
                ("completed", json::num(completed)),
                ("ftf", json::num(ftf)),
                ("ledger_sum", json::num(1.5)),
            ])
        };
        let merged = merge_status(&[cell(4.0, 2.0, 1.0), cell(6.0, 0.0, 9.0)]);
        assert_eq!(merged.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(merged.get("slot").unwrap().as_usize(), Some(4));
        assert_eq!(merged.get("submitted").unwrap().as_usize(), Some(10));
        // the empty cell's ftf carries zero weight
        assert!((merged.get("ftf").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-12);
        assert!((merged.get("ledger_sum").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn metrics_merge_reports_the_worst_cell_tail() {
        let cell = |count: f64, p99: f64, mean: f64, lp: f64| {
            ok_response(vec![
                ("decisions", json::num(count)),
                (
                    "decisions_by_reason",
                    json::obj(vec![("admit/priced", json::num(count))]),
                ),
                (
                    "solve_us",
                    json::obj(vec![
                        ("count", json::num(count)),
                        ("p50", json::num(p99 / 2.0)),
                        ("p95", json::num(p99)),
                        ("p99", json::num(p99)),
                        ("p999", json::num(p99)),
                        ("mean", json::num(mean)),
                        ("max", json::num(p99)),
                    ]),
                ),
                ("solver", json::obj(vec![("lp_solves", json::num(lp))])),
                ("uptime_secs", json::num(1.0)),
            ])
        };
        let merged = merge_metrics(&[cell(4.0, 100.0, 10.0, 7.0), cell(12.0, 300.0, 30.0, 9.0)]);
        assert_eq!(merged.get("decisions").unwrap().as_usize(), Some(16));
        let solve = merged.get("solve_us").unwrap();
        assert_eq!(solve.get("count").unwrap().as_usize(), Some(16));
        assert_eq!(solve.get("p99").unwrap().as_f64(), Some(300.0));
        // count-weighted mean: (10*4 + 30*12) / 16 = 25
        assert!((solve.get("mean").unwrap().as_f64().unwrap() - 25.0).abs() < 1e-12);
        let solver = merged.get("solver").unwrap();
        assert_eq!(solver.get("lp_solves").unwrap().as_usize(), Some(16));
        let reasons = merged.get("decisions_by_reason").unwrap();
        assert_eq!(reasons.get("admit/priced").unwrap().as_usize(), Some(16));
    }

    #[test]
    fn two_cells_serve_the_wire_surface_and_merge() {
        let service = synthetic_service_config("pd-ors", 1, 8, 16, 12);
        let jobs = service.workload.jobs(1);
        let cfg = ShardConfig {
            service,
            shards: 2,
            batch: 4,
            oplog: None,
            recover: None,
        };
        let (tx, rx) = channel::<RouterMsg>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let handle = spawn(cfg, rx, shutdown.clone()).unwrap();
        let ask = |req: Request| -> Json {
            let (rtx, rrx) = channel();
            tx.send(RouterMsg::new(req, Some(rtx))).unwrap();
            Json::parse(&rrx.recv().unwrap()).unwrap()
        };
        let mut ids = Vec::new();
        for job in jobs.iter().take(8) {
            let resp = ask(Request::Submit { job: job.clone() });
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string());
            ids.push(resp.get("job_id").unwrap().as_usize().unwrap());
        }
        let mut unique = ids.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), ids.len(), "global job ids must be distinct: {ids:?}");
        // the interleaved namespace answers explains at the router
        let e = ask(Request::Explain { job_id: ids[0] });
        assert_eq!(e.get("ok"), Some(&Json::Bool(true)), "{}", e.to_string());
        assert_eq!(e.get("job_id").unwrap().as_usize(), Some(ids[0]));
        // merged status sees every cell's counters
        let status = ask(Request::Status);
        assert_eq!(status.get("submitted").unwrap().as_usize(), Some(8));
        assert_eq!(status.get("slot").unwrap().as_usize(), Some(0));
        // cell layout over the wire
        let cells = ask(Request::Cells);
        assert_eq!(cells.get("shards").unwrap().as_usize(), Some(2));
        let entries = cells.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].get("machines_start").unwrap().as_usize(), Some(0));
        assert_eq!(entries[1].get("machines_start").unwrap().as_usize(), Some(4));
        assert_eq!(entries[1].get("machines_end").unwrap().as_usize(), Some(8));
        // cluster answers for the whole cluster
        let cluster = ask(Request::Cluster);
        assert_eq!(cluster.get("machines").unwrap().as_usize(), Some(8));
        // machine ops outside every cell fail at the router
        let bad = ask(Request::MachineDown { machine: 99 });
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)), "{}", bad.to_string());
        assert!(bad.get("error").unwrap().as_str().unwrap().contains("out of range"));
        // a tick advances every cell in lockstep
        let tick = ask(Request::Tick);
        assert_eq!(tick.get("slot").unwrap().as_usize(), Some(1));
        // shutdown is answered by the router and raises the drain flag
        let down = ask(Request::Shutdown);
        assert_eq!(down.get("draining"), Some(&Json::Bool(true)));
        assert!(shutdown.load(Ordering::SeqCst));
        drop(tx);
        let report = handle.join().unwrap().unwrap();
        assert_eq!(report.submitted, 8);
        assert_eq!(report.admitted + report.rejected + report.deferred, 8);
        assert_eq!(report.slot, 1);
        assert_eq!(report.alloc[0].len(), 8, "merged alloc spans the whole cluster");
    }
}
