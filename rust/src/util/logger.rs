//! Minimal leveled logger (the `log` facade is vendored but a full env
//! logger is not; this keeps the hot path free of locking when disabled).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    /// Parse a CLI/env level name (`error|warn|info|debug|trace`,
    /// case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process-wide count of warning-or-worse log calls. Counted even when
/// the level suppresses the output, so a quiet run still reports how
/// many problems it swallowed (surfaced as `dmlrs_log_warnings_total`).
static WARNINGS: AtomicU64 = AtomicU64::new(0);

/// Total warning-or-worse log calls since process start.
pub fn warnings() -> u64 {
    WARNINGS.load(Ordering::Relaxed)
}

/// Wire the logger to the outside world: an explicit `--log-level` value
/// wins, else the `DMLRS_LOG` environment variable, else the Info
/// default stands. Returns an error naming the bad value.
pub fn init_from(cli_level: Option<&str>) -> Result<(), String> {
    let (source, value) = match cli_level {
        Some(v) => ("--log-level", v.to_string()),
        None => match std::env::var("DMLRS_LOG") {
            Ok(v) if !v.is_empty() => ("DMLRS_LOG", v),
            _ => return Ok(()),
        },
    };
    match Level::parse(&value) {
        Some(l) => {
            set_level(l);
            Ok(())
        }
        None => Err(format!(
            "{source}: unknown log level {value:?} (want error|warn|info|debug|trace)"
        )),
    }
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if l <= Level::Warn {
        WARNINGS.fetch_add(1, Ordering::Relaxed);
    }
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn parse_accepts_known_names_case_insensitively() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("Debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), Some(Level::Trace));
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn init_from_rejects_bad_cli_value() {
        let err = init_from(Some("loud")).unwrap_err();
        assert!(err.contains("--log-level"));
        assert!(err.contains("loud"));
    }

    #[test]
    fn warnings_are_counted_even_when_suppressed() {
        let before = warnings();
        set_level(Level::Error); // Warn output suppressed...
        log(Level::Warn, format_args!("suppressed but counted"));
        log(Level::Error, format_args!("errors count too"));
        log(Level::Info, format_args!("info does not"));
        set_level(Level::Info);
        // >= : other tests may log warnings concurrently
        assert!(warnings() - before >= 2, "warn+error must both count");
    }
}
