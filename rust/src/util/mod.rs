//! Cross-cutting substrates: RNG, statistics, JSON, timing, logging.

pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
