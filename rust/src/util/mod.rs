//! Cross-cutting substrates: RNG, statistics, JSON, timing, logging, errors.

pub mod error;
pub mod json;
pub mod jsonl;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod timer;

pub use error::{Error, Result};
pub use rng::Rng;
pub use timer::Timer;
