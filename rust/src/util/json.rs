//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Parses the artifact `*.meta.json` files emitted by `python/compile/aot.py`
//! and serializes experiment results. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (not needed for our data).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| format!("bad \\u: {e}"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

/// Convenience builder for object literals in experiment outputs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n", "d": true}, "e": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("hi\n"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_meta_style() {
        let text = r#"{"name":"tiny","num_params":15328,"files":{"init":"lm_tiny_init.hlo.txt"}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("num_params").unwrap().as_usize(), Some(15328));
        assert_eq!(
            v.get("files").unwrap().get("init").unwrap().as_str(),
            Some("lm_tiny_init.hlo.txt")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn escapes_in_output() {
        let v = Json::Str("a\"b\\c\n".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\n""#);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""A""#).unwrap();
        assert_eq!(v.as_str(), Some("A"));
    }
}
