//! Minimal error substrate (anyhow is unavailable in the offline build
//! environment): a string-backed error, a crate-wide `Result`, and the
//! [`err!`](crate::err) constructor macro.

use std::fmt;

/// A string-backed error used across the CLI, runtime, and executor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Construct an [`Error`] with `format!` syntax, `anyhow!`-style.
#[macro_export]
macro_rules! err {
    ($($fmt:tt)*) => {
        $crate::util::error::Error(format!($($fmt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message() {
        let e = err!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
    }

    #[test]
    fn converts_io_errors() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
    }
}
