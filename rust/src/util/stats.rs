//! Small statistics toolkit used by the simulator metrics and benches.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation; 0.0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// p-th percentile (0..=100) by linear interpolation; 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Summary of a sample, used for bench reporting. The single percentile
/// block behind the daemon `metrics` op, the load generator's report,
/// and the bench summaries — extend it here rather than hand-rolling
/// another `percentile(...)` cluster at a call site.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std_dev: std_dev(xs),
            min: if xs.is_empty() { 0.0 } else { min },
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            p999: percentile(xs, 99.9),
            max: if xs.is_empty() { 0.0 } else { max },
        }
    }

    /// Sample count (alias of `n`, for call sites reporting it as a field).
    pub fn count(&self) -> usize {
        self.n
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} sd={:.4} min={:.4} p50={:.4} p95={:.4} p99={:.4} p999={:.4} max={:.4}",
            self.n,
            self.mean,
            self.std_dev,
            self.min,
            self.p50,
            self.p95,
            self.p99,
            self.p999,
            self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn std_dev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        // population sd is 2; sample sd = sqrt(32/7)
        assert!((std_dev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn summary_consistent() {
        let xs = [1.0, 2.0, 3.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.count(), 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
        // tail percentiles are ordered and bounded by max
        assert!(s.p95 <= s.p99 && s.p99 <= s.p999 && s.p999 <= s.max);
    }

    #[test]
    fn summary_tail_percentiles() {
        let xs: Vec<f64> = (0..=1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!((s.p99 - 990.0).abs() < 1e-9);
        assert!((s.p999 - 999.0).abs() < 1e-9);
    }
}
