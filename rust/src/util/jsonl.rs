//! Tolerant JSONL file loading, shared by the sweep
//! [`ResultStore`](crate::sweep::ResultStore) and the service op-log.
//!
//! Append-only JSONL files are written one flushed line at a time, so a
//! crashed writer leaves at most one *truncated final line* (no trailing
//! newline, or garbage after the last complete record). [`load_tolerant`]
//! repairs exactly that case — the malformed tail line is dropped and the
//! file is truncated back to the last complete record, so appending can
//! resume cleanly. A malformed line anywhere *before* the tail is still a
//! hard error: that is corruption, not crash damage, and resuming over it
//! would silently lose data.

use super::json::Json;

/// Result of [`load_tolerant`]: parsed values (1-based line number +
/// value) plus whether a truncated tail was dropped and the file rewritten.
#[derive(Debug)]
pub struct JsonlLoad {
    pub lines: Vec<(usize, Json)>,
    /// True when a malformed final line was discarded and the file
    /// truncated back to the last complete record.
    pub repaired: bool,
}

/// Load a JSONL file, repairing a truncated final line (see module docs).
/// Blank lines are skipped. A missing file loads as empty.
pub fn load_tolerant(path: &str) -> Result<JsonlLoad, String> {
    let pb = std::path::Path::new(path);
    if !pb.exists() {
        return Ok(JsonlLoad { lines: Vec::new(), repaired: false });
    }
    let text = std::fs::read_to_string(pb).map_err(|e| format!("{path}: {e}"))?;
    let mut lines = Vec::new();
    let mut repaired = false;
    let mut offset = 0usize; // byte offset of the current line start
    let mut lineno = 0usize;
    for line in text.split_inclusive('\n') {
        lineno += 1;
        let start = offset;
        offset += line.len();
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match Json::parse(trimmed) {
            Ok(v) => lines.push((lineno, v)),
            Err(e) => {
                // Only the final line (nothing but whitespace after it)
                // gets the crashed-writer tolerance.
                if text[offset..].trim().is_empty() {
                    crate::log_warn!(
                        "{path}:{lineno}: dropping truncated final \
                         line ({e}); truncating file to last complete record"
                    );
                    truncate_to(path, start as u64)?;
                    repaired = true;
                    break;
                }
                return Err(format!("{path}:{lineno}: {e}"));
            }
        }
    }
    Ok(JsonlLoad { lines, repaired })
}

fn truncate_to(path: &str, len: u64) -> Result<(), String> {
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(path)
        .map_err(|e| format!("{path}: {e}"))?;
    f.set_len(len).map_err(|e| format!("{path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("dmlrs_jsonl_{tag}_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn missing_file_is_empty() {
        let l = load_tolerant(&tmp("missing_nonexistent")).unwrap();
        assert!(l.lines.is_empty());
        assert!(!l.repaired);
    }

    #[test]
    fn loads_lines_with_numbers() {
        let p = tmp("ok");
        std::fs::write(&p, "{\"a\":1}\n\n{\"b\":2}\n").unwrap();
        let l = load_tolerant(&p).unwrap();
        assert_eq!(l.lines.len(), 2);
        assert_eq!(l.lines[0].0, 1);
        assert_eq!(l.lines[1].0, 3, "blank line counts toward numbering");
        assert!(!l.repaired);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn truncated_tail_is_dropped_and_file_rewritten() {
        let p = tmp("tail");
        std::fs::write(&p, "{\"a\":1}\n{\"b\":2}\n{\"c\": 3, \"tru").unwrap();
        let l = load_tolerant(&p).unwrap();
        assert_eq!(l.lines.len(), 2);
        assert!(l.repaired);
        // the file itself was truncated back to the complete records
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"a\":1}\n{\"b\":2}\n");
        // a second load is clean
        let again = load_tolerant(&p).unwrap();
        assert_eq!(again.lines.len(), 2);
        assert!(!again.repaired);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn malformed_middle_line_is_a_hard_error() {
        let p = tmp("mid");
        std::fs::write(&p, "{\"a\":1}\nnot json at all\n{\"b\":2}\n").unwrap();
        let e = load_tolerant(&p).unwrap_err();
        assert!(e.contains(":2:"), "{e}");
        // the file is untouched
        assert!(std::fs::read_to_string(&p).unwrap().contains("not json"));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn garbage_final_line_with_newline_is_still_repaired() {
        // a crash can also land mid-flush, leaving a complete-looking but
        // unparsable last line
        let p = tmp("nl");
        std::fs::write(&p, "{\"a\":1}\n{bad}\n").unwrap();
        let l = load_tolerant(&p).unwrap();
        assert_eq!(l.lines.len(), 1);
        assert!(l.repaired);
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"a\":1}\n");
        let _ = std::fs::remove_file(&p);
    }
}
