//! Wall-clock timing helpers for the hand-rolled bench harness
//! (criterion is unavailable in the offline build environment).

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_secs() * 1e6
    }
}

/// Measure `f` repeatedly: `warmup` unmeasured runs, then `iters` timed
/// runs; returns per-iteration seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        out.push(t.elapsed_secs());
    }
    out
}

/// Format seconds human-readably (ns/us/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        // Assert monotonic ordering, not wall-clock deltas: sleep-based
        // thresholds are flaky when the test suite saturates every core
        // (e.g. under parallel sweep tests).
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        let c = t.elapsed_secs();
        assert!(a >= 0.0);
        assert!(b >= a, "elapsed time must not go backwards: {a} then {b}");
        assert!(c >= b, "elapsed time must not go backwards: {b} then {c}");
        // unit conversions stay consistent with each other
        let ms = t.elapsed_ms();
        let us = t.elapsed_us();
        assert!(us >= ms, "1ms = 1000us: us={us} ms={ms}");
    }

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let xs = bench(2, 5, || n += 1);
        assert_eq!(xs.len(), 5);
        assert_eq!(n, 7);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" us"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }
}
