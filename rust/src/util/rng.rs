//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we implement
//! xoshiro256** (Blackman–Vigna) seeded through SplitMix64 — the standard
//! construction, statistically solid and fully reproducible across runs,
//! which matters because every experiment records its seed.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-job / per-machine substreams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_u64: {lo} > {hi}");
        let span = hi - lo + 1;
        // Lemire rejection-free-ish: fine for simulation purposes.
        lo + (self.next_u64() % span)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -((1.0 - self.f64()).ln()) / lambda
    }

    /// Poisson(lambda) by inversion (lambda expected small in our use).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // numeric guard
            }
        }
    }

    /// Sample an index according to non-negative `weights`.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_hits_bounds() {
        let mut r = Rng::new(1);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.range_u64(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy_index() {
        let mut r = Rng::new(3);
        let w = [0.0, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 5);
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let total: u64 = (0..n).map(|_| r.poisson(2.5)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 2.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(4);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }
}
