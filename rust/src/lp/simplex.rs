//! Dense two-phase primal simplex.
//!
//! Standard textbook construction: rows are normalized to `a·x = b` with
//! `b ≥ 0` using slack/surplus variables; artificial variables seed the
//! initial basis; phase 1 minimizes the artificial sum (infeasible if it
//! stays positive); phase 2 minimizes the real objective. Dantzig pricing
//! with a Bland fallback after a stall threshold guards against cycling.
//!
//! **Workspaces.** The scheduler hot path solves thousands of
//! similarly-sized LPs per arrival; allocating a fresh tableau each time
//! dominated the solve cost. [`LpWorkspace`] owns every buffer the solver
//! needs (tableau, rhs, basis, reduced costs, phase objectives, the
//! solution vector) and is reused across solves —
//! [`LpWorkspace::solve`] performs **zero heap allocations** once the
//! buffers have grown to the problem size. [`solve`] remains the one-shot
//! convenience (it builds a throwaway workspace); [`solve_with`] threads a
//! caller-owned one.

use super::problem::{Cmp, LpOutcome, LpProblem, LpSolution};

const EPS: f64 = 1e-9;

/// Solver verdict of a workspace solve; on `Optimal` the solution lives
/// in the workspace ([`LpWorkspace::x`] / [`LpWorkspace::objective`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
}

/// The dense tableau plus basis bookkeeping. Buffers persist across
/// solves; [`Tableau::reset`] re-shapes them without reallocating once
/// capacity has grown to the largest problem seen.
#[derive(Debug, Default)]
struct Tableau {
    /// `m x n` coefficient matrix (row-major), plus rhs column `b`.
    a: Vec<f64>,
    b: Vec<f64>,
    m: usize,
    n: usize,
    /// basis[i] = column index basic in row i.
    basis: Vec<usize>,
    /// Cumulative pivot count across every solve on this tableau.
    pivots: u64,
}

impl Tableau {
    fn reset(&mut self, m: usize, n: usize) {
        self.m = m;
        self.n = n;
        self.a.clear();
        self.a.resize(m * n, 0.0);
        self.b.clear();
        self.b.resize(m, 0.0);
        self.basis.clear();
        self.basis.resize(m, usize::MAX);
    }

    #[inline]
    fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.a[i * self.n + j]
    }

    fn pivot(&mut self, row: usize, col: usize) {
        self.pivots += 1;
        let n = self.n;
        let piv = self.at(row, col);
        debug_assert!(piv.abs() > EPS);
        let inv = 1.0 / piv;
        for j in 0..n {
            self.a[row * n + j] *= inv;
        }
        self.b[row] *= inv;
        for i in 0..self.m {
            if i == row {
                continue;
            }
            let f = self.at(i, col);
            if f.abs() <= EPS {
                continue;
            }
            for j in 0..n {
                let v = self.a[row * n + j];
                self.a[i * n + j] -= f * v;
            }
            self.b[i] -= f * self.b[row];
        }
        self.basis[row] = col;
    }

    /// Minimize `c·x` over the current basis; `allowed` masks columns that
    /// may enter (used to keep artificials out in phase 2). `r` is the
    /// caller-provided reduced-cost buffer.
    ///
    /// The reduced-cost row is computed once (O(n·m)) and then updated
    /// incrementally on every pivot (O(n)) — the full-tableau method.
    /// `Err(())` means unbounded.
    fn optimize(
        &mut self,
        c: &[f64],
        allowed: &[bool],
        r: &mut Vec<f64>,
        max_iters: usize,
    ) -> Result<(), ()> {
        // r_j = c_j - c_B · B^{-1} A_j
        r.clear();
        r.extend_from_slice(c);
        for i in 0..self.m {
            let cb = c[self.basis[i]];
            if cb != 0.0 {
                for j in 0..self.n {
                    r[j] -= cb * self.at(i, j);
                }
            }
        }
        let mut iters = 0usize;
        loop {
            iters += 1;
            if iters > max_iters {
                // Numerical stall: treat as optimal-at-tolerance rather
                // than looping forever (observed objective is valid).
                return Ok(());
            }
            let bland = iters > 4 * (self.n + self.m);
            let mut enter: Option<usize> = None;
            let mut best = -EPS;
            for j in 0..self.n {
                if !allowed[j] {
                    continue;
                }
                let rj = r[j];
                if rj < -1e-7 {
                    if bland {
                        enter = Some(j);
                        break;
                    }
                    if rj < best {
                        best = rj;
                        enter = Some(j);
                    }
                }
            }
            let Some(col) = enter else { return Ok(()) };
            // ratio test
            let mut leave: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            for i in 0..self.m {
                let aij = self.at(i, col);
                if aij > EPS {
                    let ratio = self.b[i] / aij;
                    if ratio < best_ratio - EPS
                        || (bland
                            && (ratio - best_ratio).abs() <= EPS
                            && leave.map_or(true, |l| self.basis[i] < self.basis[l]))
                    {
                        best_ratio = ratio;
                        leave = Some(i);
                    }
                }
            }
            let Some(row) = leave else {
                return Err(());
            };
            self.pivot(row, col);
            // Incremental reduced-cost update with the normalized pivot row.
            let rc = r[col];
            if rc != 0.0 {
                for j in 0..self.n {
                    r[j] -= rc * self.at(row, j);
                }
            }
        }
    }
}

/// The warm-start result cache of [`LpWorkspace::solve_warm`]: the
/// byte-encoded problem of the most recent warm solve plus its full
/// outcome. Coefficients are compared through `f64::to_bits`, so a hit
/// certifies the incoming problem is **bit-identical** — and since
/// [`LpWorkspace::solve`] is a pure function of the problem (the tableau
/// is rebuilt from scratch every call; `dirty_workspace_matches_fresh_solve`
/// is the regression test), replaying the stored result is exact, not an
/// approximation. That is what keeps `--cold-solver` parity byte-level.
#[derive(Debug, Default)]
struct WarmCache {
    valid: bool,
    num_vars: usize,
    /// Objective coefficient bits.
    objective: Vec<u64>,
    /// Row coefficient bits, row-major (each row is `num_vars` wide).
    coeffs: Vec<u64>,
    cmps: Vec<Cmp>,
    /// RHS bits per row.
    rhs: Vec<u64>,
    infeasible: bool,
    unbounded: bool,
    x: Vec<f64>,
    objective_value: f64,
    /// Pivots the cached solve spent (reported as saved on each hit).
    pivots: u64,
}

impl WarmCache {
    fn matches(&self, p: &LpProblem) -> bool {
        if !self.valid
            || self.num_vars != p.num_vars
            || self.rhs.len() != p.rows.len()
        {
            return false;
        }
        if !p.objective.iter().zip(&self.objective).all(|(v, b)| v.to_bits() == *b) {
            return false;
        }
        let mut off = 0;
        for (i, (a, cmp, b)) in p.rows.iter().enumerate() {
            if *cmp != self.cmps[i] || b.to_bits() != self.rhs[i] {
                return false;
            }
            let stored = &self.coeffs[off..off + a.len()];
            if !a.iter().zip(stored).all(|(v, bb)| v.to_bits() == *bb) {
                return false;
            }
            off += a.len();
        }
        true
    }

    fn store(&mut self, p: &LpProblem, status: LpStatus, x: &[f64], obj: f64, pivots: u64) {
        self.valid = true;
        self.num_vars = p.num_vars;
        self.objective.clear();
        self.objective.extend(p.objective.iter().map(|v| v.to_bits()));
        self.coeffs.clear();
        self.cmps.clear();
        self.rhs.clear();
        for (a, cmp, b) in &p.rows {
            self.coeffs.extend(a.iter().map(|v| v.to_bits()));
            self.cmps.push(*cmp);
            self.rhs.push(b.to_bits());
        }
        self.infeasible = status == LpStatus::Infeasible;
        self.unbounded = status == LpStatus::Unbounded;
        self.x.clear();
        self.x.extend_from_slice(x);
        self.objective_value = obj;
        self.pivots = pivots;
    }

    fn status(&self) -> LpStatus {
        if self.infeasible {
            LpStatus::Infeasible
        } else if self.unbounded {
            LpStatus::Unbounded
        } else {
            LpStatus::Optimal
        }
    }
}

/// Caller-owned solver buffers (see module docs). Construct once, pass to
/// [`LpWorkspace::solve`] / [`solve_with`] for every LP; the tableau and
/// all side vectors are recycled in place.
#[derive(Debug, Default)]
pub struct LpWorkspace {
    t: Tableau,
    /// Per-row normalization flags (`b < 0` rows are sign-flipped).
    flip: Vec<bool>,
    eff_cmp: Vec<Cmp>,
    slack_col: Vec<usize>,
    art_col: Vec<usize>,
    /// Phase objective buffer.
    c: Vec<f64>,
    /// Reduced-cost buffer.
    r: Vec<f64>,
    allowed: Vec<bool>,
    x: Vec<f64>,
    objective: f64,
    warm: WarmCache,
}

impl LpWorkspace {
    pub fn new() -> LpWorkspace {
        LpWorkspace::default()
    }

    /// The optimal point of the most recent [`solve`](LpWorkspace::solve)
    /// (valid only when it returned [`LpStatus::Optimal`]).
    pub fn x(&self) -> &[f64] {
        &self.x
    }

    /// Objective value of the most recent optimal solve.
    pub fn objective(&self) -> f64 {
        self.objective
    }

    /// Cumulative simplex pivots across every solve on this workspace
    /// (the `SolverStats` LP-pivot counter reads deltas of this).
    pub fn total_pivots(&self) -> u64 {
        self.t.pivots
    }

    /// Pivot count of the solve currently held by the warm-start cache —
    /// i.e. the pivots a [`solve_warm`](LpWorkspace::solve_warm) hit did
    /// *not* have to spend (feeds `SolverStats::warm_pivots_saved`).
    pub fn warm_saved_pivots(&self) -> u64 {
        self.warm.pivots
    }

    /// Solve `p`, replaying the cached result when `p` is **bit-identical**
    /// to the previous `solve_warm` problem. Returns the status plus
    /// `true` on a warm hit (zero pivots spent, `x`/`objective` restored
    /// from the cache) or `false` when it fell back to a cold
    /// [`solve`](LpWorkspace::solve) and re-remembered.
    ///
    /// Exactness: a hit is only declared when every coefficient matches by
    /// `f64::to_bits` (so `-0.0` vs `0.0` or NaN payloads can't alias), and
    /// `solve` is a pure function of the problem, so the replayed result is
    /// the same bytes the cold path would produce. Interleaved plain
    /// [`solve`](LpWorkspace::solve) calls never touch the cache; a hit
    /// restores the stored `x` copy, so staleness is impossible.
    pub fn solve_warm(&mut self, p: &LpProblem) -> (LpStatus, bool) {
        if self.warm.matches(p) {
            self.x.clear();
            self.x.extend_from_slice(&self.warm.x);
            self.objective = self.warm.objective_value;
            return (self.warm.status(), true);
        }
        let before = self.t.pivots;
        let status = self.solve(p);
        let spent = self.t.pivots - before;
        // Move x out to appease the borrow checker, then put it back.
        let x = std::mem::take(&mut self.x);
        self.warm.store(p, status, &x, self.objective, spent);
        self.x = x;
        (status, false)
    }

    /// Solve `p` in place. Allocation-free once the buffers have grown to
    /// the problem size; the solution stays in the workspace.
    pub fn solve(&mut self, p: &LpProblem) -> LpStatus {
        let _span = crate::obs::span(crate::obs::Stage::LpSolve);
        let nv = p.num_vars;
        let m = p.rows.len();
        self.x.clear();
        self.x.resize(nv, 0.0);
        self.objective = 0.0;
        if m == 0 {
            // unconstrained (x >= 0): minimum at x = 0 unless some c_j < 0.
            if p.objective.iter().any(|&c| c < -EPS) {
                return LpStatus::Unbounded;
            }
            return LpStatus::Optimal;
        }

        // Count extra columns: one slack/surplus per inequality,
        // artificials as needed (Ge and Eq rows, and Le rows with negative
        // rhs after the sign flip). Rows are normalized to b >= 0 on the
        // fly while filling the tableau — no row copies.
        let LpWorkspace {
            t,
            flip,
            eff_cmp,
            slack_col,
            art_col,
            c,
            r,
            allowed,
            x,
            objective,
        } = self;
        flip.clear();
        eff_cmp.clear();
        slack_col.clear();
        slack_col.resize(m, usize::MAX);
        art_col.clear();
        art_col.resize(m, usize::MAX);
        let mut n = nv;
        for (i, (_, cmp, b)) in p.rows.iter().enumerate() {
            let fl = *b < 0.0;
            let cmp = if fl {
                match cmp {
                    Cmp::Le => Cmp::Ge,
                    Cmp::Ge => Cmp::Le,
                    Cmp::Eq => Cmp::Eq,
                }
            } else {
                *cmp
            };
            flip.push(fl);
            eff_cmp.push(cmp);
            match cmp {
                Cmp::Le => {
                    slack_col[i] = n;
                    n += 1;
                }
                Cmp::Ge => {
                    slack_col[i] = n; // surplus (coefficient -1)
                    n += 1;
                    art_col[i] = n;
                    n += 1;
                }
                Cmp::Eq => {
                    art_col[i] = n;
                    n += 1;
                }
            }
        }

        t.reset(m, n);
        for (i, (a, _, b)) in p.rows.iter().enumerate() {
            if flip[i] {
                for j in 0..nv {
                    *t.at_mut(i, j) = -a[j];
                }
                t.b[i] = -*b;
            } else {
                for j in 0..nv {
                    *t.at_mut(i, j) = a[j];
                }
                t.b[i] = *b;
            }
            match eff_cmp[i] {
                Cmp::Le => {
                    *t.at_mut(i, slack_col[i]) = 1.0;
                    t.basis[i] = slack_col[i];
                }
                Cmp::Ge => {
                    *t.at_mut(i, slack_col[i]) = -1.0;
                    *t.at_mut(i, art_col[i]) = 1.0;
                    t.basis[i] = art_col[i];
                }
                Cmp::Eq => {
                    *t.at_mut(i, art_col[i]) = 1.0;
                    t.basis[i] = art_col[i];
                }
            }
        }

        let has_artificials = art_col.iter().any(|&col| col != usize::MAX);
        let max_iters = 50 * (n + m) + 1000;

        if has_artificials {
            // Phase 1: minimize sum of artificials.
            c.clear();
            c.resize(n, 0.0);
            for &col in art_col.iter() {
                if col != usize::MAX {
                    c[col] = 1.0;
                }
            }
            allowed.clear();
            allowed.resize(n, true);
            if t.optimize(c, allowed, r, max_iters).is_err() {
                // unbounded phase 1 cannot happen, but propagate
                return LpStatus::Unbounded;
            }
            let phase1: f64 = t
                .basis
                .iter()
                .enumerate()
                .filter(|(_, &bj)| c[bj] > 0.0)
                .map(|(i, _)| t.b[i])
                .sum();
            if phase1 > 1e-6 {
                return LpStatus::Infeasible;
            }
            // Drive remaining artificials out of the basis where possible.
            for i in 0..m {
                if c[t.basis[i]] > 0.0 {
                    // find a non-artificial column with nonzero coefficient
                    let col = (0..n).find(|&j| c[j] == 0.0 && t.at(i, j).abs() > 1e-7);
                    if let Some(j) = col {
                        t.pivot(i, j);
                    }
                    // else: redundant row; harmless to leave (b[i] ~ 0).
                }
            }
        }

        // Phase 2.
        c.clear();
        c.resize(n, 0.0);
        c[..nv].copy_from_slice(&p.objective);
        allowed.clear();
        allowed.resize(n, true);
        for &col in art_col.iter() {
            if col != usize::MAX {
                allowed[col] = false;
            }
        }
        if t.optimize(c, allowed, r, max_iters).is_err() {
            return LpStatus::Unbounded;
        }

        for i in 0..m {
            if t.basis[i] < nv {
                x[t.basis[i]] = t.b[i].max(0.0);
            }
        }
        *objective = p.objective_value(x);
        LpStatus::Optimal
    }
}

/// Solve using a caller-owned workspace; the returned [`LpOutcome`] owns a
/// copy of the solution vector (use [`LpWorkspace::solve`] directly to
/// avoid even that copy).
pub fn solve_with(p: &LpProblem, ws: &mut LpWorkspace) -> LpOutcome {
    match ws.solve(p) {
        LpStatus::Optimal => LpOutcome::Optimal(LpSolution {
            x: ws.x().to_vec(),
            objective: ws.objective(),
        }),
        LpStatus::Infeasible => LpOutcome::Infeasible,
        LpStatus::Unbounded => LpOutcome::Unbounded,
    }
}

/// One-shot solve with a throwaway workspace. See module docs.
pub fn solve(p: &LpProblem) -> LpOutcome {
    solve_with(p, &mut LpWorkspace::new())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(out: &LpOutcome, expect_obj: f64, tol: f64) -> Vec<f64> {
        let s = out.optimal().unwrap_or_else(|| panic!("not optimal: {out:?}"));
        assert!(
            (s.objective - expect_obj).abs() < tol,
            "objective {} != {expect_obj}",
            s.objective
        );
        s.x.clone()
    }

    #[test]
    fn simple_le() {
        // max x + y s.t. x + 2y <= 4, 3x + y <= 6  => min -(x+y)
        let mut p = LpProblem::new(2);
        p.set_objective(vec![-1.0, -1.0]);
        p.add_row(vec![1.0, 2.0], Cmp::Le, 4.0);
        p.add_row(vec![3.0, 1.0], Cmp::Le, 6.0);
        // optimum x=1.6, y=1.2, value 2.8
        let x = assert_opt(&solve(&p), -2.8, 1e-7);
        assert!((x[0] - 1.6).abs() < 1e-7 && (x[1] - 1.2).abs() < 1e-7);
    }

    #[test]
    fn cover_with_ge() {
        // min 2x + 3y s.t. x + y >= 10, x <= 6
        let mut p = LpProblem::new(2);
        p.set_objective(vec![2.0, 3.0]);
        p.add_row(vec![1.0, 1.0], Cmp::Ge, 10.0);
        p.add_row(vec![1.0, 0.0], Cmp::Le, 6.0);
        let x = assert_opt(&solve(&p), 2.0 * 6.0 + 3.0 * 4.0, 1e-7);
        assert!((x[0] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn equality_rows() {
        // min x + y s.t. x + 2y = 6, x - y = 0 => x = y = 2
        let mut p = LpProblem::new(2);
        p.set_objective(vec![1.0, 1.0]);
        p.add_row(vec![1.0, 2.0], Cmp::Eq, 6.0);
        p.add_row(vec![1.0, -1.0], Cmp::Eq, 0.0);
        let x = assert_opt(&solve(&p), 4.0, 1e-7);
        assert!((x[0] - 2.0).abs() < 1e-7);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = LpProblem::new(1);
        p.set_objective(vec![1.0]);
        p.add_row(vec![1.0], Cmp::Ge, 5.0);
        p.add_row(vec![1.0], Cmp::Le, 3.0);
        assert!(solve(&p).is_infeasible());
    }

    #[test]
    fn unbounded_detected() {
        // min -x s.t. x >= 1 (x can grow forever)
        let mut p = LpProblem::new(1);
        p.set_objective(vec![-1.0]);
        p.add_row(vec![1.0], Cmp::Ge, 1.0);
        assert!(matches!(solve(&p), LpOutcome::Unbounded));
    }

    #[test]
    fn negative_rhs_normalization() {
        // x - y <= -2 with min x + y => y >= x + 2, best x=0,y=2
        let mut p = LpProblem::new(2);
        p.set_objective(vec![1.0, 1.0]);
        p.add_row(vec![1.0, -1.0], Cmp::Le, -2.0);
        let x = assert_opt(&solve(&p), 2.0, 1e-7);
        assert!(x[0].abs() < 1e-7 && (x[1] - 2.0).abs() < 1e-7);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // classic degenerate example (Beale-like); just ensure termination
        let mut p = LpProblem::new(4);
        p.set_objective(vec![-0.75, 150.0, -0.02, 6.0]);
        p.add_row(vec![0.25, -60.0, -0.04, 9.0], Cmp::Le, 0.0);
        p.add_row(vec![0.5, -90.0, -0.02, 3.0], Cmp::Le, 0.0);
        p.add_row(vec![0.0, 0.0, 1.0, 0.0], Cmp::Le, 1.0);
        let out = solve(&p);
        let s = out.optimal().expect("should solve");
        assert!((s.objective - (-0.05)).abs() < 1e-6, "obj {}", s.objective);
    }

    #[test]
    fn feasibility_checker_matches_solution() {
        let mut p = LpProblem::new(3);
        p.set_objective(vec![1.0, 2.0, 0.5]);
        p.add_row(vec![1.0, 1.0, 1.0], Cmp::Ge, 4.0);
        p.add_row(vec![2.0, 0.0, 1.0], Cmp::Le, 9.0);
        p.add_row(vec![0.0, 1.0, 0.0], Cmp::Le, 2.0);
        let out = solve(&p);
        let s = out.optimal().unwrap();
        assert!(p.is_feasible(&s.x, 1e-7));
    }

    #[test]
    fn scheduling_shaped_lp() {
        // A miniature of problem (23): 2 machines, workers w_h and ps s_h.
        // min 1*w0 + 3*w1 + 2*s0 + 1*s1
        // s.t. per-machine cap: 2w_h + 1s_h <= 10
        //      w0 + w1 >= 4 (cover), w0 + w1 <= 6 (packing)
        //      s0 + s1 >= 2 (gamma cover)
        let mut p = LpProblem::new(4); // [w0, w1, s0, s1]
        p.set_objective(vec![1.0, 3.0, 2.0, 1.0]);
        p.add_row(vec![2.0, 0.0, 1.0, 0.0], Cmp::Le, 10.0);
        p.add_row(vec![0.0, 2.0, 0.0, 1.0], Cmp::Le, 10.0);
        p.add_row(vec![1.0, 1.0, 0.0, 0.0], Cmp::Ge, 4.0);
        p.add_row(vec![1.0, 1.0, 0.0, 0.0], Cmp::Le, 6.0);
        p.add_row(vec![0.0, 0.0, 1.0, 1.0], Cmp::Ge, 2.0);
        // best: w0=4 (cost 4), s1=2 (cost 2) => 6; machine0 cap: 8+0<=10 ok
        let x = assert_opt(&solve(&p), 6.0, 1e-7);
        assert!((x[0] - 4.0).abs() < 1e-7);
        assert!((x[3] - 2.0).abs() < 1e-7);
    }

    /// A dirty workspace must behave exactly like a fresh one — the
    /// LpWorkspace-reuse contract the θ-solver hot path relies on.
    #[test]
    fn dirty_workspace_matches_fresh_solve() {
        let mut big = LpProblem::new(4);
        big.set_objective(vec![-0.75, 150.0, -0.02, 6.0]);
        big.add_row(vec![0.25, -60.0, -0.04, 9.0], Cmp::Le, 0.0);
        big.add_row(vec![0.5, -90.0, -0.02, 3.0], Cmp::Le, 0.0);
        big.add_row(vec![0.0, 0.0, 1.0, 0.0], Cmp::Le, 1.0);
        let mut small = LpProblem::new(2);
        small.set_objective(vec![2.0, 3.0]);
        small.add_row(vec![1.0, 1.0], Cmp::Ge, 10.0);
        small.add_row(vec![1.0, 0.0], Cmp::Le, 6.0);

        let mut ws = LpWorkspace::new();
        // dirty the workspace with the bigger problem first, then solve
        // the smaller one on the same buffers (shrinking reuse)
        assert_eq!(ws.solve(&big), LpStatus::Optimal);
        let pivots_after_big = ws.total_pivots();
        assert!(pivots_after_big > 0);
        assert_eq!(ws.solve(&small), LpStatus::Optimal);
        let fresh = solve(&small);
        let f = fresh.optimal().unwrap();
        assert_eq!(ws.x(), &f.x[..], "reused workspace must match fresh solve");
        assert_eq!(ws.objective(), f.objective);
        assert!(ws.total_pivots() > pivots_after_big, "pivots accumulate");

        // and growing reuse: back to the big problem, still identical
        assert_eq!(ws.solve(&big), LpStatus::Optimal);
        let fb = solve(&big);
        assert_eq!(ws.x(), &fb.optimal().unwrap().x[..]);
    }

    /// Infeasible/unbounded outcomes must not leave stale state behind.
    #[test]
    fn workspace_survives_bad_outcomes() {
        let mut infeasible = LpProblem::new(1);
        infeasible.set_objective(vec![1.0]);
        infeasible.add_row(vec![1.0], Cmp::Ge, 5.0);
        infeasible.add_row(vec![1.0], Cmp::Le, 3.0);
        let mut unbounded = LpProblem::new(1);
        unbounded.set_objective(vec![-1.0]);
        unbounded.add_row(vec![1.0], Cmp::Ge, 1.0);
        let mut good = LpProblem::new(2);
        good.set_objective(vec![2.0, 3.0]);
        good.add_row(vec![1.0, 1.0], Cmp::Ge, 10.0);
        good.add_row(vec![1.0, 0.0], Cmp::Le, 6.0);

        let mut ws = LpWorkspace::new();
        assert_eq!(ws.solve(&infeasible), LpStatus::Infeasible);
        assert_eq!(ws.solve(&unbounded), LpStatus::Unbounded);
        assert_eq!(ws.solve(&good), LpStatus::Optimal);
        let f = solve(&good);
        assert_eq!(ws.x(), &f.optimal().unwrap().x[..]);
    }

    /// `solve_warm` hits must replay the exact bytes of the cold solve —
    /// x, objective, status — and spend zero pivots doing it, including
    /// when plain `solve` calls ran in between (stored-x restore) and for
    /// non-optimal statuses.
    #[test]
    fn solve_warm_replays_bit_identical_results() {
        let mut a = LpProblem::new(2);
        a.set_objective(vec![-1.0, -1.0]);
        a.add_row(vec![1.0, 2.0], Cmp::Le, 4.0);
        a.add_row(vec![3.0, 1.0], Cmp::Le, 6.0);
        let mut b = LpProblem::new(2);
        b.set_objective(vec![2.0, 3.0]);
        b.add_row(vec![1.0, 1.0], Cmp::Ge, 10.0);
        b.add_row(vec![1.0, 0.0], Cmp::Le, 6.0);

        let mut ws = LpWorkspace::new();
        let (st, hit) = ws.solve_warm(&a);
        assert_eq!((st, hit), (LpStatus::Optimal, false), "first solve is cold");
        let cold_x = ws.x().to_vec();
        let cold_obj = ws.objective();
        let saved = ws.warm_saved_pivots();
        assert!(saved > 0);

        // Identical problem => hit, no pivots, byte-identical result.
        let pivots_before = ws.total_pivots();
        let (st, hit) = ws.solve_warm(&a);
        assert_eq!((st, hit), (LpStatus::Optimal, true));
        assert_eq!(ws.total_pivots(), pivots_before, "hit spends no pivots");
        assert_eq!(ws.x(), &cold_x[..]);
        assert_eq!(ws.objective(), cold_obj);

        // An interleaved *plain* solve overwrites x but not the cache:
        // the next warm call on `a` must restore the stored copy.
        assert_eq!(ws.solve(&b), LpStatus::Optimal);
        assert_ne!(ws.x(), &cold_x[..]);
        let (st, hit) = ws.solve_warm(&a);
        assert_eq!((st, hit), (LpStatus::Optimal, true));
        assert_eq!(ws.x(), &cold_x[..]);
        assert_eq!(ws.objective(), cold_obj);

        // A different problem through solve_warm => fallback + re-remember.
        let (st, hit) = ws.solve_warm(&b);
        assert_eq!((st, hit), (LpStatus::Optimal, false));
        let (st, hit) = ws.solve_warm(&b);
        assert_eq!((st, hit), (LpStatus::Optimal, true));
        // `a` is forgotten now (single-entry cache).
        let (_, hit) = ws.solve_warm(&a);
        assert!(!hit);

        // A flipped sign bit (0.0 vs -0.0, equal under `==`) must NOT
        // hit: exactness is bit-level, not numeric.
        let mut zero = LpProblem::new(2);
        zero.set_objective(vec![2.0, 3.0]);
        zero.add_row(vec![1.0, 0.0], Cmp::Le, 6.0);
        let (_, hit) = ws.solve_warm(&zero);
        assert!(!hit);
        let mut negzero = LpProblem::new(2);
        negzero.set_objective(vec![2.0, 3.0]);
        negzero.add_row(vec![1.0, -0.0], Cmp::Le, 6.0);
        let (_, hit) = ws.solve_warm(&negzero);
        assert!(!hit, "-0.0 differs from 0.0 at the bit level");
        let (_, hit) = ws.solve_warm(&negzero);
        assert!(hit);

        // Infeasible outcomes replay too.
        let mut inf = LpProblem::new(1);
        inf.set_objective(vec![1.0]);
        inf.add_row(vec![1.0], Cmp::Ge, 5.0);
        inf.add_row(vec![1.0], Cmp::Le, 3.0);
        let (st, hit) = ws.solve_warm(&inf);
        assert_eq!((st, hit), (LpStatus::Infeasible, false));
        let (st, hit) = ws.solve_warm(&inf);
        assert_eq!((st, hit), (LpStatus::Infeasible, true));
    }

    /// `LpProblem::reset` recycles row buffers without changing semantics.
    #[test]
    fn problem_reset_reuses_rows() {
        let mut p = LpProblem::new(2);
        p.set_objective(vec![2.0, 3.0]);
        p.add_row_sparse(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 10.0);
        p.add_row_sparse(&[(0, 1.0)], Cmp::Le, 6.0);
        let first = solve(&p);
        let first = first.optimal().unwrap().clone();

        // rebuild the same problem through reset + pooled rows
        p.reset(2);
        assert!(p.rows.is_empty());
        assert!(p.objective.iter().all(|&c| c == 0.0));
        p.objective[0] = 2.0;
        p.objective[1] = 3.0;
        p.add_row_sparse(&[(0, 1.0), (1, 1.0)], Cmp::Ge, 10.0);
        p.add_row_sparse(&[(0, 1.0)], Cmp::Le, 6.0);
        let second = solve(&p);
        let second = second.optimal().unwrap().clone();
        assert_eq!(first.x, second.x);
        assert_eq!(first.objective, second.objective);

        // reset to a different width works too
        p.reset(3);
        assert_eq!(p.num_vars, 3);
        p.add_row_sparse(&[(2, 1.0)], Cmp::Le, 1.0);
        assert_eq!(p.rows[0].0.len(), 3);
    }
}
