//! Dense two-phase primal simplex.
//!
//! Standard textbook construction: rows are normalized to `a·x = b` with
//! `b ≥ 0` using slack/surplus variables; artificial variables seed the
//! initial basis; phase 1 minimizes the artificial sum (infeasible if it
//! stays positive); phase 2 minimizes the real objective. Dantzig pricing
//! with a Bland fallback after a stall threshold guards against cycling.

use super::problem::{Cmp, LpOutcome, LpProblem, LpSolution};

const EPS: f64 = 1e-9;

struct Tableau {
    /// `m x n` coefficient matrix (row-major), plus rhs column `b`.
    a: Vec<f64>,
    b: Vec<f64>,
    m: usize,
    n: usize,
    /// basis[i] = column index basic in row i.
    basis: Vec<usize>,
}

impl Tableau {
    #[inline]
    fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.a[i * self.n + j]
    }

    fn pivot(&mut self, row: usize, col: usize) {
        let n = self.n;
        let piv = self.at(row, col);
        debug_assert!(piv.abs() > EPS);
        let inv = 1.0 / piv;
        for j in 0..n {
            self.a[row * n + j] *= inv;
        }
        self.b[row] *= inv;
        for i in 0..self.m {
            if i == row {
                continue;
            }
            let f = self.at(i, col);
            if f.abs() <= EPS {
                continue;
            }
            for j in 0..n {
                let v = self.a[row * n + j];
                self.a[i * n + j] -= f * v;
            }
            self.b[i] -= f * self.b[row];
        }
        self.basis[row] = col;
    }

    /// Minimize `c·x` over the current basis; `allowed` masks columns that
    /// may enter (used to keep artificials out in phase 2).
    ///
    /// The reduced-cost row is computed once (O(n·m)) and then updated
    /// incrementally on every pivot (O(n)) — the full-tableau method.
    fn optimize(&mut self, c: &[f64], allowed: &[bool], max_iters: usize) -> Result<(), LpOutcome> {
        // r_j = c_j - c_B · B^{-1} A_j
        let mut r: Vec<f64> = c.to_vec();
        for i in 0..self.m {
            let cb = c[self.basis[i]];
            if cb != 0.0 {
                for j in 0..self.n {
                    r[j] -= cb * self.at(i, j);
                }
            }
        }
        let mut iters = 0usize;
        loop {
            iters += 1;
            if iters > max_iters {
                // Numerical stall: treat as optimal-at-tolerance rather
                // than looping forever (observed objective is valid).
                return Ok(());
            }
            let bland = iters > 4 * (self.n + self.m);
            let mut enter: Option<usize> = None;
            let mut best = -EPS;
            for j in 0..self.n {
                if !allowed[j] {
                    continue;
                }
                let rj = r[j];
                if rj < -1e-7 {
                    if bland {
                        enter = Some(j);
                        break;
                    }
                    if rj < best {
                        best = rj;
                        enter = Some(j);
                    }
                }
            }
            let Some(col) = enter else { return Ok(()) };
            // ratio test
            let mut leave: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            for i in 0..self.m {
                let aij = self.at(i, col);
                if aij > EPS {
                    let ratio = self.b[i] / aij;
                    if ratio < best_ratio - EPS
                        || (bland
                            && (ratio - best_ratio).abs() <= EPS
                            && leave.map_or(true, |l| self.basis[i] < self.basis[l]))
                    {
                        best_ratio = ratio;
                        leave = Some(i);
                    }
                }
            }
            let Some(row) = leave else {
                return Err(LpOutcome::Unbounded);
            };
            self.pivot(row, col);
            // Incremental reduced-cost update with the normalized pivot row.
            let rc = r[col];
            if rc != 0.0 {
                for j in 0..self.n {
                    r[j] -= rc * self.at(row, j);
                }
            }
        }
    }
}

/// Solve the LP. See module docs.
pub fn solve(p: &LpProblem) -> LpOutcome {
    let nv = p.num_vars;
    let m = p.rows.len();
    if m == 0 {
        // unconstrained (x >= 0): minimum at x = 0 unless some c_j < 0.
        if p.objective.iter().any(|&c| c < -EPS) {
            return LpOutcome::Unbounded;
        }
        return LpOutcome::Optimal(LpSolution { x: vec![0.0; nv], objective: 0.0 });
    }

    // Count extra columns: one slack/surplus per inequality, artificials as
    // needed (Ge and Eq rows, and Le rows with negative rhs after flip).
    let mut n = nv;
    let mut slack_col = vec![usize::MAX; m];
    let mut art_col = vec![usize::MAX; m];
    // Normalize rows to b >= 0 first.
    let mut rows: Vec<(Vec<f64>, Cmp, f64)> = p.rows.clone();
    for (a, cmp, b) in rows.iter_mut() {
        if *b < 0.0 {
            for v in a.iter_mut() {
                *v = -*v;
            }
            *b = -*b;
            *cmp = match *cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
        }
    }
    for (i, (_, cmp, _)) in rows.iter().enumerate() {
        match cmp {
            Cmp::Le => {
                slack_col[i] = n;
                n += 1;
            }
            Cmp::Ge => {
                slack_col[i] = n; // surplus (coefficient -1)
                n += 1;
                art_col[i] = n;
                n += 1;
            }
            Cmp::Eq => {
                art_col[i] = n;
                n += 1;
            }
        }
    }

    let mut t = Tableau {
        a: vec![0.0; m * n],
        b: vec![0.0; m],
        m,
        n,
        basis: vec![usize::MAX; m],
    };
    for (i, (a, cmp, b)) in rows.iter().enumerate() {
        for j in 0..nv {
            *t.at_mut(i, j) = a[j];
        }
        t.b[i] = *b;
        match cmp {
            Cmp::Le => {
                *t.at_mut(i, slack_col[i]) = 1.0;
                t.basis[i] = slack_col[i];
            }
            Cmp::Ge => {
                *t.at_mut(i, slack_col[i]) = -1.0;
                *t.at_mut(i, art_col[i]) = 1.0;
                t.basis[i] = art_col[i];
            }
            Cmp::Eq => {
                *t.at_mut(i, art_col[i]) = 1.0;
                t.basis[i] = art_col[i];
            }
        }
    }

    let has_artificials = art_col.iter().any(|&c| c != usize::MAX);
    let max_iters = 50 * (n + m) + 1000;

    if has_artificials {
        // Phase 1: minimize sum of artificials.
        let mut c1 = vec![0.0; n];
        for &c in art_col.iter() {
            if c != usize::MAX {
                c1[c] = 1.0;
            }
        }
        let allowed = vec![true; n];
        if let Err(out) = t.optimize(&c1, &allowed, max_iters) {
            return out; // unbounded phase 1 cannot happen, but propagate
        }
        let phase1: f64 = t
            .basis
            .iter()
            .enumerate()
            .filter(|(_, &bj)| c1[bj] > 0.0)
            .map(|(i, _)| t.b[i])
            .sum();
        if phase1 > 1e-6 {
            return LpOutcome::Infeasible;
        }
        // Drive remaining artificials out of the basis where possible.
        for i in 0..m {
            if c1[t.basis[i]] > 0.0 {
                // find a non-artificial column with nonzero coefficient
                let col = (0..n).find(|&j| c1[j] == 0.0 && t.at(i, j).abs() > 1e-7);
                if let Some(j) = col {
                    t.pivot(i, j);
                }
                // else: redundant row; harmless to leave (b[i] ~ 0).
            }
        }
    }

    // Phase 2.
    let mut c2 = vec![0.0; n];
    c2[..nv].copy_from_slice(&p.objective);
    let mut allowed = vec![true; n];
    for &c in art_col.iter() {
        if c != usize::MAX {
            allowed[c] = false;
        }
    }
    if let Err(out) = t.optimize(&c2, &allowed, max_iters) {
        return out;
    }

    let mut x = vec![0.0; nv];
    for i in 0..m {
        if t.basis[i] < nv {
            x[t.basis[i]] = t.b[i].max(0.0);
        }
    }
    let objective = p.objective_value(&x);
    LpOutcome::Optimal(LpSolution { x, objective })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(out: &LpOutcome, expect_obj: f64, tol: f64) -> Vec<f64> {
        let s = out.optimal().unwrap_or_else(|| panic!("not optimal: {out:?}"));
        assert!(
            (s.objective - expect_obj).abs() < tol,
            "objective {} != {expect_obj}",
            s.objective
        );
        s.x.clone()
    }

    #[test]
    fn simple_le() {
        // max x + y s.t. x + 2y <= 4, 3x + y <= 6  => min -(x+y)
        let mut p = LpProblem::new(2);
        p.set_objective(vec![-1.0, -1.0]);
        p.add_row(vec![1.0, 2.0], Cmp::Le, 4.0);
        p.add_row(vec![3.0, 1.0], Cmp::Le, 6.0);
        // optimum x=1.6, y=1.2, value 2.8
        let x = assert_opt(&solve(&p), -2.8, 1e-7);
        assert!((x[0] - 1.6).abs() < 1e-7 && (x[1] - 1.2).abs() < 1e-7);
    }

    #[test]
    fn cover_with_ge() {
        // min 2x + 3y s.t. x + y >= 10, x <= 6
        let mut p = LpProblem::new(2);
        p.set_objective(vec![2.0, 3.0]);
        p.add_row(vec![1.0, 1.0], Cmp::Ge, 10.0);
        p.add_row(vec![1.0, 0.0], Cmp::Le, 6.0);
        let x = assert_opt(&solve(&p), 2.0 * 6.0 + 3.0 * 4.0, 1e-7);
        assert!((x[0] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn equality_rows() {
        // min x + y s.t. x + 2y = 6, x - y = 0 => x = y = 2
        let mut p = LpProblem::new(2);
        p.set_objective(vec![1.0, 1.0]);
        p.add_row(vec![1.0, 2.0], Cmp::Eq, 6.0);
        p.add_row(vec![1.0, -1.0], Cmp::Eq, 0.0);
        let x = assert_opt(&solve(&p), 4.0, 1e-7);
        assert!((x[0] - 2.0).abs() < 1e-7);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = LpProblem::new(1);
        p.set_objective(vec![1.0]);
        p.add_row(vec![1.0], Cmp::Ge, 5.0);
        p.add_row(vec![1.0], Cmp::Le, 3.0);
        assert!(solve(&p).is_infeasible());
    }

    #[test]
    fn unbounded_detected() {
        // min -x s.t. x >= 1 (x can grow forever)
        let mut p = LpProblem::new(1);
        p.set_objective(vec![-1.0]);
        p.add_row(vec![1.0], Cmp::Ge, 1.0);
        assert!(matches!(solve(&p), LpOutcome::Unbounded));
    }

    #[test]
    fn negative_rhs_normalization() {
        // x - y <= -2 with min x + y => y >= x + 2, best x=0,y=2
        let mut p = LpProblem::new(2);
        p.set_objective(vec![1.0, 1.0]);
        p.add_row(vec![1.0, -1.0], Cmp::Le, -2.0);
        let x = assert_opt(&solve(&p), 2.0, 1e-7);
        assert!(x[0].abs() < 1e-7 && (x[1] - 2.0).abs() < 1e-7);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // classic degenerate example (Beale-like); just ensure termination
        let mut p = LpProblem::new(4);
        p.set_objective(vec![-0.75, 150.0, -0.02, 6.0]);
        p.add_row(vec![0.25, -60.0, -0.04, 9.0], Cmp::Le, 0.0);
        p.add_row(vec![0.5, -90.0, -0.02, 3.0], Cmp::Le, 0.0);
        p.add_row(vec![0.0, 0.0, 1.0, 0.0], Cmp::Le, 1.0);
        let out = solve(&p);
        let s = out.optimal().expect("should solve");
        assert!((s.objective - (-0.05)).abs() < 1e-6, "obj {}", s.objective);
    }

    #[test]
    fn feasibility_checker_matches_solution() {
        let mut p = LpProblem::new(3);
        p.set_objective(vec![1.0, 2.0, 0.5]);
        p.add_row(vec![1.0, 1.0, 1.0], Cmp::Ge, 4.0);
        p.add_row(vec![2.0, 0.0, 1.0], Cmp::Le, 9.0);
        p.add_row(vec![0.0, 1.0, 0.0], Cmp::Le, 2.0);
        let out = solve(&p);
        let s = out.optimal().unwrap();
        assert!(p.is_feasible(&s.x, 1e-7));
    }

    #[test]
    fn scheduling_shaped_lp() {
        // A miniature of problem (23): 2 machines, workers w_h and ps s_h.
        // min 1*w0 + 3*w1 + 2*s0 + 1*s1
        // s.t. per-machine cap: 2w_h + 1s_h <= 10
        //      w0 + w1 >= 4 (cover), w0 + w1 <= 6 (packing)
        //      s0 + s1 >= 2 (gamma cover)
        let mut p = LpProblem::new(4); // [w0, w1, s0, s1]
        p.set_objective(vec![1.0, 3.0, 2.0, 1.0]);
        p.add_row(vec![2.0, 0.0, 1.0, 0.0], Cmp::Le, 10.0);
        p.add_row(vec![0.0, 2.0, 0.0, 1.0], Cmp::Le, 10.0);
        p.add_row(vec![1.0, 1.0, 0.0, 0.0], Cmp::Ge, 4.0);
        p.add_row(vec![1.0, 1.0, 0.0, 0.0], Cmp::Le, 6.0);
        p.add_row(vec![0.0, 0.0, 1.0, 1.0], Cmp::Ge, 2.0);
        // best: w0=4 (cost 4), s1=2 (cost 2) => 6; machine0 cap: 8+0<=10 ok
        let x = assert_opt(&solve(&p), 6.0, 1e-7);
        assert!((x[0] - 4.0).abs() < 1e-7);
        assert!((x[3] - 2.0).abs() < 1e-7);
    }
}
