//! LP problem / solution types.

/// Row comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// `minimize c·x  s.t.  rows, x ≥ 0`.
#[derive(Debug, Clone, Default)]
pub struct LpProblem {
    pub num_vars: usize,
    /// Objective coefficients `c` (minimization).
    pub objective: Vec<f64>,
    /// Constraint rows `(a, cmp, b)` meaning `a·x cmp b`.
    pub rows: Vec<(Vec<f64>, Cmp, f64)>,
    /// Recycled row buffers ([`reset`](LpProblem::reset) parks dropped
    /// rows here; [`add_row_sparse`](LpProblem::add_row_sparse) reuses
    /// them) — keeps repeated problem builds allocation-free.
    pool: Vec<Vec<f64>>,
}

impl LpProblem {
    pub fn new(num_vars: usize) -> LpProblem {
        LpProblem {
            num_vars,
            objective: vec![0.0; num_vars],
            rows: Vec::new(),
            pool: Vec::new(),
        }
    }

    /// Clear the problem for reuse at a (possibly different) variable
    /// count: the objective is zeroed, rows are dropped, and their
    /// buffers are recycled for subsequent `add_row_sparse` calls.
    pub fn reset(&mut self, num_vars: usize) {
        self.num_vars = num_vars;
        self.objective.clear();
        self.objective.resize(num_vars, 0.0);
        for (a, _, _) in self.rows.drain(..) {
            self.pool.push(a);
        }
    }

    pub fn set_objective(&mut self, c: Vec<f64>) {
        assert_eq!(c.len(), self.num_vars);
        self.objective = c;
    }

    pub fn add_row(&mut self, a: Vec<f64>, cmp: Cmp, b: f64) {
        assert_eq!(a.len(), self.num_vars);
        self.rows.push((a, cmp, b));
    }

    /// Sparse convenience: coefficients given as (index, value) pairs.
    pub fn add_row_sparse(&mut self, terms: &[(usize, f64)], cmp: Cmp, b: f64) {
        let mut a = self.pool.pop().unwrap_or_default();
        a.clear();
        a.resize(self.num_vars, 0.0);
        for &(j, v) in terms {
            a[j] += v;
        }
        self.rows.push((a, cmp, b));
    }

    /// Evaluate feasibility of a point against all rows within `eps`.
    pub fn is_feasible(&self, x: &[f64], eps: f64) -> bool {
        if x.len() != self.num_vars || x.iter().any(|&v| v < -eps) {
            return false;
        }
        self.rows.iter().all(|(a, cmp, b)| {
            let lhs: f64 = a.iter().zip(x).map(|(ai, xi)| ai * xi).sum();
            match cmp {
                Cmp::Le => lhs <= b + eps,
                Cmp::Ge => lhs >= b - eps,
                Cmp::Eq => (lhs - b).abs() <= eps,
            }
        })
    }

    pub fn objective_value(&self, x: &[f64]) -> f64 {
        self.objective.iter().zip(x).map(|(c, v)| c * v).sum()
    }
}

/// An optimal solution.
#[derive(Debug, Clone)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
}

/// Solver outcome.
#[derive(Debug, Clone)]
pub enum LpOutcome {
    Optimal(LpSolution),
    Infeasible,
    Unbounded,
}

impl LpOutcome {
    pub fn optimal(&self) -> Option<&LpSolution> {
        match self {
            LpOutcome::Optimal(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_infeasible(&self) -> bool {
        matches!(self, LpOutcome::Infeasible)
    }
}
