//! Linear programming substrate (no external solver is available in the
//! offline build environment, so this is a from-scratch implementation).
//!
//! [`simplex`] implements a dense two-phase primal simplex with Dantzig
//! pricing and a Bland anti-cycling fallback. It is exact (up to fp
//! tolerance) and deliberately simple; the scheduler-side performance work
//! happens above it (machine-group aggregation in `sched::theta` shrinks
//! the LPs by orders of magnitude — see DESIGN.md §Perf).

pub mod problem;
pub mod simplex;

pub use problem::{Cmp, LpOutcome, LpProblem, LpSolution};
pub use simplex::solve;
