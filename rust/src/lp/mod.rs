//! Linear programming substrate (no external solver is available in the
//! offline build environment, so this is a from-scratch implementation).
//!
//! [`simplex`] implements a dense two-phase primal simplex with Dantzig
//! pricing and a Bland anti-cycling fallback. It is exact (up to fp
//! tolerance) and deliberately simple. Two layers of performance work sit
//! around it:
//!
//! * **above** — machine-group aggregation in `sched::solver` shrinks the
//!   LPs by orders of magnitude (see DESIGN.md §Perf and the snapshot
//!   layer in `cluster::snapshot`);
//! * **inside** — [`LpWorkspace`] makes repeated solves allocation-free:
//!   the caller owns the tableau/basis buffers and reuses them across the
//!   thousands of θ-relaxations one admission plans through.

pub mod problem;
pub mod simplex;

pub use problem::{Cmp, LpOutcome, LpProblem, LpSolution};
pub use simplex::{solve, solve_with, LpStatus, LpWorkspace};
