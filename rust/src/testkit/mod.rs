//! Property-based testing substrate (proptest is unavailable offline).
//!
//! [`check`] runs a property over `n` seeded random cases; on failure it
//! reports the case seed so the exact input reproduces with
//! `Rng::new(seed)`. No shrinking — cases are kept small instead.

use crate::util::Rng;

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropFailure {
    pub case: usize,
    pub seed: u64,
    pub message: String,
}

/// Run `prop` over `cases` seeded RNGs derived from `base_seed`.
/// The property returns `Err(msg)` to signal failure.
pub fn check<F>(name: &str, base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut meta = Rng::new(base_seed);
    for case in 0..cases {
        let seed = meta.next_u64();
        let mut rng = Rng::new(seed);
        if let Err(message) = prop(&mut rng) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}): {message}"
            );
        }
    }
}

/// Assert-like helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        check("count", 1, 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_failure() {
        check("fails", 2, 10, |rng| {
            let x = rng.f64();
            if x > 0.0 {
                Err(format!("x = {x}"))
            } else {
                Ok(())
            }
        });
    }
}
