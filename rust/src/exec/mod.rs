//! The training executor: runs a scheduled job's *actual* training through
//! the PJRT artifacts, BSP-style, with the paper's locality-dependent
//! communication model attached to every iteration.

pub mod bsp;
pub mod data;

pub use bsp::{execute_schedule, ExecConfig, ExecReport, SlotReport};
pub use data::TokenGen;
