//! Synthetic training data: seeded token streams with learnable structure.
//!
//! Pure-uniform tokens have no signal (loss would plateau at `ln V`), so
//! the generator emits a first-order Markov stream whose transition
//! structure the LM can learn — the loss curve in EXPERIMENTS.md actually
//! *falls*. The chain is deterministic per seed, so runs reproduce.

use crate::util::Rng;

/// Markov token generator over a vocabulary.
pub struct TokenGen {
    rng: Rng,
    vocab: usize,
    /// each token deterministically prefers a small successor set
    branch: usize,
}

impl TokenGen {
    pub fn new(seed: u64, vocab: usize) -> TokenGen {
        TokenGen { rng: Rng::new(seed), vocab, branch: 4 }
    }

    /// Successor candidates of token `t` (a fixed pseudo-random map).
    fn successor(&mut self, t: i32) -> i32 {
        let pick = self.rng.range_usize(0, self.branch - 1) as u64;
        // SplitMix-style deterministic successor map
        let mut z = (t as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(pick.wrapping_mul(0xBF58476D1CE4E5B9));
        z ^= z >> 29;
        (z % self.vocab as u64) as i32
    }

    /// One (batch × seq) token matrix, flattened row-major.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut t = self.rng.range_u64(0, self.vocab as u64 - 1) as i32;
            for _ in 0..seq {
                out.push(t);
                t = self.successor(t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let mut g = TokenGen::new(0, 64);
        let b = g.batch(4, 16);
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TokenGen::new(7, 128).batch(2, 8);
        let b = TokenGen::new(7, 128).batch(2, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn has_markov_structure() {
        // successors of a given token should concentrate on few values
        let mut g = TokenGen::new(3, 256);
        let stream = g.batch(1, 4096);
        let mut succ: std::collections::HashMap<i32, std::collections::HashSet<i32>> =
            std::collections::HashMap::new();
        for w in stream.windows(2) {
            succ.entry(w[0]).or_default().insert(w[1]);
        }
        let avg: f64 = succ.values().map(|s| s.len() as f64).sum::<f64>()
            / succ.len() as f64;
        assert!(avg <= 4.5, "avg successor set {avg} too diverse");
    }
}
