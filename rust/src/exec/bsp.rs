//! BSP parameter-server execution of a scheduled job (paper §3.1 workflow).
//!
//! For every slot of the schedule:
//!
//! 1. the placement fixes `W` workers, `S` parameter servers, and the
//!    locality (Fact 1) → the per-iteration simulated time
//!    `(F/W)·τ + (2g/S)/b` of Eq. (1);
//! 2. each BSP iteration: every worker computes gradients on its own
//!    token batch via the `grad` artifact (the L2/L1 JAX+Pallas graph),
//!    the PS sums the pushes and applies the Pallas `sgd_apply` kernel
//!    via the `apply` artifact (`w ← w − (lr/W)·Σ g`);
//! 3. the slot ends when its simulated time budget (1 slot) or the
//!    configured iteration cap is exhausted.
//!
//! Workers execute sequentially on the single CPU PJRT device (a thread
//! pool would serialize on the device anyway); parallelism across workers
//! is captured by the simulated-time model, wall-clock is reported
//! separately.

use crate::jobs::{Job, Locality, Schedule};
use crate::util::error::Result;
use crate::runtime::ModelBundle;
use crate::util::Timer;

use super::data::TokenGen;

/// Executor limits.
#[derive(Debug, Clone, Copy)]
pub struct ExecConfig {
    /// Hard cap on BSP iterations per slot (keeps CPU demos bounded).
    pub max_iters_per_slot: usize,
    /// Evaluate held-out loss after every slot.
    pub eval_each_slot: bool,
    pub seed: u64,
}

impl Default for ExecConfig {
    fn default() -> ExecConfig {
        ExecConfig { max_iters_per_slot: 20, eval_each_slot: false, seed: 0 }
    }
}

/// Per-slot execution record.
#[derive(Debug, Clone)]
pub struct SlotReport {
    pub t: usize,
    pub workers: u64,
    pub ps: u64,
    pub locality: Locality,
    pub iterations: usize,
    pub samples_trained: f64,
    /// Simulated in-cluster time consumed (slots; ≤ 1 unless capped).
    pub sim_time: f64,
    pub mean_loss: f32,
    pub wall_secs: f64,
}

/// Whole-schedule execution record.
#[derive(Debug, Clone)]
pub struct ExecReport {
    pub job_id: usize,
    pub slots: Vec<SlotReport>,
    /// Loss after each BSP iteration (the loss curve).
    pub losses: Vec<f32>,
    pub eval_losses: Vec<f32>,
    pub total_samples: f64,
    pub total_wall_secs: f64,
}

/// Per-BSP-iteration simulated time (Eq. (1) rearranged): each worker
/// computes `F/W` samples at τ each, then pushes/pulls `2g/S` MB over the
/// locality-determined link.
pub fn iteration_time(job: &Job, workers: u64, ps: u64, loc: Locality, g_mb: f64) -> f64 {
    let b = match loc {
        Locality::Internal => job.b_int,
        Locality::External => job.b_ext,
    };
    let f = job.batch as f64;
    (f / workers as f64) * job.tau + (2.0 * g_mb / ps as f64) / b
}

/// Execute `schedule` for `job` against the model artifacts. The `job`'s
/// analytical parameters (τ, γ, F, b) drive the simulated-time model; the
/// gradient/update math is the real compiled computation.
pub fn execute_schedule(
    bundle: &ModelBundle,
    job: &Job,
    schedule: &Schedule,
    cfg: &ExecConfig,
) -> Result<ExecReport> {
    let mut params = bundle.init_params(cfg.seed as u32)?;
    let mut gen = TokenGen::new(cfg.seed ^ 0xD5, bundle.meta.vocab);
    let mut eval_gen = TokenGen::new(cfg.seed ^ 0x5D, bundle.meta.vocab);
    let meta_batch = bundle.meta.batch;
    let seq = bundle.meta.seq_len;
    // gradient/parameter size from the *actual* model (MB)
    let g_mb = bundle.meta.num_params as f64 * 4.0 / 1e6;

    let total_timer = Timer::start();
    let mut slots = Vec::new();
    let mut losses: Vec<f32> = Vec::new();
    let mut eval_losses: Vec<f32> = Vec::new();
    let mut total_samples = 0.0;

    for slot in &schedule.slots {
        let workers: u64 = slot.placements.iter().map(|&(_, w, _)| w).sum();
        let ps: u64 = slot.placements.iter().map(|&(_, _, s)| s).sum();
        if workers == 0 || ps == 0 {
            continue;
        }
        let locality = Locality::of_placement(&slot.placements);
        let f = job.batch as f64;
        let iter_time = iteration_time(job, workers, ps, locality, g_mb);
        let budget_iters = if iter_time > 0.0 {
            (1.0 / iter_time).floor() as usize
        } else {
            usize::MAX
        };
        let iters = budget_iters.clamp(1, cfg.max_iters_per_slot);

        let wall = Timer::start();
        let mut slot_loss_sum = 0.0f32;
        for _ in 0..iters {
            // --- workers push gradients (BSP barrier = full sum) ---
            let mut grad_sum: Vec<f32> = vec![0.0; bundle.meta.num_params];
            let mut loss_sum = 0.0f32;
            for _w in 0..workers {
                let tokens = gen.batch(meta_batch, seq);
                let (g, loss) = bundle.grad(&params, &tokens)?;
                for (acc, gi) in grad_sum.iter_mut().zip(&g) {
                    *acc += gi;
                }
                loss_sum += loss;
            }
            // --- PS applies the aggregated update (Pallas sgd kernel) ---
            let scale = (bundle.meta.lr as f32) / workers as f32;
            params = bundle.apply(params, &grad_sum, scale)?;
            let mean_loss = loss_sum / workers as f32;
            losses.push(mean_loss);
            slot_loss_sum += mean_loss;
        }
        total_samples += iters as f64 * f;
        if cfg.eval_each_slot {
            let tokens = eval_gen.batch(meta_batch, seq);
            eval_losses.push(bundle.eval_loss(&params, &tokens)?);
        }
        slots.push(SlotReport {
            t: slot.t,
            workers,
            ps,
            locality,
            iterations: iters,
            samples_trained: iters as f64 * f,
            sim_time: iters as f64 * iter_time,
            mean_loss: slot_loss_sum / iters as f32,
            wall_secs: wall.elapsed_secs(),
        });
    }

    Ok(ExecReport {
        job_id: job.id,
        slots,
        losses,
        eval_losses,
        total_samples,
        total_wall_secs: total_timer.elapsed_secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::{speed, test_support::test_job};

    /// The executor's per-iteration time model and the scheduler's
    /// per-sample speed model (Eq. (1)) must agree: at the γ-consistent
    /// PS count (S = W/γ) and with g_mb = g_i, samples/slot from Eq. (1)
    /// equals F · (iterations that fit in one slot).
    #[test]
    fn executor_time_model_matches_scheduler_eq1() {
        let job = test_job(0); // gamma = 2
        for loc in [Locality::Internal, Locality::External] {
            for w in [2u64, 8, 16] {
                let s = ((w as f64 / job.gamma).ceil()) as u64;
                let iter = iteration_time(&job, w, s, loc, job.grad_size_mb);
                let iters_per_slot = 1.0 / iter;
                let exec_samples = job.batch as f64 * iters_per_slot;
                // Eq. (1): w workers at per-worker rate (with exact S=W/γ)
                let sched_samples =
                    w as f64 * speed::per_worker_rate(&job, loc);
                let rel = (exec_samples - sched_samples).abs() / sched_samples;
                assert!(
                    rel < 1e-9,
                    "{loc:?} w={w}: exec {exec_samples} vs eq1 {sched_samples}"
                );
            }
        }
    }

    #[test]
    fn internal_iterations_are_faster() {
        let job = test_job(0);
        let a = iteration_time(&job, 4, 2, Locality::Internal, job.grad_size_mb);
        let b = iteration_time(&job, 4, 2, Locality::External, job.grad_size_mb);
        assert!(a < b);
    }

    #[test]
    fn more_ps_reduces_comm_time() {
        let job = test_job(0);
        let a = iteration_time(&job, 8, 1, Locality::External, job.grad_size_mb);
        let b = iteration_time(&job, 8, 8, Locality::External, job.grad_size_mb);
        assert!(b < a);
    }
}
