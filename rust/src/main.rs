fn main() {
    dmlrs::cli::run();
}
