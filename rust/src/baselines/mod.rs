//! Baseline schedulers compared against PD-ORS in §5:
//!
//! * [`fifo`]    — FIFO order with fixed per-job worker/PS counts
//!   (Hadoop/Spark style), round-robin placement.
//! * [`drf`]     — Dominant-Resource-Fairness water-filling (YARN/Mesos).
//! * [`dorm`]    — Dorm-style utilization maximization with fairness and
//!   adjustment-overhead constraints (MILP heuristic).
//! * `OASiS`     — the primal-dual scheduler of [6] with workers and PSs
//!   on strictly separated machine halves; instantiated as
//!   [`crate::sched::PdOrs`] with [`crate::sched::Placement::Separated`].
//! * [`offline`] — the offline optimum upper bound (Fig. 10), via
//!   candidate-schedule enumeration + branch-and-bound.

pub mod dorm;
pub mod drf;
pub mod fifo;
pub mod offline;
pub mod placement;

pub use dorm::Dorm;
pub use drf::Drf;
pub use fifo::Fifo;
pub use offline::offline_optimum;
