//! Dorm baseline ([36], §5 baseline (3)): per-slot resource-utilization
//! maximization with fairness and adjustment-overhead constraints.
//!
//! Dorm solves an MILP each reconfiguration; we reproduce its behaviour
//! with the same structure greedily (documented substitution, DESIGN.md):
//!
//! * **utilization objective** — among grantable bundles, prefer the one
//!   consuming the most total resources (packs the cluster);
//! * **fairness** — a job may not exceed `1/n_active` of the dominant
//!   resource unless no other job can use the remainder;
//! * **adjustment overhead** — a job's worker count may change by at most
//!   `MAX_ADJUST` between consecutive slots (Dorm penalizes
//!   re-partitioning; we cap it).

use std::collections::HashMap;

use crate::cluster::{AllocLedger, ResVec, NUM_RESOURCES};
use crate::jobs::Job;
use crate::sim::{ActiveJob, ArrivalDecision, PlacementPolicy, Scheduler, SlotGrant};

use super::placement::{place_round_robin, SlotCapacity};

const MAX_ADJUST: u64 = 8;

pub struct Dorm {
    cursor: usize,
    /// workers granted in the previous slot, per job id
    prev_workers: HashMap<usize, u64>,
}

impl Dorm {
    pub fn new() -> Dorm {
        Dorm { cursor: 0, prev_workers: HashMap::new() }
    }
}

impl Default for Dorm {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Dorm {
    fn name(&self) -> String {
        "Dorm".into()
    }

    fn placement_policy(&self) -> PlacementPolicy {
        PlacementPolicy::RoundRobin
    }

    /// Slot-driven: every job joins the active queue at arrival.
    fn on_arrival(&mut self, _job: &Job, _ledger: &mut AllocLedger) -> ArrivalDecision {
        ArrivalDecision::Defer
    }

    fn on_slot(
        &mut self,
        t: usize,
        active: &[ActiveJob],
        ledger: &AllocLedger,
    ) -> Vec<SlotGrant> {
        let mut cap = SlotCapacity::snapshot(ledger, t);
        let n_active = active.len().max(1) as f64;
        let mut total_cap = ResVec::zero();
        for h in 0..ledger.num_machines() {
            total_cap.add_assign(ledger.capacity(h));
        }

        let mut granted: Vec<(u64, u64)> = vec![(0, 0); active.len()];
        let mut blocked = vec![false; active.len()];
        let mut acc: Vec<Vec<(usize, u64, u64)>> = vec![Vec::new(); active.len()];
        // two passes: first respecting the fair cap, then spend leftovers
        for fair_pass in [true, false] {
            for b in blocked.iter_mut() {
                *b = false;
            }
            loop {
                // candidate = bundle with the largest resource consumption
                let mut pick: Option<(usize, f64)> = None;
                for (i, aj) in active.iter().enumerate() {
                    if blocked[i] {
                        continue;
                    }
                    let (w, s) = granted[i];
                    let add_w = (aj.job.gamma.round() as u64).max(1);
                    if w + add_w > aj.job.batch {
                        blocked[i] = true;
                        continue;
                    }
                    // adjustment-overhead cap vs previous slot
                    let prev = *self.prev_workers.get(&aj.job.id).unwrap_or(&0);
                    if w + add_w > prev + MAX_ADJUST {
                        blocked[i] = true;
                        continue;
                    }
                    if fair_pass {
                        // dominant-share fairness cap
                        let used = aj.job.demand(w + add_w, s + 1);
                        let mut share: f64 = 0.0;
                        for r in 0..NUM_RESOURCES {
                            if total_cap.0[r] > 0.0 {
                                share = share.max(used.0[r] / total_cap.0[r]);
                            }
                        }
                        if share > 1.0 / n_active {
                            blocked[i] = true;
                            continue;
                        }
                    }
                    let bundle_res = aj.job.demand(add_w, 1).sum();
                    if pick.map_or(true, |(_, best)| bundle_res > best) {
                        pick = Some((i, bundle_res));
                    }
                }
                let Some((i, _)) = pick else { break };
                let aj = &active[i];
                let (w, s) = granted[i];
                let add_w = (aj.job.gamma.round() as u64).max(1);
                let need_s =
                    (((w + add_w) as f64 / aj.job.gamma).ceil() as u64).max(1);
                let add_s = need_s.saturating_sub(s);
                match place_round_robin(&aj.job, add_w, add_s, &mut cap, &mut self.cursor) {
                    Some(p) => {
                        granted[i] = (w + add_w, s + add_s);
                        acc[i].extend(p);
                    }
                    None => blocked[i] = true,
                }
            }
        }

        for (i, aj) in active.iter().enumerate() {
            self.prev_workers.insert(aj.job.id, granted[i].0);
        }

        acc.into_iter()
            .enumerate()
            .filter(|(_, p)| !p.is_empty())
            .map(|(i, p)| {
                let mut merged: std::collections::BTreeMap<usize, (u64, u64)> =
                    std::collections::BTreeMap::new();
                for (h, w, s) in p {
                    let e = merged.entry(h).or_insert((0, 0));
                    e.0 += w;
                    e.1 += s;
                }
                (i, merged.into_iter().map(|(h, (w, s))| (h, w, s)).collect())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::simulate;
    use crate::util::Rng;
    use crate::workload::synthetic::paper_cluster;
    use crate::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

    #[test]
    fn ramps_up_slowly() {
        // with MAX_ADJUST = 8, a fresh job can get at most 8 workers in
        // its first slot regardless of capacity
        let cluster = paper_cluster(20);
        let mut rng = Rng::new(5);
        let mut jobs = synthetic_jobs(&SynthConfig::paper(1, 10, MIX_DEFAULT), &mut rng);
        jobs[0].arrival = 0;
        jobs[0].gamma = 1.0;
        let mut dorm = Dorm::new();
        let ledger = AllocLedger::new(&cluster, 10);
        let active = vec![ActiveJob { job: jobs[0].clone(), remaining: 1e9 }];
        let grants = dorm.on_slot(0, &active, &ledger);
        let w: u64 = grants
            .iter()
            .flat_map(|(_, p)| p.iter().map(|&(_, w, _)| w))
            .sum();
        assert!(w <= MAX_ADJUST, "first-slot workers {w} > {MAX_ADJUST}");
    }

    #[test]
    fn completes_jobs_in_sim(){
        let cluster = paper_cluster(15);
        let mut rng = Rng::new(6);
        let jobs = synthetic_jobs(&SynthConfig::paper(12, 20, MIX_DEFAULT), &mut rng);
        let res = simulate(&jobs, &cluster, 20, &mut Dorm::new());
        assert!(res.admitted > 0);
    }
}
