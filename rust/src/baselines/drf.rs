//! Dominant Resource Fairness baseline (YARN/Mesos, §5 baseline (2)).
//!
//! Classic DRF water-filling per slot: repeatedly grant one worker/PS
//! bundle (γ workers per PS, preserving Eq. (2)) to the active job with
//! the smallest dominant share, until nothing more fits or every job hit
//! its Eq.-(4) worker cap. Placement is round-robin.

use crate::cluster::{AllocLedger, ResVec, NUM_RESOURCES};
use crate::jobs::Job;
use crate::sim::{ActiveJob, ArrivalDecision, PlacementPolicy, Scheduler, SlotGrant};

use super::placement::{place_round_robin, SlotCapacity};

pub struct Drf {
    cursor: usize,
}

impl Drf {
    pub fn new() -> Drf {
        Drf { cursor: 0 }
    }
}

impl Default for Drf {
    fn default() -> Self {
        Self::new()
    }
}

/// Dominant share of a job given its current worker/PS counts.
fn dominant_share(job: &Job, w: u64, s: u64, total_cap: &ResVec) -> f64 {
    let used = job.demand(w, s);
    let mut share: f64 = 0.0;
    for r in 0..NUM_RESOURCES {
        if total_cap.0[r] > 0.0 {
            share = share.max(used.0[r] / total_cap.0[r]);
        }
    }
    share
}

impl Scheduler for Drf {
    fn name(&self) -> String {
        "DRF".into()
    }

    fn placement_policy(&self) -> PlacementPolicy {
        PlacementPolicy::RoundRobin
    }

    /// Slot-driven: every job joins the active queue at arrival.
    fn on_arrival(&mut self, _job: &Job, _ledger: &mut AllocLedger) -> ArrivalDecision {
        ArrivalDecision::Defer
    }

    fn on_slot(
        &mut self,
        t: usize,
        active: &[ActiveJob],
        ledger: &AllocLedger,
    ) -> Vec<SlotGrant> {
        let mut cap = SlotCapacity::snapshot(ledger, t);
        let mut total_cap = ResVec::zero();
        for h in 0..ledger.num_machines() {
            total_cap.add_assign(ledger.capacity(h));
        }
        // (workers, ps) granted so far this slot, per active index
        let mut granted: Vec<(u64, u64)> = vec![(0, 0); active.len()];
        let mut blocked: Vec<bool> = vec![false; active.len()];
        let mut acc: Vec<Vec<(usize, u64, u64)>> = vec![Vec::new(); active.len()];

        loop {
            // job with the least dominant share that is not blocked/capped
            let mut pick: Option<(usize, f64)> = None;
            for (i, aj) in active.iter().enumerate() {
                if blocked[i] {
                    continue;
                }
                let (w, s) = granted[i];
                // bundle: γ workers + 1 PS (first grant); workers only after
                let add_w = (aj.job.gamma.round() as u64).max(1).min(aj.job.batch);
                if w + add_w > aj.job.batch {
                    blocked[i] = true;
                    continue;
                }
                let share = dominant_share(&aj.job, w, s, &total_cap);
                if pick.map_or(true, |(_, best)| share < best) {
                    pick = Some((i, share));
                }
            }
            let Some((i, _)) = pick else { break };
            let aj = &active[i];
            let (w, s) = granted[i];
            let add_w = (aj.job.gamma.round() as u64).max(1).min(aj.job.batch);
            let need_s =
                (((w + add_w) as f64 / aj.job.gamma).ceil() as u64).max(1);
            let add_s = need_s.saturating_sub(s);
            match place_round_robin(&aj.job, add_w, add_s, &mut cap, &mut self.cursor) {
                Some(p) => {
                    granted[i] = (w + add_w, s + add_s);
                    acc[i].extend(p);
                }
                None => blocked[i] = true,
            }
        }

        acc.into_iter()
            .enumerate()
            .filter(|(_, p)| !p.is_empty())
            .map(|(i, p)| {
                // merge duplicate machine entries
                let mut merged: std::collections::BTreeMap<usize, (u64, u64)> =
                    std::collections::BTreeMap::new();
                for (h, w, s) in p {
                    let e = merged.entry(h).or_insert((0, 0));
                    e.0 += w;
                    e.1 += s;
                }
                (i, merged.into_iter().map(|(h, (w, s))| (h, w, s)).collect())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::simulate;
    use crate::util::Rng;
    use crate::workload::synthetic::paper_cluster;
    use crate::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

    #[test]
    fn dominant_share_uses_max_fraction() {
        let job = crate::jobs::test_support::test_job(0);
        let cap = ResVec::new([10.0, 100.0, 100.0, 100.0]);
        // 2 workers: gpu 2/10 = 0.2 dominates cpu 4/100
        let s = dominant_share(&job, 2, 0, &cap);
        assert!((s - 0.2).abs() < 1e-12);
    }

    #[test]
    fn allocates_multiple_jobs_fairly() {
        let cluster = paper_cluster(10);
        let mut rng = Rng::new(3);
        let jobs = synthetic_jobs(&SynthConfig::paper(15, 20, MIX_DEFAULT), &mut rng);
        let res = simulate(&jobs, &cluster, 20, &mut Drf::new());
        assert!(res.admitted >= 2, "DRF should start several jobs");
    }

    #[test]
    fn grants_respect_worker_cap() {
        // covered by engine debug_assert on Eq. (4); run a small sim in
        // debug mode to exercise it
        let cluster = paper_cluster(4);
        let mut rng = Rng::new(4);
        let jobs = synthetic_jobs(&SynthConfig::paper(6, 10, MIX_DEFAULT), &mut rng);
        let _ = simulate(&jobs, &cluster, 10, &mut Drf::new());
    }
}
