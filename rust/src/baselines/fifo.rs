//! FIFO baseline (Hadoop/Spark style, §5 baseline (1)).
//!
//! Jobs are served strictly in arrival order with a *fixed* worker count
//! drawn once per job from [1, min(30, F_i)] (the paper: "the fixed number
//! of workers (parameter servers) is between 1 to 30") and the matching
//! `⌈w/γ⌉` parameter servers; placement is round-robin. A job that cannot
//! get its full fixed allocation this slot simply waits (no shrinking).

use std::collections::HashMap;

use crate::cluster::AllocLedger;
use crate::jobs::Job;
use crate::sim::{ActiveJob, ArrivalDecision, PlacementPolicy, Scheduler, SlotGrant};
use crate::util::Rng;

use super::placement::{place_round_robin, SlotCapacity};

pub struct Fifo {
    rng: Rng,
    fixed: HashMap<usize, u64>,
    cursor: usize,
}

impl Fifo {
    pub fn new(seed: u64) -> Fifo {
        Fifo { rng: Rng::new(seed), fixed: HashMap::new(), cursor: 0 }
    }

    fn fixed_workers(&mut self, job_id: usize, batch: u64) -> u64 {
        let rng = &mut self.rng;
        *self
            .fixed
            .entry(job_id)
            .or_insert_with(|| rng.range_u64(1, 30.min(batch).max(1)))
    }
}

impl Scheduler for Fifo {
    fn name(&self) -> String {
        "FIFO".into()
    }

    fn placement_policy(&self) -> PlacementPolicy {
        PlacementPolicy::RoundRobin
    }

    /// Slot-driven: every job joins the active queue at arrival.
    fn on_arrival(&mut self, _job: &Job, _ledger: &mut AllocLedger) -> ArrivalDecision {
        ArrivalDecision::Defer
    }

    fn on_slot(
        &mut self,
        t: usize,
        active: &[ActiveJob],
        ledger: &AllocLedger,
    ) -> Vec<SlotGrant> {
        let mut cap = SlotCapacity::snapshot(ledger, t);
        // strict arrival order
        let mut order: Vec<usize> = (0..active.len()).collect();
        order.sort_by_key(|&i| (active[i].job.arrival, active[i].job.id));
        let mut out = Vec::new();
        for i in order {
            let job = &active[i].job;
            let w = self.fixed_workers(job.id, job.batch);
            let s = ((w as f64 / job.gamma).ceil() as u64).max(1);
            if let Some(p) = place_round_robin(job, w, s, &mut cap, &mut self.cursor) {
                out.push((i, p));
            }
            // FIFO blocks the queue head-of-line style only for capacity it
            // consumed; later jobs may still fit (work-conserving variant).
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::sim::simulate;
    use crate::workload::synthetic::{paper_cluster, paper_machine_capacity};
    use crate::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

    #[test]
    fn fixed_count_is_stable() {
        let mut f = Fifo::new(0);
        let a = f.fixed_workers(3, 100);
        let b = f.fixed_workers(3, 100);
        assert_eq!(a, b);
        assert!((1..=30).contains(&a));
    }

    #[test]
    fn respects_batch_cap() {
        let mut f = Fifo::new(1);
        for id in 0..50 {
            let w = f.fixed_workers(id, 3);
            assert!(w <= 3 && w >= 1);
        }
    }

    #[test]
    fn runs_and_completes_some_jobs() {
        let cluster = paper_cluster(20);
        let mut rng = Rng::new(2);
        let jobs = synthetic_jobs(&SynthConfig::paper(20, 20, MIX_DEFAULT), &mut rng);
        let res = simulate(&jobs, &cluster, 20, &mut Fifo::new(0));
        assert!(res.admitted > 0, "FIFO should start some jobs");
        // capacity safety is asserted inside the engine (debug)
        let _ = Cluster::homogeneous(1, paper_machine_capacity());
    }
}
