//! Round-robin placement helper shared by the FIFO/DRF/Dorm baselines
//! (the paper: "workers and parameter servers are placed in a round-robin
//! fashion on available machines in Baselines (1) and (2)").

use crate::cluster::{AllocLedger, ResVec};
use crate::jobs::Job;

/// Tracks what has been handed out *within the current slot* on top of the
/// committed ledger (slot schedulers place several jobs per slot).
pub struct SlotCapacity {
    residual: Vec<ResVec>,
}

impl SlotCapacity {
    pub fn snapshot(ledger: &AllocLedger, t: usize) -> SlotCapacity {
        SlotCapacity {
            residual: (0..ledger.num_machines()).map(|h| ledger.residual(t, h)).collect(),
        }
    }

    pub fn num_machines(&self) -> usize {
        self.residual.len()
    }

    pub fn residual(&self, h: usize) -> &ResVec {
        &self.residual[h]
    }

    pub fn try_take(&mut self, h: usize, demand: &ResVec) -> bool {
        if demand.fits_within(&self.residual[h], 1e-9) {
            self.residual[h].sub_assign(demand);
            true
        } else {
            false
        }
    }
}

/// Place `w` workers and `s` PSs for `job` round-robin starting from
/// `*cursor`, taking capacity from `cap`. Returns the placements or `None`
/// (capacity untouched on failure is NOT guaranteed — callers that need
/// atomicity should check [`can_place`] first; the slot schedulers place
/// greedily and accept partial slots being rolled into later slots).
pub fn place_round_robin(
    job: &Job,
    w: u64,
    s: u64,
    cap: &mut SlotCapacity,
    cursor: &mut usize,
) -> Option<Vec<(usize, u64, u64)>> {
    let n = cap.num_machines();
    if n == 0 {
        return None;
    }
    let mut per_machine: Vec<(u64, u64)> = vec![(0, 0); n];
    // interleave worker/PS placement one unit at a time, round-robin
    let mut left_w = w;
    let mut left_s = s;
    let mut failures = 0usize;
    while left_w + left_s > 0 {
        let h = *cursor % n;
        *cursor += 1;
        let mut placed = false;
        if left_w >= left_s && left_w > 0 {
            if cap.try_take(h, &job.worker_demand) {
                per_machine[h].0 += 1;
                left_w -= 1;
                placed = true;
            } else if left_s > 0 && cap.try_take(h, &job.ps_demand) {
                per_machine[h].1 += 1;
                left_s -= 1;
                placed = true;
            }
        } else if left_s > 0 {
            if cap.try_take(h, &job.ps_demand) {
                per_machine[h].1 += 1;
                left_s -= 1;
                placed = true;
            } else if left_w > 0 && cap.try_take(h, &job.worker_demand) {
                per_machine[h].0 += 1;
                left_w -= 1;
                placed = true;
            }
        }
        if placed {
            failures = 0;
        } else {
            failures += 1;
            if failures >= n {
                return None; // nothing fits anywhere
            }
        }
    }
    let placements: Vec<(usize, u64, u64)> = per_machine
        .into_iter()
        .enumerate()
        .filter(|&(_, (pw, ps))| pw > 0 || ps > 0)
        .map(|(h, (pw, ps))| (h, pw, ps))
        .collect();
    Some(placements)
}

/// Whole-slot feasibility probe (non-destructive).
pub fn can_place(job: &Job, w: u64, s: u64, cap: &SlotCapacity) -> bool {
    // cheap conservative test: total demand vs total residual, and at
    // least one machine fits a single worker and a single PS.
    let total_demand = job.demand(w, s);
    let mut total = ResVec::zero();
    let mut one_w = false;
    let mut one_s = false;
    for h in 0..cap.num_machines() {
        total.add_assign(cap.residual(h));
        if job.worker_demand.fits_within(cap.residual(h), 1e-9) {
            one_w = true;
        }
        if job.ps_demand.fits_within(cap.residual(h), 1e-9) {
            one_s = true;
        }
    }
    total_demand.fits_within(&total, 1e-9) && (w == 0 || one_w) && (s == 0 || one_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::jobs::test_support::test_job;

    fn cap(n: usize, per: f64, horizon: usize) -> (AllocLedger, Cluster) {
        let c = Cluster::homogeneous(n, ResVec::new([per; 4]));
        (AllocLedger::new(&c, horizon), c)
    }

    #[test]
    fn spreads_over_machines() {
        let (ledger, _) = cap(4, 100.0, 4);
        let job = test_job(0);
        let mut slot = SlotCapacity::snapshot(&ledger, 0);
        let mut cursor = 0;
        let placements =
            place_round_robin(&job, 4, 2, &mut slot, &mut cursor).expect("fits");
        let machines: Vec<usize> = placements.iter().map(|&(h, _, _)| h).collect();
        assert!(machines.len() >= 2, "round robin should spread: {placements:?}");
        let w: u64 = placements.iter().map(|&(_, w, _)| w).sum();
        let s: u64 = placements.iter().map(|&(_, _, s)| s).sum();
        assert_eq!((w, s), (4, 2));
    }

    #[test]
    fn fails_when_full() {
        let (ledger, _) = cap(1, 5.0, 2);
        let job = test_job(0); // worker cpu=2 => at most 2 workers fit
        let mut slot = SlotCapacity::snapshot(&ledger, 0);
        let mut cursor = 0;
        assert!(place_round_robin(&job, 10, 5, &mut slot, &mut cursor).is_none());
    }

    #[test]
    fn take_respects_capacity() {
        let (ledger, _) = cap(1, 4.0, 1);
        let job = test_job(0);
        let mut slot = SlotCapacity::snapshot(&ledger, 0);
        assert!(slot.try_take(0, &job.worker_demand)); // uses cpu 2, mem 4 => mem now 0
        assert!(!slot.try_take(0, &job.worker_demand));
    }
}
