//! Offline optimum upper bound for Fig. 10 (the competitive-ratio study).
//!
//! The paper computes the offline optimum of Problem DMLRS by exhaustive
//! search on tiny instances (I ≤ 10, T = 10). We solve the equivalent
//! R-DMLRS formulation: enumerate a candidate-schedule set Π_i per job
//! (plans for every completion target t̃, under zero prices — i.e.
//! resource-minimal plans — plus the schedule PD-ORS itself chose, so the
//! bound provably dominates PD-ORS), then maximize Σ x_π u_π subject to
//! per-(t,h,r) capacity with branch-and-bound. This is an upper bound on
//! any schedule drawn from the candidate universe and ≥ PD-ORS by
//! construction.

use crate::cluster::{AllocLedger, Cluster, NUM_RESOURCES};
use crate::ilp::{solve_ilp_budgeted, IlpOutcome};
use crate::jobs::{Job, Schedule};
use crate::lp::{Cmp, LpProblem};
use crate::sched::dp::{plan_job_with, DpConfig, Masks};
use crate::sched::pricing::PricingParams;
use crate::sched::solver::PlannerScratch;
use crate::util::Rng;

/// One candidate schedule with its utility.
#[derive(Debug, Clone)]
struct Candidate {
    job_idx: usize,
    utility: f64,
    schedule: Schedule,
}

/// Generate per-job candidates: for each completion target `t̃`, the
/// resource-cheapest feasible schedule finishing by `t̃` on an empty
/// cluster (uniform unit prices make the DP minimize resource-time).
fn candidates_for(
    job: &Job,
    cluster: &Cluster,
    horizon: usize,
    rng: &mut Rng,
    scratch: &mut PlannerScratch,
) -> Vec<(f64, Schedule)> {
    let mut out: Vec<(f64, Schedule)> = Vec::new();
    // Uniform pricing: reuse the DP against truncated horizons, so each
    // truncation yields the best schedule completing within it.
    for t_end in (job.arrival + 1)..=horizon {
        let ledger = AllocLedger::new(cluster, t_end);
        let jobs = [job.clone()];
        let pricing = PricingParams::from_jobs(&jobs, cluster, t_end);
        let masks = Masks::all(cluster.len());
        // candidates only need coarse cost resolution — the ILP decides
        // between them on utility, not on price-cost
        let mut cfg = DpConfig::default();
        cfg.units = 24;
        cfg.theta.attempts = 20;
        if let Some(plan) = plan_job_with(job, &ledger, &pricing, &masks, &cfg, rng, scratch) {
            let u = job.utility_at(plan.completion);
            if u > 0.0 {
                out.push((u, plan.schedule));
            }
        }
    }
    // dedup identical completion times, keep best utility per completion
    out.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    out.truncate(6); // a handful per job keeps the ILP small
    out
}

/// Compute the offline optimum total utility over the candidate universe.
/// `pdors_choices` (job idx → schedule + utility) are injected as extra
/// candidates so the returned bound always dominates PD-ORS's utility.
pub fn offline_optimum(
    jobs: &[Job],
    cluster: &Cluster,
    horizon: usize,
    pdors_choices: &[(usize, f64, Schedule)],
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    // one planner scratch across every job and truncation (the memo still
    // resets per plan; only the buffers persist)
    let mut scratch = PlannerScratch::new();
    let mut cands: Vec<Candidate> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        for (u, s) in candidates_for(job, cluster, horizon, &mut rng, &mut scratch) {
            cands.push(Candidate { job_idx: i, utility: u, schedule: s });
        }
    }
    for (i, u, s) in pdors_choices {
        cands.push(Candidate { job_idx: *i, utility: *u, schedule: s.clone() });
    }
    if cands.is_empty() {
        return 0.0;
    }

    // ILP: maximize Σ u_c x_c  ⇒ minimize −Σ u_c x_c
    let n = cands.len();
    let mut lp = LpProblem::new(n);
    lp.set_objective(cands.iter().map(|c| -c.utility).collect());
    // one schedule per job
    for i in 0..jobs.len() {
        let terms: Vec<(usize, f64)> = cands
            .iter()
            .enumerate()
            .filter(|(_, c)| c.job_idx == i)
            .map(|(k, _)| (k, 1.0))
            .collect();
        if !terms.is_empty() {
            lp.add_row_sparse(&terms, Cmp::Le, 1.0);
        }
    }
    // capacity rows per (t, h, r) that any candidate touches
    let mut usage: std::collections::HashMap<(usize, usize, usize), Vec<(usize, f64)>> =
        std::collections::HashMap::new();
    for (k, c) in cands.iter().enumerate() {
        let job = &jobs[c.job_idx];
        for slot in &c.schedule.slots {
            for &(h, w, s) in &slot.placements {
                let d = job.demand(w, s);
                for r in 0..NUM_RESOURCES {
                    if d.0[r] > 0.0 {
                        usage.entry((slot.t, h, r)).or_default().push((k, d.0[r]));
                    }
                }
            }
        }
    }
    for ((_t, h, r), terms) in &usage {
        let cap = cluster.machines[*h].capacity.0[*r];
        lp.add_row_sparse(terms, Cmp::Le, cap);
    }
    // x_c ≤ 1
    for k in 0..n {
        lp.add_row_sparse(&[(k, 1.0)], Cmp::Le, 1.0);
    }

    // 60 s is ample for the Fig. 10/11 instance sizes; NodeLimit returns
    // the best incumbent (still a valid schedule set, so the reported
    // ratio under-states rather than inflates OPT).
    match solve_ilp_budgeted(&lp, &vec![true; n], 200_000, 60.0) {
        IlpOutcome::Optimal(s) => -s.objective,
        IlpOutcome::NodeLimit(Some(s)) => -s.objective,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{PdOrs, PdOrsConfig};
    use crate::workload::synthetic::paper_cluster;
    use crate::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

    #[test]
    fn offline_dominates_pdors() {
        let t = 10usize;
        let cluster = paper_cluster(4);
        let mut rng = Rng::new(11);
        let jobs = synthetic_jobs(&SynthConfig::paper(6, t, MIX_DEFAULT), &mut rng);
        let mut pdors = PdOrs::new(PdOrsConfig::default(), &jobs, &cluster, t);
        let mut ledger = AllocLedger::new(&cluster, t);
        let mut choices: Vec<(usize, f64, Schedule)> = Vec::new();
        let mut pdors_utility = 0.0;
        for (i, job) in jobs.iter().enumerate() {
            if let Some(s) = pdors.on_arrival(job, &mut ledger) {
                let u = job.utility_at(s.completion_time().unwrap());
                pdors_utility += u;
                choices.push((i, u, s));
            }
        }
        let opt = offline_optimum(&jobs, &cluster, t, &choices, 0);
        assert!(
            opt + 1e-6 >= pdors_utility,
            "OPT {opt} < PD-ORS {pdors_utility}"
        );
    }
}
