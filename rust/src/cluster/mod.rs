//! Cluster model: machines with multi-type resource capacities (paper §3.3).

pub mod resource;
pub mod state;

pub use resource::{ResVec, Resource, NUM_RESOURCES};
pub use state::AllocLedger;

/// A physical machine `h ∈ H` with capacity `C_h^r` per resource type.
#[derive(Debug, Clone, PartialEq)]
pub struct Machine {
    pub id: usize,
    pub capacity: ResVec,
}

/// The set of physical machines `H`.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub machines: Vec<Machine>,
}

impl Cluster {
    pub fn new(machines: Vec<Machine>) -> Cluster {
        Cluster { machines }
    }

    /// Homogeneous cluster of `n` machines with the given capacity.
    pub fn homogeneous(n: usize, capacity: ResVec) -> Cluster {
        Cluster {
            machines: (0..n).map(|id| Machine { id, capacity }).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.machines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.machines.is_empty()
    }

    /// Σ_h C_h^r over all machines (used by the μ bound of Eq. (14)).
    pub fn total_capacity(&self) -> ResVec {
        let mut total = ResVec::zero();
        for m in &self.machines {
            total.add_assign(&m.capacity);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_cluster() {
        let cap = ResVec::new([4.0, 10.0, 32.0, 10.0]);
        let c = Cluster::homogeneous(3, cap);
        assert_eq!(c.len(), 3);
        assert_eq!(c.machines[2].id, 2);
        assert_eq!(c.total_capacity().get(Resource::Cpu), 30.0);
    }
}
