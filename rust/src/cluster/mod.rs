//! Cluster model: machines with multi-type resource capacities (paper §3.3).

pub mod resource;
pub mod snapshot;
pub mod state;

pub use resource::{ResVec, Resource, NUM_RESOURCES};
pub use snapshot::{MachineGroup, PriceView, SignatureInterner, SlotSnapshot};
pub use state::AllocLedger;

/// A physical machine `h ∈ H` with capacity `C_h^r` per resource type.
#[derive(Debug, Clone, PartialEq)]
pub struct Machine {
    pub id: usize,
    pub capacity: ResVec,
}

/// One machine class of a heterogeneous cluster: `count` machines sharing
/// one capacity vector. The paper's evaluation uses a homogeneous EC2
/// C5n-class fleet; real clusters mix generations, which is exactly the
/// scenario axis [`Cluster::heterogeneous`] opens.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineClass {
    pub count: usize,
    pub capacity: ResVec,
}

impl MachineClass {
    pub fn new(count: usize, capacity: ResVec) -> MachineClass {
        MachineClass { count, capacity }
    }
}

/// The set of physical machines `H`.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub machines: Vec<Machine>,
}

impl Cluster {
    pub fn new(machines: Vec<Machine>) -> Cluster {
        Cluster { machines }
    }

    /// Homogeneous cluster of `n` machines with the given capacity.
    pub fn homogeneous(n: usize, capacity: ResVec) -> Cluster {
        Cluster {
            machines: (0..n).map(|id| Machine { id, capacity }).collect(),
        }
    }

    /// Heterogeneous cluster built from machine classes; machine ids are
    /// assigned sequentially in class order (all schedulers address
    /// machines through the per-machine capacities in the
    /// [`AllocLedger`], so mixed capacities need no policy changes).
    pub fn heterogeneous(classes: &[MachineClass]) -> Cluster {
        let mut machines = Vec::new();
        for class in classes {
            for _ in 0..class.count {
                machines.push(Machine { id: machines.len(), capacity: class.capacity });
            }
        }
        Cluster { machines }
    }

    pub fn len(&self) -> usize {
        self.machines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.machines.is_empty()
    }

    /// Σ_h C_h^r over all machines (used by the μ bound of Eq. (14)).
    pub fn total_capacity(&self) -> ResVec {
        let mut total = ResVec::zero();
        for m in &self.machines {
            total.add_assign(&m.capacity);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_cluster() {
        let cap = ResVec::new([4.0, 10.0, 32.0, 10.0]);
        let c = Cluster::homogeneous(3, cap);
        assert_eq!(c.len(), 3);
        assert_eq!(c.machines[2].id, 2);
        assert_eq!(c.total_capacity().get(Resource::Cpu), 30.0);
    }

    #[test]
    fn heterogeneous_cluster_ids_and_capacity() {
        let big = ResVec::new([8.0, 20.0, 64.0, 20.0]);
        let small = ResVec::new([2.0, 5.0, 16.0, 5.0]);
        let c = Cluster::heterogeneous(&[
            MachineClass::new(2, big),
            MachineClass::new(3, small),
        ]);
        assert_eq!(c.len(), 5);
        for (i, m) in c.machines.iter().enumerate() {
            assert_eq!(m.id, i);
        }
        assert_eq!(c.machines[1].capacity, big);
        assert_eq!(c.machines[2].capacity, small);
        assert_eq!(c.total_capacity().get(Resource::Gpu), 2.0 * 8.0 + 3.0 * 2.0);
    }

    #[test]
    fn heterogeneous_with_one_class_matches_homogeneous() {
        let cap = ResVec::new([4.0, 10.0, 32.0, 10.0]);
        let a = Cluster::homogeneous(4, cap);
        let b = Cluster::heterogeneous(&[MachineClass::new(4, cap)]);
        assert_eq!(a.machines, b.machines);
    }
}
