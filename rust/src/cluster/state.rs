//! Per-(t, h, r) allocation ledger `ρ_h^r[t]` — the committed resource
//! amounts the primal-dual scheduler prices against (Algorithm 1 step 3).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};

use super::resource::{ResVec, NUM_RESOURCES};
use super::Cluster;
use crate::jobs::{Job, Schedule};

/// Process-wide ledger-instance counter (see [`AllocLedger::id`]).
static NEXT_LEDGER_ID: AtomicU64 = AtomicU64::new(1);

/// Bound on the retained change log. Between two consecutive planning
/// episodes the event count is a handful of commits/releases (schedule
/// slots × placements each), so this is generous headroom; overflow is
/// handled by readers falling back to full snapshot rebuilds.
const CHANGE_LOG_CAP: usize = 1 << 16;

/// Tracks allocated resources for every future time slot.
///
/// Every mutation (commit / release / availability change) bumps a
/// per-slot version and appends a `(slot, machine)` event to a bounded
/// change log — the incremental-snapshot subsystem
/// (`sched::solver::snapcache`) reads both to delta-update only the
/// entries a committed schedule touched. Versions are authoritative for
/// staleness; the log is only a delta *hint* (truncation ⇒ rebuild).
#[derive(Debug)]
pub struct AllocLedger {
    /// `alloc[t][h]` = ρ_h[t] (vector over r).
    alloc: Vec<Vec<ResVec>>,
    capacity: Vec<ResVec>,
    horizon: usize,
    /// `avail[t][h]` — machine availability under churn. `None` (the
    /// no-churn default) means "everything available, no bookkeeping":
    /// the lazily-allocated mask is what keeps `churn = none`
    /// byte-identical to the pre-churn ledger.
    avail: Option<Vec<Vec<bool>>>,
    /// Unique instance id (never reused within a process; clones get a
    /// fresh one) — lets snapshot caches detect "different ledger".
    id: u64,
    /// Monotone per-slot mutation counters.
    slot_version: Vec<u64>,
    /// Sequence number of `log[0]`; `log_start + log.len()` is the next
    /// sequence number to be assigned.
    log_start: u64,
    /// Bounded `(t, h)` mutation events in sequence order.
    log: VecDeque<(u32, u32)>,
}

impl Clone for AllocLedger {
    /// Clones carry the allocation state but get a **fresh id** (and an
    /// empty change log): a clone diverges from its source, and version
    /// numbers alone cannot distinguish the two histories.
    fn clone(&self) -> AllocLedger {
        AllocLedger {
            alloc: self.alloc.clone(),
            capacity: self.capacity.clone(),
            horizon: self.horizon,
            avail: self.avail.clone(),
            id: NEXT_LEDGER_ID.fetch_add(1, Ordering::Relaxed),
            slot_version: self.slot_version.clone(),
            log_start: 0,
            log: VecDeque::new(),
        }
    }
}

impl AllocLedger {
    pub fn new(cluster: &Cluster, horizon: usize) -> AllocLedger {
        AllocLedger {
            alloc: vec![vec![ResVec::zero(); cluster.len()]; horizon],
            capacity: cluster.machines.iter().map(|m| m.capacity).collect(),
            horizon,
            avail: None,
            id: NEXT_LEDGER_ID.fetch_add(1, Ordering::Relaxed),
            slot_version: vec![0; horizon],
            log_start: 0,
            log: VecDeque::new(),
        }
    }

    /// Unique instance id of this ledger (process-wide, never reused).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Monotone mutation counter of slot `t`.
    pub fn slot_version(&self, t: usize) -> u64 {
        self.slot_version[t]
    }

    /// The next change-log sequence number (== total events ever logged).
    pub fn change_seq(&self) -> u64 {
        self.log_start + self.log.len() as u64
    }

    /// All `(t, h)` mutation events with sequence number `>= since`, in
    /// order — or `None` if the bounded log has dropped events past
    /// `since` (the reader must fall back to full rebuilds).
    pub fn changes_since(
        &self,
        since: u64,
    ) -> Option<impl Iterator<Item = (usize, usize)> + '_> {
        if since < self.log_start {
            return None;
        }
        let skip = (since - self.log_start) as usize;
        Some(self.log.iter().skip(skip).map(|&(t, h)| (t as usize, h as usize)))
    }

    /// Record a mutation of `(t, h)`: bump the slot version and append the
    /// delta hint (dropping the oldest hint when the log is full).
    #[inline]
    fn touch(&mut self, t: usize, h: usize) {
        self.slot_version[t] += 1;
        if self.log.len() == CHANGE_LOG_CAP {
            self.log.pop_front();
            self.log_start += 1;
        }
        self.log.push_back((t as u32, h as u32));
    }

    pub fn horizon(&self) -> usize {
        self.horizon
    }

    pub fn num_machines(&self) -> usize {
        self.capacity.len()
    }

    pub fn used(&self, t: usize, h: usize) -> &ResVec {
        &self.alloc[t][h]
    }

    pub fn capacity(&self, h: usize) -> &ResVec {
        &self.capacity[h]
    }

    /// Is machine `h` available at slot `t`? Always true until churn
    /// marks something unavailable (the mask is allocated lazily).
    pub fn available(&self, t: usize, h: usize) -> bool {
        match &self.avail {
            None => true,
            Some(a) => a[t][h],
        }
    }

    /// True iff any (t, h) is currently masked unavailable — i.e. churn
    /// has actually touched this ledger.
    pub fn has_unavailable(&self) -> bool {
        match &self.avail {
            None => false,
            Some(a) => a.iter().any(|row| row.iter().any(|&up| !up)),
        }
    }

    /// Mark machine `h` (un)available for every slot in `[from_t, horizon)`
    /// — the churn subsystem's Down/Drain/Rejoin primitive. Allocates the
    /// availability mask on first use; the no-churn path never calls this.
    pub fn set_available_from(&mut self, h: usize, from_t: usize, up: bool) {
        let machines = self.capacity.len();
        let horizon = self.horizon;
        let avail = self
            .avail
            .get_or_insert_with(|| vec![vec![true; machines]; horizon]);
        for row in avail.iter_mut().take(horizon).skip(from_t) {
            row[h] = up;
        }
        for t in from_t..horizon {
            self.touch(t, h);
        }
    }

    /// Remaining capacity `Ĉ_h^r[t] = C_h^r − ρ_h^r[t]` (clamped at 0).
    /// An unavailable (churned-out) machine has zero residual, so both
    /// the θ-solver's snapshots and the slot-driven baselines price it
    /// out without any policy-side changes.
    pub fn residual(&self, t: usize, h: usize) -> ResVec {
        if !self.available(t, h) {
            return ResVec::zero();
        }
        let mut out = self.capacity[h];
        out.sub_assign(&self.alloc[t][h]);
        for i in 0..NUM_RESOURCES {
            if out.0[i] < 0.0 {
                out.0[i] = 0.0;
            }
        }
        out
    }

    /// Commit a job's schedule: ρ += α·w + β·s at every (t, h) it touches.
    pub fn commit(&mut self, job: &Job, sched: &Schedule) {
        for slot in &sched.slots {
            for &(h, w, s) in &slot.placements {
                let add = job
                    .worker_demand
                    .scaled(w as f64)
                    .axpy(s as f64, &job.ps_demand);
                self.alloc[slot.t][h].add_assign(&add);
                self.touch(slot.t, h);
            }
        }
    }

    /// Reverse of [`commit`] (used by look-ahead searches).
    pub fn release(&mut self, job: &Job, sched: &Schedule) {
        for slot in &sched.slots {
            for &(h, w, s) in &slot.placements {
                let sub = job
                    .worker_demand
                    .scaled(w as f64)
                    .axpy(s as f64, &job.ps_demand);
                self.alloc[slot.t][h].sub_assign(&sub);
                self.touch(slot.t, h);
            }
        }
    }

    /// Check that a schedule fits in the *current* residual capacity.
    pub fn fits(&self, job: &Job, sched: &Schedule, eps: f64) -> bool {
        for slot in &sched.slots {
            for &(h, w, s) in &slot.placements {
                let need = job
                    .worker_demand
                    .scaled(w as f64)
                    .axpy(s as f64, &job.ps_demand);
                if !need.fits_within(&self.residual(slot.t, h), eps) {
                    return false;
                }
            }
        }
        true
    }

    /// True iff no (t, h, r) exceeds capacity — the invariant the property
    /// tests assert after every admission.
    pub fn within_capacity(&self, eps: f64) -> bool {
        for t in 0..self.horizon {
            for h in 0..self.capacity.len() {
                if !self.alloc[t][h].fits_within(&self.capacity[h], eps) {
                    return false;
                }
            }
        }
        true
    }

    /// Total committed resource-time over every (t, h, r) — the
    /// conservation quantity the replan release/re-commit primitives and
    /// the service's `ledger_sum` report track.
    pub fn total_used(&self) -> f64 {
        let mut sum = 0.0;
        for t in 0..self.horizon {
            for h in 0..self.capacity.len() {
                sum += self.alloc[t][h].sum();
            }
        }
        sum
    }

    /// Total committed resource-time restricted to machines `[start, end)`
    /// — the per-cell share of [`AllocLedger::total_used`]. The sharded
    /// service's conservation invariant is that the cell ledgers' totals
    /// sum to the whole-cluster accounting: for any partition of
    /// `0..num_machines` into ranges, the `total_used_in` values add up to
    /// `total_used()` exactly (same additions in the same f64 order).
    pub fn total_used_in(&self, start: usize, end: usize) -> f64 {
        let mut sum = 0.0;
        for t in 0..self.horizon {
            for h in start..end.min(self.capacity.len()) {
                sum += self.alloc[t][h].sum();
            }
        }
        sum
    }

    /// A standalone sub-ledger over machines `[start, end)`: allocation
    /// columns, capacities, and the availability mask are copied for the
    /// range; the clone gets a fresh id and an empty change log (it is a
    /// different ledger as far as snapshot caches are concerned). Used by
    /// the sharding tests to compare a cell's ledger against the matching
    /// column range of the whole-cluster ledger.
    pub fn slice_machines(&self, start: usize, end: usize) -> AllocLedger {
        assert!(start <= end && end <= self.capacity.len(), "slice out of range");
        AllocLedger {
            alloc: self
                .alloc
                .iter()
                .map(|row| row[start..end].to_vec())
                .collect(),
            capacity: self.capacity[start..end].to_vec(),
            horizon: self.horizon,
            avail: self
                .avail
                .as_ref()
                .map(|a| a.iter().map(|row| row[start..end].to_vec()).collect()),
            id: NEXT_LEDGER_ID.fetch_add(1, Ordering::Relaxed),
            slot_version: vec![0; self.horizon],
            log_start: 0,
            log: VecDeque::new(),
        }
    }

    /// Overall utilization of resource `r` in `[0, horizon)`: used / capacity.
    pub fn utilization(&self, r: usize) -> f64 {
        let mut used = 0.0;
        let mut cap = 0.0;
        for t in 0..self.horizon {
            for h in 0..self.capacity.len() {
                used += self.alloc[t][h].0[r];
                cap += self.capacity[h].0[r];
            }
        }
        if cap == 0.0 {
            0.0
        } else {
            used / cap
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Resource;
    use crate::jobs::test_support::test_job;
    use crate::jobs::{Schedule, SlotPlacement};

    fn ledger() -> AllocLedger {
        let c = Cluster::homogeneous(2, ResVec::new([8.0, 16.0, 64.0, 20.0]));
        AllocLedger::new(&c, 4)
    }

    #[test]
    fn commit_release_round_trip() {
        let mut l = ledger();
        let job = test_job(0);
        let sched = Schedule {
            job_id: 0,
            slots: vec![SlotPlacement { t: 1, placements: vec![(0, 2, 1)] }],
        };
        assert!(l.fits(&job, &sched, 1e-9));
        l.commit(&job, &sched);
        let used = *l.used(1, 0);
        let expect = job.worker_demand.scaled(2.0).axpy(1.0, &job.ps_demand);
        assert_eq!(used, expect);
        l.release(&job, &sched);
        assert_eq!(l.used(1, 0).get(Resource::Cpu), 0.0);
        assert!(l.within_capacity(0.0));
    }

    #[test]
    fn availability_masks_zero_residual() {
        let mut l = ledger();
        assert!(!l.has_unavailable());
        assert!(l.available(2, 1));
        let before = l.residual(2, 1);
        l.set_available_from(1, 2, false);
        assert!(l.has_unavailable());
        assert!(l.available(1, 1), "slots before the event stay live");
        assert!(!l.available(2, 1));
        assert!(!l.available(3, 1));
        assert_eq!(l.residual(2, 1), ResVec::zero());
        assert_eq!(l.residual(1, 1), before, "earlier slots unchanged");
        // a placement on the dead machine no longer fits
        let job = test_job(0);
        let sched = Schedule {
            job_id: 0,
            slots: vec![SlotPlacement { t: 3, placements: vec![(1, 1, 0)] }],
        };
        assert!(!l.fits(&job, &sched, 1e-9));
        // rejoin from slot 3 restores capacity there only
        l.set_available_from(1, 3, true);
        assert!(!l.available(2, 1));
        assert!(l.available(3, 1));
        assert!(l.fits(&job, &sched, 1e-9));
    }

    #[test]
    fn versions_and_change_log_track_mutations() {
        let mut l = ledger();
        let other = ledger();
        assert_ne!(l.id(), other.id(), "instances get distinct ids");
        assert_eq!(l.change_seq(), 0);
        let v1_before = l.slot_version(1);

        let job = test_job(0);
        let sched = Schedule {
            job_id: 0,
            slots: vec![SlotPlacement { t: 1, placements: vec![(0, 2, 1)] }],
        };
        l.commit(&job, &sched);
        assert_eq!(l.slot_version(1), v1_before + 1);
        assert_eq!(l.slot_version(0), 0, "untouched slots keep their version");
        let events: Vec<_> = l.changes_since(0).unwrap().collect();
        assert_eq!(events, vec![(1, 0)]);

        l.release(&job, &sched);
        assert_eq!(l.slot_version(1), v1_before + 2, "release also bumps");
        // churn events touch one machine across a slot suffix
        l.set_available_from(1, 2, false);
        assert_eq!(l.slot_version(2), 1);
        assert_eq!(l.slot_version(3), 1);
        let tail: Vec<_> = l.changes_since(2).unwrap().collect();
        assert_eq!(tail, vec![(2, 1), (3, 1)]);
        assert_eq!(l.change_seq(), 4);
        // readers behind the (here: un-truncated) log still resolve
        assert!(l.changes_since(0).is_some());

        // a clone is a *different* ledger as far as caches are concerned
        let c = l.clone();
        assert_ne!(c.id(), l.id());
        assert_eq!(c.change_seq(), 0, "clone starts a fresh log");
        assert_eq!(c.slot_version(2), l.slot_version(2));
    }

    #[test]
    fn machine_range_accounting_partitions_the_total() {
        let mut l = ledger();
        let job = test_job(0);
        for (t, h) in [(0, 0), (1, 1), (2, 0), (3, 1)] {
            let sched = Schedule {
                job_id: 0,
                slots: vec![SlotPlacement { t, placements: vec![(h, 1, 1)] }],
            };
            l.commit(&job, &sched);
        }
        let total = l.total_used();
        assert!(total > 0.0);
        assert_eq!(l.total_used_in(0, 1) + l.total_used_in(1, 2), total);
        assert_eq!(l.total_used_in(0, 2), total);
        // the sliced sub-ledger carries exactly the range's columns
        l.set_available_from(1, 2, false);
        let s = l.slice_machines(1, 2);
        assert_eq!(s.num_machines(), 1);
        assert_eq!(s.total_used(), l.total_used_in(1, 2));
        assert_eq!(s.used(1, 0), l.used(1, 1));
        assert!(!s.available(2, 0), "the availability mask is sliced too");
        assert!(s.available(1, 0));
        assert_ne!(s.id(), l.id());
    }

    #[test]
    fn residual_clamps() {
        let mut l = ledger();
        let job = test_job(0);
        let sched = Schedule {
            job_id: 0,
            slots: vec![SlotPlacement { t: 0, placements: vec![(0, 100, 0)] }],
        };
        l.commit(&job, &sched); // deliberately overcommit
        assert!(!l.within_capacity(0.0));
        let res = l.residual(0, 0);
        for i in 0..NUM_RESOURCES {
            assert!(res.0[i] >= 0.0);
        }
    }
}
