//! Resource vectors over the paper's four resource types
//! (GPU, vCPU, memory, storage) — the set `R` of §3.3.

/// Number of resource types `|R|` (the paper's evaluation uses 4).
pub const NUM_RESOURCES: usize = 4;

/// Resource type indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Resource {
    Gpu = 0,
    Cpu = 1,
    Mem = 2,
    Storage = 3,
}

impl Resource {
    pub const ALL: [Resource; NUM_RESOURCES] =
        [Resource::Gpu, Resource::Cpu, Resource::Mem, Resource::Storage];

    pub fn name(self) -> &'static str {
        match self {
            Resource::Gpu => "gpu",
            Resource::Cpu => "cpu",
            Resource::Mem => "mem",
            Resource::Storage => "storage",
        }
    }
}

/// A fixed-length vector of per-resource amounts.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResVec(pub [f64; NUM_RESOURCES]);

impl ResVec {
    pub fn new(v: [f64; NUM_RESOURCES]) -> ResVec {
        ResVec(v)
    }

    pub fn zero() -> ResVec {
        ResVec([0.0; NUM_RESOURCES])
    }

    pub fn get(&self, r: Resource) -> f64 {
        self.0[r as usize]
    }

    pub fn set(&mut self, r: Resource, v: f64) {
        self.0[r as usize] = v;
    }

    pub fn add_assign(&mut self, other: &ResVec) {
        for i in 0..NUM_RESOURCES {
            self.0[i] += other.0[i];
        }
    }

    pub fn sub_assign(&mut self, other: &ResVec) {
        for i in 0..NUM_RESOURCES {
            self.0[i] -= other.0[i];
        }
    }

    pub fn scaled(&self, k: f64) -> ResVec {
        let mut out = *self;
        for i in 0..NUM_RESOURCES {
            out.0[i] *= k;
        }
        out
    }

    /// Component-wise `self + k * other`.
    pub fn axpy(&self, k: f64, other: &ResVec) -> ResVec {
        let mut out = *self;
        for i in 0..NUM_RESOURCES {
            out.0[i] += k * other.0[i];
        }
        out
    }

    /// True iff `self[r] <= other[r] + eps` for all r.
    pub fn fits_within(&self, other: &ResVec, eps: f64) -> bool {
        (0..NUM_RESOURCES).all(|i| self.0[i] <= other.0[i] + eps)
    }

    pub fn sum(&self) -> f64 {
        self.0.iter().sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (Resource, f64)> + '_ {
        Resource::ALL.iter().map(move |&r| (r, self.get(r)))
    }
}

impl std::ops::Index<usize> for ResVec {
    type Output = f64;
    fn index(&self, i: usize) -> &f64 {
        &self.0[i]
    }
}

impl std::ops::IndexMut<usize> for ResVec {
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        &mut self.0[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let mut a = ResVec::new([1.0, 2.0, 3.0, 4.0]);
        let b = ResVec::new([0.5, 0.5, 0.5, 0.5]);
        a.add_assign(&b);
        assert_eq!(a.get(Resource::Gpu), 1.5);
        a.sub_assign(&b);
        assert_eq!(a.get(Resource::Storage), 4.0);
        assert_eq!(a.scaled(2.0).get(Resource::Cpu), 4.0);
        assert_eq!(a.axpy(2.0, &b).get(Resource::Mem), 4.0);
    }

    #[test]
    fn fits() {
        let small = ResVec::new([1.0, 1.0, 1.0, 1.0]);
        let big = ResVec::new([2.0, 2.0, 2.0, 2.0]);
        assert!(small.fits_within(&big, 0.0));
        assert!(!big.fits_within(&small, 0.0));
        assert!(big.fits_within(&big, 1e-9));
    }
}
