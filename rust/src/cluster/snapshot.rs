//! Immutable per-slot pricing snapshots — the first stage of the layered
//! solver pipeline (snapshot → memo → LP workspace → rounding).
//!
//! [`SlotSnapshot`] captures everything the θ-solver prices against in one
//! slot: per-machine prices, residual capacities, and the worker/PS
//! eligibility masks — plus the *machine groups* (machines with identical
//! `(price, residual, eligibility)` signatures collapsed into one LP
//! variable pair, DESIGN.md §Perf). The planner builds each slot's
//! snapshot **once per arrival**, so grouping is no longer re-derived
//! inside every θ-solve of the DP's forward pass.
//!
//! [`SignatureInterner`] maps a snapshot's full group structure to a dense
//! id. Interning is *exact* (the key is the complete structural data, not
//! a hash), so two slots share an id iff their θ-subproblems are
//! bit-identical for every workload — which is what makes the id safe as
//! a memoization key in `sched::solver::memo`.

use std::collections::HashMap;

use super::resource::{ResVec, NUM_RESOURCES};

/// Machines sharing one `(price, residual, eligibility)` signature.
/// `members` lists machine indices in ascending order (machines are
/// scanned in index order when grouping).
#[derive(Debug, Clone, PartialEq)]
pub struct MachineGroup {
    pub members: Vec<usize>,
    pub price: [f64; NUM_RESOURCES],
    pub residual: ResVec,
    pub allow_worker: bool,
    pub allow_ps: bool,
}

/// Per-machine structural key: price bits, residual bits, the two
/// eligibility flags.
type GroupKey = [u64; 2 * NUM_RESOURCES + 2];

fn group_key(
    price: &[f64; NUM_RESOURCES],
    resid: &ResVec,
    allow_worker: bool,
    allow_ps: bool,
) -> GroupKey {
    let mut key = [0u64; 2 * NUM_RESOURCES + 2];
    for r in 0..NUM_RESOURCES {
        key[r] = price[r].to_bits();
        key[NUM_RESOURCES + r] = resid.0[r].to_bits();
    }
    key[2 * NUM_RESOURCES] = allow_worker as u64;
    key[2 * NUM_RESOURCES + 1] = allow_ps as u64;
    key
}

/// The immutable per-slot view of the cluster the solver prices against
/// (`p_h^r[t]`, `Ĉ_h[t]`, eligibility, machine groups). See module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotSnapshot {
    /// `p_h^r[t]` per machine.
    pub prices: Vec<[f64; NUM_RESOURCES]>,
    /// Residual capacity `Ĉ_h[t]`.
    pub residual: Vec<ResVec>,
    /// Machines allowed to host workers (OASiS separates these sets;
    /// PD-ORS allows everything everywhere).
    pub allow_worker: Vec<bool>,
    /// Machines allowed to host parameter servers.
    pub allow_ps: Vec<bool>,
    /// Machine groups in first-seen (machine-index) order. With grouping
    /// disabled this is one group per eligible machine — the paper's
    /// literal per-machine formulation, kept as the grouping oracle.
    pub groups: Vec<MachineGroup>,
}

impl SlotSnapshot {
    /// Build a snapshot, deduplicating identical machines into groups
    /// when `group_machines` is set. Machines with neither eligibility
    /// flag are excluded from the groups entirely (they can host nothing).
    pub fn new(
        prices: Vec<[f64; NUM_RESOURCES]>,
        residual: Vec<ResVec>,
        allow_worker: Vec<bool>,
        allow_ps: Vec<bool>,
        group_machines: bool,
    ) -> SlotSnapshot {
        let n = residual.len();
        assert_eq!(prices.len(), n, "prices/residual length mismatch");
        assert_eq!(allow_worker.len(), n, "allow_worker length mismatch");
        assert_eq!(allow_ps.len(), n, "allow_ps length mismatch");
        let mut snap = SlotSnapshot {
            prices,
            residual,
            allow_worker,
            allow_ps,
            groups: Vec::new(),
        };
        snap.regroup(group_machines);
        snap
    }

    /// Overwrite machine `h`'s structural entry — the delta path's
    /// per-machine update. The caller must [`regroup`](Self::regroup)
    /// afterwards; until then `groups` is stale.
    pub fn set_machine(
        &mut self,
        h: usize,
        price: [f64; NUM_RESOURCES],
        residual: ResVec,
        allow_worker: bool,
        allow_ps: bool,
    ) {
        self.prices[h] = price;
        self.residual[h] = residual;
        self.allow_worker[h] = allow_worker;
        self.allow_ps[h] = allow_ps;
    }

    /// Rebuild `groups` from the per-machine vectors — the single grouping
    /// routine shared by [`new`](Self::new) and the incremental delta path
    /// (`sched::solver::snapcache`), so a delta-updated snapshot is
    /// structurally indistinguishable from a from-scratch build.
    pub fn regroup(&mut self, group_machines: bool) {
        let n = self.residual.len();
        self.groups.clear();
        let mut index: HashMap<GroupKey, usize> = HashMap::new();
        for h in 0..n {
            let aw = self.allow_worker[h];
            let ap = self.allow_ps[h];
            if !aw && !ap {
                continue;
            }
            if !group_machines {
                self.groups.push(MachineGroup {
                    members: vec![h],
                    price: self.prices[h],
                    residual: self.residual[h],
                    allow_worker: aw,
                    allow_ps: ap,
                });
                continue;
            }
            let key = group_key(&self.prices[h], &self.residual[h], aw, ap);
            match index.get(&key) {
                Some(&g) => self.groups[g].members.push(h),
                None => {
                    index.insert(key, self.groups.len());
                    self.groups.push(MachineGroup {
                        members: vec![h],
                        price: self.prices[h],
                        residual: self.residual[h],
                        allow_worker: aw,
                        allow_ps: ap,
                    });
                }
            }
        }
    }

    pub fn num_machines(&self) -> usize {
        self.residual.len()
    }

    /// Borrowed facade over the snapshot (what solver internals take when
    /// they do not need ownership).
    pub fn view(&self) -> PriceView<'_> {
        PriceView {
            prices: &self.prices,
            residual: &self.residual,
            allow_worker: &self.allow_worker,
            allow_ps: &self.allow_ps,
            groups: &self.groups,
        }
    }
}

/// Borrowed view of a [`SlotSnapshot`].
#[derive(Debug, Clone, Copy)]
pub struct PriceView<'a> {
    pub prices: &'a [[f64; NUM_RESOURCES]],
    pub residual: &'a [ResVec],
    pub allow_worker: &'a [bool],
    pub allow_ps: &'a [bool],
    pub groups: &'a [MachineGroup],
}

/// Exact structure → dense-id interner for snapshot signatures.
///
/// The key is the ordered list of group signatures *including member
/// counts* — everything the θ LP relaxation and the internal closed form
/// are built from. Two snapshots with equal ids therefore pose
/// bit-identical subproblems (group *membership* may differ between them;
/// per-slot disaggregation always uses the slot's own member lists).
#[derive(Debug, Default)]
pub struct SignatureInterner {
    ids: HashMap<Vec<u64>, u32>,
    /// Next id to hand out. Monotone except across [`clear`]: selective
    /// removal ([`remove_ids`]) never resets it, so an id freed by garbage
    /// collection is **never reused** — the property that lets memo
    /// entries keyed by old ids stay merely dead instead of wrong.
    ///
    /// [`clear`]: SignatureInterner::clear
    /// [`remove_ids`]: SignatureInterner::remove_ids
    next_id: u32,
}

impl SignatureInterner {
    pub fn new() -> SignatureInterner {
        SignatureInterner::default()
    }

    /// Drop all interned signatures (ids restart from 0) — the cold
    /// oracle's episode boundary (`--cold-solver`, and the historical
    /// per-arrival behavior). The incremental path never calls this; it
    /// retires ids selectively via [`remove_ids`](Self::remove_ids).
    pub fn clear(&mut self) {
        self.ids.clear();
        self.next_id = 0;
    }

    /// Forget the signatures behind the given ids (incremental-path GC:
    /// no cached slot references them anymore). Ids are *not* reused —
    /// see `next_id`.
    pub fn remove_ids(&mut self, dead: &std::collections::HashSet<u32>) {
        self.ids.retain(|_, id| !dead.contains(id));
    }

    /// Number of currently interned signatures.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Intern the snapshot's group structure, returning its dense id.
    pub fn intern(&mut self, snap: &SlotSnapshot) -> u32 {
        let mut key: Vec<u64> =
            Vec::with_capacity(snap.groups.len() * (2 * NUM_RESOURCES + 3));
        for g in &snap.groups {
            let gk = group_key(&g.price, &g.residual, g.allow_worker, g.allow_ps);
            key.extend_from_slice(&gk);
            key.push(g.members.len() as u64);
        }
        match self.ids.entry(key) {
            std::collections::hash_map::Entry::Occupied(e) => *e.get(),
            std::collections::hash_map::Entry::Vacant(e) => {
                let id = self.next_id;
                self.next_id += 1;
                *e.insert(id)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(n: usize, price: f64, cap: f64) -> SlotSnapshot {
        SlotSnapshot::new(
            vec![[price; NUM_RESOURCES]; n],
            vec![ResVec::new([cap; NUM_RESOURCES]); n],
            vec![true; n],
            vec![true; n],
            true,
        )
    }

    #[test]
    fn homogeneous_cluster_collapses_to_one_group() {
        let s = flat(16, 1.0, 60.0);
        assert_eq!(s.groups.len(), 1);
        assert_eq!(s.groups[0].members, (0..16).collect::<Vec<_>>());
        assert_eq!(s.num_machines(), 16);
    }

    #[test]
    fn grouping_disabled_keeps_one_group_per_machine() {
        let s = SlotSnapshot::new(
            vec![[1.0; NUM_RESOURCES]; 4],
            vec![ResVec::new([8.0; NUM_RESOURCES]); 4],
            vec![true; 4],
            vec![true; 4],
            false,
        );
        assert_eq!(s.groups.len(), 4);
        for (g, grp) in s.groups.iter().enumerate() {
            assert_eq!(grp.members, vec![g]);
        }
    }

    #[test]
    fn distinct_prices_split_groups_in_first_seen_order() {
        let mut prices = vec![[1.0; NUM_RESOURCES]; 5];
        prices[1] = [2.0; NUM_RESOURCES];
        prices[3] = [2.0; NUM_RESOURCES];
        let s = SlotSnapshot::new(
            prices,
            vec![ResVec::new([8.0; NUM_RESOURCES]); 5],
            vec![true; 5],
            vec![true; 5],
            true,
        );
        assert_eq!(s.groups.len(), 2);
        assert_eq!(s.groups[0].members, vec![0, 2, 4]);
        assert_eq!(s.groups[1].members, vec![1, 3]);
    }

    #[test]
    fn ineligible_machines_are_excluded() {
        let s = SlotSnapshot::new(
            vec![[1.0; NUM_RESOURCES]; 3],
            vec![ResVec::new([8.0; NUM_RESOURCES]); 3],
            vec![true, false, false],
            vec![true, false, true],
            true,
        );
        // machine 1 can host nothing; machine 2 differs in eligibility
        assert_eq!(s.groups.len(), 2);
        assert_eq!(s.groups[0].members, vec![0]);
        assert_eq!(s.groups[1].members, vec![2]);
    }

    #[test]
    fn interner_ids_are_structural() {
        let mut interner = SignatureInterner::new();
        let a = flat(8, 1.0, 60.0);
        let b = flat(8, 1.0, 60.0);
        let c = flat(8, 2.0, 60.0); // different price
        let d = flat(9, 1.0, 60.0); // different member count
        let ia = interner.intern(&a);
        let ib = interner.intern(&b);
        let ic = interner.intern(&c);
        let id = interner.intern(&d);
        assert_eq!(ia, ib);
        assert_ne!(ia, ic);
        assert_ne!(ia, id);
        assert_eq!(interner.len(), 3);
        interner.clear();
        assert!(interner.is_empty());
        assert_eq!(interner.intern(&c), 0, "ids restart after clear");
    }

    #[test]
    fn equal_structure_different_membership_shares_an_id() {
        // [0,1]×cheap + [2]×dear vs [0,2]×cheap + [1]×dear: same ordered
        // group structure, different member lists — the id must match
        // (the memo stores group-level data; members are per-slot).
        let cheap = [1.0; NUM_RESOURCES];
        let dear = [3.0; NUM_RESOURCES];
        let r = ResVec::new([8.0; NUM_RESOURCES]);
        let a = SlotSnapshot::new(
            vec![cheap, cheap, dear],
            vec![r; 3],
            vec![true; 3],
            vec![true; 3],
            true,
        );
        let b = SlotSnapshot::new(
            vec![cheap, dear, cheap],
            vec![r; 3],
            vec![true; 3],
            vec![true; 3],
            true,
        );
        let mut interner = SignatureInterner::new();
        assert_eq!(interner.intern(&a), interner.intern(&b));
        assert_ne!(a.groups[0].members, b.groups[0].members);
    }

    #[test]
    fn set_machine_plus_regroup_matches_from_scratch() {
        // mutate one machine of a grouped snapshot via the delta path and
        // check it is structurally identical to a fresh build
        let mut snap = flat(6, 1.0, 60.0);
        snap.set_machine(2, [2.5; NUM_RESOURCES], ResVec::new([30.0; NUM_RESOURCES]), true, false);
        snap.regroup(true);

        let mut prices = vec![[1.0; NUM_RESOURCES]; 6];
        prices[2] = [2.5; NUM_RESOURCES];
        let mut resid = vec![ResVec::new([60.0; NUM_RESOURCES]); 6];
        resid[2] = ResVec::new([30.0; NUM_RESOURCES]);
        let mut aps = vec![true; 6];
        aps[2] = false;
        let fresh = SlotSnapshot::new(prices, resid, vec![true; 6], aps, true);
        assert_eq!(snap, fresh);
        assert_eq!(snap.groups.len(), 2);
    }

    #[test]
    fn remove_ids_never_reuses_ids() {
        let mut interner = SignatureInterner::new();
        let a = flat(4, 1.0, 10.0);
        let b = flat(4, 2.0, 10.0);
        let ia = interner.intern(&a);
        let ib = interner.intern(&b);
        let dead: std::collections::HashSet<u32> = [ia].into_iter().collect();
        interner.remove_ids(&dead);
        assert_eq!(interner.len(), 1);
        // re-interning the removed structure yields a brand-new id, and
        // the surviving id is untouched
        let ia2 = interner.intern(&a);
        assert_ne!(ia2, ia);
        assert_ne!(ia2, ib);
        assert_eq!(interner.intern(&b), ib);
    }

    #[test]
    fn view_borrows_everything() {
        let s = flat(4, 1.0, 10.0);
        let v = s.view();
        assert_eq!(v.prices.len(), 4);
        assert_eq!(v.groups.len(), 1);
        assert!(v.allow_worker.iter().all(|&x| x));
    }
}
