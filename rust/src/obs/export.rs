//! Telemetry export: Chrome trace-event JSON (Perfetto /
//! `chrome://tracing`) and Prometheus text exposition.

use std::sync::Mutex;

use crate::obs::hist::Histogram;
use crate::obs::{Stage, StageSet, ALL_STAGES};
use crate::sim::events::{SimEvent, SimObserver};
use crate::util::json::{self, Json};

/// Trace-buffer cap; beyond it events are dropped and counted (a quick
/// figure run emits a few thousand spans, nowhere near this).
const TRACE_CAPACITY: usize = 1 << 20;

#[derive(Debug, Clone, Copy)]
pub struct TraceSpan {
    pub stage: Stage,
    pub ts_us: u64,
    pub dur_us: u64,
    pub tid: u64,
}

struct TraceBuf {
    spans: Vec<TraceSpan>,
    dropped: u64,
}

static TRACE_BUF: Mutex<TraceBuf> = Mutex::new(TraceBuf { spans: Vec::new(), dropped: 0 });

pub(crate) fn push_trace(stage: Stage, ts_us: u64, dur_us: u64, tid: u64) {
    let mut buf = TRACE_BUF.lock().unwrap();
    if buf.spans.len() >= TRACE_CAPACITY {
        buf.dropped += 1;
        return;
    }
    buf.spans.push(TraceSpan { stage, ts_us, dur_us, tid });
}

pub(crate) fn clear_trace() {
    let mut buf = TRACE_BUF.lock().unwrap();
    buf.spans.clear();
    buf.dropped = 0;
}

/// Drain the buffered spans (and the drop count) for export.
pub fn drain_trace() -> (Vec<TraceSpan>, u64) {
    let mut buf = TRACE_BUF.lock().unwrap();
    let dropped = buf.dropped;
    buf.dropped = 0;
    (std::mem::take(&mut buf.spans), dropped)
}

/// A [`SimObserver`] that timestamps every engine event as a Chrome
/// trace *instant* event, to interleave with the span rows. Purely
/// passive: it never touches the schedule or the RNG.
#[derive(Default)]
pub struct TelemetryObserver {
    instants: Vec<(&'static str, u64, u64)>, // (label, ts_us, tid)
}

impl TelemetryObserver {
    pub fn new() -> TelemetryObserver {
        TelemetryObserver::default()
    }

    /// Drain the span buffer plus this observer's instants into one
    /// Chrome trace-event JSON document.
    pub fn chrome_trace_json(&mut self) -> String {
        let (spans, dropped) = drain_trace();
        let instants = std::mem::take(&mut self.instants);
        chrome_trace_json(&spans, &instants, dropped)
    }

    pub fn write_chrome_trace(&mut self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.chrome_trace_json())
    }
}

impl SimObserver for TelemetryObserver {
    fn on_event(&mut self, ev: &SimEvent) {
        if crate::obs::flags() & crate::obs::TRACE != 0 {
            self.instants.push((ev.kind(), crate::obs::now_us(), crate::obs::thread_id()));
        }
    }
}

/// Serialize spans + instants in the Chrome trace-event format
/// (`ph:"X"` complete events, `ph:"i"` instants) that Perfetto and
/// `chrome://tracing` load directly.
pub fn chrome_trace_json(
    spans: &[TraceSpan],
    instants: &[(&'static str, u64, u64)],
    dropped: u64,
) -> String {
    let mut events: Vec<Json> = Vec::with_capacity(spans.len() + instants.len());
    for s in spans {
        events.push(json::obj(vec![
            ("name", json::s(s.stage.name())),
            ("cat", json::s("dmlrs")),
            ("ph", json::s("X")),
            ("ts", json::num(s.ts_us as f64)),
            ("dur", json::num(s.dur_us as f64)),
            ("pid", json::num(1.0)),
            ("tid", json::num(s.tid as f64)),
        ]));
    }
    for (label, ts_us, tid) in instants {
        events.push(json::obj(vec![
            ("name", json::s(label)),
            ("cat", json::s("dmlrs-event")),
            ("ph", json::s("i")),
            ("s", json::s("t")),
            ("ts", json::num(*ts_us as f64)),
            ("pid", json::num(1.0)),
            ("tid", json::num(*tid as f64)),
        ]));
    }
    json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", json::s("ms")),
        ("otherData", json::obj(vec![("dropped_spans", json::num(dropped as f64))])),
    ])
    .to_string()
}

/// Render a [`StageSet`] as Prometheus text exposition (format 0.0.4):
/// one `dmlrs_stage_duration_us` histogram family with a `stage` label,
/// cumulative log₂ `le` bounds, `_sum`/`_count` per stage, plus a
/// `dmlrs_stage_max_us` gauge.
pub fn prometheus_text(stages: &StageSet) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    out.push_str(
        "# HELP dmlrs_stage_duration_us Pipeline span durations per stage (microseconds).\n",
    );
    out.push_str("# TYPE dmlrs_stage_duration_us histogram\n");
    for st in ALL_STAGES {
        let h = stages.get(st);
        let name = st.name();
        let mut cum = 0u64;
        for (i, b) in h.buckets().iter().enumerate() {
            cum += b;
            // skip interior empty buckets once everything is counted,
            // but always emit the +Inf bound
            let bound = Histogram::bucket_bound(i);
            if bound == u64::MAX {
                let _ = writeln!(
                    out,
                    "dmlrs_stage_duration_us_bucket{{stage=\"{name}\",le=\"+Inf\"}} {cum}"
                );
            } else if *b > 0 || cum < h.count() {
                let _ = writeln!(
                    out,
                    "dmlrs_stage_duration_us_bucket{{stage=\"{name}\",le=\"{bound}\"}} {cum}"
                );
            }
        }
        let _ = writeln!(out, "dmlrs_stage_duration_us_sum{{stage=\"{name}\"}} {}", h.sum_us());
        let _ = writeln!(out, "dmlrs_stage_duration_us_count{{stage=\"{name}\"}} {}", h.count());
    }
    out.push_str("# HELP dmlrs_stage_max_us Maximum observed span duration per stage.\n");
    out.push_str("# TYPE dmlrs_stage_max_us gauge\n");
    for st in ALL_STAGES {
        let _ = writeln!(
            out,
            "dmlrs_stage_max_us{{stage=\"{}\"}} {}",
            st.name(),
            stages.get(st).max_us()
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chrome_trace_is_valid_json_with_span_and_instant() {
        let spans = [TraceSpan { stage: Stage::LpSolve, ts_us: 10, dur_us: 5, tid: 2 }];
        let instants = [("arrival", 12u64, 2u64)];
        let text = chrome_trace_json(&spans, &instants, 0);
        let v = Json::parse(&text).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("name").unwrap().as_str(), Some("lp_solve"));
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[0].get("dur").unwrap().as_f64(), Some(5.0));
        assert_eq!(evs[1].get("ph").unwrap().as_str(), Some("i"));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let mut stages = StageSet::new();
        stages.record(Stage::ThetaSolve, 3);
        stages.record(Stage::ThetaSolve, 300);
        let text = prometheus_text(&stages);
        assert!(text.contains("# TYPE dmlrs_stage_duration_us histogram"));
        assert!(text
            .contains("dmlrs_stage_duration_us_bucket{stage=\"theta_solve\",le=\"3\"} 1"));
        assert!(text
            .contains("dmlrs_stage_duration_us_bucket{stage=\"theta_solve\",le=\"+Inf\"} 2"));
        assert!(text.contains("dmlrs_stage_duration_us_sum{stage=\"theta_solve\"} 303"));
        assert!(text.contains("dmlrs_stage_duration_us_count{stage=\"theta_solve\"} 2"));
        // every stage appears even when empty
        assert!(text.contains("dmlrs_stage_duration_us_count{stage=\"queue_wait\"} 0"));
        // cumulative counts are monotone per stage
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("stage=\"theta_solve\",le=")) {
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n >= last);
            last = n;
        }
    }
}
