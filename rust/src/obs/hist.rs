//! Mergeable log₂-bucketed duration histograms.
//!
//! A [`Histogram`] is a fixed array of power-of-two buckets over
//! microseconds: bucket 0 holds 0 µs, bucket `i` holds durations in
//! `[2^(i-1), 2^i)` µs, and the last bucket absorbs everything above.
//! Merging is plain bucket-wise `u64` addition — associative and
//! commutative — so per-thread recorders can be folded into a global
//! aggregate in any order (the sweep pool's `--jobs 1` vs `--jobs N`
//! invariance rests on exactly this).

use crate::obs::{Stage, ALL_STAGES, NUM_STAGES};

/// Number of log₂ buckets. Bucket 30 covers up to ~2^29 µs ≈ 9 min;
/// anything longer lands in the overflow bucket.
pub const NUM_BUCKETS: usize = 31;

/// One mergeable duration histogram (microsecond log₂ buckets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Histogram {
    buckets: [u64; NUM_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram { buckets: [0; NUM_BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Bucket index for a duration: 0 for 0 µs, else `floor(log2(us)) + 1`,
    /// clamped to the overflow bucket.
    pub fn bucket_index(us: u64) -> usize {
        ((64 - us.leading_zeros()) as usize).min(NUM_BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` (`u64::MAX` for the overflow
    /// bucket) — the Prometheus `le` label.
    pub fn bucket_bound(i: usize) -> u64 {
        if i + 1 >= NUM_BUCKETS {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    pub fn record_us(&mut self, us: u64) {
        self.buckets[Self::bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Bucket-wise addition; the merged histogram is identical no matter
    /// how the recorders are grouped or ordered.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn buckets(&self) -> &[u64; NUM_BUCKETS] {
        &self.buckets
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// One histogram per instrumented [`Stage`] — the unit that per-thread
/// recorders hold and the global registry merges.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageSet {
    hists: [Histogram; NUM_STAGES],
}

impl StageSet {
    pub const fn new() -> StageSet {
        StageSet { hists: [Histogram::new(); NUM_STAGES] }
    }

    pub fn record(&mut self, stage: Stage, us: u64) {
        self.hists[stage as usize].record_us(us);
    }

    pub fn merge(&mut self, other: &StageSet) {
        for (h, o) in self.hists.iter_mut().zip(other.hists.iter()) {
            h.merge(o);
        }
    }

    pub fn get(&self, stage: Stage) -> &Histogram {
        &self.hists[stage as usize]
    }

    /// `(count, sum_us)` per stage, in [`ALL_STAGES`] order.
    pub fn totals(&self) -> [(u64, u64); NUM_STAGES] {
        let mut out = [(0u64, 0u64); NUM_STAGES];
        for (i, st) in ALL_STAGES.iter().enumerate() {
            let h = self.get(*st);
            out[i] = (h.count(), h.sum_us());
        }
        out
    }

    pub fn clear(&mut self) {
        *self = StageSet::new();
    }
}

impl Default for StageSet {
    fn default() -> StageSet {
        StageSet::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(u64::MAX), NUM_BUCKETS - 1);
        // every recorded value is ≤ its bucket's le bound
        for us in [0u64, 1, 2, 3, 7, 8, 1000, 1 << 40] {
            assert!(us <= Histogram::bucket_bound(Histogram::bucket_index(us)));
        }
    }

    #[test]
    fn merge_is_order_insensitive() {
        let samples = [3u64, 0, 17, 2048, 9, 9, 1 << 35];
        let mut serial = Histogram::new();
        for s in samples {
            serial.record_us(s);
        }
        // split across three recorders, merge in two different orders
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for (i, s) in samples.iter().enumerate() {
            [&mut a, &mut b, &mut c][i % 3].record_us(*s);
        }
        let mut m1 = Histogram::new();
        m1.merge(&a);
        m1.merge(&b);
        m1.merge(&c);
        let mut m2 = Histogram::new();
        m2.merge(&c);
        m2.merge(&a);
        m2.merge(&b);
        assert_eq!(m1, serial);
        assert_eq!(m2, serial);
    }

    #[test]
    fn merge_is_associative() {
        let mut a = Histogram::new();
        a.record_us(5);
        let mut b = Histogram::new();
        b.record_us(500);
        let mut c = Histogram::new();
        c.record_us(50_000);
        // (a + b) + c
        let mut ab = a;
        ab.merge(&b);
        let mut abc1 = ab;
        abc1.merge(&c);
        // a + (b + c)
        let mut bc = b;
        bc.merge(&c);
        let mut abc2 = a;
        abc2.merge(&bc);
        assert_eq!(abc1, abc2);
    }

    #[test]
    fn stage_set_totals() {
        let mut s = StageSet::new();
        s.record(Stage::LpSolve, 10);
        s.record(Stage::LpSolve, 20);
        s.record(Stage::Rounding, 1);
        let t = s.totals();
        assert_eq!(t[Stage::LpSolve as usize], (2, 30));
        assert_eq!(t[Stage::Rounding as usize], (1, 1));
        assert_eq!(t[Stage::ThetaSolve as usize], (0, 0));
    }
}
