//! Unified telemetry: pipeline spans, mergeable histograms, a flight
//! recorder, and Perfetto/Prometheus export.
//!
//! The admission pipeline is instrumented with RAII [`span`] guards at
//! nine stages (snapshot build, θ-solve, memo lookup, LP solve,
//! rounding, replan pass, migration pass, admission commit, daemon
//! queue-wait). Spans record into a per-thread [`hist::StageSet`] of
//! log₂-bucketed [`hist::Histogram`]s; [`flush_local`] folds a thread's
//! recorder into the global aggregate (bucket addition is associative
//! and commutative, so merge order never matters — the sweep pool calls
//! it once per worker and `--jobs 1` vs `--jobs N` aggregate
//! identically).
//!
//! Three consumers sit on top:
//! * [`export::TelemetryObserver`] + [`export::chrome_trace_json`] —
//!   Chrome trace-event JSON for Perfetto / `chrome://tracing`
//!   (`dmlrs schedule --trace-out run.json`);
//! * [`export::prometheus_text`] — Prometheus text exposition served by
//!   the daemon (`{"op":"metrics_prom"}` and `--prom-addr`);
//! * [`flight`] — a bounded ring of recent spans dumped on panic or via
//!   `{"op":"debug_dump"}`.
//!
//! **Determinism contract** (same discipline as [`crate::util::logger`]):
//! telemetry draws no RNG, never changes a schedule, and costs one
//! relaxed atomic load per site when disabled. `tests/telemetry_parity.rs`
//! enforces byte-identity of fully-instrumented runs against
//! telemetry-off runs across the scheduler zoo.

pub mod export;
pub mod flight;
pub mod hist;
pub mod provenance;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

pub use hist::{Histogram, StageSet};

/// The instrumented pipeline stages. Variant order is the canonical
/// reporting order; `name()` strings are stable identifiers used in
/// Perfetto traces, Prometheus labels, sweep JSONL fields, and
/// `verify.sh` assertions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(usize)]
pub enum Stage {
    /// Ledger slot → immutable `SlotSnapshot` (prices, residuals, groups).
    SnapshotBuild = 0,
    /// One θ(t, v) solve (Algorithm 3), cached or not.
    ThetaSolve = 1,
    /// θ-memo probe (hit or miss) under the snapshot signature key.
    MemoLookup = 2,
    /// One simplex solve in the external-placement LP.
    LpSolve = 3,
    /// Randomized-rounding attempt loop of one θ-solve.
    Rounding = 4,
    /// One elastic re-planning pass (release → re-solve → adopt).
    ReplanPass = 5,
    /// One churn migration pass (interrupt → re-plan → migrate/evict).
    MigrationPass = 6,
    /// One admission decision end-to-end (`AdmissionCore::submit`).
    AdmissionCommit = 7,
    /// Daemon request time spent queued before the core thread picked it up.
    QueueWait = 8,
}

pub const NUM_STAGES: usize = 9;

pub const ALL_STAGES: [Stage; NUM_STAGES] = [
    Stage::SnapshotBuild,
    Stage::ThetaSolve,
    Stage::MemoLookup,
    Stage::LpSolve,
    Stage::Rounding,
    Stage::ReplanPass,
    Stage::MigrationPass,
    Stage::AdmissionCommit,
    Stage::QueueWait,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::SnapshotBuild => "snapshot_build",
            Stage::ThetaSolve => "theta_solve",
            Stage::MemoLookup => "memo_lookup",
            Stage::LpSolve => "lp_solve",
            Stage::Rounding => "rounding",
            Stage::ReplanPass => "replan_pass",
            Stage::MigrationPass => "migration_pass",
            Stage::AdmissionCommit => "admission_commit",
            Stage::QueueWait => "queue_wait",
        }
    }
}

// ---------------------------------------------------------------------------
// Enable flags — one relaxed atomic load on the disabled fast path.

/// Record span durations into per-thread histograms.
pub const SPANS: u8 = 1;
/// Keep the bounded flight-recorder ring of recent spans.
pub const FLIGHT: u8 = 2;
/// Buffer individual span events for Chrome-trace export.
pub const TRACE: u8 = 4;
/// Everything on.
pub const ALL: u8 = SPANS | FLIGHT | TRACE;
/// Emit decision provenance + price samples (see [`provenance`]).
/// Deliberately *not* part of [`ALL`]: the Chrome-trace export path
/// predates provenance and its consumers expect the PR 7 event set.
pub const PROV: u8 = 8;

static FLAGS: AtomicU8 = AtomicU8::new(0);

pub fn set_flags(flags: u8) {
    FLAGS.store(flags, Ordering::Relaxed);
}

pub fn flags() -> u8 {
    FLAGS.load(Ordering::Relaxed)
}

pub fn spans_on() -> bool {
    flags() & SPANS != 0
}

/// Is decision-provenance emission on (the [`PROV`] flag)?
pub fn prov_on() -> bool {
    flags() & PROV != 0
}

// ---------------------------------------------------------------------------
// Clock, thread ids, sequence numbers.

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// µs since the first telemetry touch of this process (monotonic).
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static NEXT_SEQ: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static LOCAL: RefCell<StageSet> = const { RefCell::new(StageSet::new()) };
}

/// Small integer id of the calling thread (stable for its lifetime).
pub fn thread_id() -> u64 {
    TID.with(|t| *t)
}

// ---------------------------------------------------------------------------
// Recording.

static GLOBAL: Mutex<StageSet> = Mutex::new(StageSet::new());

/// Record one duration into the calling thread's recorder (histogram
/// path only — no flight/trace entry; used for externally-measured
/// durations like the daemon queue-wait).
pub fn record(stage: Stage, us: u64) {
    if flags() == 0 {
        return;
    }
    record_full(stage, now_us().saturating_sub(us), us);
}

fn record_full(stage: Stage, ts_us: u64, dur_us: u64) {
    let f = flags();
    if f & SPANS != 0 {
        LOCAL.with(|l| l.borrow_mut().record(stage, dur_us));
    }
    if f & (FLIGHT | TRACE) != 0 {
        let seq = NEXT_SEQ.fetch_add(1, Ordering::Relaxed);
        let tid = thread_id();
        if f & FLIGHT != 0 {
            flight::push_span(seq, stage, ts_us, dur_us, tid);
        }
        if f & TRACE != 0 {
            export::push_trace(stage, ts_us, dur_us, tid);
        }
    }
}

/// RAII span guard: measures from construction to drop. When telemetry
/// is disabled this is a single relaxed atomic load and no clock read.
pub struct SpanGuard {
    live: Option<(Stage, Instant, u64)>, // (stage, start, start ts_us)
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((stage, start, ts_us)) = self.live.take() {
            record_full(stage, ts_us, start.elapsed().as_micros() as u64);
        }
    }
}

/// Open a span for `stage`; close it by dropping the guard.
pub fn span(stage: Stage) -> SpanGuard {
    if flags() == 0 {
        return SpanGuard { live: None };
    }
    let ts_us = now_us();
    SpanGuard { live: Some((stage, Instant::now(), ts_us)) }
}

/// `let _g = span!(Stage::LpSolve);` — sugar over [`obs::span`](span).
#[macro_export]
macro_rules! span {
    ($stage:expr) => {
        $crate::obs::span($stage)
    };
}

// ---------------------------------------------------------------------------
// Registry: per-thread recorders → global aggregate.

/// Fold the calling thread's recorder into the global aggregate and
/// clear it. Workers call this before exiting (and the daemon core after
/// each request) so [`global_totals`]/Prometheus see everything.
pub fn flush_local() {
    LOCAL.with(|l| {
        let mut local = l.borrow_mut();
        let mut global = GLOBAL.lock().unwrap();
        global.merge(&local);
        local.clear();
    });
}

/// Snapshot of the global (post-flush) aggregate.
pub fn global_stages() -> StageSet {
    *GLOBAL.lock().unwrap()
}

/// `(count, sum_us)` per stage of the global aggregate, [`ALL_STAGES`] order.
pub fn global_totals() -> [(u64, u64); NUM_STAGES] {
    global_stages().totals()
}

/// `(count, sum_us)` per stage of the calling thread's (unflushed)
/// recorder — the sweep runner diffs this around each cell to attribute
/// stage time per cell.
pub fn local_totals() -> [(u64, u64); NUM_STAGES] {
    LOCAL.with(|l| l.borrow().totals())
}

/// Test/CLI hook: clear the global aggregate, the calling thread's
/// recorder, the flight ring, and the trace buffer.
pub fn reset() {
    GLOBAL.lock().unwrap().clear();
    LOCAL.with(|l| l.borrow_mut().clear());
    flight::clear();
    export::clear_trace();
}

#[cfg(test)]
mod tests {
    use super::*;

    // The flag word is process-global; in-crate tests touching it run in
    // one binary, so serialize them.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    // These tests assert on the *thread-local* recorder (unpollutable)
    // and on before/after deltas of the global one: whenever an obs test
    // turns SPANS on, concurrently running crate tests may legitimately
    // record and flush spans of their own, so exact global equality
    // would be flaky.

    #[test]
    fn disabled_span_records_nothing() {
        let _g = TEST_LOCK.lock().unwrap();
        set_flags(0);
        {
            let _s = span(Stage::LpSolve);
        }
        record(Stage::QueueWait, 5);
        let local = local_totals();
        assert_eq!(local[Stage::LpSolve as usize], (0, 0));
        assert_eq!(local[Stage::QueueWait as usize], (0, 0));
    }

    #[test]
    fn enabled_span_lands_in_histogram() {
        let _g = TEST_LOCK.lock().unwrap();
        set_flags(SPANS);
        let before = global_totals()[Stage::QueueWait as usize];
        {
            let _s = span(Stage::ThetaSolve);
        }
        record(Stage::QueueWait, 17);
        let local = local_totals();
        assert_eq!(local[Stage::ThetaSolve as usize].0, 1);
        assert_eq!(local[Stage::QueueWait as usize], (1, 17));
        flush_local();
        assert_eq!(local_totals()[Stage::ThetaSolve as usize], (0, 0));
        let after = global_totals()[Stage::QueueWait as usize];
        assert!(after.0 >= before.0 + 1 && after.1 >= before.1 + 17, "{after:?}");
        set_flags(0);
    }

    #[test]
    fn cross_thread_flush_merges() {
        let _g = TEST_LOCK.lock().unwrap();
        set_flags(SPANS);
        let before = global_totals()[Stage::AdmissionCommit as usize];
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    record(Stage::AdmissionCommit, 10);
                    record(Stage::AdmissionCommit, 20);
                    flush_local();
                });
            }
        });
        let after = global_totals()[Stage::AdmissionCommit as usize];
        assert!(after.0 >= before.0 + 6 && after.1 >= before.1 + 90, "{after:?}");
        set_flags(0);
    }

    #[test]
    fn stage_names_are_stable() {
        let names: Vec<&str> = ALL_STAGES.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            [
                "snapshot_build",
                "theta_solve",
                "memo_lookup",
                "lp_solve",
                "rounding",
                "replan_pass",
                "migration_pass",
                "admission_commit",
                "queue_wait",
            ]
        );
    }
}
