//! Decision provenance: *why* each job was admitted or rejected, and
//! what the dual prices looked like while the scheduler decided.
//!
//! The paper's admission rule (Algorithm 1) is economic — a job enters
//! iff its utility beats the total dual price `Σ p_h^r[t]` along the
//! best θ-schedule — so the explanation of every decision is a handful
//! of numbers the solver already computes: the utility at the planned
//! completion, the price it paid, their difference (the λ margin), the
//! winning slot window, and how many θ-solves landed on the internal
//! (co-located) vs external (LP + rounding) locality case. This module
//! holds the two record types that carry those numbers out of the
//! solver:
//!
//! * [`DecisionTrace`] — one record per arrival decision, captured by
//!   [`PdOrs`](crate::sched::PdOrs) from the [`PlanResult`]
//!   (`crate::sched::dp::PlanResult`) it just evaluated (or synthesized
//!   by the engine for policies that do not price, reason `"policy"`);
//! * [`PriceSample`] — the cluster's mean dual price and utilization per
//!   resource, sampled at each `SlotStart`.
//!
//! Provenance is **deterministically inert**: building a trace reads
//! only data the solve already produced (zero RNG draws, no ledger
//! mutation), and traces are *emitted* only when the [`PROV`]
//! flag (`crate::obs::PROV`) or the engine's `provenance` builder switch
//! is on — with it off, results are byte-identical to a build that never
//! heard of this module (`tests/provenance_parity.rs`).

use crate::cluster::{AllocLedger, Resource, NUM_RESOURCES};
use crate::util::json::{self, Json};

/// The provenance of one arrival decision (see module docs). `Copy` so
/// event collectors can move it out of a matched [`SimEvent`]
/// (`crate::sim::SimEvent::Decision`) by value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecisionTrace {
    pub job_id: usize,
    /// The slot the decision was made at (the engine/daemon stamp this
    /// with the actual submission slot).
    pub t: usize,
    /// `"admit"`, `"reject"`, or `"defer"`.
    pub decision: &'static str,
    /// Machine-readable reason: `"margin"` (admitted, utility beat the
    /// price), `"price"` (a feasible plan existed but priced out),
    /// `"infeasible"` (no feasible θ-schedule in the window), or
    /// `"policy"` (a non-pricing scheduler decided; no economics to
    /// report).
    pub reason: &'static str,
    /// Utility at the planned completion slot (0 for infeasible/policy).
    pub utility: f64,
    /// Total dual price of the best plan (Eq. (12) summed along the
    /// θ-schedule; 0 for infeasible/policy).
    pub price: f64,
    /// The λ margin from Algorithm 1: `utility - price`. Positive iff
    /// admitted.
    pub margin: f64,
    /// The winning plan's slot window `(first_slot, completion_slot)`;
    /// `None` when no plan existed.
    pub window: Option<(usize, usize)>,
    /// θ-solves of the winning plan that used the internal (co-located,
    /// closed-form) locality case.
    pub internal_slots: usize,
    /// θ-solves of the winning plan that used the external case (LP +
    /// randomized rounding).
    pub external_slots: usize,
    /// Randomized-rounding attempts spent on this plan.
    pub rounding_attempts: usize,
    /// Slots the DP considered (the arrival-to-horizon window).
    pub slots_considered: usize,
    /// Reuse provenance: θ-memo hits during this plan.
    pub memo_hits: u64,
    /// Warm-simplex hits during this plan.
    pub warm_hits: u64,
    /// Snapshot delta-refreshes during this plan.
    pub snapshot_delta_updates: u64,
}

impl DecisionTrace {
    /// A trace for a scheduler that does not price (fifo/drf/dorm — or a
    /// third-party `Scheduler` that never reports provenance): the
    /// decision is recorded, the economics are all zero.
    pub fn fallback(job_id: usize, decision: &'static str) -> DecisionTrace {
        DecisionTrace {
            job_id,
            t: 0,
            decision,
            reason: "policy",
            utility: 0.0,
            price: 0.0,
            margin: 0.0,
            window: None,
            internal_slots: 0,
            external_slots: 0,
            rounding_attempts: 0,
            slots_considered: 0,
            memo_hits: 0,
            warm_hits: 0,
            snapshot_delta_updates: 0,
        }
    }

    /// A rejection because no feasible θ-schedule existed in the
    /// `slots_considered`-slot window. All economics stay finite zeros
    /// (there is no price to report), keeping the JSON clean.
    pub fn infeasible(job_id: usize, slots_considered: usize) -> DecisionTrace {
        DecisionTrace {
            reason: "infeasible",
            slots_considered,
            ..DecisionTrace::fallback(job_id, "reject")
        }
    }

    /// One human-readable "why" line (what `dmlrs schedule --explain`
    /// prints).
    pub fn explain_line(&self) -> String {
        let reuse = format!(
            "memo={} warm={} deltas={}",
            self.memo_hits, self.warm_hits, self.snapshot_delta_updates
        );
        match self.reason {
            "margin" => {
                let (w0, w1) = self.window.unwrap_or((self.t, self.t));
                format!(
                    "t={:3} job {:3} admitted: utility {:.3} - price {:.3} = margin {:+.3} \
                     > 0; slots [{w0}, {w1}], locality internal={} external={}, \
                     roundings={}, {reuse}",
                    self.t,
                    self.job_id,
                    self.utility,
                    self.price,
                    self.margin,
                    self.internal_slots,
                    self.external_slots,
                    self.rounding_attempts
                )
            }
            "price" => format!(
                "t={:3} job {:3} rejected (priced out): utility {:.3} - price {:.3} = \
                 margin {:+.3} <= 0 over {} candidate slots, {reuse}",
                self.t,
                self.job_id,
                self.utility,
                self.price,
                self.margin,
                self.slots_considered
            ),
            "infeasible" => format!(
                "t={:3} job {:3} rejected (infeasible): no feasible schedule in {} \
                 candidate slots",
                self.t, self.job_id, self.slots_considered
            ),
            _ => format!(
                "t={:3} job {:3} {}: policy decision (scheduler reports no prices)",
                self.t, self.job_id, self.decision
            ),
        }
    }

    /// One compact JSON object (what `--explain-out` writes per line and
    /// the daemon's `explain` op returns).
    pub fn to_json(&self) -> Json {
        let (ws, we) = match self.window {
            Some((a, b)) => (json::num(a as f64), json::num(b as f64)),
            None => (Json::Null, Json::Null),
        };
        json::obj(vec![
            ("job_id", json::num(self.job_id as f64)),
            ("t", json::num(self.t as f64)),
            ("decision", json::s(self.decision)),
            ("reason", json::s(self.reason)),
            ("utility", json::num(self.utility)),
            ("price", json::num(self.price)),
            ("margin", json::num(self.margin)),
            ("window_start", ws),
            ("window_end", we),
            ("internal_slots", json::num(self.internal_slots as f64)),
            ("external_slots", json::num(self.external_slots as f64)),
            ("rounding_attempts", json::num(self.rounding_attempts as f64)),
            ("slots_considered", json::num(self.slots_considered as f64)),
            ("memo_hits", json::num(self.memo_hits as f64)),
            ("warm_hits", json::num(self.warm_hits as f64)),
            ("snapshot_delta_updates", json::num(self.snapshot_delta_updates as f64)),
        ])
    }
}

/// One point of the per-slot cluster price & utilization time-series
/// (the dual dynamics the paper plots): the machine-mean dual price and
/// the used/capacity ratio per resource at slot `t`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriceSample {
    pub t: usize,
    /// Machine-mean dual price per resource.
    pub price: [f64; NUM_RESOURCES],
    /// The largest per-resource mean price (a quick congestion scalar).
    pub max_price: f64,
    /// Cluster utilization per resource: total used / total capacity.
    pub utilization: [f64; NUM_RESOURCES],
}

impl PriceSample {
    /// Scalar price level: the mean over resources of the machine-mean
    /// prices (what the sweep's `mean_price_level` aggregates).
    pub fn mean_price(&self) -> f64 {
        self.price.iter().sum::<f64>() / NUM_RESOURCES as f64
    }

    pub fn to_json(&self) -> Json {
        let named = |xs: &[f64; NUM_RESOURCES]| {
            Json::Obj(
                Resource::ALL
                    .iter()
                    .map(|&r| (r.name().to_string(), json::num(xs[r as usize])))
                    .collect(),
            )
        };
        json::obj(vec![
            ("t", json::num(self.t as f64)),
            ("price", named(&self.price)),
            ("mean_price", json::num(self.mean_price())),
            ("max_price", json::num(self.max_price)),
            ("utilization", named(&self.utilization)),
        ])
    }
}

/// Machine-mean per-resource prices from a per-machine price table (what
/// [`crate::sched::dp::slot_prices`] returns).
pub fn mean_prices(per_machine: &[[f64; NUM_RESOURCES]]) -> [f64; NUM_RESOURCES] {
    let mut mean = [0.0; NUM_RESOURCES];
    if per_machine.is_empty() {
        return mean;
    }
    for row in per_machine {
        for r in 0..NUM_RESOURCES {
            mean[r] += row[r];
        }
    }
    for m in &mut mean {
        *m /= per_machine.len() as f64;
    }
    mean
}

/// Cluster utilization per resource at slot `t`: total committed
/// allocation over total capacity (0 where the cluster has none of a
/// resource).
pub fn utilization(ledger: &AllocLedger, t: usize) -> [f64; NUM_RESOURCES] {
    let mut used = [0.0; NUM_RESOURCES];
    let mut cap = [0.0; NUM_RESOURCES];
    for h in 0..ledger.num_machines() {
        for r in 0..NUM_RESOURCES {
            used[r] += ledger.used(t, h).0[r];
            cap[r] += ledger.capacity(h).0[r];
        }
    }
    let mut out = [0.0; NUM_RESOURCES];
    for r in 0..NUM_RESOURCES {
        out[r] = if cap[r] > 0.0 { used[r] / cap[r] } else { 0.0 };
    }
    out
}

/// The whole price series as one JSON document (what
/// `dmlrs schedule --price-out` writes).
pub fn price_series_json(samples: &[PriceSample]) -> Json {
    json::obj(vec![
        ("series", json::s("cluster_prices")),
        ("slots", json::num(samples.len() as f64)),
        ("samples", Json::Arr(samples.iter().map(PriceSample::to_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_and_infeasible_traces_are_finite() {
        let f = DecisionTrace::fallback(3, "defer");
        assert_eq!(f.reason, "policy");
        assert!(f.margin.is_finite() && f.price.is_finite());
        let i = DecisionTrace::infeasible(4, 7);
        assert_eq!(i.decision, "reject");
        assert_eq!(i.reason, "infeasible");
        assert_eq!(i.slots_considered, 7);
        assert!(i.explain_line().contains("infeasible"));
        // the JSON never contains a non-finite number
        assert!(!i.to_json().to_string().contains("inf"));
    }

    #[test]
    fn explain_line_carries_the_margin() {
        let tr = DecisionTrace {
            job_id: 5,
            t: 2,
            decision: "admit",
            reason: "margin",
            utility: 10.0,
            price: 4.0,
            margin: 6.0,
            window: Some((2, 6)),
            internal_slots: 3,
            external_slots: 1,
            rounding_attempts: 2,
            slots_considered: 10,
            memo_hits: 8,
            warm_hits: 1,
            snapshot_delta_updates: 4,
        };
        let line = tr.explain_line();
        assert!(line.contains("admitted"), "{line}");
        assert!(line.contains("10.000") && line.contains("4.000"), "{line}");
        assert!(line.contains("+6.000"), "{line}");
        let j = tr.to_json();
        assert_eq!(j.get("window_start").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("reason").and_then(Json::as_str), Some("margin"));
    }

    #[test]
    fn mean_prices_and_series_shape() {
        let table = vec![[1.0, 2.0, 3.0, 4.0], [3.0, 2.0, 1.0, 0.0]];
        let mean = mean_prices(&table);
        assert_eq!(mean, [2.0, 2.0, 2.0, 2.0]);
        assert_eq!(mean_prices(&[]), [0.0; NUM_RESOURCES]);
        let s = PriceSample {
            t: 1,
            price: mean,
            max_price: 2.0,
            utilization: [0.5, 0.25, 0.0, 1.0],
        };
        assert!((s.mean_price() - 2.0).abs() < 1e-12);
        let doc = price_series_json(&[s]);
        assert_eq!(doc.get("slots").and_then(Json::as_usize), Some(1));
        let first = &doc.get("samples").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("t").and_then(Json::as_usize), Some(1));
        assert!(first.get("price").unwrap().get("gpu").is_some());
    }
}
