//! Flight recorder: a bounded ring of the most recent spans and events,
//! dumped as JSON on panic, on request, or via the daemon's
//! `{"op":"debug_dump"}` wire op — so a stuck or slow daemon is
//! diagnosable post-hoc without having had tracing on from the start.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::obs::Stage;
use crate::util::json::{self, Json};

/// Ring capacity (entries, not bytes). Old entries are dropped and
/// counted, so the dump says how much history it lost.
pub const FLIGHT_CAPACITY: usize = 256;

#[derive(Debug, Clone)]
pub struct FlightEntry {
    /// Global monotonic sequence number (allocation order across threads).
    pub seq: u64,
    /// Stage name for spans, or a free-form event label.
    pub label: &'static str,
    /// Start, µs since the process telemetry epoch.
    pub ts_us: u64,
    /// Duration; 0 for instant events.
    pub dur_us: u64,
    /// Small integer id of the recording thread.
    pub tid: u64,
}

struct Ring {
    entries: VecDeque<FlightEntry>,
    dropped: u64,
}

static RING: Mutex<Ring> = Mutex::new(Ring { entries: VecDeque::new(), dropped: 0 });

pub(crate) fn push_span(seq: u64, stage: Stage, ts_us: u64, dur_us: u64, tid: u64) {
    push(FlightEntry { seq, label: stage.name(), ts_us, dur_us, tid });
}

pub(crate) fn push(e: FlightEntry) {
    let mut ring = RING.lock().unwrap();
    if ring.entries.len() >= FLIGHT_CAPACITY {
        ring.entries.pop_front();
        ring.dropped += 1;
    }
    ring.entries.push_back(e);
}

pub(crate) fn clear() {
    let mut ring = RING.lock().unwrap();
    ring.entries.clear();
    ring.dropped = 0;
}

/// Number of entries currently held.
pub fn len() -> usize {
    RING.lock().unwrap().entries.len()
}

/// Dump the ring as a JSON value: `{"capacity":…,"dropped":…,"entries":[…]}`.
pub fn dump_json() -> Json {
    let ring = RING.lock().unwrap();
    let entries: Vec<Json> = ring
        .entries
        .iter()
        .map(|e| {
            json::obj(vec![
                ("seq", json::num(e.seq as f64)),
                ("label", json::s(e.label)),
                ("ts_us", json::num(e.ts_us as f64)),
                ("dur_us", json::num(e.dur_us as f64)),
                ("tid", json::num(e.tid as f64)),
            ])
        })
        .collect();
    json::obj(vec![
        ("capacity", json::num(FLIGHT_CAPACITY as f64)),
        ("dropped", json::num(ring.dropped as f64)),
        ("entries", Json::Arr(entries)),
    ])
}

/// Install a panic hook that prints the flight-recorder dump to stderr
/// (chained in front of the previous hook). Used by the daemon so a
/// crash leaves the last ~256 spans behind.
pub fn install_panic_dump() {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        eprintln!("flight recorder dump: {}", dump_json().to_string());
        prev(info);
    }));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        clear();
        for i in 0..(FLIGHT_CAPACITY as u64 + 10) {
            push(FlightEntry { seq: i, label: "x", ts_us: i, dur_us: 1, tid: 0 });
        }
        assert_eq!(len(), FLIGHT_CAPACITY);
        let d = dump_json();
        assert_eq!(d.get("dropped").unwrap().as_f64(), Some(10.0));
        let entries = d.get("entries").unwrap().as_arr().unwrap();
        // oldest surviving entry is seq 10
        assert_eq!(entries[0].get("seq").unwrap().as_f64(), Some(10.0));
        clear();
    }
}
