//! Branch-and-bound MILP solver (minimization).
//!
//! Depth-first search with best-incumbent pruning; branches on the most
//! fractional integer variable; bounds are added as extra `x_j ≤ ⌊v⌋` /
//! `x_j ≥ ⌈v⌉` rows on a copy of the relaxation. Exact on the small
//! per-slot ILPs this repo needs (tens of variables); a node cap guards
//! pathological instances.

use crate::lp::{solve, Cmp, LpOutcome, LpProblem};

/// Integer solution (values rounded to the nearest integer).
#[derive(Debug, Clone)]
pub struct IlpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
    pub nodes_explored: usize,
}

#[derive(Debug, Clone)]
pub enum IlpOutcome {
    Optimal(IlpSolution),
    Infeasible,
    /// Node cap hit; the incumbent (if any) is returned as a bound.
    NodeLimit(Option<IlpSolution>),
}

const INT_EPS: f64 = 1e-6;

fn most_fractional(x: &[f64], integer: &[bool]) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for (j, &xi) in x.iter().enumerate() {
        if !integer[j] {
            continue;
        }
        let frac = xi - xi.floor();
        let dist = (frac - 0.5).abs();
        if frac > INT_EPS && frac < 1.0 - INT_EPS {
            if best.map_or(true, |(_, d)| dist < d) {
                best = Some((j, dist));
            }
        }
    }
    best
}

/// Minimize `p` with `integer[j]` marking integral variables.
pub fn solve_ilp(p: &LpProblem, integer: &[bool], node_limit: usize) -> IlpOutcome {
    solve_ilp_budgeted(p, integer, node_limit, f64::INFINITY)
}

/// [`solve_ilp`] with an additional wall-clock budget (seconds); on
/// exhaustion the best incumbent is returned as `NodeLimit`.
pub fn solve_ilp_budgeted(
    p: &LpProblem,
    integer: &[bool],
    node_limit: usize,
    max_secs: f64,
) -> IlpOutcome {
    assert_eq!(integer.len(), p.num_vars);
    let start = std::time::Instant::now();
    let mut incumbent: Option<IlpSolution> = None;
    let mut nodes = 0usize;
    // stack of subproblems
    let mut stack: Vec<LpProblem> = vec![p.clone()];

    while let Some(sub) = stack.pop() {
        nodes += 1;
        if nodes > node_limit
            || (nodes % 16 == 0 && start.elapsed().as_secs_f64() > max_secs)
        {
            return IlpOutcome::NodeLimit(incumbent);
        }
        let relaxed = match solve(&sub) {
            LpOutcome::Optimal(s) => s,
            LpOutcome::Infeasible => continue,
            LpOutcome::Unbounded => continue, // integral restriction may
                                              // still be bounded, but our
                                              // problems never hit this
        };
        if let Some(inc) = &incumbent {
            if relaxed.objective >= inc.objective - 1e-9 {
                continue; // bound prune
            }
        }
        match most_fractional(&relaxed.x, integer) {
            None => {
                // integral solution: snap integer vars, keep continuous ones
                let x: Vec<f64> = relaxed
                    .x
                    .iter()
                    .zip(integer)
                    .map(|(v, &is_int)| if is_int { v.round().max(0.0) } else { v.max(0.0) })
                    .collect();
                let obj = p.objective_value(&x);
                if incumbent.as_ref().map_or(true, |inc| obj < inc.objective) {
                    incumbent = Some(IlpSolution { x, objective: obj, nodes_explored: nodes });
                }
            }
            Some((j, _)) => {
                let v = relaxed.x[j];
                let mut down = sub.clone();
                let mut row = vec![0.0; p.num_vars];
                row[j] = 1.0;
                down.add_row(row.clone(), Cmp::Le, v.floor());
                let mut up = sub;
                up.add_row(row, Cmp::Ge, v.ceil());
                // DFS, exploring the "down" branch first (tends to find
                // feasible incumbents quickly on cover problems).
                stack.push(up);
                stack.push(down);
            }
        }
    }

    match incumbent {
        Some(mut s) => {
            s.nodes_explored = nodes;
            IlpOutcome::Optimal(s)
        }
        None => IlpOutcome::Infeasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integer_knapsack_cover() {
        // min 3x + 4y s.t. 2x + 3y >= 7, integer => candidates:
        // x=0,y=3 (12); x=2,y=1 (10); x=4,y=0 (12); x=1,y=2(11) => 10
        let mut p = LpProblem::new(2);
        p.set_objective(vec![3.0, 4.0]);
        p.add_row(vec![2.0, 3.0], Cmp::Ge, 7.0);
        match solve_ilp(&p, &[true, true], 10_000) {
            IlpOutcome::Optimal(s) => {
                assert!((s.objective - 10.0).abs() < 1e-6, "obj {}", s.objective);
                assert_eq!(s.x, vec![2.0, 1.0]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn respects_packing() {
        // max 5x + 4y (=> min -) s.t. 6x + 4y <= 24, x + 2y <= 6, ints
        let mut p = LpProblem::new(2);
        p.set_objective(vec![-5.0, -4.0]);
        p.add_row(vec![6.0, 4.0], Cmp::Le, 24.0);
        p.add_row(vec![1.0, 2.0], Cmp::Le, 6.0);
        match solve_ilp(&p, &[true, true], 10_000) {
            IlpOutcome::Optimal(s) => {
                // LP opt is (3, 1.5) = 21; best integer point is (4, 0) = 20
                assert!((s.objective - (-20.0)).abs() < 1e-6, "obj {}", s.objective);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_integer() {
        // 2x = 3 has no integer solution
        let mut p = LpProblem::new(1);
        p.set_objective(vec![1.0]);
        p.add_row(vec![2.0], Cmp::Eq, 3.0);
        assert!(matches!(solve_ilp(&p, &[true], 1000), IlpOutcome::Infeasible));
    }

    #[test]
    fn mixed_integer() {
        // y continuous: min x + y s.t. x + y >= 2.5, x integer
        let mut p = LpProblem::new(2);
        p.set_objective(vec![1.0, 1.0]);
        p.add_row(vec![1.0, 1.0], Cmp::Ge, 2.5);
        match solve_ilp(&p, &[true, false], 1000) {
            IlpOutcome::Optimal(s) => {
                assert!((s.objective - 2.5).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn matches_enumeration_on_random_covers() {
        use crate::util::Rng;
        let mut rng = Rng::new(42);
        for case in 0..20 {
            let n = 3;
            let mut p = LpProblem::new(n);
            let c: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 5.0)).collect();
            p.set_objective(c.clone());
            let a: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 2.0)).collect();
            let b = rng.range_f64(3.0, 8.0);
            p.add_row(a.clone(), Cmp::Ge, b);
            for j in 0..n {
                let mut cap = vec![0.0; n];
                cap[j] = 1.0;
                p.add_row(cap, Cmp::Le, 6.0);
            }
            let got = match solve_ilp(&p, &[true; 3], 100_000) {
                IlpOutcome::Optimal(s) => s.objective,
                other => panic!("case {case}: {other:?}"),
            };
            // brute force over 0..=6 per var
            let mut best = f64::INFINITY;
            for x0 in 0..=6 {
                for x1 in 0..=6 {
                    for x2 in 0..=6 {
                        let x = [x0 as f64, x1 as f64, x2 as f64];
                        let lhs: f64 = a.iter().zip(&x).map(|(ai, xi)| ai * xi).sum();
                        if lhs >= b - 1e-9 {
                            let obj: f64 =
                                c.iter().zip(&x).map(|(ci, xi)| ci * xi).sum();
                            best = best.min(obj);
                        }
                    }
                }
            }
            assert!((got - best).abs() < 1e-6, "case {case}: got {got} want {best}");
        }
    }
}
