//! Integer programming substrate: branch-and-bound over the [`crate::lp`]
//! simplex. This is the offline-oracle / Gurobi substitute used by the
//! Fig. 10 offline optimum and the Fig. 11 rounding-vs-optimal comparison.

pub mod branch_bound;

pub use branch_bound::{solve_ilp, solve_ilp_budgeted, IlpOutcome, IlpSolution};
