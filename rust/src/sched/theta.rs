//! Algorithm 4 — the per-slot problem θ(t, v): minimum-price worker/PS
//! placement that trains `v` samples of job `i` in one slot.
//!
//! Two cases per Fact 1:
//!
//! * **Internal** (`|P| = |W| = 1`, co-located): closed form — one machine
//!   hosts `w = ⌈v · τ_int⌉` workers and `s = ⌈w/γ⌉` PSs; scan machines for
//!   the cheapest feasible one.
//! * **External**: the mixed cover/packing integer program (23)–(26),
//!   solved by LP relaxation + the randomized rounding of
//!   [`super::rounding`], up to `S` attempts, keeping the cheapest
//!   feasible rounding.
//!
//! **Performance (DESIGN.md §Perf):** machines with identical price and
//! residual-capacity signatures are aggregated into *groups* before the LP
//! — on a fresh homogeneous cluster the (2H)-variable LP collapses to two
//! variables. The fractional group solution is split evenly across group
//! members before rounding (identical machines ⇒ the split preserves
//! per-machine feasibility of the relaxation).

use crate::cluster::{ResVec, NUM_RESOURCES};
use crate::jobs::{speed, Job, Locality};
use crate::lp::{solve, Cmp, LpProblem};
use crate::util::Rng;

use super::rounding::{gdelta_cover, gdelta_packing, round_coord};

/// How to choose the pre-rounding gain factor `G_δ`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GdeltaMode {
    /// Eq. (29) — favor packing (resource) feasibility.
    Packing,
    /// Eq. (30) — favor cover (workload) feasibility.
    Cover,
    /// A fixed value (Fig. 11 sweeps this).
    Fixed(f64),
}

/// θ-solver parameters.
#[derive(Debug, Clone, Copy)]
pub struct ThetaConfig {
    /// δ of Theorems 3/4.
    pub delta: f64,
    pub gdelta: GdeltaMode,
    /// Rounding attempts `S`.
    pub attempts: usize,
    /// Accepted cover fraction: a rounding is feasible when it covers
    /// `cover_fraction · W1` workers. 1.0 = strict (default). The Fig. 11
    /// sweep sets this to `min(1, G_δ)` per the paper's observation that
    /// "the violation of the cover constraint in one iteration may be
    /// acceptable" (epochs are over-estimated in practice) — otherwise
    /// G_δ < 1 admits nothing and the figure degenerates.
    pub cover_fraction: f64,
    /// Aggregate machines with identical (price, residual) signatures into
    /// single LP variables (DESIGN.md §Perf). `false` = one variable pair
    /// per machine (the paper's literal formulation; kept for the perf
    /// ablation and as the correctness oracle for grouping).
    pub group_machines: bool,
}

impl Default for ThetaConfig {
    fn default() -> ThetaConfig {
        // G_δ = 1 is the paper's empirically-best setting (Fig. 11): the
        // theoretical G_δ of Eq. (29) is far below 1 at realistic W2 and
        // makes the cover constraint fail w.h.p. (the lemmas only bound
        // the *shortfall*, which a strict scheduler cannot accept).
        ThetaConfig {
            delta: 0.25,
            gdelta: GdeltaMode::Fixed(1.0),
            attempts: 50,
            cover_fraction: 1.0,
            group_machines: true,
        }
    }
}

/// Per-slot view of the cluster the solver prices against.
pub struct SlotView<'a> {
    /// `p_h^r[t]` per machine.
    pub prices: &'a [[f64; NUM_RESOURCES]],
    /// Residual capacity `Ĉ_h[t]`.
    pub residual: &'a [ResVec],
    /// Machines allowed to host workers (OASiS separates these sets;
    /// PD-ORS allows everything everywhere).
    pub allow_worker: &'a [bool],
    /// Machines allowed to host parameter servers.
    pub allow_ps: &'a [bool],
}

/// A θ solution: total price-cost plus the integral placement.
#[derive(Debug, Clone)]
pub struct ThetaSolution {
    pub cost: f64,
    pub placements: Vec<(usize, u64, u64)>,
    /// Which case won (true = co-located / internal).
    pub internal: bool,
    /// Rounding attempts consumed (0 for the internal case).
    pub rounding_attempts: usize,
}

#[inline]
fn placement_cost(job: &Job, view: &SlotView<'_>, placements: &[(usize, u64, u64)]) -> f64 {
    let mut cost = 0.0;
    for &(h, w, s) in placements {
        for r in 0..NUM_RESOURCES {
            cost += view.prices[h][r]
                * (job.worker_demand[r] * w as f64 + job.ps_demand[r] * s as f64);
        }
    }
    cost
}

/// Internal (co-located) case: cheapest single machine hosting everything.
fn solve_internal(job: &Job, view: &SlotView<'_>, v: f64) -> Option<ThetaSolution> {
    let per_sample = speed::per_sample_time(job, Locality::Internal);
    let w = (v * per_sample).ceil().max(1.0) as u64;
    if w > job.batch {
        return None; // Eq. (4)
    }
    let s = ((w as f64 / job.gamma).ceil() as u64).max(1);
    let demand = job.demand(w, s);

    let mut best: Option<ThetaSolution> = None;
    for h in 0..view.residual.len() {
        if !view.allow_worker[h] || !view.allow_ps[h] {
            continue;
        }
        if !demand.fits_within(&view.residual[h], 1e-9) {
            continue;
        }
        let placements = vec![(h, w, s)];
        let cost = placement_cost(job, view, &placements);
        if best.as_ref().map_or(true, |b| cost < b.cost) {
            best = Some(ThetaSolution { cost, placements, internal: true, rounding_attempts: 0 });
        }
    }
    best
}

/// Key for grouping machines with identical (price, residual) signatures.
fn group_key(price: &[f64; NUM_RESOURCES], resid: &ResVec, aw: bool, ap: bool) -> [u64; 10] {
    let mut key = [0u64; 10];
    for r in 0..NUM_RESOURCES {
        key[r] = price[r].to_bits();
        key[NUM_RESOURCES + r] = resid.0[r].to_bits();
    }
    key[8] = aw as u64;
    key[9] = ap as u64;
    key
}

struct Group {
    members: Vec<usize>,
    price: [f64; NUM_RESOURCES],
    resid: ResVec,
    allow_worker: bool,
    allow_ps: bool,
}

fn build_groups(view: &SlotView<'_>, group_machines: bool) -> Vec<Group> {
    let mut groups: Vec<Group> = Vec::new();
    let mut index: std::collections::HashMap<[u64; 10], usize> =
        std::collections::HashMap::new();
    for h in 0..view.residual.len() {
        let aw = view.allow_worker[h];
        let ap = view.allow_ps[h];
        if !aw && !ap {
            continue;
        }
        if !group_machines {
            groups.push(Group {
                members: vec![h],
                price: view.prices[h],
                resid: view.residual[h],
                allow_worker: aw,
                allow_ps: ap,
            });
            continue;
        }
        let key = group_key(&view.prices[h], &view.residual[h], aw, ap);
        match index.get(&key) {
            Some(&g) => groups[g].members.push(h),
            None => {
                index.insert(key, groups.len());
                groups.push(Group {
                    members: vec![h],
                    price: view.prices[h],
                    resid: view.residual[h],
                    allow_worker: aw,
                    allow_ps: ap,
                });
            }
        }
    }
    groups
}

/// External case: grouped LP relaxation of (23)–(26) + randomized rounding.
fn solve_external(
    job: &Job,
    view: &SlotView<'_>,
    v: f64,
    cfg: &ThetaConfig,
    rng: &mut Rng,
) -> Option<ThetaSolution> {
    // Workers needed; integer-strengthened cover: w ≥ W1 ⟺ w ≥ ⌈W1⌉ for
    // integral w (tightens the relaxation so rounding can actually cover
    // tiny workloads).
    let w1 = (v * speed::per_sample_time(job, Locality::External)).ceil().max(1.0);
    if w1 > job.batch as f64 + 1e-9 {
        return None; // cover cannot meet Eq. (4) at the external rate
    }
    let groups = build_groups(view, cfg.group_machines);
    if groups.is_empty() {
        return None;
    }

    // Variables: for group g, w_g at 2g, s_g at 2g+1 (absent ones pinned 0).
    let nv = 2 * groups.len();
    let mut lp = LpProblem::new(nv);
    let mut obj = vec![0.0; nv];
    for (g, grp) in groups.iter().enumerate() {
        for r in 0..NUM_RESOURCES {
            obj[2 * g] += grp.price[r] * job.worker_demand[r];
            obj[2 * g + 1] += grp.price[r] * job.ps_demand[r];
        }
    }
    lp.set_objective(obj);
    for (g, grp) in groups.iter().enumerate() {
        let m = grp.members.len() as f64;
        // per-resource packing rows, aggregated over the group
        for r in 0..NUM_RESOURCES {
            let a = job.worker_demand[r];
            let b = job.ps_demand[r];
            if a > 0.0 || b > 0.0 {
                lp.add_row_sparse(
                    &[(2 * g, a), (2 * g + 1, b)],
                    Cmp::Le,
                    m * grp.resid.0[r],
                );
            }
        }
        if !grp.allow_worker {
            lp.add_row_sparse(&[(2 * g, 1.0)], Cmp::Le, 0.0);
        }
        if !grp.allow_ps {
            lp.add_row_sparse(&[(2 * g + 1, 1.0)], Cmp::Le, 0.0);
        }
    }
    // cover: Σ w ≥ ⌈W1⌉; packing: Σ w ≤ F; PS cover: Σ s ≥ Σ w / γ.
    let w_terms: Vec<(usize, f64)> = (0..groups.len()).map(|g| (2 * g, 1.0)).collect();
    lp.add_row_sparse(&w_terms, Cmp::Ge, w1);
    // at least one PS must exist whenever any worker runs
    let s_terms: Vec<(usize, f64)> = (0..groups.len()).map(|g| (2 * g + 1, 1.0)).collect();
    lp.add_row_sparse(&s_terms, Cmp::Ge, 1.0);
    lp.add_row_sparse(&w_terms, Cmp::Le, job.batch as f64);
    let mut ratio_terms: Vec<(usize, f64)> = Vec::with_capacity(nv);
    for g in 0..groups.len() {
        ratio_terms.push((2 * g, -1.0 / job.gamma));
        ratio_terms.push((2 * g + 1, 1.0));
    }
    lp.add_row_sparse(&ratio_terms, Cmp::Ge, 0.0);

    let sol = solve(&lp).optimal()?.clone();

    // Disaggregate the group solution evenly over members.
    let num_machines = view.residual.len();
    let mut frac_w = vec![0.0; num_machines];
    let mut frac_s = vec![0.0; num_machines];
    for (g, grp) in groups.iter().enumerate() {
        let m = grp.members.len() as f64;
        for &h in &grp.members {
            frac_w[h] = sol.x[2 * g] / m;
            frac_s[h] = sol.x[2 * g + 1] / m;
        }
    }

    // G_δ per the configured mode.
    let g_delta = match cfg.gdelta {
        GdeltaMode::Fixed(g) => g,
        GdeltaMode::Packing => {
            // W2 = min over binding packing rows of (bound / coefficient)
            let mut w2 = job.batch as f64;
            for grp in &groups {
                for r in 0..NUM_RESOURCES {
                    if job.worker_demand[r] > 0.0 {
                        w2 = w2.min(grp.resid.0[r] / job.worker_demand[r]);
                    }
                    if job.ps_demand[r] > 0.0 {
                        w2 = w2.min(grp.resid.0[r] / job.ps_demand[r]);
                    }
                }
            }
            gdelta_packing(cfg.delta, w2.max(1.0), NUM_RESOURCES * num_machines + 1)
        }
        GdeltaMode::Cover => gdelta_cover(cfg.delta, w1.max(1.0), 1),
    };

    // Hopelessness cutoffs (Chernoff, the same machinery as Lemmas 1/2):
    // if the scaled fractional solution cannot plausibly round into a
    // feasible integer point, skip the attempt loop instead of burning the
    // full S budget. A case is "hopeless" when the shortfall/overshoot
    // exceeds 6σ of the rounding distribution (P < 1e-9 ≪ 1/S).
    {
        let mut mean_w = 0.0;
        let mut var_w = 0.0;
        for h in 0..num_machines {
            let x = g_delta * frac_w[h];
            mean_w += x;
            let fr = x - x.floor();
            var_w += fr * (1.0 - fr);
        }
        let need = cfg.cover_fraction.min(1.0) * w1;
        if mean_w + 6.0 * var_w.sqrt() + 1e-9 < need {
            return None; // cover unreachable
        }
        // packing: the floor component alone already violates a machine
        for h in 0..num_machines {
            let wf = (g_delta * frac_w[h]).floor() as u64;
            let sf = (g_delta * frac_s[h]).floor() as u64;
            if (wf > 0 || sf > 0)
                && !job.demand(wf, sf).fits_within(&view.residual[h], 1e-9)
            {
                return None; // every rounding ≥ floor ⇒ always infeasible
            }
        }
    }

    // Randomized rounding, up to S attempts; keep the cheapest feasible.
    // Early-stop at the first feasible candidate: costs across roundings
    // of the same fractional point differ by O(1) units, while at extreme
    // G_δ the success probability per attempt is tiny and the paper's
    // S = 5000 budget exists precisely to brute-force that tail.
    const EARLY_STOP_FEASIBLE: usize = 1;
    let mut feasible_found = 0usize;
    let mut best: Option<ThetaSolution> = None;
    let mut attempts_used = 0;
    for attempt in 1..=cfg.attempts.max(1) {
        attempts_used = attempt;
        let mut placements: Vec<(usize, u64, u64)> = Vec::new();
        let mut total_w = 0u64;
        let mut total_s = 0u64;
        let mut feasible = true;
        for h in 0..num_machines {
            let w = round_coord(rng, g_delta * frac_w[h]);
            let s = round_coord(rng, g_delta * frac_s[h]);
            if w == 0 && s == 0 {
                continue;
            }
            // packing (24): per-machine residual capacity
            if !job.demand(w, s).fits_within(&view.residual[h], 1e-9) {
                feasible = false;
                break;
            }
            total_w += w;
            total_s += s;
            placements.push((h, w, s));
        }
        if !feasible {
            continue;
        }
        // packing (25) and cover (26)
        if total_w > job.batch {
            continue;
        }
        if (total_w as f64) < cfg.cover_fraction.min(1.0) * w1 - 1e-9 {
            continue;
        }
        // Eq. (2): enough PSs for the ratio (at least one PS overall).
        let s_needed = ((total_w as f64 / job.gamma).ceil() as u64).max(1);
        if total_s < s_needed {
            continue;
        }
        let cost = placement_cost(job, view, &placements);
        if best.as_ref().map_or(true, |b| cost < b.cost) {
            best = Some(ThetaSolution {
                cost,
                placements,
                internal: false,
                rounding_attempts: attempt,
            });
        }
        feasible_found += 1;
        if feasible_found >= EARLY_STOP_FEASIBLE {
            break;
        }
    }
    best.map(|mut b| {
        b.rounding_attempts = attempts_used;
        b
    })
}

/// Solve θ(t, v) (Algorithm 4): cheapest placement training `v` samples in
/// this slot, comparing the internal and external cases.
pub fn solve_theta(
    job: &Job,
    view: &SlotView<'_>,
    v: f64,
    cfg: &ThetaConfig,
    rng: &mut Rng,
) -> Option<ThetaSolution> {
    if v <= 0.0 {
        return Some(ThetaSolution {
            cost: 0.0,
            placements: Vec::new(),
            internal: true,
            rounding_attempts: 0,
        });
    }
    let internal = solve_internal(job, view, v);
    let external = solve_external(job, view, v, cfg, rng);
    match (internal, external) {
        (Some(a), Some(b)) => Some(if a.cost <= b.cost { a } else { b }),
        (Some(a), None) => Some(a),
        (None, Some(b)) => Some(b),
        (None, None) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::test_support::test_job;

    fn flat_view(
        n: usize,
        price: f64,
        cap: f64,
    ) -> (Vec<[f64; NUM_RESOURCES]>, Vec<ResVec>, Vec<bool>, Vec<bool>) {
        (
            vec![[price; NUM_RESOURCES]; n],
            vec![ResVec::new([cap; NUM_RESOURCES]); n],
            vec![true; n],
            vec![true; n],
        )
    }

    fn view<'a>(
        p: &'a [[f64; NUM_RESOURCES]],
        r: &'a [ResVec],
        aw: &'a [bool],
        ap: &'a [bool],
    ) -> SlotView<'a> {
        SlotView { prices: p, residual: r, allow_worker: aw, allow_ps: ap }
    }

    #[test]
    fn zero_workload_is_free() {
        let job = test_job(0);
        let (p, r, aw, ap) = flat_view(3, 1.0, 100.0);
        let mut rng = Rng::new(0);
        let sol = solve_theta(&job, &view(&p, &r, &aw, &ap), 0.0, &ThetaConfig::default(), &mut rng)
            .unwrap();
        assert_eq!(sol.cost, 0.0);
        assert!(sol.placements.is_empty());
    }

    #[test]
    fn small_workload_prefers_internal() {
        let job = test_job(0);
        let (p, r, aw, ap) = flat_view(3, 1.0, 100.0);
        let mut rng = Rng::new(0);
        // a workload fitting comfortably on one machine
        let sol = solve_theta(&job, &view(&p, &r, &aw, &ap), 100.0, &ThetaConfig::default(), &mut rng)
            .unwrap();
        assert!(sol.internal, "co-location should win on uniform prices");
        assert_eq!(sol.placements.len(), 1);
        let (_, w, s) = sol.placements[0];
        assert!(w >= 1 && s >= 1);
        assert!(w <= job.batch);
    }

    #[test]
    fn trains_enough_samples() {
        let job = test_job(0);
        let (p, r, aw, ap) = flat_view(4, 0.5, 200.0);
        let mut rng = Rng::new(1);
        let v = 400.0;
        let sol = solve_theta(&job, &view(&p, &r, &aw, &ap), v, &ThetaConfig::default(), &mut rng)
            .unwrap();
        let trained = speed::samples_in_slot(&job, &sol.placements);
        assert!(trained >= v - 1e-6, "trained {trained} of {v}");
    }

    #[test]
    fn respects_capacity() {
        let job = test_job(0);
        // capacity so tight only a couple of workers fit anywhere
        let (p, r, aw, ap) = flat_view(2, 1.0, 6.0);
        let mut rng = Rng::new(2);
        let cfg = ThetaConfig::default();
        for v in [10.0, 100.0, 1000.0] {
            if let Some(sol) = solve_theta(&job, &view(&p, &r, &aw, &ap), v, &cfg, &mut rng) {
                for &(h, w, s) in &sol.placements {
                    assert!(job.demand(w, s).fits_within(&r[h], 1e-9));
                }
            }
        }
    }

    #[test]
    fn infeasible_when_cluster_too_small() {
        let job = test_job(0);
        let (p, r, aw, ap) = flat_view(1, 1.0, 3.9); // < 1 worker + 1 ps
        let mut rng = Rng::new(3);
        let sol = solve_theta(&job, &view(&p, &r, &aw, &ap), 50.0, &ThetaConfig::default(), &mut rng);
        assert!(sol.is_none());
    }

    #[test]
    fn separated_masks_force_external() {
        let job = test_job(0);
        let (p, r, _, _) = flat_view(4, 1.0, 100.0);
        // machines 0–1 host only PSs, 2–3 only workers (OASiS style)
        let aw = vec![false, false, true, true];
        let ap = vec![true, true, false, false];
        let mut rng = Rng::new(4);
        let sol = solve_theta(&job, &view(&p, &r, &aw, &ap), 100.0, &ThetaConfig::default(), &mut rng)
            .expect("external case should be feasible");
        assert!(!sol.internal);
        for &(h, w, s) in &sol.placements {
            if w > 0 {
                assert!(aw[h], "worker on non-worker machine {h}");
            }
            if s > 0 {
                assert!(ap[h], "ps on non-ps machine {h}");
            }
        }
    }

    #[test]
    fn cheaper_machine_wins_internal() {
        let job = test_job(0);
        let mut p = vec![[2.0; NUM_RESOURCES]; 3];
        p[1] = [0.5; NUM_RESOURCES];
        let r = vec![ResVec::new([100.0; NUM_RESOURCES]); 3];
        let aw = vec![true; 3];
        let ap = vec![true; 3];
        let mut rng = Rng::new(5);
        let sol = solve_theta(&job, &view(&p, &r, &aw, &ap), 50.0, &ThetaConfig::default(), &mut rng)
            .unwrap();
        assert!(sol.internal);
        assert_eq!(sol.placements[0].0, 1, "should pick the cheap machine");
    }

    #[test]
    fn grouping_matches_ungrouped_cost() {
        // The grouped LP is a reformulation, not an approximation: on a
        // homogeneous cluster the achieved cost must match the per-machine
        // formulation up to rounding noise.
        let job = test_job(0);
        let (p, r, aw, ap) = flat_view(16, 1.0, 60.0);
        let grouped = ThetaConfig { group_machines: true, ..Default::default() };
        let ungrouped = ThetaConfig { group_machines: false, ..Default::default() };
        for v in [50.0, 400.0, 1500.0] {
            let mut r1 = Rng::new(9);
            let mut r2 = Rng::new(9);
            let a = solve_theta(&job, &view(&p, &r, &aw, &ap), v, &grouped, &mut r1);
            let b = solve_theta(&job, &view(&p, &r, &aw, &ap), v, &ungrouped, &mut r2);
            match (a, b) {
                (Some(a), Some(b)) => {
                    let tol = 0.25 * a.cost.max(b.cost) + 1e-9;
                    assert!(
                        (a.cost - b.cost).abs() <= tol,
                        "v={v}: grouped {} vs ungrouped {}",
                        a.cost,
                        b.cost
                    );
                }
                (a, b) => panic!("feasibility mismatch at v={v}: {a:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn worker_cap_blocks_oversized_slots() {
        let mut job = test_job(0);
        job.batch = 4; // at most 4 workers
        let (p, r, aw, ap) = flat_view(8, 1.0, 1e6);
        let mut rng = Rng::new(6);
        // v so large that > 4 workers would be needed even internally
        let per = speed::per_sample_time(&job, Locality::Internal);
        let v = 6.0 / per;
        let sol = solve_theta(&job, &view(&p, &r, &aw, &ap), v, &ThetaConfig::default(), &mut rng);
        assert!(sol.is_none());
    }
}
