//! Elastic re-planning (PR 5): revisit *deferred and not-yet-started
//! admitted* jobs at slot boundaries and re-solve their future-slot
//! allocations against the current [`AllocLedger`](crate::cluster::AllocLedger).
//!
//! The paper's Algorithm 1 commits a job's entire worker/PS schedule at
//! arrival time and never looks back. Its own related line of work —
//! OASiS (arXiv:1801.00936) and DL2 (arXiv:1909.06040) — shows that
//! elastically adjusting allocations as load changes is where online DML
//! schedulers win on churny, diurnal workloads: an early admission planned
//! against peak prices can strand capacity that a later, quieter slot
//! would serve better.
//!
//! A replan round at slot `t` (the start of the slot, before its
//! arrivals):
//!
//! 1. **Admitted, not yet started** — every tracked admission whose
//!    schedule lies entirely in `[t, horizon)` is *released* from the
//!    ledger, re-solved by the scheduler from slot `t` (PD-ORS runs the
//!    full snapshot → memo → LP → rounding pipeline on its long-lived
//!    [`PlannerScratch`](crate::sched::solver::PlannerScratch), so buffers
//!    and counters are recycled across the round), and either the new
//!    committed schedule is adopted or the old one is re-committed
//!    byte-for-byte. Either way the ledger conserves: the release/commit
//!    primitives on [`AdmissionCore`] check it.
//! 2. **Deferred, not yet started** — active-set jobs that have received
//!    no grants yet are offered a full admission (`old = None`); a
//!    returned schedule promotes the job out of the per-slot path.
//!
//! Schedulers advertise the capability through
//! [`Scheduler::replan_capable`]; for everything else the pass is a
//! strict no-op — no RNG draws, no events, no ledger traffic — which is
//! what makes `replan = none` byte-identical to the pre-replan system
//! (`rust/tests/replan_parity.rs` enforces it).

use crate::sim::{AdmissionCore, PlannedFinish, Scheduler};

/// When replan rounds fire. Parsed from `--replan every:<k>` / the
/// `[scheduler] replan` config key; [`ReplanPolicy::None`] is the default
/// and keeps the whole stack on its pre-replan byte-identical path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplanPolicy {
    /// Never re-plan (the paper's fire-and-forget commitment).
    None,
    /// Run a replan round at the start of every k-th slot (t > 0,
    /// t % k == 0).
    Every(usize),
}

impl Default for ReplanPolicy {
    fn default() -> ReplanPolicy {
        ReplanPolicy::None
    }
}

impl ReplanPolicy {
    /// Parse `"none"` / `"off"` / `"every:<k>"` (k ≥ 1).
    pub fn parse(s: &str) -> Result<ReplanPolicy, String> {
        let s = s.trim().to_ascii_lowercase();
        if s.is_empty() || s == "none" || s == "off" {
            return Ok(ReplanPolicy::None);
        }
        if let Some(k) = s.strip_prefix("every:") {
            return match k.trim().parse::<usize>() {
                Ok(k) if k >= 1 => Ok(ReplanPolicy::Every(k)),
                _ => Err(format!("invalid replan period {k:?} (need an integer ≥ 1)")),
            };
        }
        Err(format!(
            "invalid replan policy {s:?} (expected \"none\" or \"every:<k>\")"
        ))
    }

    pub fn is_enabled(&self) -> bool {
        !matches!(self, ReplanPolicy::None)
    }

    /// Does a replan round run at the start of slot `t`? Slot 0 never
    /// replans — nothing has been committed yet.
    pub fn fires_at(&self, t: usize) -> bool {
        match *self {
            ReplanPolicy::None => false,
            ReplanPolicy::Every(k) => t > 0 && t % k == 0,
        }
    }

    /// Human-readable form (`"none"` / `"every:4"`), reparsed by
    /// [`ReplanPolicy::parse`].
    pub fn label(&self) -> String {
        match *self {
            ReplanPolicy::None => "none".to_string(),
            ReplanPolicy::Every(k) => format!("every:{k}"),
        }
    }

    /// Stable scenario-key token; `None` for the default policy so every
    /// pre-existing sweep-store key is unchanged.
    pub fn key_token(&self) -> Option<String> {
        match *self {
            ReplanPolicy::None => None,
            ReplanPolicy::Every(k) => Some(format!("re{k}")),
        }
    }
}

/// One adopted plan change (jobs revisited but kept on their old plan do
/// not produce a record).
#[derive(Debug, Clone, Copy)]
pub struct ReplanRecord {
    pub job_id: usize,
    /// True when a deferred job was promoted to a full admission.
    pub promoted: bool,
    pub old_completion: Option<usize>,
    pub new_completion: Option<usize>,
    /// Planned completion credit before/after (`None` = the schedule does
    /// not cover the workload, so it earns nothing unless finished).
    pub old_finish: Option<PlannedFinish>,
    pub new_finish: Option<PlannedFinish>,
    pub old_utility: f64,
    pub new_utility: f64,
}

/// Outcome of one replan round.
#[derive(Debug, Clone, Default)]
pub struct ReplanReport {
    /// The slot the round ran at.
    pub slot: usize,
    /// Jobs revisited (released and re-solved, or offered promotion).
    pub revisited: usize,
    /// Adopted plan changes, in revisit order.
    pub records: Vec<ReplanRecord>,
}

impl ReplanReport {
    /// Jobs whose plan actually changed.
    pub fn replanned(&self) -> usize {
        self.records.len()
    }

    /// Total planned-utility movement of this round.
    pub fn utility_delta(&self) -> f64 {
        self.records.iter().map(|r| r.new_utility - r.old_utility).sum()
    }
}

/// Run one replan round at slot `t` over `core`'s tracked admissions and
/// unstarted deferred jobs (see module docs). A strict no-op — no RNG
/// draws, no events, no ledger traffic — unless the scheduler is
/// [`replan_capable`](Scheduler::replan_capable) and the core tracks
/// admissions.
pub fn run_replan_pass(
    core: &mut AdmissionCore,
    sched: &mut dyn Scheduler,
    t: usize,
) -> ReplanReport {
    let mut report = ReplanReport { slot: t, ..ReplanReport::default() };
    if !sched.replan_capable() || !core.replan_tracking() {
        return report;
    }
    let _span = crate::obs::span(crate::obs::Stage::ReplanPass);
    // Jobs whose schedule has begun can no longer move; forget them.
    // (Under churn tracking the prune is a no-op — started admissions stay
    // visible for the migration pass — so the loop below skips them.)
    core.prune_started_admissions(t);

    // 1. Admitted, not yet started: release → re-solve → adopt or restore.
    let mut i = 0;
    while i < core.tracked_admissions().len() {
        if core.tracked_admissions()[i].started_before(t) {
            i += 1;
            continue;
        }
        let entry = core.release_tracked(i);
        report.revisited += 1;
        let job_id = entry.job.id;
        let old_completion = entry.schedule.completion_time();
        let old_finish = entry.finish;
        let old_utility = old_finish.map_or(0.0, |f| f.utility);
        match sched.replan_job(&entry.job, Some(&entry.schedule), t, core.ledger_mut()) {
            Some(new_schedule) => {
                let changed = new_schedule != entry.schedule;
                let new_completion = new_schedule.completion_time();
                let new_finish = core.adopt_replanned(i, entry.job, new_schedule);
                if changed {
                    report.records.push(ReplanRecord {
                        job_id,
                        promoted: false,
                        old_completion,
                        new_completion,
                        old_finish,
                        new_finish,
                        old_utility,
                        new_utility: new_finish.map_or(0.0, |f| f.utility),
                    });
                }
            }
            None => core.recommit_tracked(i, entry),
        }
        i += 1;
    }

    // 2. Deferred, not yet started: offer a full admission.
    let mut d = 0;
    while d < core.active().len() {
        let unstarted = {
            let aj = &core.active()[d];
            (aj.remaining - aj.job.total_workload()).abs() <= 1e-9
        };
        if !unstarted {
            d += 1;
            continue;
        }
        let job = core.active()[d].job.clone();
        report.revisited += 1;
        match sched.replan_job(&job, None, t, core.ledger_mut()) {
            Some(schedule) => {
                let new_completion = schedule.completion_time();
                let new_finish = core.promote_deferred(d, schedule);
                report.records.push(ReplanRecord {
                    job_id: job.id,
                    promoted: true,
                    old_completion: None,
                    new_completion,
                    old_finish: None,
                    new_finish,
                    old_utility: 0.0,
                    new_utility: new_finish.map_or(0.0, |f| f.utility),
                });
                // the promoted job left the active set; `d` now points at
                // the next candidate
            }
            None => d += 1,
        }
    }
    report
}

/// One interrupted admission's fate under the churn migration pass.
#[derive(Debug, Clone, Copy)]
pub struct MigrationRecord {
    pub job_id: usize,
    /// True when no feasible migration existed and the job was dropped.
    pub evicted: bool,
    pub old_completion: Option<usize>,
    pub new_completion: Option<usize>,
    /// Completion credit before/after the interruption (`None` = the
    /// schedule did not cover the workload).
    pub old_finish: Option<PlannedFinish>,
    pub new_finish: Option<PlannedFinish>,
}

/// Outcome of one churn migration pass.
#[derive(Debug, Clone, Default)]
pub struct MigrationReport {
    /// The slot the pass ran at.
    pub slot: usize,
    /// Tracked admissions interrupted (stranded work on a down machine).
    pub interrupted: usize,
    /// Fates, in interrupt order. Admissions that had already completed
    /// before `slot` (only straggler PS-only slots were released) produce
    /// no record — their credit stands.
    pub records: Vec<MigrationRecord>,
}

impl MigrationReport {
    pub fn migrated(&self) -> usize {
        self.records.iter().filter(|r| !r.evicted).count()
    }

    pub fn evicted(&self) -> usize {
        self.records.iter().filter(|r| r.evicted).count()
    }
}

/// Interrupt and re-solve every tracked admission stranded on a machine
/// that went *Down* at slot `t` (see [`crate::chaos`]). `down` is the
/// hard-failure list for this slot — drained machines keep their
/// committed work and never appear here. For each stranded admission the
/// future (≥ `t`) slots are released, the scheduler is asked to
/// [`migrate_job`](Scheduler::migrate_job) the residual workload, and the
/// job is either re-tracked under its merged prefix+tail schedule or
/// evicted. A strict no-op — no RNG draws, no ledger traffic — while
/// `down` is empty or the core is not churn-tracking.
pub fn run_migration_pass(
    core: &mut AdmissionCore,
    sched: &mut dyn Scheduler,
    t: usize,
    down: &[usize],
) -> MigrationReport {
    let mut report = MigrationReport { slot: t, ..MigrationReport::default() };
    if down.is_empty() || !core.churn_tracking() {
        return report;
    }
    let _span = crate::obs::span(crate::obs::Stage::MigrationPass);
    let mut i = 0;
    while i < core.tracked_admissions().len() {
        if !core.tracked_admissions()[i].strands_on(down, t) {
            i += 1;
            continue;
        }
        let old_completion = core.tracked_admissions()[i].schedule.completion_time();
        let intr = core.interrupt_tracked(i, t);
        report.interrupted += 1;
        let job_id = intr.job.id;
        let old_finish = intr.old_finish;
        if old_finish.is_some_and(|f| f.slot < t) {
            // Already completed and credited before the failure; the
            // released future slots were PS-only stragglers. Retire the
            // entry silently — the credit stands.
            continue;
        }
        let residual = intr.residual_job();
        match sched.migrate_job(&residual, t, core.ledger_mut()) {
            Some(tail) => {
                let new_finish = core.commit_migrated(i, intr, tail);
                let new_completion =
                    core.tracked_admissions()[i].schedule.completion_time();
                report.records.push(MigrationRecord {
                    job_id,
                    evicted: false,
                    old_completion,
                    new_completion,
                    old_finish,
                    new_finish,
                });
                i += 1;
            }
            None => {
                // Evicted: the already-run prefix stays committed (that
                // history is real resource-time) but the job earns nothing.
                report.records.push(MigrationRecord {
                    job_id,
                    evicted: true,
                    old_completion,
                    new_completion: None,
                    old_finish,
                    new_finish: None,
                });
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{AllocLedger, Cluster, ResVec};
    use crate::jobs::test_support::test_job;
    use crate::jobs::{Job, Schedule, SlotPlacement};
    use crate::sim::{ActiveJob, ArrivalDecision, SlotGrant};

    /// Toy replan-capable scheduler: admits every arrival with a one-slot
    /// plan at `arrival + lag`, and on replan moves it to slot `t`
    /// (earlier = higher utility under a non-increasing sigmoid).
    struct Shifter {
        lag: usize,
        /// When set, promote deferred jobs on replan instead of admitting
        /// at arrival.
        defer_first: bool,
        capable: bool,
    }

    impl Shifter {
        fn plan(job: &Job, t: usize) -> Schedule {
            Schedule {
                job_id: job.id,
                slots: vec![SlotPlacement { t, placements: vec![(0, 2, 1)] }],
            }
        }
    }

    impl Scheduler for Shifter {
        fn name(&self) -> String {
            "shifter".into()
        }

        fn on_arrival(&mut self, job: &Job, ledger: &mut AllocLedger) -> ArrivalDecision {
            if self.defer_first {
                return ArrivalDecision::Defer;
            }
            let s = Shifter::plan(job, (job.arrival + self.lag).min(ledger.horizon() - 1));
            ledger.commit(job, &s);
            ArrivalDecision::Admit(s)
        }

        fn on_slot(
            &mut self,
            _t: usize,
            _active: &[ActiveJob],
            _ledger: &AllocLedger,
        ) -> Vec<SlotGrant> {
            Vec::new()
        }

        fn replan_capable(&self) -> bool {
            self.capable
        }

        fn replan_job(
            &mut self,
            job: &Job,
            _old: Option<&Schedule>,
            t: usize,
            ledger: &mut AllocLedger,
        ) -> Option<Schedule> {
            let s = Shifter::plan(job, t);
            ledger.commit(job, &s);
            Some(s)
        }
    }

    fn small_cluster() -> Cluster {
        Cluster::homogeneous(2, ResVec::new([16.0, 32.0, 64.0, 32.0]))
    }

    fn small_job(id: usize, arrival: usize) -> Job {
        let mut j = test_job(id);
        j.arrival = arrival;
        j.epochs = 1;
        j.samples = 100.0; // one slot of 2 workers covers it
        j
    }

    #[test]
    fn policy_parsing_and_firing() {
        assert_eq!(ReplanPolicy::parse("none").unwrap(), ReplanPolicy::None);
        assert_eq!(ReplanPolicy::parse("off").unwrap(), ReplanPolicy::None);
        assert_eq!(ReplanPolicy::parse("").unwrap(), ReplanPolicy::None);
        assert_eq!(
            ReplanPolicy::parse("every:4").unwrap(),
            ReplanPolicy::Every(4)
        );
        assert_eq!(
            ReplanPolicy::parse(" EVERY:2 ").unwrap(),
            ReplanPolicy::Every(2)
        );
        assert!(ReplanPolicy::parse("every:0").is_err());
        assert!(ReplanPolicy::parse("hourly").is_err());

        let p = ReplanPolicy::Every(3);
        assert!(!p.fires_at(0), "slot 0 never replans");
        assert!(p.fires_at(3));
        assert!(!p.fires_at(4));
        assert!(p.fires_at(6));
        assert!(!ReplanPolicy::None.fires_at(4));

        assert_eq!(ReplanPolicy::None.key_token(), None);
        assert_eq!(p.key_token().unwrap(), "re3");
        assert_eq!(ReplanPolicy::parse(&p.label()).unwrap(), p);
        assert_eq!(
            ReplanPolicy::parse(&ReplanPolicy::None.label()).unwrap(),
            ReplanPolicy::None
        );
    }

    #[test]
    fn pass_is_a_noop_for_incapable_schedulers() {
        let cluster = small_cluster();
        let mut core = AdmissionCore::new(&cluster, 10);
        core.set_replan_tracking(true);
        let mut sched = Shifter { lag: 5, defer_first: false, capable: false };
        core.submit(&mut sched, &small_job(0, 0));
        let before = core.ledger().total_used();
        let report = run_replan_pass(&mut core, &mut sched, 2);
        assert_eq!(report.revisited, 0);
        assert_eq!(report.replanned(), 0);
        assert_eq!(core.ledger().total_used(), before, "ledger untouched");
    }

    #[test]
    fn admitted_job_moves_and_ledger_conserves() {
        let cluster = small_cluster();
        let mut core = AdmissionCore::new(&cluster, 10);
        core.set_replan_tracking(true);
        let mut sched = Shifter { lag: 7, defer_first: false, capable: true };
        let job = small_job(0, 0);
        core.submit(&mut sched, &job);
        assert_eq!(core.tracked_admissions().len(), 1);
        let before = core.ledger().total_used();

        let report = run_replan_pass(&mut core, &mut sched, 3);
        assert_eq!(report.revisited, 1);
        assert_eq!(report.replanned(), 1);
        let r = &report.records[0];
        assert_eq!(r.job_id, 0);
        assert!(!r.promoted);
        assert_eq!(r.old_completion, Some(7));
        assert_eq!(r.new_completion, Some(3));
        assert!(
            r.new_utility >= r.old_utility,
            "earlier completion cannot lose utility"
        );
        assert!(report.utility_delta() >= 0.0);
        // same placement shape on a different slot: total usage conserved
        assert!((core.ledger().total_used() - before).abs() < 1e-9);
        assert!(core.ledger().within_capacity(1e-9));
        assert_eq!(core.tracked_admissions()[0].schedule.slots[0].t, 3);
    }

    #[test]
    fn started_jobs_are_pruned_not_replanned() {
        let cluster = small_cluster();
        let mut core = AdmissionCore::new(&cluster, 10);
        core.set_replan_tracking(true);
        let mut sched = Shifter { lag: 1, defer_first: false, capable: true };
        core.submit(&mut sched, &small_job(0, 0)); // runs at slot 1
        let report = run_replan_pass(&mut core, &mut sched, 4);
        assert_eq!(report.revisited, 0, "a started schedule is immovable");
        assert!(core.tracked_admissions().is_empty(), "pruned");
    }

    #[test]
    fn deferred_unstarted_job_is_promoted() {
        let cluster = small_cluster();
        let mut core = AdmissionCore::new(&cluster, 10);
        core.set_replan_tracking(true);
        let mut sched = Shifter { lag: 0, defer_first: true, capable: true };
        core.submit(&mut sched, &small_job(3, 0));
        assert_eq!(core.active().len(), 1);

        let report = run_replan_pass(&mut core, &mut sched, 2);
        assert_eq!(report.replanned(), 1);
        let r = &report.records[0];
        assert!(r.promoted);
        assert_eq!(r.job_id, 3);
        assert_eq!(r.old_completion, None);
        assert_eq!(r.new_completion, Some(2));
        assert!(r.new_finish.is_some(), "the toy plan covers the workload");
        assert!(core.active().is_empty(), "promoted out of the active set");
        assert_eq!(core.tracked_admissions().len(), 1);
        assert!(core.ledger().within_capacity(1e-9));
    }

    #[test]
    fn keep_decision_restores_the_ledger() {
        /// Capable scheduler that always declines to re-plan.
        struct Keeper;
        impl Scheduler for Keeper {
            fn name(&self) -> String {
                "keeper".into()
            }
            fn on_arrival(&mut self, job: &Job, ledger: &mut AllocLedger) -> ArrivalDecision {
                let s = Shifter::plan(job, job.arrival + 5);
                ledger.commit(job, &s);
                ArrivalDecision::Admit(s)
            }
            fn replan_capable(&self) -> bool {
                true
            }
            fn replan_job(
                &mut self,
                _job: &Job,
                _old: Option<&Schedule>,
                _t: usize,
                _ledger: &mut AllocLedger,
            ) -> Option<Schedule> {
                None
            }
        }
        let cluster = small_cluster();
        let mut core = AdmissionCore::new(&cluster, 10);
        core.set_replan_tracking(true);
        let mut sched = Keeper;
        core.submit(&mut sched, &small_job(0, 0));
        let before: Vec<Vec<_>> = (0..10)
            .map(|t| (0..2).map(|h| *core.ledger().used(t, h)).collect())
            .collect();
        let report = run_replan_pass(&mut core, &mut sched, 2);
        assert_eq!(report.revisited, 1);
        assert_eq!(report.replanned(), 0, "keeping the plan is not a change");
        for (t, row) in before.iter().enumerate() {
            for (h, used) in row.iter().enumerate() {
                assert_eq!(core.ledger().used(t, h), used, "slot {t} machine {h}");
            }
        }
        assert_eq!(core.tracked_admissions().len(), 1, "still tracked");
    }
}
