//! Scheduler registry: name → constructor.
//!
//! Every scheduling policy is one [`SchedulerRegistry`] entry. The CLI
//! (`schedule --scheduler <name>`, `compare`, `experiment`), the figure
//! drivers, and the examples all resolve schedulers by name here instead
//! of matching on an enum — adding a policy is a single
//! [`SchedulerRegistry::register`] call (or an entry in
//! [`SchedulerRegistry::builtin`] for in-tree ones).
//!
//! [`SchedulerSpec`] is the typed construction parameter block. It can be
//! parsed from a `[scheduler]` config section
//! ([`SchedulerSpec::from_config`]); PD-ORS knobs default to
//! [`PdOrsConfig::default`].

use crate::baselines::{Dorm, Drf, Fifo};
use crate::cluster::Cluster;
use crate::config::Config;
use crate::err;
use crate::jobs::Job;
use crate::sim::{simulate, Scheduler, SimResult};
use crate::util::error::{Error, Result};

use super::replan::ReplanPolicy;
use super::solver::GdeltaMode;
use super::{PdOrs, PdOrsConfig, Placement};

/// The built-in zoo of §5, in the paper's comparison order (registry
/// keys; resolve display labels via [`SchedulerRegistry::display`]).
pub const ZOO: [&str; 5] = ["pd-ors", "oasis", "fifo", "drf", "dorm"];

/// Typed construction parameters for one scheduler instance.
#[derive(Debug, Clone)]
pub struct SchedulerSpec {
    /// Registry key (lower-case, e.g. `"pd-ors"`).
    pub name: String,
    /// Seed for randomized policies (PD-ORS rounding, FIFO worker draws).
    pub seed: u64,
    /// Elastic re-planning cadence (`--replan every:<k>` / the
    /// `[scheduler] replan` config key). The engine and the service read
    /// it from here; replan-incapable policies silently no-op.
    pub replan: ReplanPolicy,
    /// Knobs for the primal-dual schedulers (PD-ORS / OASiS); ignored by
    /// policies that take no parameters.
    pub pdors: PdOrsConfig,
}

impl SchedulerSpec {
    pub fn new(name: &str) -> SchedulerSpec {
        SchedulerSpec {
            name: normalize(name),
            seed: 0,
            replan: ReplanPolicy::None,
            pdors: PdOrsConfig::default(),
        }
    }

    /// Set the seed (mirrored into the PD-ORS config).
    pub fn with_seed(mut self, seed: u64) -> SchedulerSpec {
        self.seed = seed;
        self.pdors.seed = seed;
        self
    }

    /// Set the replan cadence.
    pub fn with_replan(mut self, replan: ReplanPolicy) -> SchedulerSpec {
        self.replan = replan;
        self
    }

    /// Build a spec from a parsed config's `[scheduler]` section:
    ///
    /// ```text
    /// [scheduler]
    /// name = pd-ors
    /// seed = 7
    /// dp_units = 120
    /// delta = 0.25
    /// gdelta = 1.0        # or "packing" / "cover"
    /// attempts = 50
    /// cover_fraction = 1.0
    /// theta_cache = true  # false = the --no-theta-cache parity oracle
    /// cold_solver = false # true = the --cold-solver oracle: no
    ///                     # cross-arrival reuse (snapshots/memo/warm LP)
    /// replan = every:4    # elastic re-planning cadence; default "none"
    /// ```
    pub fn from_config(cfg: &Config) -> SchedulerSpec {
        let mut spec = SchedulerSpec::new(&cfg.get_or("scheduler.name", "pd-ors"));
        spec = spec.with_seed(cfg.u64("scheduler.seed", spec.seed));
        if let Some(v) = cfg.get("scheduler.replan") {
            match ReplanPolicy::parse(v) {
                Ok(p) => spec.replan = p,
                Err(e) => eprintln!("warning: ignoring scheduler.replan: {e}"),
            }
        }
        spec.pdors.dp_units = cfg.usize("scheduler.dp_units", spec.pdors.dp_units);
        spec.pdors.delta = cfg.f64("scheduler.delta", spec.pdors.delta);
        spec.pdors.attempts = cfg.usize("scheduler.attempts", spec.pdors.attempts);
        spec.pdors.cover_fraction =
            cfg.f64("scheduler.cover_fraction", spec.pdors.cover_fraction);
        spec.pdors.theta_cache =
            cfg.bool("scheduler.theta_cache", spec.pdors.theta_cache);
        spec.pdors.cold_solver =
            cfg.bool("scheduler.cold_solver", spec.pdors.cold_solver);
        if let Some(v) = cfg.get("scheduler.gdelta") {
            match v.to_ascii_lowercase().as_str() {
                "packing" => spec.pdors.gdelta = GdeltaMode::Packing,
                "cover" => spec.pdors.gdelta = GdeltaMode::Cover,
                other => match other.parse::<f64>() {
                    Ok(g) => spec.pdors.gdelta = GdeltaMode::Fixed(g),
                    Err(_) => eprintln!(
                        "warning: ignoring invalid scheduler.gdelta value {v:?} \
                         (expected \"packing\", \"cover\", or a number)"
                    ),
                },
            }
        }
        spec
    }
}

/// Normalize a user-supplied scheduler name to a registry key.
fn normalize(name: &str) -> String {
    name.trim().to_ascii_lowercase()
}

/// A scheduler constructor. Receives the spec plus the simulation context
/// (PD-ORS estimates its pricing constants from the job population).
pub type SchedulerCtor =
    Box<dyn Fn(&SchedulerSpec, &[Job], &Cluster, usize) -> Box<dyn Scheduler>>;

struct Entry {
    key: String,
    display: String,
    aliases: Vec<String>,
    description: String,
    ctor: SchedulerCtor,
}

/// Open name → constructor mapping (see module docs).
pub struct SchedulerRegistry {
    entries: Vec<Entry>,
}

impl SchedulerRegistry {
    /// An empty registry (for fully custom zoos).
    pub fn new() -> SchedulerRegistry {
        SchedulerRegistry { entries: Vec::new() }
    }

    /// The in-tree zoo: PD-ORS, OASiS, FIFO, DRF, Dorm.
    pub fn builtin() -> SchedulerRegistry {
        SchedulerRegistry::builtin_with_theta_cache(true)
    }

    /// The in-tree zoo with the θ-memoization switch forced for every
    /// primal-dual scheduler: `false` routes PD-ORS/OASiS through the
    /// parity-oracle path (what `--no-theta-cache` and the solver bench
    /// use); `true` leaves the per-spec setting in charge.
    pub fn builtin_with_theta_cache(theta_cache: bool) -> SchedulerRegistry {
        let mut reg = SchedulerRegistry::new();
        reg.register(
            "pd-ors",
            "PD-ORS",
            &["pdors"],
            "online primal-dual scheduler, co-located placement (the paper)",
            Box::new(move |spec, jobs, cluster, horizon| {
                let cfg = PdOrsConfig {
                    placement: Placement::Colocated,
                    theta_cache: spec.pdors.theta_cache && theta_cache,
                    ..spec.pdors
                };
                Box::new(PdOrs::new(cfg, jobs, cluster, horizon))
            }),
        );
        reg.register(
            "oasis",
            "OASiS",
            &[],
            "primal-dual scheduler with separated worker/PS machines [6]",
            Box::new(move |spec, jobs, cluster, horizon| {
                let cfg = PdOrsConfig {
                    placement: Placement::Separated,
                    theta_cache: spec.pdors.theta_cache && theta_cache,
                    ..spec.pdors
                };
                Box::new(PdOrs::new(cfg, jobs, cluster, horizon))
            }),
        );
        reg.register(
            "fifo",
            "FIFO",
            &[],
            "arrival order, fixed per-job worker count (Hadoop/Spark style)",
            Box::new(|spec, _jobs, _cluster, _horizon| Box::new(Fifo::new(spec.seed))),
        );
        reg.register(
            "drf",
            "DRF",
            &[],
            "dominant-resource-fairness water-filling (YARN/Mesos)",
            Box::new(|_spec, _jobs, _cluster, _horizon| Box::new(Drf::new())),
        );
        reg.register(
            "dorm",
            "Dorm",
            &[],
            "utilization maximization with fairness/adjustment constraints [36]",
            Box::new(|_spec, _jobs, _cluster, _horizon| Box::new(Dorm::new())),
        );
        reg
    }

    /// Register a policy. `key` is the canonical lower-case name,
    /// `display` the figure/table label, `aliases` extra accepted names.
    /// Re-registering an existing key replaces the earlier entry (the new
    /// entry moves to the end of the registration order), so `names()`
    /// never lists duplicates.
    pub fn register(
        &mut self,
        key: &str,
        display: &str,
        aliases: &[&str],
        description: &str,
        ctor: SchedulerCtor,
    ) {
        let key = normalize(key);
        self.entries.retain(|e| e.key != key);
        self.entries.push(Entry {
            key,
            display: display.to_string(),
            aliases: aliases.iter().map(|a| normalize(a)).collect(),
            description: description.to_string(),
            ctor,
        });
    }

    /// Resolution order: exact key match first (latest registration wins,
    /// so re-registering a key shadows the earlier entry), then aliases.
    /// A user-registered key therefore always beats a built-in alias.
    fn find(&self, name: &str) -> Option<&Entry> {
        let key = normalize(name);
        self.entries
            .iter()
            .rev()
            .find(|e| e.key == key)
            .or_else(|| {
                self.entries
                    .iter()
                    .rev()
                    .find(|e| e.aliases.iter().any(|a| *a == key))
            })
    }

    /// Registered canonical keys, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.key.as_str()).collect()
    }

    /// Display label of a registered scheduler.
    pub fn display(&self, name: &str) -> Option<&str> {
        self.find(name).map(|e| e.display.as_str())
    }

    /// One-line description of a registered scheduler.
    pub fn description(&self, name: &str) -> Option<&str> {
        self.find(name).map(|e| e.description.as_str())
    }

    /// Construct the scheduler named by `spec` for a simulation context.
    pub fn build(
        &self,
        spec: &SchedulerSpec,
        jobs: &[Job],
        cluster: &Cluster,
        horizon: usize,
    ) -> Result<Box<dyn Scheduler>> {
        match self.find(&spec.name) {
            Some(e) => Ok((e.ctor)(spec, jobs, cluster, horizon)),
            None => Err(self.unknown(&spec.name)),
        }
    }

    /// Build by name with defaults + seed (the common case).
    pub fn build_named(
        &self,
        name: &str,
        seed: u64,
        jobs: &[Job],
        cluster: &Cluster,
        horizon: usize,
    ) -> Result<Box<dyn Scheduler>> {
        self.build(&SchedulerSpec::new(name).with_seed(seed), jobs, cluster, horizon)
    }

    fn unknown(&self, name: &str) -> Error {
        err!(
            "unknown scheduler {name:?} (registered: {})",
            self.names().join(", ")
        )
    }
}

impl Default for SchedulerRegistry {
    /// Same as [`SchedulerRegistry::new`]: empty. Use
    /// [`SchedulerRegistry::builtin`] for the in-tree zoo.
    fn default() -> Self {
        SchedulerRegistry::new()
    }
}

/// Resolve `name` in the built-in registry, run it over the workload, and
/// return the aggregated result.
pub fn run_named(
    name: &str,
    jobs: &[Job],
    cluster: &Cluster,
    horizon: usize,
    seed: u64,
) -> Result<SimResult> {
    let reg = SchedulerRegistry::builtin();
    let mut s = reg.build_named(name, seed, jobs, cluster, horizon)?;
    Ok(simulate(jobs, cluster, horizon, s.as_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::AllocLedger;
    use crate::sim::ArrivalDecision;
    use crate::util::Rng;
    use crate::workload::synthetic::paper_cluster;
    use crate::workload::{synthetic_jobs, SynthConfig, MIX_DEFAULT};

    #[test]
    fn builtin_covers_the_zoo_with_display_names() {
        let reg = SchedulerRegistry::builtin();
        assert_eq!(reg.names(), ZOO.to_vec());
        assert_eq!(reg.display("pd-ors"), Some("PD-ORS"));
        assert_eq!(reg.display("PDORS"), Some("PD-ORS"), "alias + case folding");
        assert_eq!(reg.display("oasis"), Some("OASiS"));
        assert_eq!(reg.display("dorm"), Some("Dorm"));
        assert!(reg.description("drf").unwrap().contains("fairness"));
    }

    #[test]
    fn unknown_name_lists_the_registry() {
        let reg = SchedulerRegistry::builtin();
        let jobs: Vec<Job> = Vec::new();
        let cluster = paper_cluster(2);
        let e = reg
            .build(&SchedulerSpec::new("slurm"), &jobs, &cluster, 10)
            .err()
            .unwrap();
        assert!(e.to_string().contains("slurm"));
        assert!(e.to_string().contains("pd-ors"));
    }

    #[test]
    fn built_scheduler_matches_display_name() {
        let reg = SchedulerRegistry::builtin();
        let cluster = paper_cluster(4);
        let mut rng = Rng::new(1);
        let jobs = synthetic_jobs(&SynthConfig::paper(3, 10, MIX_DEFAULT), &mut rng);
        for key in ZOO {
            let s = reg.build_named(key, 0, &jobs, &cluster, 10).unwrap();
            assert_eq!(s.name(), reg.display(key).unwrap(), "{key}");
        }
    }

    #[test]
    fn custom_registration_is_resolvable() {
        struct RejectAll;
        impl Scheduler for RejectAll {
            fn name(&self) -> String {
                "reject-all".into()
            }
            fn on_arrival(
                &mut self,
                _job: &Job,
                _ledger: &mut AllocLedger,
            ) -> ArrivalDecision {
                ArrivalDecision::Reject
            }
        }
        let mut reg = SchedulerRegistry::builtin();
        reg.register(
            "reject-all",
            "RejectAll",
            &["noop"],
            "admits nothing (test)",
            Box::new(|_s, _j, _c, _h| Box::new(RejectAll)),
        );
        let cluster = paper_cluster(2);
        let mut rng = Rng::new(2);
        let jobs = synthetic_jobs(&SynthConfig::paper(4, 8, MIX_DEFAULT), &mut rng);
        let mut s = reg.build_named("noop", 0, &jobs, &cluster, 8).unwrap();
        let res = simulate(&jobs, &cluster, 8, s.as_mut());
        assert_eq!(res.admitted, 0);
        assert_eq!(res.outcomes.len(), 4);
    }

    #[test]
    fn user_key_shadows_builtin_alias() {
        struct Noop;
        impl Scheduler for Noop {
            fn name(&self) -> String {
                "Noop".into()
            }
            fn on_arrival(
                &mut self,
                _job: &Job,
                _ledger: &mut AllocLedger,
            ) -> ArrivalDecision {
                ArrivalDecision::Reject
            }
        }
        let mut reg = SchedulerRegistry::builtin();
        // "pdors" is a builtin *alias*; registering it as a *key* must win
        reg.register("pdors", "Noop", &[], "shadow test", Box::new(|_s, _j, _c, _h| Box::new(Noop)));
        assert_eq!(reg.display("pdors"), Some("Noop"));
        // the canonical builtin key is untouched
        assert_eq!(reg.display("pd-ors"), Some("PD-ORS"));
        // re-registering an existing key shadows the earlier entry
        reg.register("drf", "Noop", &[], "shadow test", Box::new(|_s, _j, _c, _h| Box::new(Noop)));
        assert_eq!(reg.display("drf"), Some("Noop"));
    }

    #[test]
    fn default_registry_is_empty_like_new() {
        assert!(SchedulerRegistry::default().names().is_empty());
    }

    #[test]
    fn spec_from_config_reads_scheduler_section() {
        let cfg = Config::parse(
            "[scheduler]\nname = OASIS\nseed = 9\ndp_units = 64\ndelta = 0.5\n\
             gdelta = 0.8\nattempts = 123\ncover_fraction = 0.9\ntheta_cache = false\n\
             cold_solver = true\n",
        )
        .unwrap();
        let spec = SchedulerSpec::from_config(&cfg);
        assert_eq!(spec.name, "oasis");
        assert_eq!(spec.replan, crate::sched::replan::ReplanPolicy::None);
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.pdors.seed, 9);
        assert_eq!(spec.pdors.dp_units, 64);
        assert_eq!(spec.pdors.delta, 0.5);
        assert_eq!(spec.pdors.attempts, 123);
        assert!(matches!(spec.pdors.gdelta, GdeltaMode::Fixed(g) if g == 0.8));
        assert_eq!(spec.pdors.cover_fraction, 0.9);
        assert!(!spec.pdors.theta_cache);
        assert!(spec.pdors.cold_solver);
    }

    #[test]
    fn spec_reads_replan_cadence() {
        use crate::sched::replan::ReplanPolicy;
        let cfg = Config::parse("[scheduler]\nreplan = every:4\n").unwrap();
        assert_eq!(
            SchedulerSpec::from_config(&cfg).replan,
            ReplanPolicy::Every(4)
        );
        // invalid values warn and keep the default
        let cfg = Config::parse("[scheduler]\nreplan = sometimes\n").unwrap();
        assert_eq!(SchedulerSpec::from_config(&cfg).replan, ReplanPolicy::None);
        let spec = SchedulerSpec::new("pd-ors").with_replan(ReplanPolicy::Every(2));
        assert_eq!(spec.replan, ReplanPolicy::Every(2));
    }

    #[test]
    fn spec_defaults_without_config_keys() {
        let cfg = Config::parse("").unwrap();
        let spec = SchedulerSpec::from_config(&cfg);
        assert_eq!(spec.name, "pd-ors");
        assert_eq!(spec.pdors.dp_units, PdOrsConfig::default().dp_units);
        assert!(spec.pdors.theta_cache, "the memo is on by default");
        assert!(!spec.pdors.cold_solver, "incremental reuse is on by default");
    }

    #[test]
    fn gdelta_modes_parse_case_insensitively() {
        let cfg = Config::parse("[scheduler]\ngdelta = Packing\n").unwrap();
        let spec = SchedulerSpec::from_config(&cfg);
        assert!(matches!(spec.pdors.gdelta, GdeltaMode::Packing));

        let cfg = Config::parse("[scheduler]\ngdelta = COVER\n").unwrap();
        let spec = SchedulerSpec::from_config(&cfg);
        assert!(matches!(spec.pdors.gdelta, GdeltaMode::Cover));

        // invalid values warn and keep the default (Fixed(1.0))
        let cfg = Config::parse("[scheduler]\ngdelta = bogus\n").unwrap();
        let spec = SchedulerSpec::from_config(&cfg);
        assert!(matches!(spec.pdors.gdelta, GdeltaMode::Fixed(g) if g == 1.0));
    }
}
