//! Algorithms 2–3: the per-job schedule search.
//!
//! The paper's DP (Eq. (21)) distributes the total workload `V_i = E_i K_i`
//! over the slots `[a_i, t̃_i]`, minimizing the price-cost Θ(t̃, V), then
//! Algorithm 2 maximizes the payoff `λ = u_i(t̃ − a_i) − Θ(t̃, V)` over t̃.
//!
//! Two deviations from the literal pseudo-code, both documented in
//! DESIGN.md:
//!
//! 1. **Workload discretization.** The paper enumerates `v ∈ [0, E_i K_i]`
//!    (up to 10^8 states). We discretize the workload into `units` equal
//!    chunks (default 40) — the per-slot θ placement rounds worker counts
//!    *up*, so any discretized plan still covers the full workload; finer
//!    grids only refine the cost. `--dp-units` scales resolution back up.
//! 2. **Single forward pass.** Computing the DP forward over t yields
//!    Θ(t̃, ·) for *every* candidate t̃ at once, instead of re-running the
//!    recursion per t̃ (the paper's Algorithm 2 loop); this is exact and
//!    saves a factor of T.
//!
//! The forward pass runs on the layered solver core: each slot's
//! [`SlotSnapshot`] lives in the planner's persistent snapshot cache
//! (refreshed from the ledger's change journal — full rebuilds only for
//! cold or invalidated slots), its signature interned, and every θ-solve
//! goes through [`solve_theta_ctx`] with the planner's
//! [`PlannerScratch`] — memoized per `(snapshot signature, job
//! signature, v)` unless the caller disabled the cache
//! (`DpConfig::theta_cache = false`, the `--no-theta-cache` parity
//! oracle). `DpConfig::cold_solver` (`--cold-solver`) additionally
//! disables every cross-arrival reuse — persistent snapshots,
//! cross-episode memo, warm-started simplex — rebuilding each episode
//! from scratch as the byte-parity oracle.

use crate::cluster::{AllocLedger, SlotSnapshot, NUM_RESOURCES};
use crate::jobs::{speed, Job, Locality, Schedule, SlotPlacement};
use crate::util::Rng;

use super::pricing::PricingParams;
use super::solver::{
    solve_theta_ctx, PlannerScratch, SolverCtx, SolverStats, ThetaConfig, ThetaSolution,
};

/// Search configuration.
#[derive(Debug, Clone, Copy)]
pub struct DpConfig {
    /// Workload discretization granularity (units per job).
    pub units: usize,
    /// Memoize θ-solutions per (snapshot signature, job signature, v)
    /// during the forward pass. `false` = the memo parity oracle: every
    /// θ-solve hits the LP.
    pub theta_cache: bool,
    /// Disable *all* cross-arrival reuse (persistent snapshots, the
    /// cross-episode memo, the warm-started simplex): every episode
    /// rebuilds from the ledger exactly like the pre-PR 8 planner — the
    /// `--cold-solver` byte-parity oracle.
    pub cold_solver: bool,
    pub theta: ThetaConfig,
}

impl Default for DpConfig {
    fn default() -> DpConfig {
        DpConfig {
            units: 120,
            theta_cache: true,
            cold_solver: false,
            theta: ThetaConfig::default(),
        }
    }
}

/// A planned schedule and its primal-dual bookkeeping.
#[derive(Debug, Clone)]
pub struct PlanResult {
    pub schedule: Schedule,
    /// Payoff λ_i = utility − price cost (RHS of (11)).
    pub payoff: f64,
    pub cost: f64,
    pub utility: f64,
    pub completion: usize,
    /// Total rounding attempts spent in accepted θ-solves (Fig. 11
    /// statistic; matches the pre-refactor bookkeeping).
    pub rounding_attempts: usize,
    /// Solver counters for this planning episode.
    pub solver: SolverStats,
    /// Winning-plan θ-solves that used the internal (co-located,
    /// closed-form) locality case. Pure derived bookkeeping for decision
    /// provenance ([`crate::obs::provenance`]) — always computed, never
    /// consulted by the planner itself.
    pub internal_slots: usize,
    /// Winning-plan θ-solves that used the external case (LP + rounding).
    pub external_slots: usize,
    /// Candidate slots the DP window covered (`start..horizon`).
    pub slots_considered: usize,
}

/// Machine-eligibility masks (PD-ORS: all true; OASiS: disjoint sets).
#[derive(Debug, Clone)]
pub struct Masks {
    pub allow_worker: Vec<bool>,
    pub allow_ps: Vec<bool>,
}

impl Masks {
    pub fn all(n: usize) -> Masks {
        Masks { allow_worker: vec![true; n], allow_ps: vec![true; n] }
    }

    /// OASiS split: the first half hosts PSs only, the second workers only.
    pub fn separated(n: usize) -> Masks {
        let half = n / 2;
        Masks {
            allow_worker: (0..n).map(|h| h >= half).collect(),
            allow_ps: (0..n).map(|h| h < half).collect(),
        }
    }
}

/// Build the per-machine price table for slot `t` from the ledger.
pub fn slot_prices(
    ledger: &AllocLedger,
    pricing: &PricingParams,
    t: usize,
) -> Vec<[f64; NUM_RESOURCES]> {
    (0..ledger.num_machines())
        .map(|h| {
            let used = ledger.used(t, h);
            let cap = ledger.capacity(h);
            let mut p = [0.0; NUM_RESOURCES];
            for r in 0..NUM_RESOURCES {
                p[r] = pricing.price(r, used.0[r], cap.0[r]);
            }
            p
        })
        .collect()
}

/// Capture slot `t` of the ledger into an immutable snapshot: prices,
/// residuals, the caller's eligibility masks, and the deduplicated
/// machine groups. Machines the churn subsystem has marked unavailable
/// at `t` are masked out of both eligibility vectors, so the solver only
/// prices live machines (and the snapshot's group signature — hence the
/// θ-memo key — reflects the outage). Without churn the masks are cloned
/// verbatim: the byte-identical no-op path.
pub fn slot_snapshot(
    ledger: &AllocLedger,
    pricing: &PricingParams,
    masks: &Masks,
    t: usize,
    group_machines: bool,
) -> SlotSnapshot {
    let _span = crate::obs::span(crate::obs::Stage::SnapshotBuild);
    let prices = slot_prices(ledger, pricing, t);
    let residual: Vec<_> =
        (0..ledger.num_machines()).map(|h| ledger.residual(t, h)).collect();
    let mut allow_worker = masks.allow_worker.clone();
    let mut allow_ps = masks.allow_ps.clone();
    if ledger.has_unavailable() {
        for h in 0..ledger.num_machines() {
            if !ledger.available(t, h) {
                allow_worker[h] = false;
                allow_ps[h] = false;
            }
        }
    }
    SlotSnapshot::new(prices, residual, allow_worker, allow_ps, group_machines)
}

/// [`plan_job_with`] over a throwaway [`PlannerScratch`] (tests, one-shot
/// callers like the offline bound). Long-lived planners (`PdOrs`) keep a
/// scratch across arrivals so buffers and memo capacity are recycled.
pub fn plan_job(
    job: &Job,
    ledger: &AllocLedger,
    pricing: &PricingParams,
    masks: &Masks,
    cfg: &DpConfig,
    rng: &mut Rng,
) -> Option<PlanResult> {
    let mut scratch = PlannerScratch::new();
    plan_job_with(job, ledger, pricing, masks, cfg, rng, &mut scratch)
}

/// Algorithms 2 + 3: find the best schedule for `job` given the current
/// ledger and prices. Returns `None` only if no feasible schedule exists
/// within the horizon (the payoff may still be ≤ 0 — admission is the
/// caller's call, per Algorithm 1 steps 3–4).
///
/// `scratch` carries the interners/memo/snapshots/workspace across
/// calls; the episode boundary is opened through
/// [`PlannerScratch::begin_episode`] — cross-arrival reuse by default,
/// a full clear under `cfg.cold_solver`. Buffers and cumulative
/// [`SolverStats`] are never cleared.
pub fn plan_job_with(
    job: &Job,
    ledger: &AllocLedger,
    pricing: &PricingParams,
    masks: &Masks,
    cfg: &DpConfig,
    rng: &mut Rng,
    scratch: &mut PlannerScratch,
) -> Option<PlanResult> {
    plan_job_from(job, job.arrival, ledger, pricing, masks, cfg, rng, scratch)
}

/// [`plan_job_with`] restricted to slots `≥ from` — the elastic replan
/// entry point: a revisited job may only move its *future* allocation,
/// while its utility stays anchored at the true arrival slot (`u_i(t̃ −
/// a_i)` with the original `a_i`; a shadow arrival would silently inflate
/// payoffs). With `from ≤ job.arrival` this is exactly `plan_job_with`.
#[allow(clippy::too_many_arguments)]
pub fn plan_job_from(
    job: &Job,
    from: usize,
    ledger: &AllocLedger,
    pricing: &PricingParams,
    masks: &Masks,
    cfg: &DpConfig,
    rng: &mut Rng,
    scratch: &mut PlannerScratch,
) -> Option<PlanResult> {
    let horizon = ledger.horizon();
    let start = job.arrival.max(from);
    if start >= horizon {
        return None;
    }
    let v_total = job.total_workload();
    let units = cfg.units.max(1);
    let unit = v_total / units as f64;

    // Cap of units trainable in one slot (internal rate is the fastest).
    let max_per_slot = speed::max_samples_per_slot(job, Locality::Internal);
    let cap_units = ((max_per_slot / unit).floor() as usize).min(units);
    if cap_units == 0 {
        return None; // even one unit cannot be trained in a slot
    }

    // Episode boundary: the single policy point (PlannerScratch docs).
    // Cold = drop all cross-arrival structure (the historical per-arrival
    // clears); incremental = GC dead signatures and sync the persistent
    // snapshot cache against the ledger's change journal.
    let cold = cfg.cold_solver;
    scratch.begin_episode(cold, ledger, masks, cfg.theta.group_machines);
    let job_sig =
        if cold || !cfg.theta_cache { 0 } else { scratch.job_sigs.intern(job) };
    let stats_before = scratch.stats;

    const INF: f64 = f64::INFINITY;
    // theta_table[t - start][dv - 1] = θ(t, dv units)
    let window = horizon - start;
    let mut theta_table: Vec<Vec<Option<ThetaSolution>>> =
        vec![vec![None; cap_units]; window];
    let mut rounding_attempts = 0usize;

    // DP forward over slots.
    let mut best_cost = vec![INF; units + 1];
    best_cost[0] = 0.0;
    // choice[ti][v] = units trained in slot (start + ti) on the best path to v.
    let mut choice: Vec<Vec<u16>> = Vec::with_capacity(window);

    let mut best: Option<(usize, f64, f64, f64)> = None; // (t̃, λ, cost, u)

    for ti in 0..window {
        let t = start + ti;
        // Cold: build a throwaway snapshot (the pre-PR 8 behavior).
        // Incremental: refresh the persistent cache (version hit / delta /
        // rebuild) and borrow the slot from it.
        let cold_snap = if cold {
            Some(slot_snapshot(ledger, pricing, masks, t, cfg.theta.group_machines))
        } else {
            scratch.refresh_slot(ledger, pricing, masks, t, cfg.theta.group_machines);
            None
        };
        let (snap, sig) = match &cold_snap {
            Some(s) => {
                let sig = if cfg.theta_cache { scratch.interner.intern(s) } else { 0 };
                (s, sig)
            }
            None => scratch.snapshots.get(t),
        };
        // θ(t, dv) for dv = 1..=cap_units
        for dv in 1..=cap_units {
            let mut ctx = SolverCtx {
                rng: &mut *rng,
                ws: &mut scratch.ws,
                memo: if cfg.theta_cache { Some(&mut scratch.memo) } else { None },
                sig,
                job_sig,
                warm_lp: !cold,
                stats: &mut scratch.stats,
            };
            let sol = solve_theta_ctx(job, snap, dv as f64 * unit, &cfg.theta, &mut ctx);
            if let Some(s) = &sol {
                rounding_attempts += s.rounding_attempts;
            }
            theta_table[ti][dv - 1] = sol;
        }
        // relax: new[v] = min(old[v], θ(t,dv) + old[v-dv])
        let mut new_cost = best_cost.clone();
        let mut slot_choice = vec![0u16; units + 1];
        for v in 1..=units {
            for dv in 1..=cap_units.min(v) {
                if let Some(th) = &theta_table[ti][dv - 1] {
                    let prev = best_cost[v - dv];
                    if prev < INF {
                        let cand = prev + th.cost;
                        if cand < new_cost[v] {
                            new_cost[v] = cand;
                            slot_choice[v] = dv as u16;
                        }
                    }
                }
            }
        }
        best_cost = new_cost;
        choice.push(slot_choice);

        // Candidate completion t̃ = t (Algorithm 2 step 2).
        if best_cost[units] < INF {
            let u = job.utility_at(t);
            let lambda = u - best_cost[units];
            if best.as_ref().map_or(true, |&(_, l, _, _)| lambda > l) {
                best = Some((ti, lambda, best_cost[units], u));
            }
        }
    }

    let solver = scratch.stats.since(&stats_before);
    let (best_ti, _lambda, cost, _u_at_t) = best?;

    // Reconstruct: walk the choice table backwards from (best_ti, units).
    // Note the DP kept per-slot choices on the best path *to that slot*;
    // because costs only relax forward, re-walking from the recorded
    // choices reproduces a valid optimal path.
    let mut slots: Vec<SlotPlacement> = Vec::new();
    let mut internal_slots = 0usize;
    let mut external_slots = 0usize;
    let mut v = units;
    let mut ti = best_ti as isize;
    while v > 0 && ti >= 0 {
        let dv = choice[ti as usize][v] as usize;
        if dv > 0 {
            let th = theta_table[ti as usize][dv - 1]
                .as_ref()
                .expect("choice points at a computed θ");
            if th.internal {
                internal_slots += 1;
            } else {
                external_slots += 1;
            }
            slots.push(SlotPlacement {
                t: start + ti as usize,
                placements: th.placements.clone(),
            });
            v -= dv;
        }
        ti -= 1;
    }
    if v > 0 {
        return None; // should not happen: the DP said units was reachable
    }
    slots.sort_by_key(|s| s.t);
    let schedule = Schedule { job_id: job.id, slots };
    let completion = schedule.completion_time().unwrap_or(start);
    // The DP's λ used u(t̃); the reconstructed path may finish earlier
    // (utility can only improve since u is non-increasing).
    let utility = job.utility_at(completion);
    let payoff = utility - cost;

    Some(PlanResult {
        schedule,
        payoff,
        cost,
        utility,
        completion,
        rounding_attempts,
        solver,
        internal_slots,
        external_slots,
        slots_considered: window,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::cluster::ResVec;
    use crate::jobs::test_support::test_job;
    use crate::workload::synthetic::paper_machine_capacity;

    fn setup(h: usize, t: usize) -> (AllocLedger, PricingParams) {
        let cluster = Cluster::homogeneous(h, paper_machine_capacity());
        let ledger = AllocLedger::new(&cluster, t);
        let jobs = vec![test_job(0)];
        let pricing = PricingParams::from_jobs(&jobs, &cluster, t);
        (ledger, pricing)
    }

    #[test]
    fn plans_cover_workload() {
        let (ledger, pricing) = setup(4, 10);
        let job = test_job(0);
        let masks = Masks::all(4);
        let mut rng = Rng::new(0);
        let plan = plan_job(&job, &ledger, &pricing, &masks, &DpConfig::default(), &mut rng)
            .expect("feasible");
        assert!(plan.schedule.covers_workload(&job, 1.0));
        assert!(plan.schedule.respects_worker_cap(&job));
        assert!(plan.schedule.respects_arrival(&job));
        assert!(plan.schedule.respects_gamma(&job));
        assert!(ledger.fits(&job, &plan.schedule, 1e-9));
        assert_eq!(plan.completion, plan.schedule.completion_time().unwrap());
        assert!((plan.utility - job.utility_at(plan.completion)).abs() < 1e-9);
        assert!((plan.payoff - (plan.utility - plan.cost)).abs() < 1e-9);
        assert!(plan.solver.theta_solves > 0, "DP must account its θ-solves");
        assert_eq!(
            plan.internal_slots + plan.external_slots,
            plan.schedule.slots.len(),
            "every winning slot carries a locality case"
        );
        assert_eq!(plan.slots_considered, 10, "arrival-0 window spans the horizon");
    }

    #[test]
    fn empty_cluster_cannot_plan() {
        let cluster = Cluster::homogeneous(2, ResVec::new([0.5, 0.5, 0.5, 0.5]));
        let ledger = AllocLedger::new(&cluster, 10);
        let job = test_job(0);
        let pricing = PricingParams::from_jobs(&[job.clone()], &cluster, 10);
        let masks = Masks::all(2);
        let mut rng = Rng::new(0);
        assert!(plan_job(&job, &ledger, &pricing, &masks, &DpConfig::default(), &mut rng).is_none());
    }

    #[test]
    fn arrival_beyond_horizon_rejected() {
        let (ledger, pricing) = setup(4, 10);
        let mut job = test_job(0);
        job.arrival = 10;
        let masks = Masks::all(4);
        let mut rng = Rng::new(0);
        assert!(plan_job(&job, &ledger, &pricing, &masks, &DpConfig::default(), &mut rng).is_none());
    }

    #[test]
    fn later_arrival_shifts_schedule() {
        let (ledger, pricing) = setup(4, 12);
        let mut job = test_job(0);
        job.arrival = 5;
        let masks = Masks::all(4);
        let mut rng = Rng::new(0);
        let plan = plan_job(&job, &ledger, &pricing, &masks, &DpConfig::default(), &mut rng)
            .expect("feasible");
        assert!(plan.schedule.slots.iter().all(|s| s.t >= 5));
    }

    #[test]
    fn more_units_refines_cost() {
        let (ledger, pricing) = setup(4, 10);
        let job = test_job(0);
        let masks = Masks::all(4);
        let mut rng1 = Rng::new(0);
        let coarse = plan_job(
            &job,
            &ledger,
            &pricing,
            &masks,
            &DpConfig { units: 8, ..Default::default() },
            &mut rng1,
        )
        .unwrap();
        let mut rng2 = Rng::new(0);
        let fine = plan_job(
            &job,
            &ledger,
            &pricing,
            &masks,
            &DpConfig { units: 64, ..Default::default() },
            &mut rng2,
        )
        .unwrap();
        // finer discretization can only help (allow small fp slack)
        assert!(fine.cost <= coarse.cost * 1.05 + 1e-9);
    }

    /// The tentpole parity contract at the DP level: with and without the
    /// θ-memo, the planned schedule, its cost, and the RNG stream are
    /// byte-identical — on an empty ledger every slot shares one
    /// signature, so the cached run must also show memo hits and fewer
    /// LP solves.
    #[test]
    fn theta_cache_is_semantically_invisible() {
        let (ledger, pricing) = setup(6, 12);
        let job = test_job(0);
        let masks = Masks::all(6);
        let cached_cfg = DpConfig::default();
        let oracle_cfg = DpConfig { theta_cache: false, ..Default::default() };

        let mut rng_a = Rng::new(3);
        let a = plan_job(&job, &ledger, &pricing, &masks, &cached_cfg, &mut rng_a)
            .expect("feasible");
        let mut rng_b = Rng::new(3);
        let b = plan_job(&job, &ledger, &pricing, &masks, &oracle_cfg, &mut rng_b)
            .expect("feasible");

        assert_eq!(a.schedule.slots, b.schedule.slots);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.payoff, b.payoff);
        assert_eq!(a.completion, b.completion);
        assert_eq!(a.rounding_attempts, b.rounding_attempts);
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG lockstep");

        assert_eq!(a.solver.theta_solves, b.solver.theta_solves);
        assert!(a.solver.memo_hits > 0, "quiet slots must hit the memo");
        assert_eq!(b.solver.memo_hits, 0, "oracle never consults a memo");
        assert!(
            a.solver.lp_solves < b.solver.lp_solves,
            "memo must absorb repeat LP solves ({} vs {})",
            a.solver.lp_solves,
            b.solver.lp_solves
        );
    }

    /// The replan entry point: planning from a later slot keeps the
    /// utility anchored at the true arrival and only uses future slots.
    #[test]
    fn plan_from_restricts_slots_and_keeps_utility_anchor() {
        let (ledger, pricing) = setup(4, 12);
        let job = test_job(0); // arrival 0
        let masks = Masks::all(4);
        let cfg = DpConfig::default();
        let mut scratch = PlannerScratch::new();

        let mut rng = Rng::new(9);
        let plan = plan_job_from(
            &job, 5, &ledger, &pricing, &masks, &cfg, &mut rng, &mut scratch,
        )
        .expect("feasible from slot 5");
        assert!(plan.schedule.slots.iter().all(|s| s.t >= 5), "past slots used");
        assert!(plan.schedule.covers_workload(&job, 1.0));
        // utility is u(t̃ − a_i) with the ORIGINAL arrival, not slot 5
        assert!((plan.utility - job.utility_at(plan.completion)).abs() < 1e-12);
        assert!(plan.completion >= 5);

        // from ≤ arrival is exactly plan_job_with (same RNG draws)
        let mut rng_a = Rng::new(4);
        let mut rng_b = Rng::new(4);
        let a = plan_job(&job, &ledger, &pricing, &masks, &cfg, &mut rng_a).unwrap();
        let b = plan_job_from(
            &job, 0, &ledger, &pricing, &masks, &cfg, &mut rng_b,
            &mut PlannerScratch::new(),
        )
        .unwrap();
        assert_eq!(a.schedule.slots, b.schedule.slots);
        assert_eq!(a.cost, b.cost);
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG lockstep");
    }

    /// A reused scratch must not leak memo state across planning episodes.
    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        let (ledger, pricing) = setup(4, 10);
        let job_a = test_job(0);
        let job_b = test_job(1);
        let masks = Masks::all(4);
        let cfg = DpConfig::default();

        let mut scratch = PlannerScratch::new();
        let mut rng1 = Rng::new(5);
        let _ = plan_job_with(&job_a, &ledger, &pricing, &masks, &cfg, &mut rng1, &mut scratch);
        let reused =
            plan_job_with(&job_b, &ledger, &pricing, &masks, &cfg, &mut rng1, &mut scratch)
                .expect("feasible");

        // fresh scratch + identical RNG history for job_b
        let mut rng2 = Rng::new(5);
        let mut warmup = PlannerScratch::new();
        let _ = plan_job_with(&job_a, &ledger, &pricing, &masks, &cfg, &mut rng2, &mut warmup);
        let fresh = plan_job(&job_b, &ledger, &pricing, &masks, &cfg, &mut rng2)
            .expect("feasible");

        assert_eq!(reused.schedule.slots, fresh.schedule.slots);
        assert_eq!(reused.cost, fresh.cost);
        // cumulative counters accumulate across both plans
        assert!(scratch.stats.theta_solves >= reused.solver.theta_solves);
    }
}
